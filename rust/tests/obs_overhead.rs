//! PR-7/PR-8 acceptance: the observability layer — spans *and* the typed
//! decision-event log — is provably inert when off and semantically
//! invisible when on.
//!
//! One test owns this file so it runs in its own process and may flip the
//! global recording toggle without racing other tests. Phase 1 (recording
//! off) runs a ThreeSieves batch workload and a full in-process service
//! conversation, asserting **zero** recorded span events, **zero**
//! decision events, all-zero wall-clock stats and all-zero decision
//! counters. Phase 2 re-runs the identical workloads with recording on
//! and asserts the selection outputs — values, summaries, per-push
//! replies, semantic stats — are bit-identical to phase 1, that the
//! per-stage wall fields and decision counters now populate, that the
//! expected span names (kernel-panel, solve-panel, sieve-scan,
//! service-request) were recorded, that the decision-event stream flows
//! (accept/reject events, NDJSON export parses back line by line, and
//! the Chrome trace carries the `events.<kind>` fold-in markers), and
//! that the trace export parses back.

use std::time::Duration;

use threesieves::algorithms::three_sieves::SieveTuning;
use threesieves::algorithms::{StreamingAlgorithm, ThreeSieves};
use threesieves::config::ServiceConfig;
use threesieves::data::{registry, Dataset};
use threesieves::functions::{LogDetConfig, NativeLogDet};
use threesieves::metrics::AlgoStats;
use threesieves::obs;
use threesieves::service::{PushBody, Request, Response, SessionManager, SessionSpec};
use threesieves::util::json::Json;

fn dataset() -> Dataset {
    registry::get("fact-highlevel-like", 600, 3).unwrap()
}

/// The standalone workload: chunked ThreeSieves over the fixed dataset.
fn run_threesieves(ds: &Dataset) -> (u64, Vec<f32>, AlgoStats) {
    let k = 8;
    let f = NativeLogDet::new(LogDetConfig::for_streaming(ds.dim(), k));
    let mut algo = ThreeSieves::new(Box::new(f), k, 0.01, SieveTuning::FixedT(200));
    for chunk in ds.raw().chunks(64 * ds.dim()) {
        algo.process_batch(chunk);
    }
    (algo.value().to_bits(), algo.summary(), algo.stats())
}

/// The service workload, driven through the instrumented `execute`
/// dispatch: OPEN, chunked PUSHes, then the per-session stats and
/// summary. Returns the deterministic reply lines (OPEN/PUSH) plus the
/// session's semantic stats and summary for cross-phase comparison.
fn run_service(ds: &Dataset) -> (Vec<String>, AlgoStats, Vec<f32>) {
    let mgr = SessionManager::new(ServiceConfig {
        idle_timeout: Duration::ZERO,
        ..ServiceConfig::default()
    });
    let spec = SessionSpec::three_sieves(ds.dim(), 6, 0.01, 100);
    let mut lines = Vec::new();
    lines.push(mgr.execute(&Request::Open { id: "obs".into(), spec }).to_line());
    for chunk in ds.raw().chunks(64 * ds.dim()) {
        let req = Request::Push { id: "obs".into(), body: PushBody::Packed(chunk.to_vec()) };
        lines.push(mgr.execute(&req).to_line());
    }
    // METRICS == Σ STATS must extend to the wall fields: one live session,
    // so the aggregate equals its stats exactly (in both phases).
    let st = mgr.stats("obs").unwrap().stats;
    let m = mgr.metrics();
    assert_eq!(m.wall_kernel_ns, st.wall_kernel_ns);
    assert_eq!(m.wall_solve_ns, st.wall_solve_ns);
    assert_eq!(m.wall_scan_ns, st.wall_scan_ns);
    let summary = mgr.summary("obs").unwrap().data;
    (lines, st, summary)
}

#[test]
fn observability_is_inert_off_and_invisible_on() {
    let ds = dataset();

    // Phase 1: recording off (the default). Nothing may reach the rings
    // and no wall-clock counter may advance.
    assert!(!obs::enabled());
    let (value_off, summary_off, stats_off) = run_threesieves(&ds);
    let (lines_off, svc_stats_off, svc_summary_off) = run_service(&ds);
    assert_eq!(obs::event_count(), 0, "tracing off must record zero span events");
    assert_eq!(obs::events::count(), 0, "events off must record zero decision events");
    assert_eq!(obs::events::totals().logged(), 0, "cumulative event totals must stay zero");
    assert_eq!(stats_off.wall_kernel_ns, 0);
    assert_eq!(stats_off.wall_solve_ns, 0);
    assert_eq!(stats_off.wall_scan_ns, 0);
    assert_eq!(svc_stats_off.wall_kernel_ns, 0);
    assert_eq!(
        stats_off.accepts + stats_off.rejects + stats_off.defers + stats_off.threshold_moves,
        0,
        "events off must leave every decision counter at zero"
    );
    assert_eq!(svc_stats_off.accepts + svc_stats_off.rejects, 0);

    // Phase 2: recording on. Identical workloads, identical outputs.
    obs::set_enabled(true);
    let (value_on, summary_on, stats_on) = run_threesieves(&ds);
    let (lines_on, svc_stats_on, svc_summary_on) = run_service(&ds);
    assert_eq!(value_on, value_off, "f(S) must be bit-identical with tracing on");
    assert_eq!(summary_on, summary_off);
    assert_eq!(stats_on, stats_off, "semantic stats must not move");
    assert_eq!(lines_on, lines_off, "wire replies must be bit-identical");
    assert_eq!(svc_stats_on, svc_stats_off);
    assert_eq!(svc_summary_on, svc_summary_off);
    // ...but the measured stage walls now populate.
    assert!(stats_on.wall_kernel_ns > 0, "kernel wall must advance while recording");
    assert!(stats_on.wall_solve_ns > 0, "solve wall must advance while recording");
    assert!(stats_on.wall_scan_ns > 0, "scan wall must advance while recording");
    // ...and so do the decision counters — without touching any field the
    // equality above compares.
    assert!(stats_on.accepts > 0, "a non-empty summary implies accept decisions");
    assert!(stats_on.rejects > 0, "a 600-element stream implies reject decisions");
    assert!(stats_on.accepts >= stats_on.stored as u64, "every stored element was accepted");
    assert!(svc_stats_on.accepts > 0 && svc_stats_on.rejects > 0);

    // The typed decision-event stream flows and its NDJSON export parses
    // back line by line.
    let totals = obs::events::totals();
    assert!(totals.accepts > 0 && totals.rejects > 0, "decision events must flow: {totals:?}");
    assert!(obs::events::count() > 0);
    let ev_path = std::env::temp_dir().join("obs_overhead_events.ndjson");
    obs::events::write_ndjson(&ev_path).expect("write events NDJSON");
    let text = std::fs::read_to_string(&ev_path).unwrap();
    let mut parsed = 0usize;
    for line in text.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("NDJSON line must parse: {e}: {line}"));
        assert!(j.get("type").as_str().is_some(), "every event carries a type: {line}");
        assert!(j.get("ts_us").as_f64().is_some(), "every event is timestamped: {line}");
        parsed += 1;
    }
    assert_eq!(parsed, obs::events::count(), "export must cover every ring-held event");
    let _ = std::fs::remove_file(&ev_path);

    // The `METRICS HIST` surface now carries the request-latency histogram.
    let mgr = SessionManager::new(ServiceConfig::default());
    match mgr.execute(&Request::MetricsHist) {
        Response::MetricsHistData(hists) => {
            let req = hists
                .iter()
                .find(|h| h.name == "service.request_ns")
                .expect("request histogram registered");
            assert!(req.count > 0);
            assert!(req.p50 <= req.p99 && req.p99 as u64 <= req.max);
        }
        other => panic!("METRICS HIST: {other:?}"),
    }

    // The trace export parses back and contains the acceptance spans.
    let path = std::env::temp_dir().join("obs_overhead_trace.json");
    obs::write_chrome_trace(&path).expect("write trace");
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).expect("valid trace JSON");
    let names: Vec<&str> = doc
        .get("traceEvents")
        .as_arr()
        .expect("traceEvents array")
        .iter()
        .filter_map(|e| e.get("name").as_str())
        .collect();
    for want in ["kernel-panel", "solve-panel", "sieve-scan", "service-request"] {
        assert!(names.contains(&want), "trace must contain {want:?}, got {names:?}");
    }
    // Decision totals fold into the same trace as instant-event markers.
    for want in ["events.accept", "events.reject"] {
        assert!(names.contains(&want), "trace must fold in {want:?}, got {names:?}");
    }
    assert!(obs::event_count() > 0);

    obs::set_enabled(false);
    let _ = std::fs::remove_file(&path);
    // Off again: a fresh workload adds nothing to the drained rings and
    // nothing to the cumulative decision totals.
    let drained = obs::drain();
    assert!(!drained.is_empty());
    let totals_before = obs::events::totals();
    run_threesieves(&ds);
    assert_eq!(obs::event_count(), 0, "disabling must stop recording immediately");
    assert_eq!(obs::events::totals(), totals_before, "disabled emits must not count");
}
