//! The single-registry acceptance gate: every construction and dispatch
//! surface — config specs, CLI flags, the service OPEN grammar, the race
//! coordinator, the docs — agrees with `algorithms::registry` on the
//! exact algorithm name set. Registering a future algorithm therefore
//! touches exactly one file (`rust/src/algorithms/registry.rs`); this
//! suite is what enforces that promise.

use threesieves::algorithms::registry::{self, markdown_table, AlgoSpec};
use threesieves::algorithms::StreamingAlgorithm;
use threesieves::coordinator::registry_lanes;
use threesieves::data::synthetic::{Mixture, MixtureSource};
use threesieves::data::{Dataset, StreamSource};
use threesieves::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
use threesieves::service::Request;
use threesieves::util::rng::Rng;

const DIM: usize = 8;

fn stream(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from(seed);
    let mix = Mixture::random(DIM, 4, 5.0, 0.5, &mut rng);
    let mut ds = MixtureSource::new(mix, n, seed).materialize("registry-field", n);
    ds.normalize();
    ds
}

fn oracle(k: usize) -> Box<dyn SubmodularFunction> {
    Box::new(NativeLogDet::new(LogDetConfig::with_gamma(DIM, k, 1.0, 1.0)))
}

/// The name-set equality check: config (`AlgoSpec::of`), CLI
/// (`AlgoSpec::from_flags`) and wire (`OPEN ... algo=<name>`) all accept
/// exactly the registry's names — no surface has a private roster.
#[test]
fn config_cli_and_protocol_accept_exactly_the_registry_name_set() {
    for name in registry::names() {
        let spec = AlgoSpec::of(name, &[]).unwrap_or_else(|e| panic!("config {name}: {e}"));
        assert_eq!(spec.name(), name);
        let cli = AlgoSpec::from_flags(name, &|_| None)
            .unwrap_or_else(|e| panic!("cli {name}: {e}"));
        assert_eq!(cli.id(), spec.id(), "{name}: CLI defaults drift from registry defaults");
        let line = format!("OPEN s1 k=3 dim={DIM} algo={name}");
        match Request::parse(&line) {
            Ok(Request::Open { spec: open, .. }) => assert_eq!(
                open.algo.id(),
                spec.id(),
                "{name}: wire defaults drift from registry defaults"
            ),
            other => panic!("wire {name}: OPEN rejected a registry name: {other:?}"),
        }
    }
    // And nothing else gets in: each surface rejects a near-miss with the
    // registry's did-you-mean suggestion.
    let bogus = "three-seives";
    let config_err = AlgoSpec::of(bogus, &[]).unwrap_err();
    let cli_err = AlgoSpec::from_flags(bogus, &|_| None).unwrap_err();
    let wire_err = match Request::parse(&format!("OPEN s1 k=3 dim={DIM} algo={bogus}")) {
        Err((_, msg)) => msg,
        Ok(req) => panic!("wire accepted {bogus:?}: {req:?}"),
    };
    for (surface, err) in [("config", config_err), ("cli", cli_err), ("wire", wire_err)] {
        assert!(err.contains("unknown algo"), "{surface}: {err}");
        assert!(err.contains("did you mean \"three-sieves\"?"), "{surface}: {err}");
    }
}

#[test]
fn aliases_resolve_to_their_canonical_entries() {
    for (alias, canonical) in [
        ("independent-set-improvement", "isi"),
        ("streamclipper", "stream-clipper"),
        ("subsampled", "subsampled-sieve-streaming"),
    ] {
        let spec = AlgoSpec::of(alias, &[]).unwrap_or_else(|e| panic!("{alias}: {e}"));
        assert_eq!(spec.name(), canonical, "{alias}");
    }
}

/// Every streaming entry builds at defaults and survives a real stream —
/// the registry's build functions are live code paths, not stubs.
#[test]
fn every_streaming_entry_builds_and_runs_end_to_end() {
    let ds = stream(300, 61);
    let k = 4;
    for name in registry::streaming_names() {
        let spec = AlgoSpec::of(name, &[]).unwrap();
        let mut algo = spec.build(oracle(k), k, Some(ds.len()));
        assert_eq!(algo.dim(), DIM, "{name}");
        assert_eq!(algo.k(), k, "{name}");
        for block in ds.raw().chunks(64 * DIM) {
            algo.process_batch(block);
        }
        algo.finalize();
        assert_eq!(algo.stats().elements, ds.len() as u64, "{name}: element accounting");
        assert!(algo.value() > 0.0, "{name}: selected nothing");
        assert!(algo.summary_len() > 0 && algo.summary_len() <= k, "{name}: summary size");
    }
    // The race roster is the same set, derived from the same table.
    assert_eq!(registry_lanes(DIM, k, None).len(), registry::streaming_names().len());
}

/// The README "Algorithms" table is generated output — it must match
/// `registry::markdown_table()` verbatim so docs cannot drift.
#[test]
fn readme_algorithms_table_matches_the_registry() {
    let readme = include_str!("../../README.md");
    let table = markdown_table();
    assert!(
        readme.contains(&table),
        "README.md algorithms table is stale; regenerate it from \
         registry::markdown_table():\n{table}"
    );
}

/// The protocol doc's OPEN grammar must list every registry name and every
/// wire-visible parameter key.
#[test]
fn protocol_doc_lists_every_registry_name_and_wire_key() {
    let doc = include_str!("../../docs/protocol.md");
    for name in registry::names() {
        assert!(doc.contains(name), "docs/protocol.md is missing algo name {name:?}");
    }
    for key in registry::wire_param_keys() {
        assert!(doc.contains(key), "docs/protocol.md is missing OPEN key {key:?}");
    }
}

/// The point of the subsampled wrapper: measurably fewer oracle queries
/// than its inner algorithm on the identical stream, with identical
/// element accounting (the reduction is visible, not hidden by stats).
#[test]
fn subsampling_cuts_oracle_queries_measurably() {
    let ds = stream(1200, 62);
    let k = 6;
    let run = |spec: &AlgoSpec| {
        let mut algo = spec.build(oracle(k), k, Some(ds.len()));
        for block in ds.raw().chunks(64 * DIM) {
            algo.process_batch(block);
        }
        algo.finalize();
        algo.stats()
    };
    let full = run(&AlgoSpec::sieve_streaming(0.1));
    let half = run(&AlgoSpec::subsampled_sieve_streaming(0.1, 0.5, 7));
    assert_eq!(full.elements, half.elements, "observed-element accounting must not shrink");
    assert!(
        half.queries * 3 <= full.queries * 2,
        "p=0.5 must cut queries well below the full stream: {} vs {}",
        half.queries,
        full.queries
    );
}
