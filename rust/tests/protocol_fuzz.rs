//! Protocol fuzz smoke (satellite of the fault-injection PR): seeded LCG
//! mutations of valid request lines are thrown at a live server. The
//! contract under garbage input is narrow and absolute — every line gets
//! exactly one `OK`/`ERR` reply (frames from an accidentally-armed WATCH
//! may interleave), or the connection closes cleanly. Never a panic,
//! never a hang. Mutations are substitution-only, so line lengths (and
//! with them any numeric fields a mutation yields) stay bounded.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use threesieves::config::ServiceConfig;
use threesieves::exec::Parallelism;
use threesieves::service::{PushBody, Request, Server, SessionSpec, WatchMode};

const LCG_MUL: u64 = 6364136223846793005;
const LCG_INC: u64 = 1442695040888963407;

struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(LCG_MUL).wrapping_add(LCG_INC);
        self.0 >> 33
    }
}

/// The valid corpus: one of each verb, rendered by the same serializer
/// the real client uses.
fn corpus() -> Vec<String> {
    let spec = SessionSpec::three_sieves(8, 4, 0.05, 40);
    let rows: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
    vec![
        Request::Open { id: "fz".into(), spec: spec.clone() }.to_line(),
        Request::Push {
            id: "fz".into(),
            body: PushBody::Rows(rows.chunks(8).map(<[f32]>::to_vec).collect()),
        }
        .to_line(),
        Request::Push { id: "fz".into(), body: PushBody::Packed(rows) }.to_line(),
        Request::Summary { id: "fz".into() }.to_line(),
        Request::Stats { id: "fz".into() }.to_line(),
        Request::Close { id: "fz".into(), discard: true }.to_line(),
        Request::Metrics.to_line(),
        Request::MetricsHist.to_line(),
        Request::Watch { interval_ms: 60_000, mode: WatchMode::Events }.to_line(),
        Request::Ping.to_line(),
    ]
}

/// Substitute 1–6 bytes at seeded positions. Newlines and carriage
/// returns are excluded so one mutation stays one wire line.
fn mutate(line: &str, lcg: &mut Lcg) -> String {
    let mut bytes = line.as_bytes().to_vec();
    if bytes.is_empty() {
        return String::new();
    }
    let edits = 1 + (lcg.next() as usize % 6);
    for _ in 0..edits {
        let pos = lcg.next() as usize % bytes.len();
        let mut b = (lcg.next() % 256) as u8;
        if b == b'\n' || b == b'\r' {
            b = b'#';
        }
        bytes[pos] = b;
    }
    // Lossy round-trip mirrors what the server itself does with the line.
    String::from_utf8_lossy(&bytes).into_owned()
}

struct FuzzConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl FuzzConn {
    fn connect(addr: std::net::SocketAddr) -> FuzzConn {
        let stream = TcpStream::connect(addr).unwrap();
        // The hang detector: any reply slower than this fails the test.
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().unwrap();
        FuzzConn { reader: BufReader::new(stream), writer }
    }

    /// Send one line; classify the server's behavior. `Ok(true)` = got a
    /// reply, `Ok(false)` = connection closed cleanly (reconnect).
    fn exchange(&mut self, line: &str) -> std::io::Result<bool> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        // Whitespace-only lines are skipped by the server without a reply.
        if line.trim().is_empty() {
            return Ok(true);
        }
        loop {
            let mut reply = String::new();
            let n = self.reader.read_line(&mut reply)?;
            if n == 0 {
                return Ok(false); // clean close (QUIT mutation, oversize line)
            }
            if reply.starts_with("FRAME") {
                continue; // a mutated line re-armed WATCH; frames interleave
            }
            assert!(
                reply.starts_with("OK") || reply.starts_with("ERR"),
                "unclassifiable reply to {line:?}: {reply:?}"
            );
            return Ok(true);
        }
    }
}

#[test]
fn mutated_frames_always_get_err_or_clean_close_never_a_hang() {
    let cfg = ServiceConfig {
        idle_timeout: Duration::ZERO,
        parallelism: Parallelism::Off,
        max_sessions: 8,
        max_total_stored: 512,
        ..ServiceConfig::default()
    };
    let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
    let addr = handle.addr();
    let corpus = corpus();
    let mut lcg = Lcg(0x5eed_f00d_cafe_0042);
    let mut conn = FuzzConn::connect(addr);
    let mut replies = 0u32;
    let mut closes = 0u32;
    for i in 0..500 {
        let base = &corpus[(lcg.next() as usize) % corpus.len()];
        // Every 10th line goes through unmutated, keeping real sessions
        // appearing and disappearing underneath the garbage.
        let line =
            if i % 10 == 0 { base.clone() } else { mutate(base, &mut lcg) };
        match conn.exchange(&line) {
            Ok(true) => replies += 1,
            Ok(false) => {
                closes += 1;
                conn = FuzzConn::connect(addr);
            }
            Err(e) => panic!("server hung or died on {line:?}: {e}"),
        }
    }
    assert!(replies > 400, "most lines must be answered in place ({replies})");
    // The server survives the storm: a clean request on a fresh
    // connection still round-trips, and the manager still answers.
    let mut probe = FuzzConn::connect(addr);
    assert!(probe.exchange("PING").unwrap());
    let metrics = handle.manager().metrics();
    assert!(metrics.sessions <= 8, "admission caps held under fuzz");
    eprintln!("fuzz: {replies} replies, {closes} clean closes");
    handle.shutdown();
}
