//! Batch-vs-scalar parity: the batched ingestion path must be
//! *semantically invisible*.
//!
//! Three layers are pinned here (issue #1 acceptance criteria):
//! * kernels — `Kernel::eval_block` matches `Kernel::eval` to 1e-9;
//! * oracles — `NativeLogDet::peek_gain_batch` matches `peek_gain`
//!   element-wise (bitwise, in fact) with identical query accounting;
//! * algorithms — for every `process_batch` override, a randomized stream
//!   processed in chunks yields the identical summary, value and resource
//!   stats as the per-item path, across several chunk sizes.

use threesieves::algorithms::three_sieves::SieveTuning;
use threesieves::algorithms::{
    RandomReservoir, Salsa, SieveStreaming, SieveStreamingPP, StreamClipper, StreamingAlgorithm,
    Subsampled, ThreeSieves,
};
use threesieves::coordinator::ShardedThreeSieves;
use threesieves::data::synthetic::{Mixture, MixtureSource};
use threesieves::data::{Dataset, StreamSource};
use threesieves::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
use threesieves::kernels::{CosineKernel, Kernel, NormalizedLinearKernel, RbfKernel};
use threesieves::util::rng::Rng;

const DIM: usize = 8;

fn stream(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from(seed);
    let mix = Mixture::random(DIM, 4, 5.0, 0.5, &mut rng);
    let mut ds = MixtureSource::new(mix, n, seed).materialize("parity", n);
    ds.normalize();
    ds
}

fn oracle(k: usize) -> Box<dyn SubmodularFunction> {
    Box::new(NativeLogDet::new(LogDetConfig::with_gamma(DIM, k, 1.0, 1.0)))
}

/// Same oracle with the §Perf-iteration-7 blocked multi-RHS solve
/// disabled — the per-candidate forward-solve baseline. `clone_empty`
/// propagates the toggle into every sieve an algorithm spawns.
fn percand_oracle(k: usize) -> Box<dyn SubmodularFunction> {
    let mut f = NativeLogDet::new(LogDetConfig::with_gamma(DIM, k, 1.0, 1.0));
    f.set_blocked_solve(false);
    Box::new(f)
}

/// Drive `algo` over `ds` per item and `twin` over the same rows in
/// `chunk`-item blocks, then assert both ended in the same state.
fn assert_parity(
    algo: &mut dyn StreamingAlgorithm,
    twin: &mut dyn StreamingAlgorithm,
    ds: &Dataset,
    chunk: usize,
) {
    for row in ds.iter() {
        algo.process(row);
    }
    for block in ds.raw().chunks(chunk * DIM) {
        twin.process_batch(block);
    }
    algo.finalize();
    twin.finalize();
    let label = format!("{} chunk={chunk}", algo.name());
    assert_eq!(
        algo.value().to_bits(),
        twin.value().to_bits(),
        "{label}: value {} vs {}",
        algo.value(),
        twin.value()
    );
    assert_eq!(algo.summary(), twin.summary(), "{label}: summary rows differ");
    assert_eq!(algo.summary_len(), twin.summary_len(), "{label}: summary len");
    let (a, b) = (algo.stats(), twin.stats());
    assert_eq!(a.queries, b.queries, "{label}: queries {a:?} vs {b:?}");
    assert_eq!(a.elements, b.elements, "{label}: elements");
    assert_eq!(a.peak_stored, b.peak_stored, "{label}: peak_stored");
    assert_eq!(a.stored, b.stored, "{label}: stored");
    assert_eq!(a.instances, b.instances, "{label}: instances");
}

const CHUNKS: [usize; 4] = [1, 7, 64, 1000];

#[test]
fn kernels_eval_block_matches_eval() {
    let kernels: Vec<Box<dyn Kernel>> = vec![
        Box::new(RbfKernel::new(0.7)),
        Box::new(RbfKernel::for_batch(DIM)),
        Box::new(RbfKernel::for_streaming(DIM)),
        Box::new(CosineKernel),
        Box::new(NormalizedLinearKernel),
    ];
    let mut rng = Rng::seed_from(1);
    let (n, b) = (13, 9);
    let rows: Vec<f32> = (0..n * DIM).map(|_| rng.normal() as f32).collect();
    let xs: Vec<f32> = (0..b * DIM).map(|_| rng.normal() as f32).collect();
    for k in &kernels {
        let mut out = vec![0.0; b * n];
        let mut scratch = Vec::new();
        k.eval_block(&xs, &rows, DIM, &mut out, &mut scratch);
        for q in 0..b {
            for i in 0..n {
                let want = k.eval(&xs[q * DIM..(q + 1) * DIM], &rows[i * DIM..(i + 1) * DIM]);
                assert!(
                    (out[q * n + i] - want).abs() < 1e-9,
                    "{} ({q},{i}): {} vs {want}",
                    k.name(),
                    out[q * n + i]
                );
            }
        }
    }
}

#[test]
fn logdet_batch_gains_match_scalar_elementwise() {
    let mut rng = Rng::seed_from(2);
    for &summary_n in &[0usize, 1, 5, 12] {
        let mut batch_oracle = NativeLogDet::new(LogDetConfig::with_gamma(DIM, 16, 0.8, 1.0));
        let mut scalar_oracle = NativeLogDet::new(LogDetConfig::with_gamma(DIM, 16, 0.8, 1.0));
        for _ in 0..summary_n {
            let item: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
            batch_oracle.accept(&item);
            scalar_oracle.accept(&item);
        }
        for &count in &[1usize, 3, 4, 8, 11] {
            let cands: Vec<f32> = (0..count * DIM).map(|_| rng.normal() as f32).collect();
            let mut gains = Vec::new();
            batch_oracle.peek_gain_batch(&cands, count, &mut gains);
            assert_eq!(gains.len(), count);
            for (i, &g) in gains.iter().enumerate() {
                let single = scalar_oracle.peek_gain(&cands[i * DIM..(i + 1) * DIM]);
                assert_eq!(
                    g.to_bits(),
                    single.to_bits(),
                    "|S|={summary_n} count={count} item {i}: {g} vs {single}"
                );
            }
            assert_eq!(batch_oracle.queries(), scalar_oracle.queries());
        }
    }
}

#[test]
fn three_sieves_batch_parity() {
    let ds = stream(2500, 10);
    let k = 8;
    for chunk in CHUNKS {
        let mut a = ThreeSieves::new(oracle(k), k, 0.01, SieveTuning::FixedT(40));
        let mut b = ThreeSieves::new(oracle(k), k, 0.01, SieveTuning::FixedT(40));
        assert_parity(&mut a, &mut b, &ds, chunk);
        assert!(
            b.stats().queries_per_element() <= 1.02,
            "batched ThreeSieves must keep ≤1 query/element: {}",
            b.stats().queries_per_element()
        );
    }
}

#[test]
fn three_sieves_small_t_batch_parity() {
    // T smaller than the chunk: the scan hits threshold drops constantly,
    // exercising the replay path.
    let ds = stream(1500, 11);
    let k = 12;
    for chunk in CHUNKS {
        let mut a = ThreeSieves::new(oracle(k), k, 0.2, SieveTuning::FixedT(3));
        let mut b = ThreeSieves::new(oracle(k), k, 0.2, SieveTuning::FixedT(3));
        assert_parity(&mut a, &mut b, &ds, chunk);
    }
}

#[test]
fn three_sieves_m_estimation_batch_parity() {
    // estimate-m replays per item inside process_batch; parity must still
    // hold exactly.
    let ds = stream(1200, 12);
    let k = 6;
    for chunk in [7usize, 64] {
        let mut a = ThreeSieves::with_m_estimation(oracle(k), k, 0.05, SieveTuning::FixedT(25));
        let mut b = ThreeSieves::with_m_estimation(oracle(k), k, 0.05, SieveTuning::FixedT(25));
        assert_parity(&mut a, &mut b, &ds, chunk);
    }
}

#[test]
fn sieve_streaming_batch_parity() {
    let ds = stream(1500, 13);
    let k = 6;
    for chunk in CHUNKS {
        let mut a = SieveStreaming::new(oracle(k), k, 0.1);
        let mut b = SieveStreaming::new(oracle(k), k, 0.1);
        assert_parity(&mut a, &mut b, &ds, chunk);
    }
}

#[test]
fn sieve_streaming_pp_batch_parity() {
    // ++ prunes and spawns sieves on LB growth mid-stream — the hardest
    // coupling for the batched path.
    let ds = stream(1800, 14);
    let k = 6;
    for chunk in CHUNKS {
        let mut a = SieveStreamingPP::new(oracle(k), k, 0.1);
        let mut b = SieveStreamingPP::new(oracle(k), k, 0.1);
        assert_parity(&mut a, &mut b, &ds, chunk);
    }
}

#[test]
fn salsa_batch_parity() {
    // Length hint on: includes the position-adaptive rule whose threshold
    // moves *within* a chunk.
    let ds = stream(1200, 15);
    let k = 5;
    for chunk in CHUNKS {
        let mut a = Salsa::new(oracle(k), k, 0.2, Some(ds.len()));
        let mut b = Salsa::new(oracle(k), k, 0.2, Some(ds.len()));
        assert_parity(&mut a, &mut b, &ds, chunk);
    }
}

#[test]
fn sharded_three_sieves_batch_parity() {
    let ds = stream(1500, 16);
    let k = 6;
    for chunk in CHUNKS {
        let mut a = ShardedThreeSieves::new(oracle(k), k, 0.05, SieveTuning::FixedT(20), 3);
        let mut b = ShardedThreeSieves::new(oracle(k), k, 0.05, SieveTuning::FixedT(20), 3);
        assert_parity(&mut a, &mut b, &ds, chunk);
    }
}

/// §Perf iteration 7: the blocked multi-RHS solve must be bitwise
/// invisible across every batch-capable algorithm — summaries, objective
/// values, queries AND kernel_evals (the solve touches no kernel
/// entries, so the measured counter must not move either). Each
/// algorithm runs once on the default blocked oracle and once on the
/// per-candidate baseline, over the same chunked stream.
#[test]
fn blocked_solve_matches_per_candidate_across_algorithms() {
    let ds = stream(1500, 19);
    let k = 6;
    let n = ds.len();
    type Build<'a> = &'a dyn Fn(Box<dyn SubmodularFunction>) -> Box<dyn StreamingAlgorithm>;
    let three = |o: Box<dyn SubmodularFunction>| -> Box<dyn StreamingAlgorithm> {
        Box::new(ThreeSieves::new(o, k, 0.05, SieveTuning::FixedT(25)))
    };
    let sharded = |o: Box<dyn SubmodularFunction>| -> Box<dyn StreamingAlgorithm> {
        Box::new(ShardedThreeSieves::new(o, k, 0.05, SieveTuning::FixedT(20), 3))
    };
    let ss = |o: Box<dyn SubmodularFunction>| -> Box<dyn StreamingAlgorithm> {
        Box::new(SieveStreaming::new(o, k, 0.1))
    };
    let pp = |o: Box<dyn SubmodularFunction>| -> Box<dyn StreamingAlgorithm> {
        Box::new(SieveStreamingPP::new(o, k, 0.1))
    };
    let salsa = |o: Box<dyn SubmodularFunction>| -> Box<dyn StreamingAlgorithm> {
        Box::new(Salsa::new(o, k, 0.2, Some(n)))
    };
    let builds: [(&str, Build<'_>); 5] = [
        ("ThreeSieves", &three),
        ("ShardedThreeSieves", &sharded),
        ("SieveStreaming", &ss),
        ("SieveStreaming++", &pp),
        ("Salsa", &salsa),
    ];
    for (name, build) in builds {
        let mut blocked = build(oracle(k));
        let mut percand = build(percand_oracle(k));
        for block in ds.raw().chunks(37 * DIM) {
            blocked.process_batch(block);
            percand.process_batch(block);
        }
        assert_eq!(blocked.value().to_bits(), percand.value().to_bits(), "{name}: value bits");
        assert_eq!(blocked.summary(), percand.summary(), "{name}: summary rows");
        assert_eq!(blocked.stats(), percand.stats(), "{name}: stats (incl. kernel_evals)");
        assert!(blocked.stats().queries > 0, "{name}: workload must exercise the oracle");
    }
}

#[test]
fn stream_clipper_batch_parity() {
    // Two thresholds move independently within a chunk (accepts raise τ,
    // deferrals mutate the clip buffer) — the batched scan must replay
    // both exactly.
    let ds = stream(1500, 20);
    let k = 6;
    for chunk in CHUNKS {
        let mut a = StreamClipper::new(oracle(k), k, 1.0, 0.5);
        let mut b = StreamClipper::new(oracle(k), k, 1.0, 0.5);
        assert_parity(&mut a, &mut b, &ds, chunk);
    }
}

#[test]
fn subsampled_batch_parity() {
    // The coin is indexed by absolute stream position, not position in
    // chunk, so any chunking keeps the identical kept set and hands the
    // inner algorithm the identical thinned stream.
    let ds = stream(1500, 21);
    let k = 6;
    for chunk in CHUNKS {
        let mut a = Subsampled::new(Box::new(SieveStreaming::new(oracle(k), k, 0.1)), 0.5, 7);
        let mut b = Subsampled::new(Box::new(SieveStreaming::new(oracle(k), k, 0.1)), 0.5, 7);
        assert_parity(&mut a, &mut b, &ds, chunk);
    }
    for chunk in [7usize, 64] {
        let inner = || Box::new(ThreeSieves::new(oracle(k), k, 0.05, SieveTuning::FixedT(25)));
        let mut a = Subsampled::new(inner(), 0.25, 9);
        let mut b = Subsampled::new(inner(), 0.25, 9);
        assert_parity(&mut a, &mut b, &ds, chunk);
    }
}

#[test]
fn default_process_batch_matches_for_non_overriding_algorithms() {
    // RandomReservoir has no override; the trait default must be exact.
    let ds = stream(800, 17);
    let k = 5;
    let mut a = RandomReservoir::new(oracle(k), k, 99);
    let mut b = RandomReservoir::new(oracle(k), k, 99);
    assert_parity(&mut a, &mut b, &ds, 13);
}

#[test]
fn batch_parity_survives_reset() {
    // Drift-style reset mid-stream: both paths reset at the same element
    // and must still agree afterwards (cumulative query accounting).
    let ds = stream(1600, 18);
    let k = 6;
    let half = ds.raw().len() / (2 * DIM) * DIM;
    let mut a = ThreeSieves::new(oracle(k), k, 0.01, SieveTuning::FixedT(30));
    let mut b = ThreeSieves::new(oracle(k), k, 0.01, SieveTuning::FixedT(30));
    for row in ds.raw()[..half].chunks_exact(DIM) {
        a.process(row);
    }
    b.process_batch(&ds.raw()[..half]);
    a.reset();
    b.reset();
    for row in ds.raw()[half..].chunks_exact(DIM) {
        a.process(row);
    }
    b.process_batch(&ds.raw()[half..]);
    assert_eq!(a.value().to_bits(), b.value().to_bits());
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.stats().queries, b.stats().queries);
    assert_eq!(a.stats().elements, b.stats().elements);
}
