//! Chaos acceptance gate: under a seeded fault schedule — connection
//! reset mid-stream, NaN injection into a push, torn checkpoint write on
//! close, a server restart over the same checkpoint dir — every surviving
//! session's `SUMMARY` and `STATS` must be **bit-identical** to a
//! fault-free run of the same stream.
//!
//! Fault arming is process-global, so every test here serializes on one
//! local mutex and disarms before releasing it.

use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use threesieves::config::ServiceConfig;
use threesieves::data::registry;
use threesieves::exec::Parallelism;
use threesieves::fault::{self, site, FaultKind, FaultPlan};
use threesieves::metrics::AlgoStats;
use threesieves::service::{Client, ClientError, ErrorCode, RetryPolicy, Server, SessionSpec};

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(PoisonError::into_inner)
}

const DIM: usize = 16;
const CHUNK_ROWS: usize = 40;

fn workload() -> (Vec<f32>, SessionSpec) {
    let ds = registry::get("fact-highlevel-like", 600, 77).unwrap();
    assert_eq!(ds.dim(), DIM);
    (ds.raw().to_vec(), SessionSpec::three_sieves(DIM, 6, 0.01, 80))
}

fn retry_fast() -> RetryPolicy {
    RetryPolicy { base_delay: Duration::from_millis(1), ..RetryPolicy::default() }
}

/// Push one chunk, absorbing at most one `ERR nonfinite`: the injection
/// poisons the batch server-side, the gate rejects it atomically, and the
/// same (clean) chunk is re-sent — so the oracle sees exactly the
/// fault-free stream.
fn push_absorbing_nan(client: &mut Client, id: &str, chunk: &[f32], dim: usize) -> u64 {
    match client.push_rows(id, chunk, dim) {
        Ok(reply) => reply.rows,
        Err(ClientError::Server { code: ErrorCode::NonFinite, .. }) => {
            client.push_rows(id, chunk, dim).unwrap().rows
        }
        Err(other) => panic!("push failed beyond the planned faults: {other}"),
    }
}

fn final_state(client: &mut Client, id: &str) -> (f64, Vec<f32>, AlgoStats, usize) {
    let summary = client.summary(id).unwrap();
    let stats = client.stats(id).unwrap();
    assert_eq!(summary.value.to_bits(), stats.value.to_bits());
    (summary.value, summary.data, stats.stats, stats.drift_events)
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ts_chaos_{}_{tag}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn seeded_fault_schedule_is_bit_identical_to_fault_free_run() {
    let _serial = serial();
    let (raw, spec) = workload();
    let chunks: Vec<&[f32]> = raw.chunks(CHUNK_ROWS * DIM).collect();
    let split = chunks.len() / 2; // server restart happens here

    // ---- fault-free baseline ------------------------------------------
    let base_dir = tmpdir("base");
    let cfg = |dir: &std::path::Path| ServiceConfig {
        idle_timeout: Duration::ZERO,
        checkpoint_dir: Some(dir.to_path_buf()),
        parallelism: Parallelism::Off,
        ..ServiceConfig::default()
    };
    let baseline = {
        let handle = Server::start(cfg(&base_dir), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.open("s1", &spec).unwrap();
        for chunk in &chunks {
            client.push_rows("s1", chunk, DIM).unwrap();
        }
        let state = final_state(&mut client, "s1");
        handle.shutdown();
        state
    };

    // ---- chaos run -----------------------------------------------------
    let chaos_dir = tmpdir("chaos");
    let injected_before = fault::injected_total();

    // Phase A: stream the first half under the schedule. The reset drops
    // the 5th request line (the 5th PUSH) before dispatch and the retry
    // re-sends it exactly; the NaN poisons the 7th *dispatched* PUSH,
    // which the non-finite gate rejects whole.
    let handle = Server::start(cfg(&chaos_dir), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(handle.addr()).unwrap().with_retry(retry_fast());
    client.open("s1", &spec).unwrap();
    fault::arm(
        FaultPlan::new()
            .nth(site::CONN_READ, FaultKind::ConnReset, 4, 1, 1)
            .nth(site::PUSH_ROWS, FaultKind::PoisonNan, 6, 1, 1)
            .once(site::CKPT_WRITE, FaultKind::TornWrite { bytes: 24 }),
    );
    for chunk in &chunks[..split] {
        push_absorbing_nan(&mut client, "s1", chunk, DIM);
    }
    // "Kill mid-checkpoint": the torn write fires on the first close
    // attempt, which must fail loudly with the session still live...
    match client.close("s1", false) {
        Err(ClientError::Server { code: ErrorCode::Io, .. }) => {}
        other => panic!("torn checkpoint write must surface as ERR io, got {other:?}"),
    }
    // ...and the retried close rewrites the checkpoint atomically.
    assert!(client.close("s1", false).unwrap(), "second close checkpoints");
    let m = handle.manager().metrics();
    assert_eq!(m.rejected_rows, CHUNK_ROWS as u64, "one poisoned batch was rejected");
    handle.shutdown();

    // Phase B: a fresh server over the same dir sweeps the checkpoint
    // dir, resumes the session bit-identically, and finishes the stream.
    let handle = Server::start(cfg(&chaos_dir), "127.0.0.1:0").unwrap();
    let mut client = Client::connect(handle.addr()).unwrap().with_retry(retry_fast());
    assert!(client.open("s1", &spec).unwrap(), "must resume from the close checkpoint");
    for chunk in &chunks[split..] {
        push_absorbing_nan(&mut client, "s1", chunk, DIM);
    }
    let chaos = final_state(&mut client, "s1");
    fault::disarm();
    handle.shutdown();

    assert!(fault::injected_total() > injected_before, "the schedule actually fired");
    // The acceptance bar: bit-identical SUMMARY and STATS.
    assert_eq!(baseline.0.to_bits(), chaos.0.to_bits(), "f(S) must match to the bit");
    assert_eq!(baseline.1, chaos.1, "summaries must match exactly");
    assert_eq!(baseline.2, chaos.2, "algorithm stats must match exactly");
    assert_eq!(baseline.3, chaos.3, "drift counts must match");

    std::fs::remove_dir_all(&base_dir).ok();
    std::fs::remove_dir_all(&chaos_dir).ok();
}

#[test]
fn slow_read_fault_delays_but_never_alters_results() {
    let _serial = serial();
    let (raw, spec) = workload();
    let chunks: Vec<&[f32]> = raw.chunks(CHUNK_ROWS * DIM).collect();

    let run = |plan: Option<FaultPlan>| {
        let cfg = ServiceConfig {
            idle_timeout: Duration::ZERO,
            parallelism: Parallelism::Off,
            ..ServiceConfig::default()
        };
        let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.open("slow", &spec).unwrap();
        if let Some(plan) = plan {
            fault::arm(plan);
        }
        for chunk in &chunks {
            client.push_rows("slow", chunk, DIM).unwrap();
        }
        fault::disarm();
        let state = final_state(&mut client, "slow");
        handle.shutdown();
        state
    };

    let clean = run(None);
    let slowed = run(Some(FaultPlan::new().nth(
        site::CONN_READ,
        FaultKind::SlowRead { ms: 10 },
        0,
        3,
        u64::MAX,
    )));
    assert_eq!(clean.0.to_bits(), slowed.0.to_bits());
    assert_eq!(clean.1, slowed.1);
    assert_eq!(clean.2, slowed.2);
}

#[test]
fn reply_side_reset_retries_idempotent_verbs_exactly() {
    let _serial = serial();
    let (raw, spec) = workload();
    let cfg = ServiceConfig {
        idle_timeout: Duration::ZERO,
        parallelism: Parallelism::Off,
        ..ServiceConfig::default()
    };
    let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(handle.addr()).unwrap().with_retry(retry_fast());
    client.open("rw", &spec).unwrap();
    client.push_rows("rw", &raw[..8 * DIM], DIM).unwrap();
    let before = client.stats("rw").unwrap();
    // The reply to the next request is lost AFTER dispatch; STATS is
    // idempotent, so the transparent re-send returns the same answer.
    fault::arm(FaultPlan::new().once(site::CONN_WRITE, FaultKind::ConnReset));
    let after = client.stats("rw").unwrap();
    fault::disarm();
    assert_eq!(before.value.to_bits(), after.value.to_bits());
    assert_eq!(before.stats, after.stats);
    handle.shutdown();
}

#[test]
fn handler_panic_over_tcp_quarantines_only_that_tenant() {
    let _serial = serial();
    let (raw, spec) = workload();
    let cfg = ServiceConfig {
        idle_timeout: Duration::ZERO,
        parallelism: Parallelism::Threads(2),
        ..ServiceConfig::default()
    };
    let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(handle.addr()).unwrap();
    client.open("victim", &spec).unwrap();
    client.open("bystander", &spec).unwrap();
    client.push_rows("bystander", &raw[..8 * DIM], DIM).unwrap();
    fault::arm(FaultPlan::new().once(site::SESSION_HANDLER, FaultKind::Panic));
    match client.push_rows("victim", &raw[..8 * DIM], DIM) {
        Err(ClientError::Server { code: ErrorCode::Quarantined, .. }) => {}
        other => panic!("expected ERR quarantined, got {other:?}"),
    }
    fault::disarm();
    // The victim stays fenced; the bystander and the manager are fine.
    match client.stats("victim") {
        Err(ClientError::Server { code: ErrorCode::Quarantined, .. }) => {}
        other => panic!("expected ERR quarantined, got {other:?}"),
    }
    let by = client.stats("bystander").unwrap();
    assert_eq!(by.stats.elements, 8);
    let m = client.metrics().unwrap();
    assert_eq!(m.quarantines, 1);
    assert_eq!(m.sessions, 2, "quarantined tenant still holds its slot");
    // Discard-close releases the slot and the id becomes reusable.
    client.close("victim", true).unwrap();
    assert!(!client.open("victim", &spec).unwrap());
    client.push_rows("victim", &raw[..8 * DIM], DIM).unwrap();
    handle.shutdown();
}
