//! Checkpoint corruption matrix (satellite of the fault-injection PR):
//! every way a `.ckpt` can rot on disk — truncation, a single flipped
//! bit, an unknown version header, an empty file, a stale `.tmp` from a
//! torn save — must (a) be detected with the right [`Corruption`] class,
//! (b) quarantine the file to a `.corrupt` sibling instead of deleting
//! evidence, and (c) leave a fresh `OPEN` of the same id working.

use std::path::{Path, PathBuf};
use std::time::Duration;

use threesieves::config::ServiceConfig;
use threesieves::coordinator::checkpoint::{
    sweep_dir, Checkpoint, CheckpointError, Corruption,
};
use threesieves::data::registry;
use threesieves::service::{PushBody, SessionManager, SessionSpec};

const DIM: usize = 10;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ts_ckpt_matrix_{}_{tag}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn cfg(dir: &Path) -> ServiceConfig {
    ServiceConfig {
        idle_timeout: Duration::ZERO,
        checkpoint_dir: Some(dir.to_path_buf()),
        ..ServiceConfig::default()
    }
}

/// Run a session to completion so `<id>.ckpt` holds real restorable state.
fn write_good_checkpoint(dir: &Path, id: &str) -> SessionSpec {
    let spec = SessionSpec::three_sieves(DIM, 5, 0.01, 60);
    let mgr = SessionManager::new(cfg(dir));
    let ds = registry::get("forestcover-like", 300, 5).unwrap();
    assert_eq!(ds.dim(), DIM);
    mgr.open(id, &spec).unwrap();
    mgr.push(id, &PushBody::Packed(ds.raw().to_vec())).unwrap();
    assert!(mgr.close(id, false).unwrap(), "close must checkpoint");
    assert!(dir.join(format!("{id}.ckpt")).exists());
    spec
}

/// The shared acceptance path for one corruption case: load classifies it,
/// a fresh manager's sweep quarantines it, and the same id opens fresh.
fn assert_quarantined_and_reopenable(
    dir: &Path,
    id: &str,
    spec: &SessionSpec,
    expect: impl Fn(&Corruption) -> bool,
    case: &str,
) {
    let path = dir.join(format!("{id}.ckpt"));
    match Checkpoint::load(&path) {
        Err(CheckpointError::Corrupt(c)) => {
            assert!(expect(&c), "{case}: wrong corruption class: {c}")
        }
        other => panic!("{case}: expected Corrupt, got {other:?}"),
    }
    let mgr = SessionManager::new(cfg(dir));
    assert!(!path.exists(), "{case}: sweep must move the corrupt file aside");
    assert!(
        dir.join(format!("{id}.ckpt.corrupt")).exists(),
        "{case}: quarantined sibling must keep the bytes"
    );
    assert_eq!(mgr.metrics().ckpt_quarantines, 1, "{case}");
    assert!(!mgr.open(id, spec).unwrap(), "{case}: fresh OPEN must proceed");
    mgr.push(id, &PushBody::Packed(vec![0.5; 4 * DIM])).unwrap();
}

#[test]
fn truncated_checkpoint_quarantines() {
    let dir = tmpdir("trunc");
    let spec = write_good_checkpoint(&dir, "t");
    let path = dir.join("t.ckpt");
    let bytes = std::fs::read(&path).unwrap();
    // A deep cut (half the file) survives the magic check but the last 8
    // bytes are no longer the FNV trailer of what precedes them — v2
    // truncation is caught by the checksum, by design.
    assert!(matches!(
        Checkpoint::decode(&bytes[..bytes.len() / 2]),
        Err(CheckpointError::Corrupt(Corruption::ChecksumMismatch { .. }))
    ));
    // A cut shallower than the fixed framing is classified as Truncated.
    assert!(matches!(
        Checkpoint::decode(&bytes[..10]),
        Err(CheckpointError::Corrupt(Corruption::Truncated(_)))
    ));
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert_quarantined_and_reopenable(
        &dir,
        "t",
        &spec,
        |c| matches!(c, Corruption::ChecksumMismatch { .. }),
        "truncated",
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_bit_flip_fails_the_checksum() {
    let dir = tmpdir("flip");
    let spec = write_good_checkpoint(&dir, "f");
    let path = dir.join("f.ckpt");
    let mut bytes = std::fs::read(&path).unwrap();
    // Flip one payload bit, well clear of the 8-byte FNV trailer.
    let idx = bytes.len() - 16;
    bytes[idx] ^= 0x04;
    std::fs::write(&path, &bytes).unwrap();
    assert_quarantined_and_reopenable(
        &dir,
        "f",
        &spec,
        |c| matches!(c, Corruption::ChecksumMismatch { .. }),
        "bit-flip",
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unknown_version_header_quarantines() {
    let dir = tmpdir("ver");
    let spec = write_good_checkpoint(&dir, "v");
    let path = dir.join("v.ckpt");
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[6] = b'9'; // TSCKPT2\n -> TSCKPT9\n
    std::fs::write(&path, &bytes).unwrap();
    assert_quarantined_and_reopenable(
        &dir,
        "v",
        &spec,
        |c| matches!(c, Corruption::UnsupportedVersion(b'9')),
        "unknown-version",
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn empty_file_quarantines_without_panicking() {
    let dir = tmpdir("empty");
    let spec = write_good_checkpoint(&dir, "e");
    std::fs::write(dir.join("e.ckpt"), b"").unwrap();
    assert_quarantined_and_reopenable(
        &dir,
        "e",
        &spec,
        |c| matches!(c, Corruption::Truncated(_)),
        "empty",
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_tmp_is_cleaned_and_the_real_checkpoint_still_resumes() {
    let dir = tmpdir("tmp");
    let spec = write_good_checkpoint(&dir, "s");
    // A crash between staging and rename leaves `<id>.ckpt.tmp`; the good
    // checkpoint from an earlier save is still the newest durable state.
    std::fs::write(dir.join("s.ckpt.tmp"), b"torn staging garbage").unwrap();
    let report = sweep_dir(&dir);
    assert_eq!((report.good, report.quarantined, report.stale_tmp), (1, 0, 1));
    assert!(!dir.join("s.ckpt.tmp").exists(), "stale tmp must be removed");
    let mgr = SessionManager::new(cfg(&dir));
    assert!(mgr.open("s", &spec).unwrap(), "the intact checkpoint must still resume");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn garbage_header_is_bad_magic_not_a_crash() {
    let dir = tmpdir("magic");
    let spec = write_good_checkpoint(&dir, "g");
    let path = dir.join("g.ckpt");
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[..8].copy_from_slice(b"NOTAHDR\n");
    std::fs::write(&path, &bytes).unwrap();
    assert_quarantined_and_reopenable(
        &dir,
        "g",
        &spec,
        |c| matches!(c, Corruption::BadMagic),
        "bad-magic",
    );
    std::fs::remove_dir_all(&dir).ok();
}
