//! Shared kernel-panel broker parity: the broker must be *semantically
//! invisible* and *strictly cheaper*.
//!
//! For every multi-sieve algorithm wired through the broker
//! (SieveStreaming, SieveStreaming++, Salsa), running the identical
//! stream through (a) the per-item scalar path, (b) the per-sieve batched
//! panels, and (c) the shared broker panels — at `--threads off`, 2 and
//! 8 — must produce bit-identical objective values, identical summaries
//! and identical *reported* resource stats (queries, elements, stored,
//! peak, instances). Only the measured `kernel_evals` may differ, and
//! only downward: shared ≤ per-sieve, with a ≥2× drop on the multi-sieve
//! working point the benches track (ε = 0.01).
//!
//! A checkpoint/resume roundtrip under the broker is pinned too: pausing
//! a broker-driven SieveStreaming mid-stream and resuming into a fresh
//! instance (fresh row store, replayed interning) must continue
//! bit-identically to the run that never paused.

use threesieves::algorithms::{
    Salsa, SieveStreaming, SieveStreamingPP, StreamClipper, StreamingAlgorithm, Subsampled,
};
use threesieves::data::synthetic::{Mixture, MixtureSource};
use threesieves::data::{Dataset, StreamSource};
use threesieves::exec::{ExecContext, Parallelism};
use threesieves::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
use threesieves::metrics::AlgoStats;
use threesieves::util::rng::Rng;

const DIM: usize = 8;
const CHUNK: usize = 64;

fn stream(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from(seed);
    let mix = Mixture::random(DIM, 4, 5.0, 0.5, &mut rng);
    let mut ds = MixtureSource::new(mix, n, seed).materialize("panel-parity", n);
    ds.normalize();
    ds
}

fn oracle(k: usize) -> Box<dyn SubmodularFunction> {
    Box::new(NativeLogDet::new(LogDetConfig::with_gamma(DIM, k, 1.0, 1.0)))
}

/// The per-candidate forward-solve baseline (§Perf iteration 7 toggle);
/// `clone_empty` propagates the flag into every sieve.
fn percand_oracle(k: usize) -> Box<dyn SubmodularFunction> {
    let mut f = NativeLogDet::new(LogDetConfig::with_gamma(DIM, k, 1.0, 1.0));
    f.set_blocked_solve(false);
    Box::new(f)
}

/// Drive `algo` over `ds` in `CHUNK`-row blocks under `par`.
fn run_batched(
    mut algo: Box<dyn StreamingAlgorithm>,
    ds: &Dataset,
    par: Parallelism,
) -> (u64, Vec<f32>, AlgoStats) {
    algo.set_exec(ExecContext::new(par));
    for block in ds.raw().chunks(CHUNK * DIM) {
        algo.process_batch(block);
    }
    algo.finalize();
    (algo.value().to_bits(), algo.summary(), algo.stats())
}

/// Drive `algo` per item (the scalar reference).
fn run_scalar(mut algo: Box<dyn StreamingAlgorithm>, ds: &Dataset) -> (u64, Vec<f32>, AlgoStats) {
    for row in ds.iter() {
        algo.process(row);
    }
    algo.finalize();
    (algo.value().to_bits(), algo.summary(), algo.stats())
}

/// Everything except `kernel_evals` must match exactly; `kernel_evals`
/// is compared by the caller (it is *supposed* to move between paths).
type RunOutcome = (u64, Vec<f32>, AlgoStats);

fn assert_same_semantics(label: &str, a: &RunOutcome, b: &RunOutcome) {
    assert_eq!(a.0, b.0, "{label}: value bits");
    assert_eq!(a.1, b.1, "{label}: summary rows");
    assert_eq!(a.2.queries, b.2.queries, "{label}: queries");
    assert_eq!(a.2.elements, b.2.elements, "{label}: elements");
    assert_eq!(a.2.stored, b.2.stored, "{label}: stored");
    assert_eq!(a.2.peak_stored, b.2.peak_stored, "{label}: peak_stored");
    assert_eq!(a.2.instances, b.2.instances, "{label}: instances");
}

/// The full parity contract for one algorithm family: scalar vs shared vs
/// per-sieve, across thread counts; kernel evals monotone (shared ≤
/// per-sieve) and thread-count invariant.
fn assert_panel_sharing_parity(
    shared: &dyn Fn() -> Box<dyn StreamingAlgorithm>,
    per_sieve: &dyn Fn() -> Box<dyn StreamingAlgorithm>,
    ds: &Dataset,
) {
    let name = shared().name();
    let scalar = run_scalar(shared(), ds);
    let plain_off = run_batched(per_sieve(), ds, Parallelism::Off);
    let shared_off = run_batched(shared(), ds, Parallelism::Off);
    assert_same_semantics(&format!("{name} shared vs scalar"), &shared_off, &scalar);
    assert_same_semantics(&format!("{name} shared vs per-sieve"), &shared_off, &plain_off);
    assert!(
        shared_off.2.kernel_evals <= plain_off.2.kernel_evals,
        "{name}: shared panels must never evaluate more kernel entries: {} vs {}",
        shared_off.2.kernel_evals,
        plain_off.2.kernel_evals
    );
    assert!(plain_off.2.kernel_evals > 0, "{name}: workload must exercise the kernel");
    for threads in [2usize, 8] {
        let got = run_batched(shared(), ds, Parallelism::Threads(threads));
        let label = format!("{name} shared threads={threads}");
        assert_eq!(shared_off.0, got.0, "{label}: value bits");
        assert_eq!(shared_off.1, got.1, "{label}: summary rows");
        assert_eq!(shared_off.2, got.2, "{label}: stats (incl. kernel_evals)");
    }
}

#[test]
fn sieve_streaming_panel_sharing_parity() {
    let ds = stream(1500, 41);
    let k = 6;
    let shared =
        || -> Box<dyn StreamingAlgorithm> { Box::new(SieveStreaming::new(oracle(k), k, 0.1)) };
    let per_sieve = || -> Box<dyn StreamingAlgorithm> {
        let mut a = SieveStreaming::new(oracle(k), k, 0.1);
        a.set_panel_sharing(false);
        Box::new(a)
    };
    assert_panel_sharing_parity(&shared, &per_sieve, &ds);
}

#[test]
fn sieve_streaming_pp_panel_sharing_parity() {
    // ++ prunes and spawns sieves on LB growth mid-chunk — the broker
    // must survive the rebind (survivors keep chunk-local rows, spawned
    // sieves scan the remainder from scratch).
    let ds = stream(1800, 42);
    let k = 6;
    let shared =
        || -> Box<dyn StreamingAlgorithm> { Box::new(SieveStreamingPP::new(oracle(k), k, 0.1)) };
    let per_sieve = || -> Box<dyn StreamingAlgorithm> {
        let mut a = SieveStreamingPP::new(oracle(k), k, 0.1);
        a.set_panel_sharing(false);
        Box::new(a)
    };
    assert_panel_sharing_parity(&shared, &per_sieve, &ds);
}

#[test]
fn salsa_panel_sharing_parity() {
    // Length hint on: includes the position-adaptive rule, whose
    // threshold moves *within* a chunk.
    let ds = stream(1500, 43);
    let k = 5;
    let n = ds.len();
    let shared =
        || -> Box<dyn StreamingAlgorithm> { Box::new(Salsa::new(oracle(k), k, 0.2, Some(n))) };
    let per_sieve = || -> Box<dyn StreamingAlgorithm> {
        let mut a = Salsa::new(oracle(k), k, 0.2, Some(n));
        a.set_panel_sharing(false);
        Box::new(a)
    };
    assert_panel_sharing_parity(&shared, &per_sieve, &ds);
}

#[test]
fn stream_clipper_panel_sharing_parity() {
    // One sieve plus a clip buffer whose deferrals ride the same first-hit
    // scan — the broker must leave the buffer's contents untouched too
    // (summary and value would drift at finalize otherwise).
    let ds = stream(1500, 49);
    let k = 6;
    let shared = || -> Box<dyn StreamingAlgorithm> {
        Box::new(StreamClipper::new(oracle(k), k, 1.0, 0.5))
    };
    let per_sieve = || -> Box<dyn StreamingAlgorithm> {
        let mut a = StreamClipper::new(oracle(k), k, 1.0, 0.5);
        a.set_panel_sharing(false);
        Box::new(a)
    };
    assert_panel_sharing_parity(&shared, &per_sieve, &ds);
}

#[test]
fn subsampled_panel_sharing_parity() {
    // The wrapper thins the chunk *before* the inner algorithm sees it, so
    // the broker operates on the kept rows only — parity must hold through
    // the extra indirection (incl. the forwarded exec context).
    let ds = stream(1500, 50);
    let k = 6;
    let shared = || -> Box<dyn StreamingAlgorithm> {
        Box::new(Subsampled::new(Box::new(SieveStreaming::new(oracle(k), k, 0.1)), 0.5, 7))
    };
    let per_sieve = || -> Box<dyn StreamingAlgorithm> {
        let mut inner = SieveStreaming::new(oracle(k), k, 0.1);
        inner.set_panel_sharing(false);
        Box::new(Subsampled::new(Box::new(inner), 0.5, 7))
    };
    assert_panel_sharing_parity(&shared, &per_sieve, &ds);
}

/// §Perf iteration 7 acceptance: scalar vs blocked vs per-candidate
/// solves under the broker must agree on values, summaries, queries AND
/// kernel_evals at `--threads off`, 2 and 8. The coarse ε keeps the live
/// sieve count below `2 × threads` at 8 threads, so the 2-D
/// (sieve × candidate-range) solve grid engages there while `off`/2 run
/// the unit-serial paths — every combination must be bit-identical.
#[test]
fn blocked_solve_grid_parity_across_threads() {
    let ds = stream(1500, 47);
    let k = 6;
    let n = ds.len();
    type Build<'a> = &'a dyn Fn(Box<dyn SubmodularFunction>) -> Box<dyn StreamingAlgorithm>;
    let ss = |o: Box<dyn SubmodularFunction>| -> Box<dyn StreamingAlgorithm> {
        Box::new(SieveStreaming::new(o, k, 0.3))
    };
    let pp = |o: Box<dyn SubmodularFunction>| -> Box<dyn StreamingAlgorithm> {
        Box::new(SieveStreamingPP::new(o, k, 0.3))
    };
    let salsa = |o: Box<dyn SubmodularFunction>| -> Box<dyn StreamingAlgorithm> {
        Box::new(Salsa::new(o, k, 0.8, Some(n)))
    };
    let builds: [(&str, Build<'_>); 3] =
        [("SieveStreaming", &ss), ("SieveStreaming++", &pp), ("Salsa", &salsa)];
    for (name, build) in builds {
        let scalar = run_scalar(build(oracle(k)), &ds);
        let blocked_off = run_batched(build(oracle(k)), &ds, Parallelism::Off);
        assert_same_semantics(&format!("{name} blocked vs scalar"), &blocked_off, &scalar);
        for par in [Parallelism::Off, Parallelism::Threads(2), Parallelism::Threads(8)] {
            let blocked = run_batched(build(oracle(k)), &ds, par);
            let percand = run_batched(build(percand_oracle(k)), &ds, par);
            let label = format!("{name} threads={par}");
            assert_eq!(blocked_off.0, blocked.0, "{label}: value bits");
            assert_eq!(blocked_off.1, blocked.1, "{label}: summary rows");
            assert_eq!(blocked_off.2, blocked.2, "{label}: stats (incl. kernel_evals)");
            assert_eq!(blocked.0, percand.0, "{label}: per-candidate value bits");
            assert_eq!(blocked.1, percand.1, "{label}: per-candidate summary rows");
            assert_eq!(blocked.2, percand.2, "{label}: per-candidate stats");
        }
    }
}

/// Checkpoint → restore → continue with the blocked solves active and
/// the 2-D solve grid engaged (8 threads over a coarse sieve set): the
/// resumed run must be bit-identical to the run that never paused.
#[test]
fn checkpoint_resume_roundtrip_under_blocked_solve_grid() {
    let ds = stream(1600, 48);
    let k = 6;
    let build = || SieveStreaming::new(oracle(k), k, 0.3);
    let half = ds.len() / 2 * DIM;
    let exec = ExecContext::new(Parallelism::Threads(8));

    let mut whole = build();
    let mut first = build();
    whole.set_exec(exec.clone());
    first.set_exec(exec.clone());
    for block in ds.raw()[..half].chunks(CHUNK * DIM) {
        whole.process_batch(block);
        first.process_batch(block);
    }
    let state = first.snapshot_state().expect("SieveStreaming snapshots under the grid");
    let parsed = threesieves::util::json::Json::parse(&state.to_string()).unwrap();
    let summary = first.summary();

    let mut resumed = build();
    resumed.restore_state(&parsed, &summary).unwrap();
    resumed.set_exec(exec.clone());
    assert_eq!(resumed.value().to_bits(), first.value().to_bits());
    assert_eq!(resumed.stats(), first.stats());
    for block in ds.raw()[half..].chunks(CHUNK * DIM) {
        whole.process_batch(block);
        resumed.process_batch(block);
    }
    assert_eq!(resumed.value().to_bits(), whole.value().to_bits());
    assert_eq!(resumed.summary(), whole.summary());
    assert_eq!(resumed.stats(), whole.stats(), "stats must survive the pause under the grid");
}

/// The acceptance working point: a dense multi-sieve grid (ε = 0.01) is
/// exactly where per-sieve panels redo the most work, so the broker must
/// cut measured kernel evaluations by at least 2× — in practice far more,
/// since U ≪ Σ per-sieve summary sizes.
#[test]
fn shared_panels_halve_kernel_evals_at_eps_001() {
    let ds = stream(1500, 44);
    let k = 16;
    let mut shared = SieveStreaming::new(oracle(k), k, 0.01);
    let mut plain = SieveStreaming::new(oracle(k), k, 0.01);
    plain.set_panel_sharing(false);
    for block in ds.raw().chunks(CHUNK * DIM) {
        shared.process_batch(block);
        plain.process_batch(block);
    }
    let (se, pe) = (shared.stats().kernel_evals, plain.stats().kernel_evals);
    assert_eq!(shared.value().to_bits(), plain.value().to_bits());
    assert_eq!(shared.stats().queries, plain.stats().queries);
    assert!(
        se * 2 <= pe,
        "broker must cut kernel evals ≥2× at ε=0.01: shared {se} vs per-sieve {pe}"
    );
}

/// Mixed ingestion: scalar and batched calls interleaved on the same
/// instance — the broker's interned ids must stay coherent across both
/// paths (scalar accepts intern too).
#[test]
fn mixed_scalar_and_batched_ingestion_stays_coherent() {
    let ds = stream(1200, 45);
    let k = 6;
    let mut mixed = SieveStreaming::new(oracle(k), k, 0.1);
    let mut scalar = SieveStreaming::new(oracle(k), k, 0.1);
    let rows = ds.len();
    let third = rows / 3;
    for row in ds.raw()[..third * DIM].chunks_exact(DIM) {
        mixed.process(row);
        scalar.process(row);
    }
    for block in ds.raw()[third * DIM..2 * third * DIM].chunks(17 * DIM) {
        mixed.process_batch(block);
    }
    for row in ds.raw()[third * DIM..2 * third * DIM].chunks_exact(DIM) {
        scalar.process(row);
    }
    for row in ds.raw()[2 * third * DIM..].chunks_exact(DIM) {
        mixed.process(row);
        scalar.process(row);
    }
    assert_eq!(mixed.value().to_bits(), scalar.value().to_bits());
    assert_eq!(mixed.summary(), scalar.summary());
    assert_eq!(mixed.stats().queries, scalar.stats().queries);
}

/// Checkpoint → JSON text → restore → continue, with the broker active on
/// both timelines and the continuation running on the exec pool: the
/// resumed run must be bit-identical to the run that never paused —
/// values, summaries and the full stats struct, kernel evals included.
#[test]
fn checkpoint_resume_roundtrip_under_the_broker() {
    let ds = stream(1600, 46);
    let k = 6;
    let build = || SieveStreaming::new(oracle(k), k, 0.1);
    let half = ds.len() / 2 * DIM;
    let exec = ExecContext::new(Parallelism::Threads(2));

    let mut whole = build();
    let mut first = build();
    whole.set_exec(exec.clone());
    first.set_exec(exec.clone());
    for block in ds.raw()[..half].chunks(CHUNK * DIM) {
        whole.process_batch(block);
        first.process_batch(block);
    }
    let state = first.snapshot_state().expect("SieveStreaming snapshots under the broker");
    let text = state.to_string();
    let parsed = threesieves::util::json::Json::parse(&text).unwrap();
    let summary = first.summary();

    let mut resumed = build();
    resumed.restore_state(&parsed, &summary).unwrap();
    resumed.set_exec(exec.clone());
    assert_eq!(resumed.value().to_bits(), first.value().to_bits());
    assert_eq!(resumed.stats(), first.stats(), "restore must reproduce the reported stats");

    for block in ds.raw()[half..].chunks(CHUNK * DIM) {
        whole.process_batch(block);
        resumed.process_batch(block);
    }
    assert_eq!(resumed.value().to_bits(), whole.value().to_bits());
    assert_eq!(resumed.summary(), whole.summary());
    assert_eq!(resumed.stats(), whole.stats(), "kernel-eval accounting must survive the pause");
}
