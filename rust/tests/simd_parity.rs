//! SIMD backend parity: every dispatch table must be **bitwise
//! identical** to the scalar reference on every shape.
//!
//! Two layers of pinning:
//!
//! 1. **Primitive parity** — the five [`threesieves::simd::Ops`]
//!    primitives (f32 dot, interleaved 4-candidate dot, f64 dot,
//!    squared distance, batched RBF entry pass) and the blocked
//!    [`kernel_panel_into`] are compared `to_bits` against the scalar
//!    table over randomized shapes: odd dims, vector tails of 0–3
//!    elements past the lane width, empty inputs, candidate blocks
//!    B ∈ {1, 3, 4, 64}. These use the explicit tables
//!    ([`scalar_ops`]/[`simd_ops`]) and never touch the process-wide
//!    selection, so they are race-free under the parallel test runner.
//! 2. **End-to-end rosters** — full streaming runs with the backend
//!    forced via [`select`] must produce bit-identical values,
//!    summaries and stats at `--threads off`, 2 and 8, and across a
//!    checkpoint/resume pause. These flip the global dispatch slot, so
//!    they serialize on a local mutex.
//!
//! On machines without AVX2/NEON `simd_ops()` is `None` and the SIMD
//! half of each test self-skips — the scalar half still runs, so the
//! suite compiles and passes on every target.

use std::sync::{Mutex, OnceLock};

use threesieves::algorithms::{SieveStreaming, StreamingAlgorithm};
use threesieves::data::synthetic::{Mixture, MixtureSource};
use threesieves::data::{Dataset, StreamSource};
use threesieves::exec::{ExecContext, Parallelism};
use threesieves::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
use threesieves::metrics::AlgoStats;
use threesieves::simd::{self, kernel_panel_into, scalar_ops, simd_ops, BackendChoice, Ops};
use threesieves::util::rng::Rng;

/// Dims covering every tail class (len % 4 ∈ {0,1,2,3}), the empty
/// vector, single elements, odd primes and the bench working points.
const DIMS: [usize; 16] = [0, 1, 2, 3, 4, 5, 7, 8, 11, 16, 17, 19, 31, 64, 127, 128];

fn f32_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

fn f64_vec(rng: &mut Rng, len: usize) -> Vec<f64> {
    (0..len).map(|_| rng.normal()).collect()
}

#[test]
fn dot_and_sq_dist_parity_across_shapes() {
    let Some(simd) = simd_ops() else { return };
    let scalar = scalar_ops();
    for seed in [1u64, 2, 3] {
        let mut rng = Rng::seed_from(seed);
        for d in DIMS {
            let a = f32_vec(&mut rng, d);
            let b = f32_vec(&mut rng, d);
            let label = format!("seed={seed} d={d}");
            assert_eq!(
                (simd.dot)(&a, &b).to_bits(),
                (scalar.dot)(&a, &b).to_bits(),
                "dot {label}"
            );
            assert_eq!(
                (simd.sq_dist)(&a, &b).to_bits(),
                (scalar.sq_dist)(&a, &b).to_bits(),
                "sq_dist {label}"
            );
            let af = f64_vec(&mut rng, d);
            let bf = f64_vec(&mut rng, d);
            assert_eq!(
                (simd.dot_f64)(&af, &bf).to_bits(),
                (scalar.dot_f64)(&af, &bf).to_bits(),
                "dot_f64 {label}"
            );
        }
    }
}

#[test]
fn dot_x4_parity_and_lane_structure() {
    let scalar = scalar_ops();
    for seed in [4u64, 5] {
        let mut rng = Rng::seed_from(seed);
        for d in DIMS {
            let xs_owned: [Vec<f32>; 4] = [
                f32_vec(&mut rng, d),
                f32_vec(&mut rng, d),
                f32_vec(&mut rng, d),
                f32_vec(&mut rng, d),
            ];
            let xs: [&[f32]; 4] = [&xs_owned[0], &xs_owned[1], &xs_owned[2], &xs_owned[3]];
            let row = f32_vec(&mut rng, d);
            let want = (scalar.dot_x4)(&xs, &row);
            // Lane structure: each interleaved lane is exactly the
            // plain dot of its candidate — that is what lets the panel
            // builder mix blocked and tail candidates bitwise-freely.
            for q in 0..4 {
                assert_eq!(
                    want[q].to_bits(),
                    (scalar.dot)(xs[q], &row).to_bits(),
                    "scalar lane {q} d={d}"
                );
            }
            if let Some(simd) = simd_ops() {
                let got = (simd.dot_x4)(&xs, &row);
                for q in 0..4 {
                    assert_eq!(
                        got[q].to_bits(),
                        want[q].to_bits(),
                        "simd lane {q} seed={seed} d={d}"
                    );
                }
            }
        }
    }
}

#[test]
fn rbf_entries_parity_including_cutoff_and_clamp() {
    let scalar = scalar_ops();
    for gamma in [0.25f64, 1.0, 17.5] {
        let mut rng = Rng::seed_from(6);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 64, 67] {
            // Mix ordinary squared distances with negatives (the
            // cancellation clamp) and entries past the exp-32 cutoff.
            let d2: Vec<f64> = (0..len)
                .map(|i| match i % 3 {
                    0 => rng.normal().abs(),
                    1 => -rng.normal().abs() * 1e-3,
                    _ => rng.normal().abs() * 40.0,
                })
                .collect();
            let mut want = d2.clone();
            (scalar.rbf_entries)(gamma, &mut want);
            // The batched pass is elementwise `rbf_entry`.
            for (i, (&w, &x)) in want.iter().zip(&d2).enumerate() {
                assert_eq!(
                    w.to_bits(),
                    simd::rbf_entry(gamma, x).to_bits(),
                    "scalar elementwise gamma={gamma} len={len} i={i}"
                );
            }
            if let Some(simd_t) = simd_ops() {
                let mut got = d2.clone();
                (simd_t.rbf_entries)(gamma, &mut got);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "simd gamma={gamma} len={len} i={i}");
                }
            }
        }
    }
}

/// Build one panel under `ops` — candidates × summary rows.
fn panel_under(
    ops: &Ops,
    feats: &[f32],
    d: usize,
    n: usize,
    gamma: f64,
    items: &[f32],
    count: usize,
) -> Vec<f64> {
    let scalar = scalar_ops();
    let row_norms: Vec<f64> = feats.chunks_exact(d.max(1)).map(|r| (scalar.dot)(r, r)).collect();
    let mut out = vec![0.0f64; count * n];
    kernel_panel_into(ops, feats, &row_norms, d, n, gamma, items, count, &mut out);
    out
}

#[test]
fn kernel_panel_parity_across_block_shapes() {
    let gamma = 0.7;
    let mut rng = Rng::seed_from(7);
    for d in [3usize, 8, 17] {
        for n in [0usize, 1, 9] {
            for count in [1usize, 3, 4, 64] {
                let feats = f32_vec(&mut rng, n * d);
                let items = f32_vec(&mut rng, count * d);
                let scalar_panel = panel_under(scalar_ops(), &feats, d, n, gamma, &items, count);
                // The scalar panel must equal entrywise `rbf_entry` of
                // the ‖x‖²+‖s‖²−2⟨x,s⟩ decomposition — the defining
                // identity the blocked build promises.
                let sc = scalar_ops();
                for b in 0..count {
                    let x = &items[b * d..(b + 1) * d];
                    let xsq = (sc.dot)(x, x);
                    for i in 0..n {
                        let row = &feats[i * d..(i + 1) * d];
                        let d2 = xsq + (sc.dot)(row, row) - 2.0 * (sc.dot)(x, row);
                        assert_eq!(
                            scalar_panel[b * n + i].to_bits(),
                            simd::rbf_entry(gamma, d2).to_bits(),
                            "scalar panel entry d={d} n={n} count={count} b={b} i={i}"
                        );
                    }
                }
                if let Some(simd_t) = simd_ops() {
                    let simd_panel = panel_under(simd_t, &feats, d, n, gamma, &items, count);
                    for (i, (s, r)) in simd_panel.iter().zip(&scalar_panel).enumerate() {
                        assert_eq!(
                            s.to_bits(),
                            r.to_bits(),
                            "panel d={d} n={n} count={count} entry {i}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn empty_panel_is_a_no_op() {
    for ops in [Some(scalar_ops()), simd_ops()].into_iter().flatten() {
        let mut out: Vec<f64> = Vec::new();
        kernel_panel_into(ops, &[], &[], 4, 0, 1.0, &[], 0, &mut out);
        assert!(out.is_empty(), "{}", ops.name);
    }
}

// ---------------------------------------------------------------------
// End-to-end rosters: the global dispatch slot is process-wide, so the
// tests below serialize on one mutex and restore the environment's
// choice before returning.
// ---------------------------------------------------------------------

fn backend_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

const DIM: usize = 8;
const CHUNK: usize = 64;

fn stream(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from(seed);
    let mix = Mixture::random(DIM, 4, 5.0, 0.5, &mut rng);
    let mut ds = MixtureSource::new(mix, n, seed).materialize("simd-parity", n);
    ds.normalize();
    ds
}

fn oracle(k: usize) -> Box<dyn SubmodularFunction> {
    Box::new(NativeLogDet::new(LogDetConfig::with_gamma(DIM, k, 1.0, 1.0)))
}

fn run_roster(ds: &Dataset, k: usize, par: Parallelism) -> (u64, Vec<f32>, AlgoStats) {
    let mut algo = SieveStreaming::new(oracle(k), k, 0.1);
    algo.set_exec(ExecContext::new(par));
    for block in ds.raw().chunks(CHUNK * DIM) {
        algo.process_batch(block);
    }
    algo.finalize();
    (algo.value().to_bits(), algo.summary(), algo.stats())
}

/// Forcing `simd` must be invisible end to end: bit-identical value,
/// summary and the full stats struct against the pinned scalar backend,
/// at every thread count. On machines without AVX2/NEON `Simd` resolves
/// to the scalar table and the comparison is trivially exact — the
/// fallback contract itself.
#[test]
fn e2e_backend_is_bitwise_invisible_across_threads() {
    let _g = backend_lock().lock().unwrap_or_else(|e| e.into_inner());
    let ds = stream(1500, 51);
    let k = 6;
    for par in [Parallelism::Off, Parallelism::Threads(2), Parallelism::Threads(8)] {
        simd::select(BackendChoice::Scalar);
        let scalar = run_roster(&ds, k, par);
        simd::select(BackendChoice::Simd);
        let simd_run = run_roster(&ds, k, par);
        let label = format!("threads={par}");
        assert_eq!(scalar.0, simd_run.0, "{label}: value bits");
        assert_eq!(scalar.1, simd_run.1, "{label}: summary rows");
        assert_eq!(scalar.2, simd_run.2, "{label}: stats (incl. kernel_evals)");
    }
    simd::select(simd::env_choice());
}

/// Checkpoint under the scalar backend, resume under `simd` (and the
/// reverse): the pause, the backend flip and the continuation must all
/// be bitwise invisible against an unpaused scalar run.
#[test]
fn e2e_checkpoint_resume_survives_a_backend_flip() {
    let _g = backend_lock().lock().unwrap_or_else(|e| e.into_inner());
    let ds = stream(1600, 52);
    let k = 6;
    let half = ds.len() / 2 * DIM;
    let exec = ExecContext::new(Parallelism::Threads(2));
    let build = || SieveStreaming::new(oracle(k), k, 0.1);

    simd::select(BackendChoice::Scalar);
    let mut whole = build();
    whole.set_exec(exec.clone());
    for block in ds.raw().chunks(CHUNK * DIM) {
        whole.process_batch(block);
    }

    for (first_be, second_be) in [
        (BackendChoice::Scalar, BackendChoice::Simd),
        (BackendChoice::Simd, BackendChoice::Scalar),
    ] {
        simd::select(first_be);
        let mut first = build();
        first.set_exec(exec.clone());
        for block in ds.raw()[..half].chunks(CHUNK * DIM) {
            first.process_batch(block);
        }
        let state = first.snapshot_state().expect("SieveStreaming snapshots");
        let parsed = threesieves::util::json::Json::parse(&state.to_string()).unwrap();
        let summary = first.summary();

        simd::select(second_be);
        let mut resumed = build();
        resumed.restore_state(&parsed, &summary).unwrap();
        resumed.set_exec(exec.clone());
        for block in ds.raw()[half..].chunks(CHUNK * DIM) {
            resumed.process_batch(block);
        }
        let label = format!("{first_be:?}→{second_be:?}");
        assert_eq!(resumed.value().to_bits(), whole.value().to_bits(), "{label}: value");
        assert_eq!(resumed.summary(), whole.summary(), "{label}: summary");
        assert_eq!(resumed.stats(), whole.stats(), "{label}: stats");
    }
    simd::select(simd::env_choice());
}
