//! Service integration: the multi-tenant acceptance gate.
//!
//! * 8 concurrent tenants over real TCP (loopback), heterogeneous
//!   algorithms/dims, CSV and packed encodings — every per-session summary,
//!   value and stat must be **bit-identical** to running the same stream
//!   standalone in-process.
//! * The `METRICS` snapshot's aggregate item/query counts must equal the
//!   sum of the per-session `STATS` replies.
//! * Close → re-`OPEN` resumes from the checkpoint and finishes
//!   bit-identically to a never-interrupted run.
//! * Admission control refuses over-cap `OPEN`s with typed error codes.

use std::path::PathBuf;
use std::time::Duration;

use threesieves::algorithms::StreamingAlgorithm;
use threesieves::config::{AlgoSpec, ServiceConfig};
use threesieves::coordinator::checkpoint::Checkpoint;
use threesieves::data::registry;
use threesieves::exec::Parallelism;
use threesieves::experiments::{build_algo, GammaMode};
use threesieves::metrics::AlgoStats;
use threesieves::service::{Client, ClientError, ErrorCode, Server, SessionSpec, WatchMode};
use threesieves::util::json::Json;

const CHUNK_ROWS: usize = 64;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ts_svc_it_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Standalone replay: the same chunks through the same spec, no service.
fn standalone(spec: &SessionSpec, raw: &[f32]) -> (f64, Vec<f32>, AlgoStats) {
    let mut algo = build_algo(&spec.algo, spec.dim, spec.k, GammaMode::Streaming, None);
    for chunk in raw.chunks(CHUNK_ROWS * spec.dim) {
        algo.process_batch(chunk);
    }
    (algo.value(), algo.summary(), algo.stats())
}

/// One tenant's workload: dataset surrogate + session spec.
fn tenant(i: usize) -> (&'static str, usize, u64, SessionSpec) {
    let ts = |eps: f64, t: u64| AlgoSpec::three_sieves(eps, t);
    let spec = |algo: AlgoSpec, dim: usize, k: usize| SessionSpec { algo, dim, k, drift: None };
    match i {
        0 => ("fact-highlevel-like", 400, 1, spec(ts(0.01, 80), 16, 6)),
        1 => ("forestcover-like", 500, 2, spec(ts(0.005, 50), 10, 5)),
        2 => {
            let algo = AlgoSpec::subsampled_sieve_streaming(0.1, 0.5, 11);
            ("abc-like", 300, 3, spec(algo, 50, 4))
        }
        3 => ("creditfraud-like", 350, 4, spec(AlgoSpec::sieve_streaming_pp(0.1), 29, 4)),
        4 => ("kddcup-like", 300, 5, spec(AlgoSpec::salsa(0.1, false), 41, 4)),
        5 => ("fact-highlevel-like", 450, 6, spec(AlgoSpec::quickstream(2, 0.1, 7), 16, 5)),
        6 => ("stream51-like", 400, 7, spec(AlgoSpec::stream_clipper(1.0, 0.5), 64, 6)),
        _ => {
            let algo = AlgoSpec::sharded_three_sieves(0.02, 60, 3);
            ("examiner-like", 350, 8, spec(algo, 50, 5))
        }
    }
}

#[test]
fn eight_concurrent_tenants_over_tcp_match_standalone() {
    let cfg = ServiceConfig {
        idle_timeout: Duration::ZERO,
        parallelism: Parallelism::Threads(10),
        ..ServiceConfig::default()
    };
    let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
    let addr = handle.addr();

    let workers: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let (dataset, n, seed, spec) = tenant(i);
                let ds = registry::get(dataset, n, seed).unwrap();
                assert_eq!(ds.dim(), spec.dim, "tenant {i} dim");
                let id = format!("tenant-{i}");
                let mut client = Client::connect(addr).unwrap();
                assert!(!client.open(&id, &spec).unwrap(), "tenant {i}: fresh open");
                let (want_value, want_summary, want_stats) = standalone(&spec, ds.raw());
                let mut last = None;
                for chunk in ds.raw().chunks(CHUNK_ROWS * spec.dim) {
                    // Alternate encodings: both must be bit-exact on the wire.
                    let reply = if i % 2 == 0 {
                        client.push_packed(&id, chunk).unwrap()
                    } else {
                        client.push_rows(&id, chunk, spec.dim).unwrap()
                    };
                    last = Some(reply);
                }
                let last = last.unwrap();
                assert_eq!(last.value.to_bits(), want_value.to_bits(), "tenant {i}: value");
                let got = client.summary(&id).unwrap();
                assert_eq!(got.dim, spec.dim);
                assert_eq!(got.data, want_summary, "tenant {i}: summary bits");
                let stats = client.stats(&id).unwrap();
                assert_eq!(stats.stats, want_stats, "tenant {i}: stats");
                assert_eq!(stats.stats.elements, n as u64);
                // Session stays open so the metrics check below can
                // aggregate it; the connection closes politely.
                client.quit().unwrap();
                stats.stats
            })
        })
        .collect();

    let mut sum = AlgoStats::default();
    let mut stored_sum = 0usize;
    for w in workers {
        let st = w.join().unwrap();
        sum.queries += st.queries;
        sum.kernel_evals += st.kernel_evals;
        sum.elements += st.elements;
        stored_sum += st.stored;
    }

    // The acceptance invariant: service-wide aggregates equal the sum of
    // per-session AlgoStats.
    let mut client = Client::connect(addr).unwrap();
    let m = client.metrics().unwrap();
    assert_eq!(m.sessions, 8);
    assert_eq!(m.items, sum.elements, "metrics items != sum of session elements");
    assert_eq!(m.queries, sum.queries, "metrics queries != sum of session queries");
    assert_eq!(m.kernel_evals, sum.kernel_evals, "metrics kernel_evals != session sum");
    assert_eq!(m.stored, stored_sum);
    assert_eq!(m.items_total, sum.elements);
    assert_eq!(m.opens, 8);
    for i in 0..8 {
        assert!(!client.close(&format!("tenant-{i}"), true).unwrap());
    }
    assert_eq!(client.metrics().unwrap().sessions, 0);
    client.quit().unwrap();
    handle.shutdown();
}

#[test]
fn close_reopen_resumes_bit_identically_over_tcp() {
    let dir = tmpdir("resume");
    let cfg = ServiceConfig {
        idle_timeout: Duration::ZERO,
        checkpoint_dir: Some(dir.clone()),
        parallelism: Parallelism::Threads(2),
        ..ServiceConfig::default()
    };
    let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
    let spec = SessionSpec::three_sieves(16, 6, 0.01, 70);
    let ds = registry::get("fact-highlevel-like", 800, 21).unwrap();
    let half = ds.len() / 2 * ds.dim();

    let mut client = Client::connect(handle.addr()).unwrap();
    assert!(!client.open("res", &spec).unwrap());
    for chunk in ds.raw()[..half].chunks(CHUNK_ROWS * spec.dim) {
        client.push_packed("res", chunk).unwrap();
    }
    assert!(client.close("res", false).unwrap(), "close must checkpoint");
    let ckpt_path = dir.join("res.ckpt");
    let ck = Checkpoint::load(&ckpt_path).unwrap();
    assert_ne!(ck.state, Json::Null, "resumable state must be persisted");
    assert_eq!(ck.elements, (ds.len() / 2) as u64);
    assert!(!dir.join("res.ckpt.tmp").exists(), "atomic save leaves no staging file");

    // Re-OPEN resumes and the continued run is bit-identical to one that
    // never paused.
    assert!(client.open("res", &spec).unwrap(), "must resume from checkpoint");
    for chunk in ds.raw()[half..].chunks(CHUNK_ROWS * spec.dim) {
        client.push_packed("res", chunk).unwrap();
    }
    let (want_value, want_summary, want_stats) = standalone(&spec, ds.raw());
    let got = client.summary("res").unwrap();
    assert_eq!(got.value.to_bits(), want_value.to_bits());
    assert_eq!(got.data, want_summary);
    let stats = client.stats("res").unwrap();
    // Everything the paper accounts is chunking-invariant and must match
    // the never-paused run exactly. `kernel_evals` is *measured* work and
    // legitimately depends on chunk boundaries, which differ across the
    // pause point - assert it separately.
    assert_eq!(stats.stats.queries, want_stats.queries, "queries must continue across the pause");
    assert_eq!(stats.stats.elements, want_stats.elements);
    assert_eq!(stats.stats.stored, want_stats.stored);
    assert_eq!(stats.stats.peak_stored, want_stats.peak_stored);
    assert_eq!(stats.stats.instances, want_stats.instances);
    assert!(stats.stats.kernel_evals > 0, "resumed accounting must keep counting kernel work");
    client.quit().unwrap();
    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Close → re-`OPEN` roundtrip for one spec over real TCP: the checkpoint
/// must carry resumable state, and the resumed run must finish with the
/// same values, summary and chunking-invariant stats as a standalone run
/// that never paused.
fn assert_resume_roundtrip(tag: &str, spec: SessionSpec, n: usize, seed: u64) {
    let dir = tmpdir(tag);
    let cfg = ServiceConfig {
        idle_timeout: Duration::ZERO,
        checkpoint_dir: Some(dir.clone()),
        parallelism: Parallelism::Threads(2),
        ..ServiceConfig::default()
    };
    let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
    let ds = registry::get("fact-highlevel-like", n, seed).unwrap();
    assert_eq!(ds.dim(), spec.dim, "{tag}: dataset dim");
    let half = ds.len() / 2 * ds.dim();
    let mut client = Client::connect(handle.addr()).unwrap();
    assert!(!client.open(tag, &spec).unwrap(), "{tag}: fresh open");
    for chunk in ds.raw()[..half].chunks(CHUNK_ROWS * spec.dim) {
        client.push_packed(tag, chunk).unwrap();
    }
    assert!(client.close(tag, false).unwrap(), "{tag}: close must checkpoint");
    let ck = Checkpoint::load(&dir.join(format!("{tag}.ckpt"))).unwrap();
    assert_ne!(ck.state, Json::Null, "{tag}: resumable state must be persisted");
    assert!(client.open(tag, &spec).unwrap(), "{tag}: must resume from checkpoint");
    for chunk in ds.raw()[half..].chunks(CHUNK_ROWS * spec.dim) {
        client.push_packed(tag, chunk).unwrap();
    }
    let (want_value, want_summary, want_stats) = standalone(&spec, ds.raw());
    let got = client.summary(tag).unwrap();
    assert_eq!(got.value.to_bits(), want_value.to_bits(), "{tag}: value");
    assert_eq!(got.data, want_summary, "{tag}: summary bits");
    let stats = client.stats(tag).unwrap();
    assert_eq!(stats.stats.queries, want_stats.queries, "{tag}: queries across the pause");
    assert_eq!(stats.stats.elements, want_stats.elements, "{tag}: elements");
    assert_eq!(stats.stats.stored, want_stats.stored, "{tag}: stored");
    assert_eq!(stats.stats.peak_stored, want_stats.peak_stored, "{tag}: peak_stored");
    assert_eq!(stats.stats.instances, want_stats.instances, "{tag}: instances");
    client.quit().unwrap();
    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stream_clipper_close_reopen_resumes_bit_identically_over_tcp() {
    let algo = AlgoSpec::stream_clipper(1.0, 0.5);
    assert_resume_roundtrip("clip-res", SessionSpec { algo, dim: 16, k: 6, drift: None }, 800, 22);
}

#[test]
fn subsampled_close_reopen_resumes_bit_identically_over_tcp() {
    // The thinning coin's stream index rides the checkpoint, so the
    // resumed wrapper keeps the identical kept/dropped sequence.
    let algo = AlgoSpec::subsampled_sieve_streaming(0.1, 0.5, 7);
    assert_resume_roundtrip("sub-res", SessionSpec { algo, dim: 16, k: 6, drift: None }, 800, 23);
}

#[test]
fn shutdown_checkpoints_open_sessions() {
    let dir = tmpdir("shutdown");
    let cfg = ServiceConfig {
        idle_timeout: Duration::ZERO,
        checkpoint_dir: Some(dir.clone()),
        ..ServiceConfig::default()
    };
    let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
    let spec = SessionSpec::three_sieves(16, 5, 0.02, 40);
    let ds = registry::get("fact-highlevel-like", 300, 33).unwrap();
    let mut client = Client::connect(handle.addr()).unwrap();
    client.open("sd", &spec).unwrap();
    client.push_packed("sd", ds.raw()).unwrap();
    client.quit().unwrap();
    let m = handle.shutdown();
    assert_eq!(m.sessions, 1, "snapshot taken before sessions close");
    let ck = Checkpoint::load(&dir.join("sd.ckpt")).unwrap();
    assert_eq!(ck.elements, ds.len() as u64);
    std::fs::remove_dir_all(&dir).ok();
}

/// PR-8 acceptance: a `WATCH` subscriber streams frames while a second
/// connection pushes a real workload, and the frame stream ends up
/// consistent with the final `METRICS` reply — cumulative event totals
/// never regress across frames, sequence numbers strictly increase, and
/// once the workload is done the process-wide totals in a fresh frame
/// cover the session's decision counters (they aggregate at least this
/// server's session, possibly more from tests sharing the process).
#[test]
fn watch_streams_frames_while_second_connection_pushes() {
    threesieves::obs::set_enabled(true);
    let cfg = ServiceConfig {
        idle_timeout: Duration::ZERO,
        parallelism: Parallelism::Threads(4),
        ..ServiceConfig::default()
    };
    let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
    let addr = handle.addr();

    let mut watcher = Client::connect(addr).unwrap();
    let granted = watcher.watch(100, WatchMode::All).unwrap();
    assert!(granted >= 100, "server honors (or clamps up) the requested interval");
    let first = watcher.next_frame().unwrap();
    assert!(first.events.is_some() && first.hists.is_some(), "mode=all carries both sections");

    // The workload runs on its own connection while frames tick.
    let pusher = std::thread::spawn(move || {
        let ds = registry::get("fact-highlevel-like", 600, 44).unwrap();
        let spec = SessionSpec::three_sieves(ds.dim(), 6, 0.01, 100);
        let mut client = Client::connect(addr).unwrap();
        assert!(!client.open("watched", &spec).unwrap());
        for chunk in ds.raw().chunks(CHUNK_ROWS * ds.dim()) {
            client.push_packed("watched", chunk).unwrap();
        }
        let m = client.metrics().unwrap();
        client.quit().unwrap();
        m
    });
    let m = pusher.join().unwrap();
    assert!(m.accepts > 0 && m.rejects > 0, "METRICS must expose live decision aggregates");

    // Frames already in flight may predate the workload's end; keep
    // reading (they arrive every interval regardless) until one's totals
    // cover the finished session. Every frame on the way must keep the
    // stream invariants.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let mut last = first;
    loop {
        let frame = watcher.next_frame().unwrap();
        assert!(frame.seq > last.seq, "frame sequence must strictly increase");
        assert!(frame.dropped >= last.dropped, "the coalescing counter is cumulative");
        let (now, prev) = (frame.events.unwrap(), last.events.unwrap());
        assert!(
            now.accepts >= prev.accepts
                && now.rejects >= prev.rejects
                && now.defers >= prev.defers,
            "cumulative event totals must never regress: {now:?} after {prev:?}"
        );
        last = frame;
        if now.accepts >= m.accepts && now.rejects >= m.rejects && now.defers >= m.defers {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "frames never caught up with METRICS: {now:?} vs {m:?}"
        );
    }
    handle.shutdown();
    threesieves::obs::set_enabled(false);
}

#[test]
fn admission_and_validation_errors_over_tcp() {
    let cfg = ServiceConfig {
        idle_timeout: Duration::ZERO,
        max_sessions: 2,
        max_total_stored: 10,
        ..ServiceConfig::default()
    };
    let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
    let mut client = Client::connect(handle.addr()).unwrap();
    let small = SessionSpec::three_sieves(4, 4, 0.05, 20);
    client.open("a", &small).unwrap();
    client.open("b", &small).unwrap();
    // Session cap.
    match client.open("c", &small) {
        Err(ClientError::Server { code: ErrorCode::SessionLimit, .. }) => {}
        other => panic!("expected session-limit, got {other:?}"),
    }
    // Reservation cap: 4 + 4 + 7 > 10 even under the session cap.
    client.close("b", true).unwrap();
    match client.open("c", &SessionSpec::three_sieves(4, 7, 0.05, 20)) {
        Err(ClientError::Server { code: ErrorCode::Capacity, .. }) => {}
        other => panic!("expected capacity, got {other:?}"),
    }
    // Dim mismatch and unknown session are typed too.
    match client.push_rows("a", &[1.0, 2.0, 3.0], 3) {
        Err(ClientError::Server { code: ErrorCode::DimMismatch, .. }) => {}
        other => panic!("expected dim-mismatch, got {other:?}"),
    }
    match client.stats("ghost") {
        Err(ClientError::Server { code: ErrorCode::NoSession, .. }) => {}
        other => panic!("expected no-session, got {other:?}"),
    }
    match client.open("a", &small) {
        Err(ClientError::Server { code: ErrorCode::Exists, .. }) => {}
        other => panic!("expected exists, got {other:?}"),
    }
    client.quit().unwrap();
    handle.shutdown();
}
