//! Integration: the three-layer composition.
//!
//! Loads the AOT artifacts produced by `make artifacts` (L1 Pallas kernel +
//! L2 JAX gain/append graphs lowered to HLO text), executes them through
//! the PJRT CPU client, and checks the PJRT-backed oracle agrees with the
//! pure-Rust incremental-Cholesky oracle — then runs a full ThreeSieves
//! selection on top of the compiled artifact.
//!
//! Skips (with a loud message) when `artifacts/` has not been built.

// The whole suite needs the real PJRT engine; the default build links the
// dependency-free stub instead (see `runtime::stub`).
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use threesieves::algorithms::three_sieves::SieveTuning;
use threesieves::algorithms::{StreamingAlgorithm, ThreeSieves};
use threesieves::data::registry;
use threesieves::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
use threesieves::runtime::{Engine, Manifest, PjrtLogDet};
use threesieves::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        None
    }
}

fn native_like(cfg: &threesieves::runtime::ArtifactConfig) -> NativeLogDet {
    NativeLogDet::new(LogDetConfig::with_gamma(cfg.d, cfg.k, cfg.gamma, cfg.a))
}

#[test]
fn manifest_and_engine_load() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::cpu().expect("PJRT CPU client");
    assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());
    let manifest = Manifest::load(&dir).expect("manifest");
    assert!(!manifest.configs.is_empty());
    for c in &manifest.configs {
        for ep in ["gain", "append", "value"] {
            let p = manifest.file_path(c, ep).unwrap();
            assert!(p.exists(), "missing artifact {}", p.display());
        }
    }
}

#[test]
fn pjrt_gain_matches_native_on_empty_summary() {
    let Some(dir) = artifacts_dir() else { return };
    let mut oracle = PjrtLogDet::from_artifacts(&dir, "quickstart_d16").expect("artifact oracle");
    let d = oracle.dim();
    let mut rng = Rng::seed_from(1);
    for _ in 0..4 {
        let item: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let g = oracle.peek_gain(&item);
        let want = 0.5 * (2.0f64).ln(); // ½·ln(1+a), a = 1
        assert!((g - want).abs() < 1e-5, "empty-summary gain {g} vs {want}");
    }
}

#[test]
fn pjrt_agrees_with_native_through_a_selection_run() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let graphs =
        threesieves::runtime::pjrt_logdet::GraphSet::load(&engine, &manifest, "quickstart_d16")
            .unwrap();
    let mut pjrt = PjrtLogDet::new(engine, graphs);
    let cfg = manifest.config("quickstart_d16").unwrap().clone();
    let mut native = native_like(&cfg);

    let mut rng = Rng::seed_from(7);
    let mut accepted = 0;
    // Interleave peeks and accepts; the two oracles must track each other.
    for step in 0..60 {
        let item: Vec<f32> = (0..cfg.d).map(|_| (rng.normal() * 0.6) as f32).collect();
        let gp = pjrt.peek_gain(&item);
        let gn = native.peek_gain(&item);
        assert!(
            (gp - gn).abs() < 2e-4 * (1.0 + gn.abs()),
            "step {step}: pjrt {gp} vs native {gn}"
        );
        if gp > 0.25 && accepted < cfg.k {
            pjrt.accept(&item);
            native.accept(&item);
            accepted += 1;
            assert!(
                (pjrt.current_value() - native.current_value()).abs()
                    < 2e-4 * (1.0 + native.current_value()),
                "value divergence after accept {accepted}: {} vs {}",
                pjrt.current_value(),
                native.current_value()
            );
        }
    }
    assert!(accepted > 3, "test must exercise accepts (got {accepted})");
    assert_eq!(pjrt.len(), native.len());
}

#[test]
fn pjrt_batch_matches_singles() {
    let Some(dir) = artifacts_dir() else { return };
    let mut oracle = PjrtLogDet::from_artifacts(&dir, "quickstart_d16").unwrap();
    let d = oracle.dim();
    let b = oracle.batch_size();
    let mut rng = Rng::seed_from(3);
    // Fill a few rows first.
    for _ in 0..5 {
        let item: Vec<f32> = (0..d).map(|_| (rng.normal() * 0.5) as f32).collect();
        oracle.accept(&item);
    }
    let count = b + 3; // force chunking across two executions
    let cands: Vec<f32> = (0..count * d).map(|_| (rng.normal() * 0.5) as f32).collect();
    let mut batch = Vec::new();
    oracle.peek_gain_batch(&cands, count, &mut batch);
    assert_eq!(batch.len(), count);
    for i in 0..count {
        let single = oracle.peek_gain(&cands[i * d..(i + 1) * d]);
        assert!(
            (batch[i] - single).abs() < 1e-6,
            "batch[{i}] {} vs single {single}",
            batch[i]
        );
    }
}

#[test]
fn pjrt_remove_rebuilds_consistently() {
    let Some(dir) = artifacts_dir() else { return };
    let mut oracle = PjrtLogDet::from_artifacts(&dir, "quickstart_d16").unwrap();
    let d = oracle.dim();
    let mut rng = Rng::seed_from(9);
    let items: Vec<Vec<f32>> =
        (0..5).map(|_| (0..d).map(|_| (rng.normal() * 0.5) as f32).collect()).collect();
    for it in &items {
        oracle.accept(it);
    }
    oracle.remove(2);
    assert_eq!(oracle.len(), 4);
    // Compare against native built from the kept rows.
    let manifest = Manifest::load(&dir).unwrap();
    let cfg = manifest.config("quickstart_d16").unwrap().clone();
    let mut native = native_like(&cfg);
    for (i, it) in items.iter().enumerate() {
        if i != 2 {
            native.accept(it);
        }
    }
    assert!(
        (oracle.current_value() - native.current_value()).abs() < 5e-4,
        "{} vs {}",
        oracle.current_value(),
        native.current_value()
    );
}

#[test]
fn threesieves_runs_end_to_end_on_pjrt_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let oracle = PjrtLogDet::from_artifacts(&dir, "stream_d16_k32").expect("stream artifact");
    let k = 10usize;
    let mut algo = ThreeSieves::new(Box::new(oracle), k, 0.05, SieveTuning::FixedT(30));
    // fact-highlevel-like is 16-dim, matching the artifact's d.
    let ds = registry::get("fact-highlevel-like", 600, 5).unwrap();
    for row in ds.iter() {
        algo.process(row);
    }
    assert_eq!(algo.summary_len(), k, "PJRT-backed ThreeSieves must fill K");
    assert!(algo.value() > 0.0);

    // Cross-check the selected value against a native recomputation.
    let manifest = Manifest::load(&dir).unwrap();
    let cfg = manifest.config("stream_d16_k32").unwrap().clone();
    let mut native = native_like(&cfg);
    let summary = algo.summary();
    for row in summary.chunks_exact(16) {
        native.accept(row);
    }
    assert!(
        (algo.value() - native.current_value()).abs() < 1e-3 * (1.0 + native.current_value()),
        "pjrt value {} vs native recomputation {}",
        algo.value(),
        native.current_value()
    );
}
