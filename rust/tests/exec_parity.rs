//! Thread-count invariance: the exec pool must be *semantically
//! invisible*. For every algorithm that fans work out across the pool
//! (ShardedThreeSieves shards, SieveStreaming/Salsa sieves) and for the
//! race coordinator, running the identical stream with parallelism `off`,
//! 2 and 8 threads must produce bit-identical objective values, identical
//! summaries and identical resource stats — queries, elements, stored,
//! peak — because the pool only relocates each unit's computation, never
//! reorders or splits it (see `rust/src/exec/`).

use threesieves::algorithms::three_sieves::SieveTuning;
use threesieves::algorithms::{Salsa, SieveStreaming, StreamClipper, StreamingAlgorithm, Subsampled};
use threesieves::coordinator::checkpoint::Checkpoint;
use threesieves::coordinator::{race, AlgoFactory, RaceConfig, ShardedThreeSieves};
use threesieves::data::synthetic::{Mixture, MixtureSource};
use threesieves::data::{registry, Dataset, StreamSource};
use threesieves::exec::{ExecContext, Parallelism};
use threesieves::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
use threesieves::metrics::AlgoStats;
use threesieves::util::rng::Rng;

const DIM: usize = 8;
const CHUNK: usize = 64;

fn stream(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from(seed);
    let mix = Mixture::random(DIM, 4, 5.0, 0.5, &mut rng);
    let mut ds = MixtureSource::new(mix, n, seed).materialize("exec-parity", n);
    ds.normalize();
    ds
}

fn oracle(k: usize) -> Box<dyn SubmodularFunction> {
    Box::new(NativeLogDet::new(LogDetConfig::with_gamma(DIM, k, 1.0, 1.0)))
}

/// Chunk `ds` through `algo` under `par` and capture the final state.
fn run_under(
    mut algo: Box<dyn StreamingAlgorithm>,
    ds: &Dataset,
    par: Parallelism,
) -> (u64, Vec<f32>, AlgoStats) {
    algo.set_exec(ExecContext::new(par));
    for block in ds.raw().chunks(CHUNK * DIM) {
        algo.process_batch(block);
    }
    algo.finalize();
    (algo.value().to_bits(), algo.summary(), algo.stats())
}

/// The invariance contract for one algorithm family.
fn assert_thread_invariant(build: &dyn Fn() -> Box<dyn StreamingAlgorithm>, ds: &Dataset) {
    let (value_off, summary_off, stats_off) = run_under(build(), ds, Parallelism::Off);
    for threads in [2usize, 8] {
        let (value, summary, stats) = run_under(build(), ds, Parallelism::Threads(threads));
        let label = format!("{} threads={threads}", build().name());
        assert_eq!(value_off, value, "{label}: value bits");
        assert_eq!(summary_off, summary, "{label}: summary rows");
        assert_eq!(stats_off, stats, "{label}: stats {stats_off:?} vs {stats:?}");
    }
    assert!(stats_off.queries > 0, "workload must exercise the oracle");
}

#[test]
fn sharded_three_sieves_thread_invariance() {
    let ds = stream(2000, 31);
    let k = 6;
    let build = || -> Box<dyn StreamingAlgorithm> {
        Box::new(ShardedThreeSieves::new(oracle(k), k, 0.05, SieveTuning::FixedT(20), 4))
    };
    assert_thread_invariant(&build, &ds);
}

#[test]
fn sieve_streaming_thread_invariance() {
    let ds = stream(1500, 32);
    let k = 6;
    let build =
        || -> Box<dyn StreamingAlgorithm> { Box::new(SieveStreaming::new(oracle(k), k, 0.1)) };
    assert_thread_invariant(&build, &ds);
}

#[test]
fn salsa_thread_invariance() {
    // Length hint on: includes the position-adaptive rule, whose
    // threshold moves *within* a chunk — the fan-out must replay the
    // per-item position dependence identically on worker threads.
    let ds = stream(1500, 33);
    let k = 5;
    let n = ds.len();
    let build =
        || -> Box<dyn StreamingAlgorithm> { Box::new(Salsa::new(oracle(k), k, 0.2, Some(n))) };
    assert_thread_invariant(&build, &ds);
}

#[test]
fn stream_clipper_thread_invariance() {
    // The clip buffer mutates only in the sequential Phase B of the grid
    // driver, so its contents — and therefore the finalize-time swap-ins —
    // must be identical at every thread count.
    let ds = stream(1500, 37);
    let k = 6;
    let build =
        || -> Box<dyn StreamingAlgorithm> { Box::new(StreamClipper::new(oracle(k), k, 1.0, 0.5)) };
    assert_thread_invariant(&build, &ds);
}

#[test]
fn subsampled_thread_invariance() {
    // The coin sequence depends only on (seed, index); the pool never sees
    // the dropped rows, so the inner fan-out stays invariant too.
    let ds = stream(1500, 38);
    let k = 6;
    let build = || -> Box<dyn StreamingAlgorithm> {
        Box::new(Subsampled::new(Box::new(SieveStreaming::new(oracle(k), k, 0.1)), 0.5, 7))
    };
    assert_thread_invariant(&build, &ds);
}

#[test]
fn sharded_thread_invariance_with_tiny_t() {
    // T far smaller than the chunk: shards pop thresholds constantly, so
    // the scan's threshold-drop path runs on the workers too.
    let ds = stream(1200, 34);
    let k = 8;
    let build = || -> Box<dyn StreamingAlgorithm> {
        Box::new(ShardedThreeSieves::new(oracle(k), k, 0.2, SieveTuning::FixedT(3), 6))
    };
    assert_thread_invariant(&build, &ds);
}

/// The race coordinator: identical factories under `off` and a shared
/// 4-thread pool (chunked broadcast) must produce identical lane reports.
#[test]
fn race_thread_invariance() {
    let lanes = |dim: usize| -> Vec<(String, AlgoFactory)> {
        vec![
            (
                "sharded".to_string(),
                Box::new(move || {
                    let f = NativeLogDet::new(LogDetConfig::for_streaming(dim, 6));
                    Box::new(ShardedThreeSieves::new(
                        Box::new(f),
                        6,
                        0.05,
                        SieveTuning::FixedT(40),
                        4,
                    )) as Box<dyn StreamingAlgorithm>
                }) as AlgoFactory,
            ),
            (
                "sieves".to_string(),
                Box::new(move || {
                    let f = NativeLogDet::new(LogDetConfig::for_streaming(dim, 6));
                    Box::new(SieveStreaming::new(Box::new(f), 6, 0.1))
                        as Box<dyn StreamingAlgorithm>
                }) as AlgoFactory,
            ),
        ]
    };
    let run = |par: Parallelism, batch: usize| {
        let src = registry::source("fact-highlevel-like", 1200, 9).unwrap();
        race(
            src,
            lanes(16),
            RaceConfig { batch_size: batch, parallelism: par, ..Default::default() },
        )
    };
    let base = run(Parallelism::Off, 1);
    for (par, batch) in [(Parallelism::Off, 32), (Parallelism::Threads(4), 32)] {
        let got = run(par, batch);
        assert_eq!(base.len(), got.len());
        for (a, b) in base.iter().zip(&got) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "lane {}: value", a.name);
            assert_eq!(a.summary, b.summary, "lane {}: summary", a.name);
            // Reported accounting is batch-size-invariant by contract;
            // `kernel_evals` is measured work and moves with the batch
            // size (bigger panels, more speculative entries), so it is
            // excluded from this cross-batch comparison (the
            // panel_sharing_parity suite pins it at fixed batching).
            assert_eq!(a.stats.queries, b.stats.queries, "lane {}: queries", a.name);
            assert_eq!(a.stats.elements, b.stats.elements, "lane {}: elements", a.name);
            assert_eq!(a.stats.stored, b.stats.stored, "lane {}: stored", a.name);
            assert_eq!(a.stats.peak_stored, b.stats.peak_stored, "lane {}: peak", a.name);
            assert_eq!(a.stats.instances, b.stats.instances, "lane {}: instances", a.name);
        }
    }
}

/// Checkpoint roundtrip under the pool: a ShardedThreeSieves driven by the
/// pool checkpoints identically to a sequential twin at mid-stream, the
/// persisted summary reproduces the value in a fresh oracle, and both
/// resume over the second half to the identical final state.
#[test]
fn sharded_checkpoint_roundtrip_resumes_identically_under_pool() {
    let dir = std::env::temp_dir().join(format!("ts_exec_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ds = stream(1600, 35);
    let k = 6;
    let half = ds.len() / 2 * DIM;

    let build = || ShardedThreeSieves::new(oracle(k), k, 0.05, SieveTuning::FixedT(25), 4);
    let mut seq = build();
    let mut par = build();
    par.set_exec(ExecContext::new(Parallelism::Threads(4)));

    let drive = |algo: &mut ShardedThreeSieves, raw: &[f32]| {
        for block in raw.chunks(CHUNK * DIM) {
            algo.process_batch(block);
        }
    };
    drive(&mut seq, &ds.raw()[..half]);
    drive(&mut par, &ds.raw()[..half]);

    let snapshot = |algo: &ShardedThreeSieves| Checkpoint {
        algorithm: algo.name(),
        dim: DIM,
        k,
        value: algo.value(),
        elements: (ds.len() / 2) as u64,
        drift_events: 0,
        state: threesieves::util::json::Json::Null,
        summary: algo.summary(),
    };
    let (p_seq, p_par) = (dir.join("seq.ckpt"), dir.join("par.ckpt"));
    snapshot(&seq).save(&p_seq).unwrap();
    snapshot(&par).save(&p_par).unwrap();
    let ck_seq = Checkpoint::load(&p_seq).unwrap();
    let ck_par = Checkpoint::load(&p_par).unwrap();
    assert_eq!(ck_seq, ck_par, "mid-stream checkpoints must match bit for bit");

    // The persisted summary reproduces the value in a fresh oracle.
    let mut restored = oracle(k);
    for row in ck_par.summary.chunks_exact(DIM) {
        restored.accept(row);
    }
    assert!(
        (restored.current_value() - ck_par.value).abs() < 1e-6 * (1.0 + ck_par.value.abs()),
        "restored value {} != checkpointed {}",
        restored.current_value(),
        ck_par.value
    );

    // Both runs resume over the second half to the identical final state.
    drive(&mut seq, &ds.raw()[half..]);
    drive(&mut par, &ds.raw()[half..]);
    assert_eq!(seq.value().to_bits(), par.value().to_bits());
    assert_eq!(seq.summary(), par.summary());
    assert_eq!(seq.stats(), par.stats());
    std::fs::remove_dir_all(&dir).ok();
}

/// `auto` parallelism is just a thread count — still invariant.
#[test]
fn auto_parallelism_matches_off() {
    let ds = stream(900, 36);
    let k = 5;
    let build = || -> Box<dyn StreamingAlgorithm> {
        Box::new(ShardedThreeSieves::new(oracle(k), k, 0.1, SieveTuning::FixedT(15), 3))
    };
    let (v_off, s_off, st_off) = run_under(build(), &ds, Parallelism::Off);
    let (v_auto, s_auto, st_auto) = run_under(build(), &ds, Parallelism::Auto);
    assert_eq!(v_off, v_auto);
    assert_eq!(s_off, s_auto);
    assert_eq!(st_off, st_auto);
}
