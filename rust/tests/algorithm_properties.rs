//! Property-based tests over the algorithm family (hand-rolled harness in
//! `threesieves::util::proptest` — the proptest crate is not vendored).
//!
//! Invariants checked across random workloads, cardinalities and
//! hyperparameters:
//!   * cardinality: no algorithm ever exceeds K summary elements;
//!   * consistency: reported value equals the oracle value of the reported
//!     summary (no stale bookkeeping);
//!   * resource bands: ThreeSieves/Random stay at ≤K stored elements and
//!     ≤1 gain query per element; sieve-family memory stays ≤ sieves·K;
//!   * approximation sanity: on easy clustered data every non-random
//!     algorithm reaches a constant fraction of Greedy.

use threesieves::algorithms::three_sieves::SieveTuning;
use threesieves::algorithms::*;
use threesieves::data::synthetic::{Mixture, MixtureSource};
use threesieves::data::{Dataset, StreamSource};
use threesieves::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
use threesieves::util::proptest::{check, prop_assert, prop_close};
use threesieves::util::rng::Rng;

#[derive(Debug, Clone)]
struct Workload {
    seed: u64,
    n: usize,
    dim: usize,
    k: usize,
    epsilon: f64,
    t: usize,
}

fn gen_workload(rng: &mut Rng) -> Workload {
    Workload {
        seed: rng.next_u64(),
        n: rng.range(200, 900),
        dim: rng.range(2, 12),
        k: rng.range(2, 12),
        epsilon: [0.01, 0.05, 0.1, 0.3][rng.range(0, 4)],
        t: rng.range(5, 120),
    }
}

fn dataset(w: &Workload) -> Dataset {
    let mut rng = Rng::seed_from(w.seed);
    let clusters = rng.range(2, 7);
    let mix = Mixture::random(w.dim, clusters, 5.0, 0.5, &mut rng);
    let mut ds = MixtureSource::new(mix, w.n, w.seed).materialize("prop", w.n);
    ds.normalize();
    ds
}

fn oracle(w: &Workload) -> Box<dyn SubmodularFunction> {
    Box::new(NativeLogDet::new(LogDetConfig::with_gamma(w.dim, w.k, 1.0, 1.0)))
}

fn algos_for(w: &Workload) -> Vec<Box<dyn StreamingAlgorithm>> {
    vec![
        Box::new(RandomReservoir::new(oracle(w), w.k, w.seed)),
        Box::new(IndependentSetImprovement::new(oracle(w), w.k)),
        Box::new(SieveStreaming::new(oracle(w), w.k, w.epsilon)),
        Box::new(SieveStreamingPP::new(oracle(w), w.k, w.epsilon)),
        Box::new(Salsa::new(oracle(w), w.k, w.epsilon, Some(w.n))),
        Box::new(QuickStream::new(oracle(w), w.k.max(2), 2, w.epsilon, w.seed)),
        Box::new(ThreeSieves::new(oracle(w), w.k, w.epsilon, SieveTuning::FixedT(w.t))),
    ]
}

fn run_all(w: &Workload) -> Vec<(String, Box<dyn StreamingAlgorithm>)> {
    let ds = dataset(w);
    algos_for(w)
        .into_iter()
        .map(|mut a| {
            for row in ds.iter() {
                a.process(row);
            }
            a.finalize();
            (a.name(), a)
        })
        .collect()
}

#[test]
fn prop_cardinality_never_exceeded() {
    check("cardinality", 12, 0xC0FFEE, gen_workload, |w| {
        for (name, a) in run_all(w) {
            prop_assert(
                a.summary_len() <= a.k().max(2),
                format!("{name}: |S| = {} > K = {}", a.summary_len(), a.k()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_reported_value_matches_summary() {
    check("value-consistency", 10, 0xBEEF, gen_workload, |w| {
        for (name, a) in run_all(w) {
            // Recompute f on the reported summary with a fresh oracle.
            let mut fresh = oracle(w);
            let summary = a.summary();
            for row in summary.chunks_exact(w.dim) {
                fresh.accept(row);
            }
            prop_close(&format!("{name} value"), a.value(), fresh.current_value(), 1e-6, 1e-8)?;
        }
        Ok(())
    });
}

#[test]
fn prop_threesieves_resource_bands() {
    check("threesieves-resources", 15, 0xFEED, gen_workload, |w| {
        let ds = dataset(w);
        let mut a = ThreeSieves::new(oracle(w), w.k, w.epsilon, SieveTuning::FixedT(w.t));
        for row in ds.iter() {
            a.process(row);
        }
        let st = a.stats();
        prop_assert(st.peak_stored <= w.k, format!("memory {} > K {}", st.peak_stored, w.k))?;
        prop_assert(
            st.queries <= st.elements + 2 * w.k as u64,
            format!("queries {} vs elements {}", st.queries, st.elements),
        )?;
        prop_assert(st.instances == 1, "ThreeSieves must keep exactly one sieve")?;
        Ok(())
    });
}

#[test]
fn prop_sieve_memory_bounded_by_grid() {
    check("sieve-memory", 8, 0xABCD, gen_workload, |w| {
        let ds = dataset(w);
        let mut a = SieveStreaming::new(oracle(w), w.k, w.epsilon);
        let sieves = a.sieve_count();
        for row in ds.iter() {
            a.process(row);
        }
        let st = a.stats();
        prop_assert(
            st.peak_stored <= sieves * w.k,
            format!("peak {} > sieves {} * K {}", st.peak_stored, sieves, w.k),
        )?;
        Ok(())
    });
}

#[test]
fn prop_values_nonnegative_and_bounded_by_opt_bound() {
    // f(S) <= K * ln(1 + a) (Buschjäger et al. 2017) for every algorithm.
    check("opt-bound", 8, 0x1234, gen_workload, |w| {
        let bound = w.k.max(2) as f64 * (2.0f64).ln() + 1e-9;
        for (name, a) in run_all(w) {
            prop_assert(a.value() >= -1e-9, format!("{name} negative value"))?;
            prop_assert(
                a.value() <= bound,
                format!("{name} value {} exceeds OPT bound {bound}", a.value()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_reset_is_idempotent_restart() {
    check("reset-restart", 6, 0x77, gen_workload, |w| {
        let ds = dataset(w);
        let mut a = ThreeSieves::new(oracle(w), w.k, w.epsilon, SieveTuning::FixedT(w.t));
        for row in ds.iter() {
            a.process(row);
        }
        let v1 = a.value();
        a.reset();
        for row in ds.iter() {
            a.process(row);
        }
        prop_close("value after reset+rerun", a.value(), v1, 1e-9, 1e-12)?;
        Ok(())
    });
}

#[test]
fn prop_nonrandom_algorithms_beat_fraction_of_greedy() {
    check("vs-greedy", 5, 0x5EED, gen_workload, |w| {
        // Clustered, easy data: every thresholding algorithm should land
        // within a constant factor of Greedy (loose band — this is a sanity
        // property, the tight comparison lives in the figure benches).
        let ds = dataset(w);
        let mut g = Greedy::new(oracle(w), w.k);
        g.fit(&ds);
        let gv = g.value();
        if gv <= 0.0 {
            return Ok(());
        }
        for (name, a) in run_all(w) {
            if name.starts_with("Random") || name.starts_with("QuickStream") {
                continue; // expectation-only guarantees
            }
            let rel = a.value() / gv;
            prop_assert(rel > 0.3, format!("{name} rel {rel:.3} below sanity band on easy data"))?;
        }
        Ok(())
    });
}
