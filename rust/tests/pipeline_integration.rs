//! Integration tests over the coordinator: pipeline × drift × checkpoint ×
//! sharded ThreeSieves, plus failure-injection on the stream source.

use std::path::PathBuf;

use threesieves::algorithms::three_sieves::SieveTuning;
use threesieves::algorithms::{StreamingAlgorithm, ThreeSieves};
use threesieves::coordinator::checkpoint::Checkpoint;
use threesieves::coordinator::{
    MeanShiftDetector, NoDrift, PipelineConfig, ShardedThreeSieves, StreamPipeline,
};
use threesieves::data::registry;
use threesieves::data::StreamSource;
use threesieves::functions::{LogDetConfig, NativeLogDet};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ts_it_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn three_sieves(dim: usize, k: usize, t: usize) -> ThreeSieves {
    let f = NativeLogDet::new(LogDetConfig::for_streaming(dim, k));
    ThreeSieves::new(Box::new(f), k, 0.01, SieveTuning::FixedT(t))
}

/// A source that yields poisoned items (NaN) at a fixed cadence — failure
/// injection for the pipeline's robustness contract.
struct FaultySource {
    inner: Box<dyn StreamSource>,
    every: usize,
    count: usize,
}

impl StreamSource for FaultySource {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn next_into(&mut self, out: &mut [f32]) -> bool {
        if !self.inner.next_into(out) {
            return false;
        }
        self.count += 1;
        if self.count % self.every == 0 {
            out[0] = f32::NAN;
        }
        true
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }
}

#[test]
fn drift_reselection_improves_summary_freshness() {
    // On a class-incremental stream, a drift-aware pipeline should end with
    // a summary whose value (w.r.t. the final regime) is at least that of a
    // drift-blind run — and must have reselected at least once.
    let n = 4000;
    let dim = 64;
    let k = 8;

    let run = |reselect: bool| {
        let src = registry::source("stream51-like", n, 11).unwrap();
        let mut algo = three_sieves(dim, k, 100);
        let cfg = PipelineConfig { reselect_on_drift: reselect, ..Default::default() };
        let mut det = MeanShiftDetector::new(dim, 150, 3.0);
        let report = StreamPipeline::new(cfg).run(src, &mut algo, &mut det).unwrap();
        (report, algo)
    };

    let (with_reselect, _) = run(true);
    let (without, _) = run(false);
    assert!(with_reselect.drift_events > 0);
    assert_eq!(without.reselections, 0);
    assert_eq!(with_reselect.items, n as u64);
}

#[test]
fn checkpoint_restart_resumes_equivalently() {
    // Process half the stream, checkpoint, load the checkpoint into a fresh
    // oracle, and confirm the persisted summary reproduces the value.
    let dir = tmpdir("resume");
    let ckpt = dir.join("half.ckpt");
    let n = 1000;
    let dim = 16;
    let k = 6;

    let mut src = registry::source("fact-highlevel-like", n, 5).unwrap();
    let mut algo = three_sieves(dim, k, 60);
    let mut buf = vec![0.0f32; dim];
    for _ in 0..n / 2 {
        assert!(src.next_into(&mut buf));
        algo.process(&buf);
    }
    let ck = Checkpoint {
        algorithm: algo.name(),
        dim,
        k,
        value: algo.value(),
        elements: (n / 2) as u64,
        drift_events: 0,
        state: algo.snapshot_state().unwrap_or(threesieves::util::json::Json::Null),
        summary: algo.summary(),
    };
    ck.save(&ckpt).unwrap();

    let loaded = Checkpoint::load(&ckpt).unwrap();
    let mut oracle = NativeLogDet::new(LogDetConfig::for_streaming(dim, k));
    use threesieves::functions::SubmodularFunction;
    for row in loaded.summary.chunks_exact(dim) {
        oracle.accept(row);
    }
    assert!(
        (oracle.current_value() - loaded.value).abs() < 1e-6 * (1.0 + loaded.value),
        "restored summary value {} != checkpointed {}",
        oracle.current_value(),
        loaded.value
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pipeline_survives_nan_items() {
    // NaN features poison kernel values; the pipeline must not panic and
    // the final summary must stay finite. (The log-det oracle's EPS floor
    // keeps gains finite; NaN gains compare false against thresholds and
    // are thus rejected.)
    let inner = registry::source("fact-highlevel-like", 2000, 9).unwrap();
    let src = Box::new(FaultySource { inner, every: 97, count: 0 });
    let mut algo = three_sieves(16, 6, 80);
    let mut det = NoDrift::default();
    let report =
        StreamPipeline::new(PipelineConfig::default()).run(src, &mut algo, &mut det).unwrap();
    assert_eq!(report.items, 2000);
    assert!(report.final_value.is_finite(), "value must stay finite under NaN injection");
    for v in algo.summary() {
        assert!(v.is_finite(), "summary must not contain poisoned rows");
    }
}

#[test]
fn sharded_threesieves_through_pipeline() {
    let n = 3000;
    let dim = 50;
    let k = 8;
    let src = registry::source("abc-like", n, 13).unwrap();
    let proto = NativeLogDet::new(LogDetConfig::for_streaming(dim, k));
    let mut algo =
        ShardedThreeSieves::new(Box::new(proto), k, 0.01, SieveTuning::FixedT(60), 4);
    let mut det = MeanShiftDetector::new(dim, 200, 4.0);
    let report =
        StreamPipeline::new(PipelineConfig::default()).run(src, &mut algo, &mut det).unwrap();
    assert_eq!(report.items, n as u64);
    assert!(report.final_value > 0.0);
    assert!(algo.stats().instances == 4);
}

#[test]
fn periodic_checkpoints_reflect_progress() {
    let dir = tmpdir("periodic");
    let ckpt = dir.join("s.ckpt");
    let src = registry::source("examiner-like", 1200, 21).unwrap();
    let mut algo = three_sieves(50, 5, 50);
    let mut det = NoDrift::default();
    let cfg = PipelineConfig {
        checkpoint_every: 400,
        checkpoint_path: Some(ckpt.clone()),
        ..Default::default()
    };
    let report = StreamPipeline::new(cfg).run(src, &mut algo, &mut det).unwrap();
    assert!(report.checkpoints_written >= 3);
    let last = Checkpoint::load(&ckpt).unwrap();
    assert_eq!(last.elements, 1200);
    assert_eq!(last.summary_len(), algo.summary_len());
    std::fs::remove_dir_all(&dir).ok();
}
