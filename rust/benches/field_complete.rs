//! Bench: the **complete competitor field** — every streaming entry in
//! the algorithm registry at its default parameters, run over the drift
//! streams. One row per (algorithm × dataset): objective, oracle queries,
//! kernel evaluations and wall time, plus the ThreeSieves-vs-field ratio
//! table CI tracks, and a race-coordinator smoke over the same
//! registry-derived roster.
//!
//! Run: `cargo bench --bench field_complete` (`TS_BENCH_N`, `TS_BENCH_K`).
//! Writes results/field_complete.{csv,json} and the CI artifact
//! `bench_field_complete.json`.

use std::path::PathBuf;

use threesieves::algorithms::registry;
use threesieves::config::AlgoSpec;
use threesieves::coordinator::{race, registry_lanes, winner, RaceConfig};
use threesieves::data::registry as datasets;
use threesieves::experiments::table2;
use threesieves::experiments::{run_batch_protocol, run_stream_protocol, GammaMode};
use threesieves::metrics::{write_records, RunRecord};

fn main() {
    // `--trace-out` / `--events-out` (or TS_TRACE_OUT / TS_EVENTS_OUT)
    // arm observability for the whole run; inert otherwise.
    let obs = threesieves::obs::BenchObs::from_env();
    let n: usize =
        std::env::var("TS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(2_000);
    let k: usize = std::env::var("TS_BENCH_K").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    let seed = 42u64;
    let field = registry::streaming_names();
    let drift = table2::drift_datasets();
    println!(
        "== complete field: {} streaming algorithms × {} drift streams, n = {n}, K = {k} ==",
        field.len(),
        drift.len()
    );

    let mut records: Vec<RunRecord> = Vec::new();
    for info in &drift {
        let ds = datasets::get(info.name, n, seed).expect("registered dataset");
        let greedy =
            run_batch_protocol(&AlgoSpec::greedy(), &ds, k, GammaMode::Streaming, 1.0).value;
        for name in &field {
            let spec = AlgoSpec::of(name, &[]).expect("registry name");
            let mut src = datasets::source(info.name, n, seed).unwrap();
            let rec = run_stream_protocol(
                &spec,
                src.as_mut(),
                info.name,
                k,
                GammaMode::Streaming,
                greedy,
            );
            println!(
                "[field] {:<16} {:<34} rel={:.3} q={:<8} ke={:<10} t={:.3}s mem={}",
                rec.dataset,
                rec.algorithm,
                rec.relative_to_greedy,
                rec.stats.queries,
                rec.stats.kernel_evals,
                rec.runtime.as_secs_f64(),
                rec.stats.peak_stored,
            );
            records.push(rec);
        }
    }
    write_records(&PathBuf::from("results").join("field_complete"), &records).expect("results");

    // The CI artifact: one JSON object per (algorithm × drift stream).
    let mut json = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"algorithm\": {:?}, \"dataset\": {:?}, \"objective\": {:.6}, \
             \"rel_to_greedy\": {:.4}, \"queries\": {}, \"kernel_evals\": {}, \
             \"wall_s\": {:.6}, \"peak_stored\": {}}}{}\n",
            r.algorithm,
            r.dataset,
            r.value,
            r.relative_to_greedy,
            r.stats.queries,
            r.stats.kernel_evals,
            r.runtime.as_secs_f64(),
            r.stats.peak_stored,
            if i + 1 < records.len() { "," } else { "" },
        ));
    }
    json.push_str("]\n");
    std::fs::write("bench_field_complete.json", json).expect("bench_field_complete.json");

    // ThreeSieves vs the field, aggregated over the drift streams: the
    // paper's claim in one table — competitive objective at a fraction of
    // the queries. Subsampled rows show *their* oracle reduction the same
    // way (q× < 1 vs their inner algorithm's row).
    // Per-algorithm sums: (name, rel, queries, kernel_evals, wall).
    let mut agg: Vec<(String, f64, u64, u64, f64)> = Vec::new();
    for r in &records {
        match agg.iter_mut().find(|a| a.0 == r.algorithm) {
            Some(a) => {
                a.1 += r.relative_to_greedy;
                a.2 += r.stats.queries;
                a.3 += r.stats.kernel_evals;
                a.4 += r.runtime.as_secs_f64();
            }
            None => agg.push((
                r.algorithm.clone(),
                r.relative_to_greedy,
                r.stats.queries,
                r.stats.kernel_evals,
                r.runtime.as_secs_f64(),
            )),
        }
    }
    let ts = agg
        .iter()
        .find(|a| a.0.starts_with("ThreeSieves"))
        .expect("ThreeSieves is in the field")
        .clone();
    let streams = drift.len() as f64;
    println!("\n== ThreeSieves vs field (summed over {} drift streams) ==", drift.len());
    println!(
        "{:<34} | {:>8} | {:>9} | {:>9} | {:>8}",
        "algorithm", "rel", "queries×", "kernel×", "wall×"
    );
    for (name, rel, q, ke, wall) in &agg {
        println!(
            "{:<34} | {:>8.3} | {:>9.2} | {:>9.2} | {:>8.2}",
            name,
            rel / streams,
            *q as f64 / ts.2.max(1) as f64,
            *ke as f64 / ts.3.max(1) as f64,
            wall / ts.4.max(1e-9),
        );
    }

    // Race smoke: the registry-derived roster fans out over one drift
    // stream through the coordinator — every lane must finish the stream.
    let info = drift[0];
    let race_n = (n / 2).max(500);
    let ds = datasets::get(info.name, race_n, seed).expect("race dataset");
    let src = datasets::source(info.name, race_n, seed).unwrap();
    let lanes = registry_lanes(ds.dim(), k, Some(race_n));
    println!("\n== race smoke: {} lanes on {} (n = {race_n}) ==", lanes.len(), info.name);
    let reports = race(src, lanes, RaceConfig { batch_size: 64, ..Default::default() });
    for r in &reports {
        assert_eq!(r.stats.elements, race_n as u64, "lane {} missed items", r.name);
        println!(
            "  {:<28} f(S)={:.4} q={:<8} t={:.3}s",
            r.name, r.value, r.stats.queries, r.wall_seconds
        );
    }
    let best = winner(&reports);
    println!("race winner: {} (f(S) = {:.4})", best.name, best.value);
    obs.finish();
    println!("\nfield_complete done — artifact in bench_field_complete.json");
}
