//! Bench: **Table 1, measured** — empirical peak memory and queries per
//! element for all ten algorithms on a fixed stream, printed against the
//! theoretical rows.
//!
//! Run: `cargo bench --bench table1_resources` (`TS_BENCH_N`, `TS_BENCH_K`).
//! Writes results/table1.{csv,json}.

use std::path::PathBuf;

use threesieves::experiments::table1;

fn main() {
    // `--trace-out` / `--events-out` (or TS_TRACE_OUT / TS_EVENTS_OUT)
    // arm observability for the whole run; inert otherwise.
    let obs = threesieves::obs::BenchObs::from_env();
    let n: usize =
        std::env::var("TS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(3_000);
    let k: usize = std::env::var("TS_BENCH_K").ok().and_then(|v| v.parse().ok()).unwrap_or(20);
    println!("== Table 1 measured: n = {n}, K = {k}, eps = 0.01 ==\n");
    let records = table1::run(&PathBuf::from("results"), n, k, 42).expect("table1");

    // Verify the paper's resource ordering claims hold on this run.
    let get = |prefix: &str| {
        records
            .iter()
            .find(|r| r.algorithm.starts_with(prefix))
            .unwrap_or_else(|| panic!("{prefix} missing"))
    };
    let three = get("ThreeSieves");
    let sieve = get("SieveStreaming");
    let salsa = get("Salsa");
    println!("\nresource-ordering checks:");
    println!(
        "  ThreeSieves memory {} ≤ K = {k}: {}",
        three.stats.peak_stored,
        three.stats.peak_stored <= k
    );
    println!(
        "  memory factor SieveStreaming/ThreeSieves: {:.1}×",
        sieve.stats.peak_stored as f64 / three.stats.peak_stored.max(1) as f64
    );
    println!(
        "  memory factor Salsa/ThreeSieves: {:.1}×",
        salsa.stats.peak_stored as f64 / three.stats.peak_stored.max(1) as f64
    );
    println!(
        "  query factor SieveStreaming/ThreeSieves: {:.1}×",
        sieve.stats.queries as f64 / three.stats.queries.max(1) as f64
    );
    println!(
        "  runtime factor Salsa/ThreeSieves: {:.1}×",
        salsa.runtime.as_secs_f64() / three.runtime.as_secs_f64().max(1e-9)
    );
    obs.finish();
    println!("\ntable1 done — full rows in results/table1.csv");
}
