//! Bench: regenerate **Figure 2** — relative performance, runtime and
//! memory over K (fixed ε = 0.001) on the five batch-dataset surrogates.
//!
//! Run: `cargo bench --bench fig2_k_sweep` (env `TS_BENCH_N`, `TS_BENCH_KS`
//! to rescale). Prints the same three series per dataset the paper plots
//! and writes results/fig2.{csv,json}.

use std::path::PathBuf;

use threesieves::experiments::figures::{fig2, SweepScale};

fn main() {
    // `--trace-out` / `--events-out` (or TS_TRACE_OUT / TS_EVENTS_OUT)
    // arm observability for the whole run; inert otherwise.
    let obs = threesieves::obs::BenchObs::from_env();
    let n: usize =
        std::env::var("TS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(1_500);
    let ks: Vec<usize> = std::env::var("TS_BENCH_KS")
        .ok()
        .map(|v| v.split(',').filter_map(|t| t.parse().ok()).collect())
        .unwrap_or_else(|| vec![5, 10, 20, 50]);
    let out = PathBuf::from("results");
    println!("== Figure 2 sweep: K over {ks:?}, eps = 0.001, n = {n} per dataset ==");
    let records = fig2(&out, SweepScale { n, seed: 42 }, &ks).expect("fig2 sweep");

    // Summary series per dataset: the paper's first row (rel-to-greedy).
    println!("\n== series: relative performance (rows = K) ==");
    let mut datasets: Vec<String> = records.iter().map(|r| r.dataset.clone()).collect();
    datasets.sort();
    datasets.dedup();
    for ds in &datasets {
        println!("\n[{ds}]");
        for &k in &ks {
            let mut row = format!("K={k:<4}");
            for algo in [
                "ThreeSieves(T=5000)",
                "SieveStreaming",
                "SieveStreaming++",
                "Salsa",
                "IndependentSetImprovement",
                "Random",
            ] {
                if let Some(r) = records
                    .iter()
                    .find(|r| r.dataset == *ds && r.k == k && r.algorithm == algo)
                {
                    row.push_str(&format!(" {}={:.2}", algo_short(algo), r.relative_to_greedy));
                }
            }
            println!("  {row}");
        }
    }
    obs.finish();
    println!("\nfig2 done — full rows in results/fig2.csv");
}

fn algo_short(a: &str) -> &'static str {
    match a {
        "ThreeSieves(T=5000)" => "3S",
        "SieveStreaming" => "SS",
        "SieveStreaming++" => "SS++",
        "Salsa" => "SAL",
        "IndependentSetImprovement" => "ISI",
        "Random" => "RND",
        _ => "?",
    }
}
