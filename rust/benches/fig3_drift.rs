//! Bench: regenerate **Figure 3** — single-pass streaming with concept
//! drift on stream51/abc/examiner surrogates, relative performance vs K
//! for ε ∈ {0.1, 0.01}.
//!
//! Run: `cargo bench --bench fig3_drift` (`TS_BENCH_N`, `TS_BENCH_KS`).
//! Writes results/fig3.{csv,json}.

use std::path::PathBuf;

use threesieves::experiments::figures::{fig3, SweepScale};

fn main() {
    // `--trace-out` / `--events-out` (or TS_TRACE_OUT / TS_EVENTS_OUT)
    // arm observability for the whole run; inert otherwise.
    let obs = threesieves::obs::BenchObs::from_env();
    let n: usize =
        std::env::var("TS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(1_500);
    let ks: Vec<usize> = std::env::var("TS_BENCH_KS")
        .ok()
        .map(|v| v.split(',').filter_map(|t| t.parse().ok()).collect())
        .unwrap_or_else(|| vec![5, 10, 20, 50]);
    let out = PathBuf::from("results");
    println!("== Figure 3 sweep: drift streams, K over {ks:?}, eps in {{0.1, 0.01}}, n = {n} ==");
    let records = fig3(&out, SweepScale { n, seed: 42 }, &ks).expect("fig3 sweep");

    println!("\n== series: relative performance under drift ==");
    let mut datasets: Vec<String> = records.iter().map(|r| r.dataset.clone()).collect();
    datasets.sort();
    datasets.dedup();
    for ds in &datasets {
        for &eps in &[0.1, 0.01] {
            println!("\n[{ds}] eps={eps}");
            for &k in &ks {
                let pick = |algo: &str| {
                    records.iter().find(|r| {
                        r.dataset == *ds && r.k == k && r.epsilon == eps && r.algorithm == algo
                    })
                };
                let fmt = |r: Option<&threesieves::metrics::RunRecord>| match r {
                    Some(r) => format!("{:.2}", r.relative_to_greedy),
                    None => "-".into(),
                };
                println!(
                    "  K={k:<4} 3S(5000)={} 3S(500)={} SS={} SS++={} CLP={} SUB(SS)={} \
                     SUB(3S,500)={} ISI={} RND={}",
                    fmt(pick("ThreeSieves(T=5000)")),
                    fmt(pick("ThreeSieves(T=500)")),
                    fmt(pick("SieveStreaming")),
                    fmt(pick("SieveStreaming++")),
                    fmt(pick("StreamClipper")),
                    fmt(pick("Subsampled(p=0.5)+SieveStreaming")),
                    fmt(pick("Subsampled(p=0.5)+ThreeSieves(T=500)")),
                    fmt(pick("IndependentSetImprovement")),
                    fmt(pick("Random")),
                );
            }
        }
    }
    obs.finish();
    println!("\nfig3 done — full rows in results/fig3.csv");
}
