//! Micro-benchmarks of the per-element hot path — the §Perf work surface.
//!
//! * native log-det gain query: kernel row (O(nd)) + forward solve (O(n²))
//! * batched gain panel: `peek_gain_batch` vs a scalar `peek_gain` loop —
//!   the batched-ingestion speedup (issue #1 pins ≥1.5× at n=K=64, d=128)
//! * Cholesky append and delete
//! * PJRT gain query (single + batched) for the compiled artifact, showing
//!   the dispatch overhead the native path avoids and the batch
//!   amortization the artifact path relies on
//! * ThreeSieves end-to-end items/second, per-item vs chunked ingestion
//! * ShardedThreeSieves scaling across the exec pool (1/2/4/8 threads) —
//!   the issue-#2 acceptance point (>1.5× at 4 threads)
//! * Multi-tenant service throughput: 8 concurrent TCP sessions driven by
//!   the in-process client against a loopback server (the issue-#3
//!   serving path, protocol + session manager included)
//! * Shared kernel-panel broker: multi-sieve SieveStreaming with
//!   per-sieve panels vs the cross-sieve shared panel at ε ∈ {0.1, 0.01}
//!   — measured kernel evals + wall time (the issue-#4 acceptance point:
//!   ≥2× fewer kernel evals at ε = 0.01)
//! * Blocked multi-RHS solve panel: per-candidate vs blocked forward
//!   solve inside `peek_gain_batch` at n ∈ {32, 128}, B ∈ {16, 64} on a
//!   solve-dominated configuration (the issue-#5 acceptance point:
//!   blocked wall ≤ per-candidate at n = 128)
//! * SIMD dispatch tables: scalar vs the CPU's SIMD table on the
//!   dispatched hot loops — blocked kernel panel, interleaved
//!   4-candidate dot, and the full blocked-solve gain path — at
//!   d ∈ {16, 128} (the PR-9 acceptance point: ≥1.5× on the kernel
//!   panel at d = 128, gated in CI via `--simd-json`)
//! * Observability overhead: the same ThreeSieves chunked run with span/
//!   wall-clock recording off vs on, plus the per-stage (kernel / solve /
//!   scan) wall breakdown the recording surfaces (the PR-7 acceptance
//!   point: ≤3% ns/query overhead, gated in CI via `--obs-json`)
//! * Fault-injection overhead: the full service push path with the chaos
//!   harness disarmed (one relaxed load per site) vs armed with an inert
//!   rule (the PR-10 acceptance point: disarmed ratio ≤ 1.03, gated in
//!   CI via `--fault-json`)
//!
//! Run: `cargo bench --bench micro_hotpath [-- [--quick] [--json PATH]
//! [--scaling-json PATH] [--service-json PATH] [--panel-json PATH]
//! [--solve-json PATH] [--simd-json PATH] [--obs-json PATH]
//! [--fault-json PATH] [--backend scalar|simd|auto]]`.
//! `--quick` shrinks iteration counts to CI-smoke scale; `--json PATH`
//! writes the headline numbers as a JSON object (the CI bench job uploads
//! it as an artifact so the BENCH_* trajectory populates); the other
//! `--*-json` flags write the thread-scaling, service-throughput,
//! panel-sharing, solve-panel, SIMD-backend and observability-overhead
//! numbers as their own artifacts. `--backend` pins the process-wide
//! kernel dispatch table for every row above (default: `TS_KERNEL_BACKEND`
//! or auto-detect); the SIMD head-to-head rows time both explicit tables
//! regardless.

use std::path::PathBuf;

use threesieves::algorithms::three_sieves::SieveTuning;
use threesieves::algorithms::{SieveStreaming, StreamingAlgorithm, ThreeSieves};
use threesieves::coordinator::ShardedThreeSieves;
use threesieves::data::registry;
use threesieves::exec::{ExecContext, Parallelism};
use threesieves::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
use threesieves::runtime::PjrtLogDet;
use threesieves::util::json::Json;
use threesieves::util::rng::Rng;
use threesieves::util::timer::bench_loop;

/// Headline metrics accumulated for `--json`.
struct Report {
    entries: Vec<(String, f64)>,
}

impl Report {
    fn push(&mut self, key: impl Into<String>, value: f64) {
        self.entries.push((key.into(), value));
    }

    fn write(&self, path: &str) -> std::io::Result<()> {
        let obj =
            Json::obj(self.entries.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect());
        std::fs::write(path, obj.to_string())
    }
}

fn rand_rows(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
    (0..n * d).map(|_| rng.normal() as f32).collect()
}

fn bench_native_gain(d: usize, n_summary: usize, iters: usize) {
    let mut rng = Rng::seed_from(1);
    let rows = rand_rows(&mut rng, n_summary, d);
    let mut f = NativeLogDet::new(LogDetConfig::with_gamma(d, n_summary, 2.0 * d as f64, 1.0));
    for i in 0..n_summary {
        f.accept(&rows[i * d..(i + 1) * d]);
    }
    let probe = rand_rows(&mut rng, 1, d);
    let mut sink = 0.0;
    let stats = bench_loop(iters / 10, iters, || {
        sink += f.peek_gain(&probe);
    });
    println!(
        "native gain      d={d:<4} |S|={n_summary:<4}: {:>9.1} ns/query  ({:.2}M q/s)  [{}]",
        stats.mean() * 1e9,
        1e-6 / stats.mean(),
        stats.summary("s")
    );
    std::hint::black_box(sink);
}

/// The tentpole measurement: scalar peek_gain loop vs one peek_gain_batch
/// panel over the same B candidates, at the paper-scale working point.
/// Returns the throughput ratio (batched / scalar).
fn bench_batched_gain(d: usize, n_summary: usize, b: usize, iters: usize, rep: &mut Report) -> f64 {
    let mut rng = Rng::seed_from(4);
    let rows = rand_rows(&mut rng, n_summary, d);
    let mut f = NativeLogDet::new(LogDetConfig::with_gamma(d, n_summary, 2.0 * d as f64, 1.0));
    for i in 0..n_summary {
        f.accept(&rows[i * d..(i + 1) * d]);
    }
    let cands = rand_rows(&mut rng, b, d);
    let mut sink = 0.0;
    let scalar = bench_loop(iters / 10, iters, || {
        for i in 0..b {
            sink += f.peek_gain(&cands[i * d..(i + 1) * d]);
        }
    });
    let mut out = Vec::new();
    let batched = bench_loop(iters / 10, iters, || {
        f.peek_gain_batch(&cands, b, &mut out);
        sink += out[0];
    });
    std::hint::black_box(sink);
    let scalar_ns = scalar.mean() * 1e9 / b as f64;
    let batched_ns = batched.mean() * 1e9 / b as f64;
    let speedup = scalar_ns / batched_ns;
    println!(
        "batched gain     d={d:<4} |S|={n_summary:<4} B={b:<4}: scalar {scalar_ns:>8.1} ns/q  \
         batched {batched_ns:>8.1} ns/q  speedup {speedup:.2}x"
    );
    if n_summary == 64 && d == 128 && b == 64 {
        rep.push("batched_gain_n64_d128_scalar_ns_per_query", scalar_ns);
        rep.push("batched_gain_n64_d128_batched_ns_per_query", batched_ns);
        rep.push("batched_gain_n64_d128_speedup", speedup);
    }
    speedup
}

fn bench_native_append_remove(d: usize, k: usize, iters: usize) {
    let mut rng = Rng::seed_from(2);
    let rows = rand_rows(&mut rng, k, d);
    let stats = bench_loop(iters / 10 + 1, iters, || {
        let mut f = NativeLogDet::new(LogDetConfig::with_gamma(d, k, 2.0 * d as f64, 1.0));
        for i in 0..k {
            f.accept(&rows[i * d..(i + 1) * d]);
        }
        f.remove(0);
        f.remove(k / 2 - 1);
    });
    println!(
        "native build+2del d={d:<4} K={k:<4}: {:>9.1} µs/cycle [{}]",
        stats.mean() * 1e6,
        stats.summary("s")
    );
}

fn bench_pjrt_gain(artifacts: &PathBuf, iters: usize) {
    let Ok(mut oracle) = PjrtLogDet::from_artifacts(artifacts, "quickstart_d16") else {
        println!("pjrt gain        : SKIP (artifacts not built or pjrt feature off)");
        return;
    };
    let d = oracle.dim();
    let b = oracle.batch_size();
    let mut rng = Rng::seed_from(3);
    for _ in 0..8 {
        let item = rand_rows(&mut rng, 1, d);
        oracle.accept(&item);
    }
    let probe = rand_rows(&mut rng, 1, d);
    let mut sink = 0.0;
    let stats = bench_loop(iters / 10, iters, || {
        sink += oracle.peek_gain(&probe);
    });
    println!(
        "pjrt gain (B=1)  d={d:<4} |S|=8  : {:>9.1} µs/query [{}]",
        stats.mean() * 1e6,
        stats.summary("s")
    );
    let cands = rand_rows(&mut rng, b, d);
    let mut out = Vec::new();
    let stats = bench_loop(iters / 10, iters, || {
        oracle.peek_gain_batch(&cands, b, &mut out);
    });
    println!(
        "pjrt gain (B={b:<2}) d={d:<4} |S|=8  : {:>9.1} µs/batch = {:>7.1} µs/query [{}]",
        stats.mean() * 1e6,
        stats.mean() * 1e6 / b as f64,
        stats.summary("s")
    );
    std::hint::black_box(sink);
}

fn bench_threesieves_throughput(n: usize, iters: usize, rep: &mut Report) {
    let dataset = "fact-highlevel-like";
    let info = registry::info(dataset).unwrap();
    let ds = registry::get(dataset, n, 7).unwrap();
    for k in [10usize, 50] {
        for batch in [1usize, 64] {
            let stats = bench_loop(1, iters, || {
                let f = NativeLogDet::new(LogDetConfig::for_streaming(info.dim, k));
                let mut algo =
                    ThreeSieves::new(Box::new(f), k, 0.001, SieveTuning::FixedT(1000));
                if batch == 1 {
                    for row in ds.iter() {
                        algo.process(row);
                    }
                } else {
                    for chunk in ds.raw().chunks(batch * info.dim) {
                        algo.process_batch(chunk);
                    }
                }
                std::hint::black_box(algo.value());
            });
            let items_per_s = n as f64 / stats.mean();
            println!(
                "threesieves e2e  d={:<4} K={k:<4} B={batch:<3}: {:>9.2} ms/{n} items = \
                 {items_per_s:>8.0} items/s [{}]",
                info.dim,
                stats.mean() * 1e3,
                stats.summary("s")
            );
            if k == 50 {
                let key = if batch == 1 {
                    "threesieves_e2e_k50_scalar_items_per_s"
                } else {
                    "threesieves_e2e_k50_batched_items_per_s"
                };
                rep.push(key, items_per_s);
            }
        }
    }
}

/// The issue-#2 acceptance point: ShardedThreeSieves chunked ingestion,
/// shards fanned out across the exec pool at 1/2/4/8 threads. The shard
/// count (8) and small-ish T keep every shard busy walking its threshold
/// partition, so per-chunk work is coarse (one B×n gain panel per shard
/// per rejection run) and the pool's speedup reflects the real serving
/// path. Thread count 1 is `Parallelism::Off` — the sequential baseline.
/// Results are bit-identical across thread counts (exec_parity pins it);
/// only the wall clock moves.
fn bench_sharded_scaling(n: usize, iters: usize, rep: &mut Report, scaling: &mut Report) {
    let dataset = "abc-like";
    let info = registry::info(dataset).unwrap();
    let ds = registry::get(dataset, n, 7).unwrap();
    let (k, shards, t, eps, batch) = (32usize, 8usize, 200usize, 0.002f64, 256usize);
    let mut baseline_items_per_s = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let par = if threads == 1 { Parallelism::Off } else { Parallelism::Threads(threads) };
        // One pool per thread count, reused across iterations — steady
        // state, not spawn cost.
        let exec = ExecContext::new(par);
        let stats = bench_loop(1, iters, || {
            let f = NativeLogDet::new(LogDetConfig::for_streaming(info.dim, k));
            let mut algo =
                ShardedThreeSieves::new(Box::new(f), k, eps, SieveTuning::FixedT(t), shards);
            algo.set_exec(exec.clone());
            for chunk in ds.raw().chunks(batch * info.dim) {
                algo.process_batch(chunk);
            }
            std::hint::black_box(algo.value());
        });
        let items_per_s = n as f64 / stats.mean();
        let speedup = if baseline_items_per_s > 0.0 {
            items_per_s / baseline_items_per_s
        } else {
            baseline_items_per_s = items_per_s;
            1.0
        };
        println!(
            "sharded scaling  d={:<4} K={k:<4} p={shards} threads={threads}: \
             {:>9.2} ms/{n} items = {items_per_s:>8.0} items/s  speedup {speedup:.2}x [{}]",
            info.dim,
            stats.mean() * 1e3,
            stats.summary("s")
        );
        let key_tp = format!("sharded_scaling_t{threads}_items_per_s");
        let key_sp = format!("sharded_scaling_t{threads}_speedup");
        rep.push(key_tp.clone(), items_per_s);
        rep.push(key_sp.clone(), speedup);
        scaling.push(key_tp, items_per_s);
        scaling.push(key_sp, speedup);
    }
}

/// The issue-#5 acceptance rows: per-candidate vs blocked multi-RHS
/// forward solve inside `peek_gain_batch`, at solve-dominated working
/// points (d = 16 keeps the kernel panel O(n·d) well below the solve's
/// O(n²) at n = 128). Both paths are bitwise identical
/// (`set_blocked_solve` only moves the factor's memory traffic); the
/// wall-clock ratio is the whole point, tracked in CI via `--solve-json`
/// (`bench_solve_panel.json`).
fn bench_solve_panel(iters: usize, rep: &mut Report, solve: &mut Report) {
    let d = 16usize;
    let mut rng = Rng::seed_from(9);
    for n in [32usize, 128] {
        let rows = rand_rows(&mut rng, n, d);
        for b in [16usize, 64] {
            let cands = rand_rows(&mut rng, b, d);
            let mut secs = [0f64; 2]; // [per-candidate, blocked]
            for (mode, blocked) in [false, true].into_iter().enumerate() {
                let mut f =
                    NativeLogDet::new(LogDetConfig::with_gamma(d, n, 2.0 * d as f64, 1.0));
                f.set_blocked_solve(blocked);
                for i in 0..n {
                    f.accept(&rows[i * d..(i + 1) * d]);
                }
                let mut out = Vec::new();
                let mut sink = 0.0;
                let stats = bench_loop(iters / 10, iters, || {
                    f.peek_gain_batch(&cands, b, &mut out);
                    sink += out[0];
                });
                std::hint::black_box(sink);
                secs[mode] = stats.mean();
            }
            let per_ns = secs[0] * 1e9 / b as f64;
            let blk_ns = secs[1] * 1e9 / b as f64;
            let speedup = per_ns / blk_ns;
            println!(
                "solve panel      d={d:<4} |S|={n:<4} B={b:<4}: per-cand {per_ns:>8.1} ns/q  \
                 blocked {blk_ns:>8.1} ns/q  speedup {speedup:.2}x"
            );
            for (key, val) in [
                (format!("solve_panel_n{n}_b{b}_per_candidate_ns_per_query"), per_ns),
                (format!("solve_panel_n{n}_b{b}_blocked_ns_per_query"), blk_ns),
                (format!("solve_panel_n{n}_b{b}_speedup"), speedup),
            ] {
                rep.push(key.clone(), val);
                solve.push(key, val);
            }
        }
    }
}

/// The shared kernel-panel broker head-to-head: a multi-sieve
/// SieveStreaming ingesting the same chunked stream with per-sieve B×n
/// panels vs the shared broker panel (one U×B panel per chunk across all
/// sieves), at ε ∈ {0.1, 0.01}. Reports measured kernel-entry
/// evaluations and wall time; the dense ε = 0.01 grid is the acceptance
/// point (kernel evals must drop ≥2× — `panel_sharing_parity` pins the
/// bit-identical summaries/queries, this row tracks the measured ratio).
fn bench_panel_sharing(n: usize, iters: usize, rep: &mut Report, panel: &mut Report) {
    let dataset = "fact-highlevel-like";
    let info = registry::info(dataset).unwrap();
    let ds = registry::get(dataset, n, 7).unwrap();
    let (k, batch) = (32usize, 64usize);
    for eps in [0.1f64, 0.01] {
        let mut evals = [0u64; 2]; // [per-sieve, shared]
        let mut secs = [0f64; 2];
        for (mode, shared) in [false, true].into_iter().enumerate() {
            let mut kernel_evals = 0u64;
            let stats = bench_loop(1, iters, || {
                let f = NativeLogDet::new(LogDetConfig::for_streaming(info.dim, k));
                let mut algo = SieveStreaming::new(Box::new(f), k, eps);
                algo.set_panel_sharing(shared);
                for chunk in ds.raw().chunks(batch * info.dim) {
                    algo.process_batch(chunk);
                }
                kernel_evals = algo.stats().kernel_evals;
                std::hint::black_box(algo.value());
            });
            evals[mode] = kernel_evals;
            secs[mode] = stats.mean();
            let label = if shared { "shared " } else { "per-sieve" };
            println!(
                "panel sharing    d={:<4} K={k:<4} eps={eps:<5} {label:<9}: \
                 {:>9.2} ms/{n} items  kernel_evals={kernel_evals} [{}]",
                info.dim,
                stats.mean() * 1e3,
                stats.summary("s")
            );
        }
        let eval_ratio = evals[0] as f64 / evals[1].max(1) as f64;
        let speedup = secs[0] / secs[1];
        println!(
            "panel sharing    d={:<4} K={k:<4} eps={eps:<5} ratio    : \
             kernel evals {eval_ratio:.2}x fewer, wall {speedup:.2}x faster",
            info.dim
        );
        let tag = if eps == 0.1 { "eps01" } else { "eps001" };
        for (key, val) in [
            (format!("panel_sharing_{tag}_per_sieve_kernel_evals"), evals[0] as f64),
            (format!("panel_sharing_{tag}_shared_kernel_evals"), evals[1] as f64),
            (format!("panel_sharing_{tag}_kernel_eval_ratio"), eval_ratio),
            (format!("panel_sharing_{tag}_wall_speedup"), speedup),
        ] {
            rep.push(key.clone(), val);
            panel.push(key, val);
        }
    }
}

/// Multi-tenant serving throughput: `sessions` concurrent tenants over
/// loopback TCP, each streaming `n_per_session` items in 64-row packed
/// chunks through its own connection. Measures the full serving path —
/// protocol encode/decode, session-manager locking, per-tenant algorithm
/// work — not just the algorithm kernel.
fn bench_service_sessions(
    n_per_session: usize,
    sessions: usize,
    iters: usize,
    rep: &mut Report,
    svc: &mut Report,
) {
    use threesieves::config::ServiceConfig;
    use threesieves::service::{Client, Server, SessionSpec};

    let dataset = "fact-highlevel-like";
    let info = registry::info(dataset).unwrap();
    let k = 8usize;
    let data: Vec<_> = (0..sessions)
        .map(|i| registry::get(dataset, n_per_session, 40 + i as u64).unwrap())
        .collect();
    let stats = bench_loop(1, iters, || {
        let cfg = ServiceConfig {
            idle_timeout: std::time::Duration::ZERO,
            parallelism: Parallelism::Threads(sessions + 2),
            ..ServiceConfig::default()
        };
        let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
        let addr = handle.addr();
        let workers: Vec<_> = data
            .iter()
            .enumerate()
            .map(|(i, ds)| {
                let raw = ds.raw().to_vec();
                let dim = ds.dim();
                std::thread::spawn(move || {
                    let id = format!("bench-{i}");
                    let spec = SessionSpec::three_sieves(dim, k, 0.01, 500);
                    let mut client = Client::connect(addr).unwrap();
                    client.open(&id, &spec).unwrap();
                    for chunk in raw.chunks(64 * dim) {
                        client.push_packed(&id, chunk).unwrap();
                    }
                    client.close(&id, true).unwrap();
                    client.quit().unwrap();
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        handle.shutdown();
    });
    let total_items = (sessions * n_per_session) as f64;
    let items_per_s = total_items / stats.mean();
    println!(
        "service sessions d={:<4} K={k:<4} tenants={sessions}: {:>9.2} ms/{} items = \
         {items_per_s:>8.0} items/s [{}]",
        info.dim,
        stats.mean() * 1e3,
        sessions * n_per_session,
        stats.summary("s")
    );
    let key = format!("service_{sessions}sessions_items_per_s");
    rep.push(key.clone(), items_per_s);
    svc.push(key, items_per_s);
    svc.push("service_sessions", sessions as f64);
    svc.push("service_items_per_session", n_per_session as f64);
}

/// The PR-9 acceptance rows: scalar vs SIMD dispatch table on the
/// dispatched hot loops at d ∈ {16, 128}. The kernel-panel and dot_x4
/// rows time the explicit tables head-to-head (no global state); the
/// blocked-solve row flips the process-wide selection around the full
/// `peek_gain_batch` path — blocked kernel panel plus blocked forward
/// solve behind the same seam — and restores the run's backend after.
/// The d = 128 kernel-panel speedup is the CI headline
/// (`simd_kernel_panel_d128_speedup`, pinned ≥1.5× on AVX2 runners).
/// Self-skips on CPUs without a SIMD table — every row would be 1.0x by
/// definition (`simd` falls back to the scalar table there).
fn bench_simd(iters: usize, rep: &mut Report, simd_rep: &mut Report) {
    use threesieves::simd::{self, kernel_panel_into, scalar_ops, simd_ops, BackendChoice};
    let Some(simd_t) = simd_ops() else {
        println!("simd backend     : SKIP (no AVX2/NEON on this CPU)");
        return;
    };
    let mut rng = Rng::seed_from(11);
    let (n, b) = (64usize, 64usize);
    let mut sink = 0.0f64;
    for d in [16usize, 128] {
        let gamma = 1.0 / d as f64;
        let feats = rand_rows(&mut rng, n, d);
        let items = rand_rows(&mut rng, b, d);
        let mut out = vec![0.0f64; b * n];
        let mut secs = [0f64; 2]; // [scalar, simd]
        for (mode, ops) in [scalar_ops(), simd_t].into_iter().enumerate() {
            let norms: Vec<f64> = feats.chunks_exact(d).map(|r| (ops.dot)(r, r)).collect();
            let stats = bench_loop(iters / 10, iters, || {
                kernel_panel_into(ops, &feats, &norms, d, n, gamma, &items, b, &mut out);
                sink += out[0];
            });
            secs[mode] = stats.mean();
        }
        let scalar_ns = secs[0] * 1e9 / b as f64;
        let simd_ns = secs[1] * 1e9 / b as f64;
        let speedup = scalar_ns / simd_ns;
        println!(
            "simd kernel panel d={d:<4} |S|={n:<4} B={b:<4}: scalar {scalar_ns:>8.1} ns/q  \
             simd {simd_ns:>8.1} ns/q  speedup {speedup:.2}x"
        );
        for (key, val) in [
            (format!("simd_kernel_panel_d{d}_scalar_ns_per_query"), scalar_ns),
            (format!("simd_kernel_panel_d{d}_simd_ns_per_query"), simd_ns),
            (format!("simd_kernel_panel_d{d}_speedup"), speedup),
        ] {
            rep.push(key.clone(), val);
            simd_rep.push(key, val);
        }

        let x4 = |i: usize| &items[i * d..(i + 1) * d];
        let xs: [&[f32]; 4] = [x4(0), x4(1), x4(2), x4(3)];
        for (mode, ops) in [scalar_ops(), simd_t].into_iter().enumerate() {
            let stats = bench_loop(iters / 10, iters, || {
                for row in feats.chunks_exact(d) {
                    let v = (ops.dot_x4)(&xs, row);
                    sink += v[0] + v[1] + v[2] + v[3];
                }
            });
            secs[mode] = stats.mean();
        }
        let scalar_ns = secs[0] * 1e9 / (n * 4) as f64;
        let simd_ns = secs[1] * 1e9 / (n * 4) as f64;
        let speedup = scalar_ns / simd_ns;
        println!(
            "simd dot_x4      d={d:<4} |S|={n:<4}       : scalar {scalar_ns:>8.1} ns/dot \
             simd {simd_ns:>8.1} ns/dot speedup {speedup:.2}x"
        );
        for (key, val) in [
            (format!("simd_dot_x4_d{d}_scalar_ns"), scalar_ns),
            (format!("simd_dot_x4_d{d}_simd_ns"), simd_ns),
            (format!("simd_dot_x4_d{d}_speedup"), speedup),
        ] {
            rep.push(key.clone(), val);
            simd_rep.push(key, val);
        }

        // Full seam: |S| = 128 makes the O(|S|²) blocked forward solve
        // dominate at d = 16, while d = 128 splits the time with the
        // kernel panel — both ride the selected dispatch table.
        let n_solve = 128usize;
        let rows = rand_rows(&mut rng, n_solve, d);
        let cands = rand_rows(&mut rng, b, d);
        let prev = simd::active_name();
        let choices = [BackendChoice::Scalar, BackendChoice::Simd];
        for (mode, choice) in choices.into_iter().enumerate() {
            simd::select(choice);
            let cfg = LogDetConfig::with_gamma(d, n_solve, 2.0 * d as f64, 1.0);
            let mut f = NativeLogDet::new(cfg);
            for i in 0..n_solve {
                f.accept(&rows[i * d..(i + 1) * d]);
            }
            let mut gains = Vec::new();
            let stats = bench_loop(iters / 10, iters, || {
                f.peek_gain_batch(&cands, b, &mut gains);
                sink += gains[0];
            });
            secs[mode] = stats.mean();
        }
        let restore = if prev == "scalar" { BackendChoice::Scalar } else { BackendChoice::Simd };
        simd::select(restore);
        let scalar_ns = secs[0] * 1e9 / b as f64;
        let simd_ns = secs[1] * 1e9 / b as f64;
        let speedup = scalar_ns / simd_ns;
        println!(
            "simd blocked slv d={d:<4} |S|={n_solve:<4} B={b:<4}: scalar {scalar_ns:>8.1} ns/q  \
             simd {simd_ns:>8.1} ns/q  speedup {speedup:.2}x"
        );
        for (key, val) in [
            (format!("simd_blocked_solve_d{d}_scalar_ns_per_query"), scalar_ns),
            (format!("simd_blocked_solve_d{d}_simd_ns_per_query"), simd_ns),
            (format!("simd_blocked_solve_d{d}_speedup"), speedup),
        ] {
            rep.push(key.clone(), val);
            simd_rep.push(key, val);
        }
    }
    std::hint::black_box(sink);
}

/// The PR-7 acceptance row: an identical ThreeSieves chunked run with
/// observability recording off, then on. Min-over-iterations wall keeps
/// scheduler noise out of the ratio; CI pins `obs_overhead_ratio` ≤ 1.03.
/// With recording on the oracle's per-stage wall counters populate, so
/// the same run also yields the kernel / solve / scan stage breakdown.
fn bench_obs_overhead(n: usize, iters: usize, rep: &mut Report, obs: &mut Report) {
    let dataset = "fact-highlevel-like";
    let info = registry::info(dataset).unwrap();
    let ds = registry::get(dataset, n, 7).unwrap();
    let (k, batch) = (50usize, 64usize);
    let mut ns_per_query = [0f64; 2]; // [off, on]
    let mut breakdown = (0u64, 0u64, 0u64);
    let mut on_wall_s = 0f64;
    for (mode, on) in [false, true].into_iter().enumerate() {
        threesieves::obs::set_enabled(on);
        let mut queries = 0u64;
        let stats = bench_loop(1, iters, || {
            let f = NativeLogDet::new(LogDetConfig::for_streaming(info.dim, k));
            let mut algo = ThreeSieves::new(Box::new(f), k, 0.001, SieveTuning::FixedT(1000));
            for chunk in ds.raw().chunks(batch * info.dim) {
                algo.process_batch(chunk);
            }
            let st = algo.stats();
            queries = st.queries;
            if on {
                breakdown = (st.wall_kernel_ns, st.wall_solve_ns, st.wall_scan_ns);
            }
            std::hint::black_box(algo.value());
        });
        ns_per_query[mode] = stats.min() * 1e9 / queries.max(1) as f64;
        if on {
            on_wall_s = stats.min();
        }
    }
    threesieves::obs::set_enabled(false);
    let ratio = ns_per_query[1] / ns_per_query[0];
    println!(
        "obs overhead     d={:<4} K={k:<4} B={batch:<3}: off {:>8.1} ns/q  on {:>8.1} ns/q  \
         overhead {ratio:.3}x",
        info.dim, ns_per_query[0], ns_per_query[1]
    );
    let (kn, sn, cn) = breakdown;
    let pct = |ns: u64| 100.0 * ns as f64 / (on_wall_s * 1e9).max(1.0);
    println!(
        "obs stages       kernel {:.1}% ({:.2} ms)  solve {:.1}% ({:.2} ms)  \
         scan {:.1}% ({:.2} ms) of traced wall",
        pct(kn),
        kn as f64 / 1e6,
        pct(sn),
        sn as f64 / 1e6,
        pct(cn),
        cn as f64 / 1e6
    );
    for (key, val) in [
        ("obs_off_ns_per_query".to_string(), ns_per_query[0]),
        ("obs_on_ns_per_query".to_string(), ns_per_query[1]),
        ("obs_overhead_ratio".to_string(), ratio),
        ("obs_wall_kernel_ns".to_string(), kn as f64),
        ("obs_wall_solve_ns".to_string(), sn as f64),
        ("obs_wall_scan_ns".to_string(), cn as f64),
    ] {
        rep.push(key.clone(), val);
        obs.push(key, val);
    }
}

/// The PR-10 acceptance row: the full service push path (session manager,
/// non-finite gate, fault hooks, algorithm) with the fault harness
/// disarmed vs armed with a rule that never fires. Disarmed, every site
/// is one relaxed atomic load; armed, each hit walks the plan's rule list
/// and declines. CI pins `fault_overhead_ratio` ≤ 1.03 — the chaos
/// harness must be free when it is off. Min-over-iterations wall keeps
/// scheduler noise out of the ratio, mirroring the obs-overhead row.
fn bench_fault_overhead(n: usize, iters: usize, rep: &mut Report, fault_rep: &mut Report) {
    use threesieves::config::ServiceConfig;
    use threesieves::fault::{self, site, FaultKind, FaultPlan};
    use threesieves::service::{PushBody, SessionManager, SessionSpec};

    let dataset = "fact-highlevel-like";
    let info = registry::info(dataset).unwrap();
    let ds = registry::get(dataset, n, 7).unwrap();
    let (k, batch) = (50usize, 64usize);
    let spec = SessionSpec::three_sieves(info.dim, k, 0.001, 1000);
    let mut ns_per_query = [0f64; 2]; // [disarmed, armed-noop]
    for (mode, armed) in [false, true].into_iter().enumerate() {
        if armed {
            // Armed but inert: the rule waits for hit u64::MAX, so every
            // site check takes the slow path, scans the plan and declines.
            fault::arm(FaultPlan::new().nth(
                site::PUSH_ROWS,
                FaultKind::IoError,
                u64::MAX,
                1,
                1,
            ));
        }
        let mut queries = 0u64;
        let stats = bench_loop(1, iters, || {
            let mgr = SessionManager::new(ServiceConfig {
                idle_timeout: std::time::Duration::ZERO,
                ..ServiceConfig::default()
            });
            mgr.open("bench-fault", &spec).unwrap();
            for chunk in ds.raw().chunks(batch * info.dim) {
                mgr.push("bench-fault", &PushBody::Packed(chunk.to_vec())).unwrap();
            }
            queries = mgr.stats("bench-fault").unwrap().stats.queries;
            mgr.close("bench-fault", true).unwrap();
        });
        fault::disarm();
        ns_per_query[mode] = stats.min() * 1e9 / queries.max(1) as f64;
    }
    let ratio = ns_per_query[1] / ns_per_query[0];
    println!(
        "fault overhead   d={:<4} K={k:<4} B={batch:<3}: disarmed {:>8.1} ns/q  \
         armed-noop {:>8.1} ns/q  overhead {ratio:.3}x",
        info.dim, ns_per_query[0], ns_per_query[1]
    );
    for (key, val) in [
        ("fault_disarmed_ns_per_query".to_string(), ns_per_query[0]),
        ("fault_armed_noop_ns_per_query".to_string(), ns_per_query[1]),
        ("fault_overhead_ratio".to_string(), ratio),
    ] {
        rep.push(key.clone(), val);
        fault_rep.push(key, val);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let scaling_json_path = args
        .iter()
        .position(|a| a == "--scaling-json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let service_json_path = args
        .iter()
        .position(|a| a == "--service-json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let panel_json_path = args
        .iter()
        .position(|a| a == "--panel-json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let solve_json_path = args
        .iter()
        .position(|a| a == "--solve-json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let obs_json_path = args
        .iter()
        .position(|a| a == "--obs-json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let simd_json_path = args
        .iter()
        .position(|a| a == "--simd-json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let fault_json_path = args
        .iter()
        .position(|a| a == "--fault-json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let backend_choice = match args.iter().position(|a| a == "--backend") {
        None => threesieves::simd::env_choice(),
        Some(i) => {
            let v = args.get(i + 1).map(String::as_str).unwrap_or("");
            threesieves::simd::BackendChoice::parse(v)
                .unwrap_or_else(|| panic!("--backend {v}: expected scalar|simd|auto"))
        }
    };
    let backend = threesieves::simd::select(backend_choice).name;
    let mut rep = Report { entries: Vec::new() };
    let mut scaling = Report { entries: Vec::new() };
    let mut service = Report { entries: Vec::new() };
    let mut panel = Report { entries: Vec::new() };
    let mut solve = Report { entries: Vec::new() };
    let mut obs = Report { entries: Vec::new() };
    let mut simd_rep = Report { entries: Vec::new() };
    let mut fault_rep = Report { entries: Vec::new() };

    println!(
        "== micro hot-path benchmarks{} (backend: {backend}) ==",
        if quick { " (quick)" } else { "" }
    );
    let gain_iters = if quick { 200 } else { 2000 };
    for (d, n) in [(16usize, 10usize), (16, 50), (64, 50), (256, 100)] {
        bench_native_gain(d, n, gain_iters);
    }
    // The issue-#1 acceptance point: n = K = 64, d = 128, chunk of 64.
    let panel_iters = if quick { 50 } else { 500 };
    bench_batched_gain(128, 64, 64, panel_iters, &mut rep);
    bench_batched_gain(128, 64, 256, panel_iters, &mut rep);
    bench_batched_gain(32, 16, 64, panel_iters, &mut rep);
    // The issue-#5 acceptance point: blocked vs per-candidate solve wall
    // on the solve-dominated scenarios.
    bench_solve_panel(gain_iters, &mut rep, &mut solve);
    // The PR-9 acceptance rows: scalar vs SIMD table head-to-head.
    bench_simd(panel_iters, &mut rep, &mut simd_rep);
    bench_native_append_remove(16, 50, if quick { 10 } else { 50 });
    bench_native_append_remove(64, 100, if quick { 10 } else { 50 });
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    bench_pjrt_gain(&artifacts, if quick { 40 } else { 200 });
    let (e2e_n, e2e_iters) = if quick { (4_000, 2) } else { (20_000, 5) };
    bench_threesieves_throughput(e2e_n, e2e_iters, &mut rep);
    let (scale_n, scale_iters) = if quick { (4_000, 2) } else { (16_000, 3) };
    bench_sharded_scaling(scale_n, scale_iters, &mut rep, &mut scaling);
    let (panel_n, panel_iters) = if quick { (3_000, 2) } else { (10_000, 3) };
    bench_panel_sharing(panel_n, panel_iters, &mut rep, &mut panel);
    let (svc_n, svc_iters) = if quick { (2_000, 2) } else { (8_000, 3) };
    bench_service_sessions(svc_n, 8, svc_iters, &mut rep, &mut service);
    // Last so the global enable toggles cannot leak into the rows above.
    let (obs_n, obs_iters) = if quick { (4_000, 3) } else { (20_000, 5) };
    bench_obs_overhead(obs_n, obs_iters, &mut rep, &mut obs);
    bench_fault_overhead(obs_n, obs_iters, &mut rep, &mut fault_rep);

    if let Some(path) = json_path {
        match rep.write(&path) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
    if let Some(path) = scaling_json_path {
        match scaling.write(&path) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
    if let Some(path) = service_json_path {
        match service.write(&path) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
    if let Some(path) = panel_json_path {
        match panel.write(&path) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
    if let Some(path) = solve_json_path {
        match solve.write(&path) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
    if let Some(path) = obs_json_path {
        match obs.write(&path) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
    if let Some(path) = simd_json_path {
        match simd_rep.write(&path) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
    if let Some(path) = fault_json_path {
        match fault_rep.write(&path) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
}
