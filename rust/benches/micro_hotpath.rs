//! Micro-benchmarks of the per-element hot path — the §Perf work surface.
//!
//! * native log-det gain query: kernel row (O(nd)) + forward solve (O(n²))
//! * Cholesky append and delete
//! * PJRT gain query (single + batched) for the compiled artifact, showing
//!   the dispatch overhead the native path avoids and the batch
//!   amortization the artifact path relies on
//! * ThreeSieves end-to-end items/second
//!
//! Run: `cargo bench --bench micro_hotpath`.

use std::path::PathBuf;

use threesieves::algorithms::three_sieves::SieveTuning;
use threesieves::algorithms::{StreamingAlgorithm, ThreeSieves};
use threesieves::data::registry;
use threesieves::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
use threesieves::runtime::PjrtLogDet;
use threesieves::util::rng::Rng;
use threesieves::util::timer::bench_loop;

fn rand_rows(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
    (0..n * d).map(|_| rng.normal() as f32).collect()
}

fn bench_native_gain(d: usize, n_summary: usize) {
    let mut rng = Rng::seed_from(1);
    let rows = rand_rows(&mut rng, n_summary, d);
    let mut f = NativeLogDet::new(LogDetConfig::with_gamma(d, n_summary, 2.0 * d as f64, 1.0));
    for i in 0..n_summary {
        f.accept(&rows[i * d..(i + 1) * d]);
    }
    let probe = rand_rows(&mut rng, 1, d);
    let mut sink = 0.0;
    let stats = bench_loop(200, 2000, || {
        sink += f.peek_gain(&probe);
    });
    println!(
        "native gain      d={d:<4} |S|={n_summary:<4}: {:>9.1} ns/query  ({:.2}M q/s)  [{}]",
        stats.mean() * 1e9,
        1e-6 / stats.mean(),
        stats.summary("s")
    );
    std::hint::black_box(sink);
}

fn bench_native_append_remove(d: usize, k: usize) {
    let mut rng = Rng::seed_from(2);
    let rows = rand_rows(&mut rng, k, d);
    let stats = bench_loop(5, 50, || {
        let mut f = NativeLogDet::new(LogDetConfig::with_gamma(d, k, 2.0 * d as f64, 1.0));
        for i in 0..k {
            f.accept(&rows[i * d..(i + 1) * d]);
        }
        f.remove(0);
        f.remove(k / 2 - 1);
    });
    println!(
        "native build+2del d={d:<4} K={k:<4}: {:>9.1} µs/cycle [{}]",
        stats.mean() * 1e6,
        stats.summary("s")
    );
}

fn bench_pjrt_gain(artifacts: &PathBuf) {
    let Ok(mut oracle) = PjrtLogDet::from_artifacts(artifacts, "quickstart_d16") else {
        println!("pjrt gain        : SKIP (artifacts not built)");
        return;
    };
    let d = oracle.dim();
    let b = oracle.batch_size();
    let mut rng = Rng::seed_from(3);
    for _ in 0..8 {
        let item = rand_rows(&mut rng, 1, d);
        oracle.accept(&item);
    }
    let probe = rand_rows(&mut rng, 1, d);
    let mut sink = 0.0;
    let stats = bench_loop(20, 200, || {
        sink += oracle.peek_gain(&probe);
    });
    println!(
        "pjrt gain (B=1)  d={d:<4} |S|=8  : {:>9.1} µs/query [{}]",
        stats.mean() * 1e6,
        stats.summary("s")
    );
    let cands = rand_rows(&mut rng, b, d);
    let mut out = Vec::new();
    let stats = bench_loop(20, 200, || {
        oracle.peek_gain_batch(&cands, b, &mut out);
    });
    println!(
        "pjrt gain (B={b:<2}) d={d:<4} |S|=8  : {:>9.1} µs/batch = {:>7.1} µs/query [{}]",
        stats.mean() * 1e6,
        stats.mean() * 1e6 / b as f64,
        stats.summary("s")
    );
    std::hint::black_box(sink);
}

fn bench_threesieves_throughput() {
    let dataset = "fact-highlevel-like";
    let n = 20_000;
    let info = registry::info(dataset).unwrap();
    let ds = registry::get(dataset, n, 7).unwrap();
    for k in [10usize, 50] {
        let stats = bench_loop(1, 5, || {
            let f = NativeLogDet::new(LogDetConfig::for_streaming(info.dim, k));
            let mut algo =
                ThreeSieves::new(Box::new(f), k, 0.001, SieveTuning::FixedT(1000));
            for row in ds.iter() {
                algo.process(row);
            }
            std::hint::black_box(algo.value());
        });
        println!(
            "threesieves e2e  d={:<4} K={k:<4}: {:>9.2} ms/20k items = {:>8.0} items/s [{}]",
            info.dim,
            stats.mean() * 1e3,
            n as f64 / stats.mean(),
            stats.summary("s")
        );
    }
}

fn main() {
    println!("== micro hot-path benchmarks ==");
    for (d, n) in [(16usize, 10usize), (16, 50), (64, 50), (256, 100)] {
        bench_native_gain(d, n);
    }
    bench_native_append_remove(16, 50);
    bench_native_append_remove(64, 100);
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    bench_pjrt_gain(&artifacts);
    bench_threesieves_throughput();
}
