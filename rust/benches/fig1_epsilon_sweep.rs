//! Bench: regenerate **Figure 1** — relative performance, runtime and
//! memory over ε (fixed K = 50) on the batch-dataset surrogates.
//!
//! Run: `cargo bench --bench fig1_epsilon_sweep` (`TS_BENCH_N` rescales).
//! Writes results/fig1.{csv,json}.

use std::path::PathBuf;

use threesieves::experiments::figures::{fig1, SweepScale};

fn main() {
    // `--trace-out` / `--events-out` (or TS_TRACE_OUT / TS_EVENTS_OUT)
    // arm observability for the whole run; inert otherwise.
    let obs = threesieves::obs::BenchObs::from_env();
    let n: usize =
        std::env::var("TS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(1_500);
    let out = PathBuf::from("results");
    println!("== Figure 1 sweep: eps in {{0.001..0.1}}, K = 50, n = {n} per dataset ==");
    let records = fig1(&out, SweepScale { n, seed: 42 }).expect("fig1 sweep");

    // The paper's second/third rows: runtime and memory vs eps, which is
    // where ThreeSieves' flat resource profile shows.
    println!("\n== series: runtime (s) and peak memory vs eps ==");
    let mut datasets: Vec<String> = records.iter().map(|r| r.dataset.clone()).collect();
    datasets.sort();
    datasets.dedup();
    for ds in &datasets {
        println!("\n[{ds}]");
        for &eps in &[0.001, 0.005, 0.01, 0.05, 0.1] {
            let pick = |algo: &str| {
                records
                    .iter()
                    .find(|r| r.dataset == *ds && r.epsilon == eps && r.algorithm == algo)
            };
            let fmt = |r: Option<&threesieves::metrics::RunRecord>| match r {
                Some(r) => format!("{:.2}s/{}el", r.runtime.as_secs_f64(), r.stats.peak_stored),
                None => "-".into(),
            };
            println!(
                "  eps={eps:<6} 3S(T=5000)={} SS={} SS++={} SAL={}",
                fmt(pick("ThreeSieves(T=5000)")),
                fmt(pick("SieveStreaming")),
                fmt(pick("SieveStreaming++")),
                fmt(pick("Salsa")),
            );
        }
    }
    obs.finish();
    println!("\nfig1 done — full rows in results/fig1.csv");
}
