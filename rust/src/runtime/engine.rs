//! PJRT execution engine: load HLO-text artifacts, compile once, execute.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin in this image). The
//! interchange format is HLO *text* — `HloModuleProto::from_text_file`
//! reassigns instruction ids, which sidesteps the 64-bit-id protos jax≥0.5
//! emits that xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).

use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

/// Shared PJRT client. Cheap to clone (Rc internally).
#[derive(Clone)]
pub struct Engine {
    client: Rc<xla::PjRtClient>,
}

impl Engine {
    /// CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client: Rc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text file and compile it for this client.
    pub fn load_graph(&self, path: &Path) -> Result<LoadedGraph> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedGraph {
            exe,
            name: path.file_name().and_then(|s| s.to_str()).unwrap_or("graph").to_string(),
        })
    }

    /// Upload a host f32 slice as a device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading f32 buffer")
    }

    /// Upload a host i32 slice as a device buffer.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading i32 buffer")
    }
}

/// A compiled executable (one AOT entry point).
pub struct LoadedGraph {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl LoadedGraph {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with literal inputs; unpack the `return_tuple=True` output
    /// into per-output literals.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        self.unpack(outs)
    }

    /// Execute with device-resident buffers (state stays on device between
    /// calls — the hot path used by `PjrtLogDet::peek_gain_batch`).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let outs =
            self.exe.execute_b(args).with_context(|| format!("executing(b) {}", self.name))?;
        self.unpack(outs)
    }

    fn unpack(&self, outs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        let buf = outs
            .first()
            .and_then(|replica| replica.first())
            .with_context(|| format!("{}: no output buffer", self.name))?;
        let lit = buf.to_literal_sync().context("fetching output literal")?;
        let parts = lit.to_tuple().context("untupling output")?;
        Ok(parts)
    }
}

/// Read a literal into an f32 vec (converting from the stored dtype).
pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    let converted = lit.convert(xla::PrimitiveType::F32).context("converting literal to f32")?;
    converted.to_vec::<f32>().context("reading literal data")
}

/// Read a literal into an i32 vec.
pub fn literal_to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    let converted = lit.convert(xla::PrimitiveType::S32).context("converting literal to i32")?;
    converted.to_vec::<i32>().context("reading literal data")
}

/// Build an f32 literal of the given shape.
pub fn f32_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    lit.reshape(dims).context("reshaping literal")
}

/// Build an i32 literal of the given shape.
pub fn i32_literal(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    lit.reshape(dims).context("reshaping literal")
}
