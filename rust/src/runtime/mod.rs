//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The real engine ([`engine`], [`pjrt_logdet`]) wraps the `xla` crate's
//! PJRT C-API bindings, which exist only inside the accelerator image, so
//! both modules sit behind the `pjrt` cargo feature. The default build
//! swaps in [`stub`]: same public surface, constructors return a
//! "disabled" error, and callers (CLI `pjrt-info`, the micro benches)
//! degrade to a skip message. The [`manifest`] parser is dependency-free
//! and always available.

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod pjrt_logdet;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, LoadedGraph};
pub use manifest::{ArtifactConfig, Manifest};
#[cfg(feature = "pjrt")]
pub use pjrt_logdet::PjrtLogDet;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Engine, PjrtLogDet};
