//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.

pub mod engine;
pub mod manifest;
pub mod pjrt_logdet;

pub use engine::{Engine, LoadedGraph};
pub use manifest::{ArtifactConfig, Manifest};
pub use pjrt_logdet::PjrtLogDet;
