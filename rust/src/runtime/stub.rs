//! Dependency-free stand-ins for the PJRT runtime (`pjrt` feature off).
//!
//! The real engine executes AOT-compiled HLO artifacts through the `xla`
//! crate's PJRT bindings, which are only available inside the accelerator
//! image. These stubs keep the public surface compiling in hermetic builds:
//! every constructor returns [`PjrtDisabled`], so the CLI's `pjrt-info`
//! command and the micro benches print a skip message instead of failing
//! to link. The types are never constructible — trait methods are
//! `unreachable!` by design, not placeholders.

use std::fmt;
use std::path::Path;

use crate::functions::SubmodularFunction;

/// Error returned by every stub constructor.
#[derive(Debug, Clone)]
pub struct PjrtDisabled;

impl fmt::Display for PjrtDisabled {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT runtime disabled: rebuild with --features pjrt inside the accelerator image"
        )
    }
}

impl std::error::Error for PjrtDisabled {}

/// Stub PJRT client handle (never constructible).
pub struct Engine {
    _private: (),
}

impl Engine {
    /// Always fails: the PJRT plugin is not linked into this build.
    pub fn cpu() -> Result<Self, PjrtDisabled> {
        Err(PjrtDisabled)
    }

    pub fn platform(&self) -> String {
        unreachable!("stub Engine cannot be constructed")
    }
}

/// Stub PJRT-backed oracle (never constructible).
pub struct PjrtLogDet {
    _private: (),
}

impl PjrtLogDet {
    /// Always fails: the PJRT plugin is not linked into this build.
    pub fn from_artifacts(_dir: &Path, _cfg_name: &str) -> Result<Self, PjrtDisabled> {
        Err(PjrtDisabled)
    }

    pub fn batch_size(&self) -> usize {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }
}

impl SubmodularFunction for PjrtLogDet {
    fn dim(&self) -> usize {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn len(&self) -> usize {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn current_value(&self) -> f64 {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn max_singleton_value(&self) -> f64 {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn peek_gain(&mut self, _item: &[f32]) -> f64 {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn accept(&mut self, _item: &[f32]) {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn remove(&mut self, _idx: usize) {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn summary(&self) -> &[f32] {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn reset(&mut self) {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn queries(&self) -> u64 {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn clone_empty(&self) -> Box<dyn SubmodularFunction> {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }

    fn parallel_safe(&self) -> bool {
        unreachable!("stub PjrtLogDet cannot be constructed")
    }
}
