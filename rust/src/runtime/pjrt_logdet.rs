//! The log-det oracle backed by the AOT-compiled JAX/Pallas artifact.
//!
//! This is the three-layer composition made concrete: the L1 Pallas RBF
//! kernel and L2 gain/append graphs were lowered once at build time
//! (`make artifacts`); here they execute through PJRT with **zero Python**
//! on the request path. State (`summary`, `chol`, `n`) round-trips as
//! device buffers between calls: gain queries run entirely against cached
//! device state, and only accepts synchronize back to the host.
//!
//! Semantics match [`NativeLogDet`](crate::functions::NativeLogDet)
//! (`rust/tests/pjrt_roundtrip.rs` asserts agreement to float tolerance).

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::functions::SubmodularFunction;
use crate::util::mathx::floor_eps;

use super::engine::{f32_literal, i32_literal, literal_to_f32, literal_to_i32, Engine, LoadedGraph};
use super::manifest::{ArtifactConfig, Manifest};

/// Compiled entry points for one artifact config, shared between oracle
/// clones (compilation happens once).
pub struct GraphSet {
    pub cfg: ArtifactConfig,
    pub gain: LoadedGraph,
    pub append: LoadedGraph,
    pub value: LoadedGraph,
}

impl GraphSet {
    /// Load + compile the three entry points of `cfg_name`.
    pub fn load(engine: &Engine, manifest: &Manifest, cfg_name: &str) -> Result<Rc<Self>> {
        let cfg = manifest.config(cfg_name)?.clone();
        let gain = engine.load_graph(&manifest.file_path(&cfg, "gain")?)?;
        let append = engine.load_graph(&manifest.file_path(&cfg, "append")?)?;
        let value = engine.load_graph(&manifest.file_path(&cfg, "value")?)?;
        Ok(Rc::new(GraphSet { cfg, gain, append, value }))
    }
}

/// Device-resident padded state.
struct DeviceState {
    summary: xla::PjRtBuffer,
    chol: xla::PjRtBuffer,
    n: xla::PjRtBuffer,
}

/// PJRT-backed submodular oracle.
pub struct PjrtLogDet {
    engine: Engine,
    graphs: Rc<GraphSet>,
    /// Host mirror of the padded state (source of truth).
    summary: Vec<f32>,
    chol: Vec<f32>,
    n: usize,
    /// Cached device copy of the state (invalidated by accept/reset).
    device: RefCell<Option<DeviceState>>,
    value: f64,
    queries: u64,
    /// Candidate staging buffer (B×d, zero-padded).
    cand_buf: Vec<f32>,
}

impl PjrtLogDet {
    pub fn new(engine: Engine, graphs: Rc<GraphSet>) -> Self {
        let (k, d) = (graphs.cfg.k, graphs.cfg.d);
        let mut chol = vec![0.0f32; k * k];
        for i in 0..k {
            chol[i * k + i] = 1.0;
        }
        PjrtLogDet {
            engine,
            summary: vec![0.0; k * d],
            chol,
            n: 0,
            device: RefCell::new(None),
            value: 0.0,
            queries: 0,
            cand_buf: vec![0.0; graphs.cfg.b * d],
            graphs,
        }
    }

    /// Convenience: engine + manifest dir + config name.
    pub fn from_artifacts(dir: &std::path::Path, cfg_name: &str) -> Result<Self> {
        let engine = Engine::cpu()?;
        let manifest = Manifest::load(dir)?;
        let graphs = GraphSet::load(&engine, &manifest, cfg_name)?;
        Ok(Self::new(engine, graphs))
    }

    fn k_cap(&self) -> usize {
        self.graphs.cfg.k
    }

    /// Max candidates per gain execution (the artifact's static B).
    pub fn batch_size(&self) -> usize {
        self.graphs.cfg.b
    }

    /// Ensure the device holds the current state; upload if stale.
    fn ensure_device(&self) -> Result<()> {
        let mut slot = self.device.borrow_mut();
        if slot.is_none() {
            let (k, d) = (self.graphs.cfg.k, self.graphs.cfg.d);
            *slot = Some(DeviceState {
                summary: self.engine.upload_f32(&self.summary, &[k, d])?,
                chol: self.engine.upload_f32(&self.chol, &[k, k])?,
                n: self.engine.upload_i32(&[self.n as i32], &[1])?,
            });
        }
        Ok(())
    }

    /// Run the gain graph on up to `b` candidates (padded batch) and return
    /// the first `count` gains.
    fn run_gain(&self, cands: &[f32], count: usize) -> Result<Vec<f64>> {
        let (b, d) = (self.graphs.cfg.b, self.graphs.cfg.d);
        debug_assert!(count <= b);
        self.ensure_device()?;
        let cand_buf = self.engine.upload_f32(cands, &[b, d])?;
        let slot = self.device.borrow();
        let state = slot.as_ref().expect("ensured above");
        let outs = self
            .graphs
            .gain
            .run_buffers(&[&state.summary, &state.chol, &state.n, &cand_buf])?;
        let gains = literal_to_f32(&outs[0])?;
        Ok(gains[..count].iter().map(|&g| g as f64).collect())
    }

    fn recompute_value(&mut self) {
        // f(S) = Σ ln diag(L) over valid rows — host-side from the mirror.
        let k = self.k_cap();
        let mut v = 0.0;
        for i in 0..self.n {
            v += floor_eps(self.chol[i * k + i] as f64).ln();
        }
        self.value = v;
    }
}

impl SubmodularFunction for PjrtLogDet {
    fn dim(&self) -> usize {
        self.graphs.cfg.d
    }

    fn len(&self) -> usize {
        self.n
    }

    fn current_value(&self) -> f64 {
        self.value
    }

    fn max_singleton_value(&self) -> f64 {
        0.5 * (1.0 + self.graphs.cfg.a).ln()
    }

    fn peek_gain(&mut self, item: &[f32]) -> f64 {
        self.queries += 1;
        let d = self.graphs.cfg.d;
        self.cand_buf.iter_mut().for_each(|v| *v = 0.0);
        self.cand_buf[..d].copy_from_slice(item);
        let cands = std::mem::take(&mut self.cand_buf);
        let gains = self.run_gain(&cands, 1).expect("PJRT gain execution failed");
        self.cand_buf = cands;
        gains[0]
    }

    fn peek_gain_batch(&mut self, items: &[f32], count: usize, out: &mut Vec<f64>) {
        let (b, d) = (self.graphs.cfg.b, self.graphs.cfg.d);
        out.clear();
        let mut done = 0;
        while done < count {
            let take = (count - done).min(b);
            self.queries += take as u64;
            self.cand_buf.iter_mut().for_each(|v| *v = 0.0);
            self.cand_buf[..take * d].copy_from_slice(&items[done * d..(done + take) * d]);
            let cands = std::mem::take(&mut self.cand_buf);
            let gains = self.run_gain(&cands, take).expect("PJRT gain execution failed");
            self.cand_buf = cands;
            out.extend_from_slice(&gains);
            done += take;
        }
    }

    fn accept(&mut self, item: &[f32]) {
        assert!(self.n < self.k_cap(), "PjrtLogDet summary is at artifact capacity K");
        self.queries += 1;
        let (k, d) = (self.graphs.cfg.k, self.graphs.cfg.d);
        let run = || -> Result<(Vec<f32>, Vec<f32>, i32)> {
            let args = [
                f32_literal(&self.summary, &[k as i64, d as i64])?,
                f32_literal(&self.chol, &[k as i64, k as i64])?,
                i32_literal(&[self.n as i32], &[1])?,
                f32_literal(item, &[d as i64])?,
            ];
            let outs = self.graphs.append.run(&args)?;
            let summary = literal_to_f32(&outs[0])?;
            let chol = literal_to_f32(&outs[1])?;
            let n = literal_to_i32(&outs[2]).context("reading n")?[0];
            Ok((summary, chol, n))
        };
        let (summary, chol, n) = run().expect("PJRT append execution failed");
        self.summary = summary;
        self.chol = chol;
        self.n = n as usize;
        *self.device.borrow_mut() = None; // device copy is stale
        self.recompute_value();
    }

    fn remove(&mut self, idx: usize) {
        // The AOT graph set has no delete entry point (the threshold-family
        // algorithms never remove); rebuild by replaying the kept rows.
        assert!(idx < self.n);
        self.queries += 1;
        let d = self.graphs.cfg.d;
        let kept: Vec<f32> = (0..self.n)
            .filter(|&i| i != idx)
            .flat_map(|i| self.summary[i * d..(i + 1) * d].to_vec())
            .collect();
        self.reset();
        for row in kept.chunks_exact(d) {
            self.accept(row);
        }
    }

    fn summary(&self) -> &[f32] {
        &self.summary[..self.n * self.graphs.cfg.d]
    }

    fn reset(&mut self) {
        let (k, d) = (self.graphs.cfg.k, self.graphs.cfg.d);
        self.summary = vec![0.0; k * d];
        self.chol = vec![0.0; k * k];
        for i in 0..k {
            self.chol[i * k + i] = 1.0;
        }
        self.n = 0;
        self.value = 0.0;
        *self.device.borrow_mut() = None;
    }

    fn queries(&self) -> u64 {
        self.queries
    }

    fn clone_empty(&self) -> Box<dyn SubmodularFunction> {
        Box::new(PjrtLogDet::new(self.engine.clone(), self.graphs.clone()))
    }

    fn parallel_safe(&self) -> bool {
        // Clones share the `Rc`'d engine + graph set and PJRT device
        // buffers are thread-confined: this oracle must stay on the
        // thread that built it (the trait default, restated explicitly).
        false
    }
}
