//! Parse `artifacts/manifest.json` written by `python/compile/aot.py`.
//!
//! The manifest is the only shape contract between the build-time Python
//! layer and the Rust runtime: each config entry records the static shapes
//! `(d, K, B)`, the baked constants `(gamma, a)` and the HLO text file for
//! each entry point (`gain`, `append`, `value`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Errors loading or validating a manifest.
#[derive(Debug)]
pub enum ManifestError {
    Io { path: PathBuf, err: std::io::Error },
    Parse(crate::util::json::JsonError),
    Invalid(String),
    UnknownConfig(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io { path, err } => {
                write!(f, "io error reading {}: {err}", path.display())
            }
            ManifestError::Parse(e) => write!(f, "manifest parse error: {e}"),
            ManifestError::Invalid(msg) => write!(f, "manifest invalid: {msg}"),
            ManifestError::UnknownConfig(name) => write!(f, "no artifact config named {name:?}"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io { err, .. } => Some(err),
            ManifestError::Parse(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::util::json::JsonError> for ManifestError {
    fn from(e: crate::util::json::JsonError) -> Self {
        ManifestError::Parse(e)
    }
}

/// One AOT-lowered shape/constant configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactConfig {
    pub name: String,
    pub d: usize,
    pub k: usize,
    pub b: usize,
    pub gamma: f64,
    pub a: f64,
    /// Entry point name → HLO text file (relative to the artifact dir).
    pub files: BTreeMap<String, String>,
}

impl ArtifactConfig {
    fn from_json(j: &Json) -> Result<Self, ManifestError> {
        let req = |key: &str| -> Result<&Json, ManifestError> {
            let v = j.get(key);
            if *v == Json::Null {
                Err(ManifestError::Invalid(format!("config missing key {key:?}")))
            } else {
                Ok(v)
            }
        };
        let name = req("name")?
            .as_str()
            .ok_or_else(|| ManifestError::Invalid("name must be a string".into()))?
            .to_string();
        let num = |key: &str| -> Result<f64, ManifestError> {
            req(key)?.as_f64().ok_or_else(|| ManifestError::Invalid(format!("{key} not a number")))
        };
        let files_json = req("files")?
            .as_obj()
            .ok_or_else(|| ManifestError::Invalid("files must be an object".into()))?;
        let mut files = BTreeMap::new();
        for (ep, f) in files_json {
            let fname = f
                .as_str()
                .ok_or_else(|| ManifestError::Invalid(format!("files.{ep} not a string")))?;
            files.insert(ep.clone(), fname.to_string());
        }
        for ep in ["gain", "append", "value"] {
            if !files.contains_key(ep) {
                return Err(ManifestError::Invalid(format!(
                    "config {name:?} missing entry point {ep:?}"
                )));
            }
        }
        Ok(ArtifactConfig {
            name,
            d: num("d")? as usize,
            k: num("k")? as usize,
            b: num("b")? as usize,
            gamma: num("gamma")?,
            a: num("a")?,
            files,
        })
    }
}

/// The parsed manifest plus its base directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: Vec<ArtifactConfig>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self, ManifestError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|err| ManifestError::Io { path: path.clone(), err })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (the base dir is still needed to resolve files).
    pub fn parse(dir: &Path, text: &str) -> Result<Self, ManifestError> {
        let j = Json::parse(text)?;
        let configs_json = j
            .get("configs")
            .as_arr()
            .ok_or_else(|| ManifestError::Invalid("missing configs array".into()))?;
        let mut configs = Vec::with_capacity(configs_json.len());
        for cj in configs_json {
            configs.push(ArtifactConfig::from_json(cj)?);
        }
        if configs.is_empty() {
            return Err(ManifestError::Invalid("manifest has no configs".into()));
        }
        Ok(Manifest { dir: dir.to_path_buf(), configs })
    }

    /// Find a config by name.
    pub fn config(&self, name: &str) -> Result<&ArtifactConfig, ManifestError> {
        self.configs
            .iter()
            .find(|c| c.name == name)
            .ok_or_else(|| ManifestError::UnknownConfig(name.to_string()))
    }

    /// Pick a config matching (d, k) with the largest batch ≤ `b_max`
    /// (used by callers that just need "something that fits").
    pub fn best_match(&self, d: usize, k: usize) -> Option<&ArtifactConfig> {
        self.configs.iter().filter(|c| c.d == d && c.k >= k).max_by_key(|c| c.b)
    }

    /// Absolute path of an entry point's HLO file.
    pub fn file_path(&self, cfg: &ArtifactConfig, entry: &str) -> Result<PathBuf, ManifestError> {
        let fname = cfg
            .files
            .get(entry)
            .ok_or_else(|| ManifestError::Invalid(format!("no entry point {entry:?}")))?;
        Ok(self.dir.join(fname))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "configs": [
        {"name": "q16", "d": 16, "k": 32, "b": 8, "gamma": 32.0, "a": 1.0,
         "files": {"gain": "q16.gain.hlo.txt", "append": "q16.append.hlo.txt",
                   "value": "q16.value.hlo.txt"}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        let c = m.config("q16").unwrap();
        assert_eq!(c.d, 16);
        assert_eq!(c.k, 32);
        assert_eq!(c.b, 8);
        assert!((c.gamma - 32.0).abs() < 1e-12);
        assert_eq!(m.file_path(c, "gain").unwrap(), PathBuf::from("/tmp/arts/q16.gain.hlo.txt"));
    }

    #[test]
    fn unknown_config_errors() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        assert!(matches!(m.config("nope"), Err(ManifestError::UnknownConfig(_))));
    }

    #[test]
    fn missing_entry_point_rejected() {
        let bad = SAMPLE.replace("\"value\": \"q16.value.hlo.txt\"", "\"other\": \"x\"");
        assert!(matches!(Manifest::parse(Path::new("."), &bad), Err(ManifestError::Invalid(_))));
    }

    #[test]
    fn empty_configs_rejected() {
        let bad = r#"{"configs": []}"#;
        assert!(matches!(Manifest::parse(Path::new("."), bad), Err(ManifestError::Invalid(_))));
    }

    #[test]
    fn best_match_prefers_largest_batch() {
        let two = r#"{"configs": [
          {"name": "a", "d": 16, "k": 32, "b": 1, "gamma": 8.0, "a": 1.0,
           "files": {"gain": "a", "append": "a", "value": "a"}},
          {"name": "b", "d": 16, "k": 32, "b": 8, "gamma": 8.0, "a": 1.0,
           "files": {"gain": "b", "append": "b", "value": "b"}}
        ]}"#;
        let m = Manifest::parse(Path::new("."), two).unwrap();
        assert_eq!(m.best_match(16, 20).unwrap().name, "b");
        assert!(m.best_match(17, 20).is_none());
    }
}
