//! Multi-algorithm race: run several selection algorithms over the *same*
//! stream concurrently, one worker thread each, and collect a comparative
//! report. This is the coordinator behind the figure sweeps when
//! `TS_PARALLEL` is set, and a deployment tool in its own right (e.g. run
//! ThreeSieves with several `T` values live and serve the best summary).
//!
//! Algorithms are not `Send` (the PJRT oracle is Rc-based), so workers
//! receive *factory closures* and construct their algorithm on-thread. The
//! stream is fanned out by a broadcaster thread through one bounded channel
//! per worker (slowest worker applies backpressure to the source, keeping
//! every algorithm on the identical stream prefix).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::Instant;

use crate::algorithms::StreamingAlgorithm;
use crate::data::StreamSource;
use crate::metrics::AlgoStats;

/// Result of one lane of the race.
#[derive(Clone, Debug)]
pub struct LaneReport {
    pub name: String,
    pub value: f64,
    pub summary: Vec<f32>,
    pub summary_len: usize,
    pub stats: AlgoStats,
    pub wall_seconds: f64,
}

/// Factory that builds an algorithm on the worker thread.
pub type AlgoFactory = Box<dyn FnOnce() -> Box<dyn StreamingAlgorithm> + Send>;

/// Race configuration.
pub struct RaceConfig {
    /// Per-lane channel capacity (backpressure window).
    pub channel_capacity: usize,
}

impl Default for RaceConfig {
    fn default() -> Self {
        RaceConfig { channel_capacity: 4096 }
    }
}

/// Fan one stream out to N algorithms, each on its own thread.
pub fn race(
    mut source: Box<dyn StreamSource>,
    factories: Vec<(String, AlgoFactory)>,
    cfg: RaceConfig,
) -> Vec<LaneReport> {
    assert!(!factories.is_empty(), "race needs at least one lane");
    let dim = source.dim();

    let mut senders: Vec<SyncSender<Vec<f32>>> = Vec::with_capacity(factories.len());
    let mut handles = Vec::with_capacity(factories.len());
    for (label, factory) in factories {
        let (tx, rx): (SyncSender<Vec<f32>>, Receiver<Vec<f32>>) =
            sync_channel(cfg.channel_capacity.max(1));
        senders.push(tx);
        handles.push(std::thread::spawn(move || -> LaneReport {
            let mut algo = factory();
            assert_eq!(algo.dim(), dim, "lane {label}: dim mismatch");
            let start = Instant::now();
            for item in rx.iter() {
                algo.process(&item);
            }
            algo.finalize();
            LaneReport {
                name: if label.is_empty() { algo.name() } else { label },
                value: algo.value(),
                summary: algo.summary(),
                summary_len: algo.summary_len(),
                stats: algo.stats(),
                wall_seconds: start.elapsed().as_secs_f64(),
            }
        }));
    }

    // Broadcast loop: one allocation per item, cloned per lane.
    let mut buf = vec![0.0f32; dim];
    while source.next_into(&mut buf) {
        for tx in &senders {
            if tx.send(buf.clone()).is_err() {
                // A worker panicked; drop out, join below will surface it.
                break;
            }
        }
    }
    drop(senders);

    handles
        .into_iter()
        .map(|h| h.join().expect("race worker panicked"))
        .collect()
}

/// Pick the winning lane by value.
pub fn winner(reports: &[LaneReport]) -> &LaneReport {
    reports
        .iter()
        .max_by(|a, b| a.value.partial_cmp(&b.value).unwrap())
        .expect("non-empty race")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::three_sieves::SieveTuning;
    use crate::algorithms::{RandomReservoir, ThreeSieves};
    use crate::data::registry;
    use crate::functions::{LogDetConfig, NativeLogDet};

    fn ts_factory(dim: usize, k: usize, t: usize) -> AlgoFactory {
        Box::new(move || {
            let f = NativeLogDet::new(LogDetConfig::for_streaming(dim, k));
            Box::new(ThreeSieves::new(Box::new(f), k, 0.01, SieveTuning::FixedT(t)))
        })
    }

    #[test]
    fn all_lanes_see_the_full_stream() {
        let src = registry::source("fact-highlevel-like", 1500, 1).unwrap();
        let lanes = vec![
            ("t50".to_string(), ts_factory(16, 6, 50)),
            ("t200".to_string(), ts_factory(16, 6, 200)),
            (
                "random".to_string(),
                Box::new(move || {
                    let f = NativeLogDet::new(LogDetConfig::for_streaming(16, 6));
                    Box::new(RandomReservoir::new(Box::new(f), 6, 3))
                        as Box<dyn StreamingAlgorithm>
                }) as AlgoFactory,
            ),
        ];
        let reports = race(src, lanes, RaceConfig::default());
        assert_eq!(reports.len(), 3);
        for r in &reports {
            assert_eq!(r.stats.elements, 1500, "lane {} missed items", r.name);
            assert!(r.value > 0.0);
        }
        let w = winner(&reports);
        assert!(reports.iter().all(|r| r.value <= w.value));
    }

    #[test]
    fn lanes_are_isolated() {
        // Identical factories => identical results (no cross-lane state).
        let src = registry::source("fact-highlevel-like", 800, 2).unwrap();
        let lanes = vec![
            ("a".to_string(), ts_factory(16, 5, 100)),
            ("b".to_string(), ts_factory(16, 5, 100)),
        ];
        let reports = race(src, lanes, RaceConfig::default());
        assert_eq!(reports[0].value, reports[1].value);
        assert_eq!(reports[0].summary, reports[1].summary);
    }

    #[test]
    fn tiny_channel_still_completes() {
        let src = registry::source("fact-highlevel-like", 1000, 3).unwrap();
        let lanes = vec![("t".to_string(), ts_factory(16, 4, 50))];
        let reports = race(src, lanes, RaceConfig { channel_capacity: 1 });
        assert_eq!(reports[0].stats.elements, 1000);
    }

    #[test]
    #[should_panic(expected = "race needs at least one lane")]
    fn empty_race_rejected() {
        let src = registry::source("fact-highlevel-like", 10, 4).unwrap();
        race(src, Vec::new(), RaceConfig::default());
    }
}
