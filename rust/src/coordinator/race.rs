//! Multi-algorithm race: run several selection algorithms over the *same*
//! stream concurrently, one worker thread each, and collect a comparative
//! report. This is the coordinator behind the figure sweeps when
//! `TS_PARALLEL` is set, and a deployment tool in its own right (e.g. run
//! ThreeSieves with several `T` values live and serve the best summary).
//!
//! Algorithms are not `Send` (the PJRT oracle is Rc-based), so workers
//! receive *factory closures* and construct their algorithm on-thread. The
//! stream is fanned out by a broadcaster thread through one bounded channel
//! per worker (slowest worker applies backpressure to the source, keeping
//! every algorithm on the identical stream prefix) — per item, or in
//! `batch_size` chunks consumed through `process_batch`. On top of the
//! one-thread-per-lane concurrency, a [`RaceConfig::parallelism`] pool is
//! shared across lanes so shard/sieve algorithms also fan out *within*
//! their lane (see [`crate::exec`]).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::Instant;

use crate::algorithms::StreamingAlgorithm;
use crate::data::StreamSource;
use crate::exec::{ExecContext, Parallelism};
use crate::metrics::AlgoStats;

/// Result of one lane of the race.
#[derive(Clone, Debug)]
pub struct LaneReport {
    pub name: String,
    pub value: f64,
    pub summary: Vec<f32>,
    pub summary_len: usize,
    pub stats: AlgoStats,
    pub wall_seconds: f64,
}

/// Factory that builds an algorithm on the worker thread.
pub type AlgoFactory = Box<dyn FnOnce() -> Box<dyn StreamingAlgorithm> + Send>;

/// Race configuration.
pub struct RaceConfig {
    /// Per-lane channel capacity (backpressure window).
    pub channel_capacity: usize,
    /// Items broadcast per message (1 = per-item). Larger chunks reach the
    /// lanes through [`StreamingAlgorithm::process_batch`] —
    /// semantics-preserving, amortizing both channel traffic and the
    /// oracle's kernel work.
    pub batch_size: usize,
    /// Worker pool **shared by all lanes** for algorithms whose batched
    /// work fans out (shards/sieves). Lanes always get a dedicated thread
    /// each (the bounded-channel broadcast requires every lane to drain
    /// concurrently); this adds intra-lane parallelism on top. Results are
    /// bit-identical at every setting (see [`crate::exec`]).
    pub parallelism: Parallelism,
}

impl Default for RaceConfig {
    fn default() -> Self {
        RaceConfig { channel_capacity: 4096, batch_size: 1, parallelism: Parallelism::Off }
    }
}

/// Fan one stream out to N algorithms, each on its own thread.
pub fn race(
    mut source: Box<dyn StreamSource>,
    factories: Vec<(String, AlgoFactory)>,
    cfg: RaceConfig,
) -> Vec<LaneReport> {
    assert!(!factories.is_empty(), "race needs at least one lane");
    let dim = source.dim();
    let batch = cfg.batch_size.max(1);
    // One pool shared by every lane (sequential context when `off`); the
    // pool's scoped calls interleave lanes' jobs safely.
    let exec = ExecContext::new(cfg.parallelism);

    let mut senders: Vec<SyncSender<Vec<f32>>> = Vec::with_capacity(factories.len());
    let mut handles = Vec::with_capacity(factories.len());
    for (label, factory) in factories {
        let (tx, rx): (SyncSender<Vec<f32>>, Receiver<Vec<f32>>) =
            sync_channel(cfg.channel_capacity.max(1));
        senders.push(tx);
        let exec = exec.clone();
        handles.push(std::thread::spawn(move || -> LaneReport {
            let mut algo = factory();
            assert_eq!(algo.dim(), dim, "lane {label}: dim mismatch");
            algo.set_exec(exec);
            let start = Instant::now();
            if batch == 1 {
                for item in rx.iter() {
                    algo.process(&item);
                }
            } else {
                for chunk in rx.iter() {
                    algo.process_batch(&chunk);
                }
            }
            algo.finalize();
            LaneReport {
                name: if label.is_empty() { algo.name() } else { label },
                value: algo.value(),
                summary: algo.summary(),
                summary_len: algo.summary_len(),
                stats: algo.stats(),
                wall_seconds: start.elapsed().as_secs_f64(),
            }
        }));
    }

    // Broadcast loop: one allocation per message, cloned per lane.
    let mut buf = vec![0.0f32; dim];
    if batch == 1 {
        while source.next_into(&mut buf) {
            for tx in &senders {
                if tx.send(buf.clone()).is_err() {
                    // A worker panicked; drop out, join below will surface it.
                    break;
                }
            }
        }
    } else {
        let mut chunk: Vec<f32> = Vec::with_capacity(batch * dim);
        loop {
            chunk.clear();
            while chunk.len() < batch * dim && source.next_into(&mut buf) {
                chunk.extend_from_slice(&buf);
            }
            if chunk.is_empty() {
                break;
            }
            let exhausted = chunk.len() < batch * dim;
            for tx in &senders {
                if tx.send(chunk.clone()).is_err() {
                    break;
                }
            }
            if exhausted {
                break;
            }
        }
    }
    drop(senders);

    handles
        .into_iter()
        .map(|h| h.join().expect("race worker panicked"))
        .collect()
}

/// One race lane per **streaming** registry entry at its default
/// parameters — the whole competitor field, derived from
/// [`crate::algorithms::registry`] so a newly registered algorithm joins
/// the race roster with no code change here. Offline entries (Greedy) are
/// excluded; they cannot consume a broadcast stream.
pub fn registry_lanes(
    dim: usize,
    k: usize,
    stream_len: Option<usize>,
) -> Vec<(String, AlgoFactory)> {
    use crate::config::AlgoSpec;
    use crate::functions::{LogDetConfig, NativeLogDet};
    crate::algorithms::registry::streaming_names()
        .into_iter()
        .map(|name| {
            let spec = AlgoSpec::of(name, &[]).expect("registry name builds at defaults");
            let factory: AlgoFactory = Box::new(move || {
                let f = NativeLogDet::new(LogDetConfig::for_streaming(dim, k));
                spec.build(Box::new(f), k, stream_len)
            });
            (name.to_string(), factory)
        })
        .collect()
}

/// Pick the winning lane by value.
pub fn winner(reports: &[LaneReport]) -> &LaneReport {
    reports
        .iter()
        .max_by(|a, b| a.value.total_cmp(&b.value))
        .expect("non-empty race")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::three_sieves::SieveTuning;
    use crate::algorithms::{RandomReservoir, ThreeSieves};
    use crate::data::registry;
    use crate::functions::{LogDetConfig, NativeLogDet};

    fn ts_factory(dim: usize, k: usize, t: usize) -> AlgoFactory {
        Box::new(move || {
            let f = NativeLogDet::new(LogDetConfig::for_streaming(dim, k));
            Box::new(ThreeSieves::new(Box::new(f), k, 0.01, SieveTuning::FixedT(t)))
        })
    }

    #[test]
    fn all_lanes_see_the_full_stream() {
        let src = registry::source("fact-highlevel-like", 1500, 1).unwrap();
        let lanes = vec![
            ("t50".to_string(), ts_factory(16, 6, 50)),
            ("t200".to_string(), ts_factory(16, 6, 200)),
            (
                "random".to_string(),
                Box::new(move || {
                    let f = NativeLogDet::new(LogDetConfig::for_streaming(16, 6));
                    Box::new(RandomReservoir::new(Box::new(f), 6, 3))
                        as Box<dyn StreamingAlgorithm>
                }) as AlgoFactory,
            ),
        ];
        let reports = race(src, lanes, RaceConfig::default());
        assert_eq!(reports.len(), 3);
        for r in &reports {
            assert_eq!(r.stats.elements, 1500, "lane {} missed items", r.name);
            assert!(r.value > 0.0);
        }
        let w = winner(&reports);
        assert!(reports.iter().all(|r| r.value <= w.value));
    }

    #[test]
    fn lanes_are_isolated() {
        // Identical factories => identical results (no cross-lane state).
        let src = registry::source("fact-highlevel-like", 800, 2).unwrap();
        let lanes = vec![
            ("a".to_string(), ts_factory(16, 5, 100)),
            ("b".to_string(), ts_factory(16, 5, 100)),
        ];
        let reports = race(src, lanes, RaceConfig::default());
        assert_eq!(reports[0].value, reports[1].value);
        assert_eq!(reports[0].summary, reports[1].summary);
    }

    #[test]
    fn tiny_channel_still_completes() {
        let src = registry::source("fact-highlevel-like", 1000, 3).unwrap();
        let lanes = vec![("t".to_string(), ts_factory(16, 4, 50))];
        let reports = race(src, lanes, RaceConfig { channel_capacity: 1, ..Default::default() });
        assert_eq!(reports[0].stats.elements, 1000);
    }

    #[test]
    fn registry_field_races_end_to_end() {
        let n = 400;
        let src = registry::source("fact-highlevel-like", n, 5).unwrap();
        let lanes = registry_lanes(16, 4, Some(n));
        let expected = crate::algorithms::registry::streaming_names().len();
        assert_eq!(lanes.len(), expected, "one lane per streaming registry entry");
        let reports = race(src, lanes, RaceConfig { batch_size: 32, ..Default::default() });
        assert_eq!(reports.len(), expected);
        for r in &reports {
            // Subsampled lanes still observe every element (thinning is
            // internal and accounted as observed).
            assert_eq!(r.stats.elements, n as u64, "lane {} missed items", r.name);
            assert!(r.value > 0.0, "lane {} selected nothing", r.name);
        }
    }

    #[test]
    #[should_panic(expected = "race needs at least one lane")]
    fn empty_race_rejected() {
        let src = registry::source("fact-highlevel-like", 10, 4).unwrap();
        race(src, Vec::new(), RaceConfig::default());
    }
}
