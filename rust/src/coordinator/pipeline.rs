//! The streaming pipeline: source → bounded channel → selection worker.
//!
//! The source runs on its own thread (sources are `Send`); items flow
//! through a `sync_channel` whose bound provides **backpressure** — if the
//! selection worker falls behind, the producer blocks instead of buffering
//! unboundedly. The consumer side runs the (non-`Send`) algorithm on the
//! calling thread, interleaving drift detection, periodic checkpointing and
//! throughput accounting.

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::time::Instant;

use crate::algorithms::StreamingAlgorithm;
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::drift::DriftDetector;
use crate::data::StreamSource;
use crate::exec::{ExecContext, Parallelism};

/// Pipeline tuning knobs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Bounded channel capacity (items) — the backpressure window.
    pub channel_capacity: usize,
    /// Items handed to the algorithm per [`StreamingAlgorithm::process_batch`]
    /// call (1 = the scalar per-item path). Batching is semantically
    /// identical to per-item processing — same summary, value and query
    /// accounting — but amortizes the oracle's kernel work across the
    /// chunk. Drift checks still run per item: a drift event flushes the
    /// pending chunk before the reset, so batching never reorders the
    /// observe → checkpoint → reset → process sequence.
    pub batch_size: usize,
    /// Checkpoint the summary every this many items (0 = never).
    pub checkpoint_every: u64,
    /// Checkpoint path (required if checkpoint_every > 0).
    pub checkpoint_path: Option<PathBuf>,
    /// On drift: reset the algorithm and start a fresh summary.
    pub reselect_on_drift: bool,
    /// Worker threads for algorithms whose batched work decomposes into
    /// independent units (ShardedThreeSieves shards, SieveStreaming/Salsa
    /// sieves). The pool is built once per [`StreamPipeline::run`] and
    /// reused across chunks; results are bit-identical at every setting
    /// (see [`crate::exec`]). Most effective with `batch_size > 1` —
    /// per-item processing leaves no coarse units to fan out.
    pub parallelism: Parallelism,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            channel_capacity: 1024,
            batch_size: 1,
            checkpoint_every: 0,
            checkpoint_path: None,
            reselect_on_drift: true,
            parallelism: Parallelism::Off,
        }
    }
}

/// End-of-run report.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub items: u64,
    pub drift_events: usize,
    pub reselections: usize,
    pub checkpoints_written: usize,
    pub wall_seconds: f64,
    /// Items/second over the whole run.
    pub throughput: f64,
    /// Producer-side blocked sends (backpressure engagements).
    pub backpressure_hits: u64,
    pub final_value: f64,
    pub final_summary_len: usize,
}

/// Orchestrates one stream through one algorithm.
pub struct StreamPipeline {
    cfg: PipelineConfig,
}

impl StreamPipeline {
    pub fn new(cfg: PipelineConfig) -> Self {
        StreamPipeline { cfg }
    }

    /// Run `source` to exhaustion through `algo`.
    ///
    /// The drift detector observes every item *before* it reaches the
    /// algorithm; when it fires (and `reselect_on_drift` is set) the current
    /// summary is checkpointed as an epoch artifact and the algorithm is
    /// reset — the paper's prescribed deployment for ThreeSieves under
    /// non-iid streams.
    pub fn run(
        &self,
        mut source: Box<dyn StreamSource>,
        algo: &mut dyn StreamingAlgorithm,
        drift: &mut dyn DriftDetector,
    ) -> std::io::Result<PipelineReport> {
        let dim = source.dim();
        assert_eq!(dim, algo.dim(), "source dim {} != algorithm dim {}", dim, algo.dim());
        // One pool for the whole run, reused chunk after chunk (the
        // algorithm holds the handle; a sequential context is a no-op).
        algo.set_exec(ExecContext::new(self.cfg.parallelism));
        let (tx, rx): (SyncSender<Vec<f32>>, Receiver<Vec<f32>>) =
            sync_channel(self.cfg.channel_capacity.max(1));

        // Producer thread: pull from the source, push into the channel.
        // try_send-then-send so we can count backpressure engagements.
        let producer = std::thread::spawn(move || -> u64 {
            let mut hits = 0u64;
            let mut buf = vec![0.0f32; dim];
            while source.next_into(&mut buf) {
                match tx.try_send(buf.clone()) {
                    Ok(()) => {}
                    Err(TrySendError::Full(item)) => {
                        hits += 1;
                        if tx.send(item).is_err() {
                            break; // consumer gone
                        }
                    }
                    Err(TrySendError::Disconnected(_)) => break,
                }
            }
            hits
        });

        let start = Instant::now();
        let mut items = 0u64;
        let mut reselections = 0usize;
        let mut checkpoints = 0usize;
        // Chunked ingestion: items accumulate into `chunk` and reach the
        // algorithm through process_batch (batch_size 1 keeps the direct
        // per-item call — no buffering overhead on the default path).
        // Drift is still observed per item *before* the item joins the
        // chunk; a drift event flushes the pending chunk (all pre-drift
        // items) so the epoch checkpoint and reset see exactly the same
        // state as the per-item path.
        let batch = self.cfg.batch_size.max(1);
        let mut chunk: Vec<f32> = Vec::with_capacity(batch * dim);
        for item in rx.iter() {
            items += 1;
            if drift.observe(&item) && self.cfg.reselect_on_drift {
                if !chunk.is_empty() {
                    algo.process_batch(&chunk);
                    chunk.clear();
                }
                // Epoch boundary: persist the outgoing summary, then restart.
                if let Some(path) = &self.cfg.checkpoint_path {
                    let epoch_path =
                        path.with_extension(format!("epoch{}.ckpt", drift.events()));
                    self.write_checkpoint(algo, drift, items, &epoch_path)?;
                    checkpoints += 1;
                }
                {
                    let _g = crate::obs::span("drift-reset");
                    crate::obs::emit_event(crate::obs::Event::DriftReset { elements: items });
                    algo.reset();
                }
                reselections += 1;
            }
            let every = self.cfg.checkpoint_every;
            let boundary = every > 0 && items % every == 0;
            if batch == 1 {
                algo.process(&item);
            } else {
                chunk.extend_from_slice(&item);
                if chunk.len() >= batch * dim || boundary {
                    algo.process_batch(&chunk);
                    chunk.clear();
                }
            }
            if boundary {
                if let Some(path) = &self.cfg.checkpoint_path {
                    self.write_checkpoint(algo, drift, items, path)?;
                    checkpoints += 1;
                }
            }
        }
        if !chunk.is_empty() {
            algo.process_batch(&chunk);
            chunk.clear();
        }
        algo.finalize();
        let backpressure_hits = producer.join().unwrap_or(0);
        let wall = start.elapsed().as_secs_f64();

        if let Some(path) = &self.cfg.checkpoint_path {
            self.write_checkpoint(algo, drift, items, path)?;
            checkpoints += 1;
        }

        Ok(PipelineReport {
            items,
            drift_events: drift.events(),
            reselections,
            checkpoints_written: checkpoints,
            wall_seconds: wall,
            throughput: if wall > 0.0 { items as f64 / wall } else { 0.0 },
            backpressure_hits,
            final_value: algo.value(),
            final_summary_len: algo.summary_len(),
        })
    }

    fn write_checkpoint(
        &self,
        algo: &dyn StreamingAlgorithm,
        drift: &dyn DriftDetector,
        items: u64,
        path: &std::path::Path,
    ) -> std::io::Result<()> {
        let ck = Checkpoint {
            algorithm: algo.name(),
            dim: algo.dim(),
            k: algo.k(),
            value: algo.value(),
            elements: items,
            drift_events: drift.events(),
            // Resumable algorithms (ThreeSieves) embed their full run
            // state so a restart can continue bit-identically; for the
            // rest the checkpoint stays a summary artifact.
            state: algo.snapshot_state().unwrap_or(crate::util::json::Json::Null),
            summary: algo.summary(),
        };
        ck.save(path).map_err(|e| std::io::Error::other(e.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::three_sieves::SieveTuning;
    use crate::algorithms::ThreeSieves;
    use crate::coordinator::drift::{MeanShiftDetector, NoDrift};
    use crate::data::registry;
    use crate::functions::{LogDetConfig, NativeLogDet};

    fn algo(dim: usize, k: usize) -> ThreeSieves {
        let f = NativeLogDet::new(LogDetConfig::for_streaming(dim, k));
        ThreeSieves::new(Box::new(f), k, 0.01, SieveTuning::FixedT(100))
    }

    #[test]
    fn pipeline_consumes_whole_stream() {
        let src = registry::source("fact-highlevel-like", 800, 1).unwrap();
        let mut a = algo(16, 6);
        let mut det = NoDrift::default();
        let report = StreamPipeline::new(PipelineConfig::default())
            .run(src, &mut a, &mut det)
            .unwrap();
        assert_eq!(report.items, 800);
        assert_eq!(report.drift_events, 0);
        assert!(report.final_value > 0.0);
        assert!(report.throughput > 0.0);
    }

    #[test]
    fn tiny_channel_engages_backpressure() {
        let src = registry::source("fact-highlevel-like", 2000, 2).unwrap();
        let mut a = algo(16, 6);
        let mut det = NoDrift::default();
        let cfg = PipelineConfig { channel_capacity: 1, ..Default::default() };
        let report = StreamPipeline::new(cfg).run(src, &mut a, &mut det).unwrap();
        assert_eq!(report.items, 2000);
        assert!(report.backpressure_hits > 0, "capacity-1 channel must block");
    }

    #[test]
    fn batched_ingestion_matches_per_item() {
        // Same source/seed through batch_size 1 and 32: identical summary
        // state and item counts (process_batch is semantics-preserving).
        let mut reports = Vec::new();
        for batch_size in [1usize, 32] {
            let src = registry::source("fact-highlevel-like", 1200, 6).unwrap();
            let mut a = algo(16, 6);
            let mut det = NoDrift::default();
            let cfg = PipelineConfig { batch_size, ..Default::default() };
            reports.push((
                StreamPipeline::new(cfg).run(src, &mut a, &mut det).unwrap(),
                a.stats(),
                a.summary(),
            ));
        }
        let (r1, s1, sum1) = &reports[0];
        let (r2, s2, sum2) = &reports[1];
        assert_eq!(r1.items, r2.items);
        assert_eq!(r1.final_summary_len, r2.final_summary_len);
        assert_eq!(r1.final_value.to_bits(), r2.final_value.to_bits());
        assert_eq!(s1.queries, s2.queries);
        assert_eq!(sum1, sum2);
    }

    #[test]
    fn batched_ingestion_with_drift_matches_per_item() {
        // Drift resets interleave with chunk flushes; the flush-before-
        // reset ordering must keep the batched run identical to per-item.
        let mut runs = Vec::new();
        for batch_size in [1usize, 17] {
            let src = registry::source("stream51-like", 2000, 8).unwrap();
            let mut a = algo(64, 6);
            let mut det = MeanShiftDetector::new(64, 100, 3.0);
            let cfg = PipelineConfig { batch_size, ..Default::default() };
            let report = StreamPipeline::new(cfg).run(src, &mut a, &mut det).unwrap();
            assert_eq!(report.items, 2000);
            assert_eq!(report.reselections, report.drift_events);
            runs.push((report, a.stats(), a.summary()));
        }
        let (r1, s1, sum1) = &runs[0];
        let (r2, s2, sum2) = &runs[1];
        assert!(r1.drift_events > 0, "stream51-like must drift");
        assert_eq!(r1.drift_events, r2.drift_events);
        assert_eq!(r1.final_value.to_bits(), r2.final_value.to_bits());
        assert_eq!(r1.final_summary_len, r2.final_summary_len);
        assert_eq!(s1.queries, s2.queries);
        assert_eq!(sum1, sum2);
    }

    #[test]
    fn drift_triggers_reselection() {
        // stream51-like: class-incremental jumps should fire the detector.
        let src = registry::source("stream51-like", 3000, 3).unwrap();
        let mut a = algo(64, 8);
        let mut det = MeanShiftDetector::new(64, 100, 3.0);
        let report = StreamPipeline::new(PipelineConfig::default())
            .run(src, &mut a, &mut det)
            .unwrap();
        assert!(report.drift_events > 0, "class-incremental stream must drift");
        assert_eq!(report.reselections, report.drift_events);
    }

    #[test]
    fn checkpoints_are_written_and_loadable() {
        let dir = std::env::temp_dir().join(format!("ts_pipe_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = dir.join("summary.ckpt");
        let src = registry::source("fact-highlevel-like", 500, 4).unwrap();
        let mut a = algo(16, 5);
        let mut det = NoDrift::default();
        let cfg = PipelineConfig {
            checkpoint_every: 200,
            checkpoint_path: Some(ckpt.clone()),
            ..Default::default()
        };
        let report = StreamPipeline::new(cfg).run(src, &mut a, &mut det).unwrap();
        assert!(report.checkpoints_written >= 3); // 200, 400, final
        let ck = Checkpoint::load(&ckpt).unwrap();
        assert_eq!(ck.dim, 16);
        assert_eq!(ck.elements, 500);
        assert_eq!(ck.summary_len(), a.summary_len());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "source dim")]
    fn dim_mismatch_is_rejected() {
        let src = registry::source("fact-highlevel-like", 10, 5).unwrap();
        let mut a = algo(8, 3); // wrong dim
        let mut det = NoDrift::default();
        let _ = StreamPipeline::new(PipelineConfig::default()).run(src, &mut a, &mut det);
    }
}
