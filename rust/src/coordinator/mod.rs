//! The L3 streaming coordinator.
//!
//! ThreeSieves assumes an iid stream and the paper prescribes pairing it
//! with "an appropriate concept drift detection mechanism ... so that
//! summaries are e.g. re-selected periodically" (§3). This module is that
//! mechanism plus the production plumbing around it:
//!
//! * [`pipeline::StreamPipeline`] — source → bounded channel (backpressure)
//!   → algorithm, with per-stage metrics and an optional drift detector
//!   that triggers summary re-selection.
//! * [`drift::MeanShiftDetector`] — windowed mean-shift drift detection.
//! * [`sharded::ShardedThreeSieves`] — the paper's "more memory available"
//!   extension: parallel ThreeSieves instances over disjoint threshold
//!   partitions, best summary wins.
//! * [`checkpoint`] — summary state save/restore for restartable pipelines.

pub mod checkpoint;
pub mod drift;
pub mod page_hinkley;
pub mod pipeline;
pub mod race;
pub mod sharded;

pub use drift::{DriftDetector, MeanShiftDetector, NoDrift};
pub use page_hinkley::PageHinkleyDetector;
pub use pipeline::{PipelineConfig, PipelineReport, StreamPipeline};
pub use race::{race, registry_lanes, winner, AlgoFactory, LaneReport, RaceConfig};
pub use sharded::ShardedThreeSieves;
