//! Concept-drift detection for summary re-selection.
//!
//! The detector watches the raw feature stream (not the summaries): a
//! reference window's mean vector is compared against a sliding current
//! window; when the shift exceeds `threshold × pooled scale` the detector
//! fires and the pipeline re-selects the summary. This is deliberately a
//! simple, O(d)-per-item detector — the paper only requires *a* mechanism,
//! and mean-shift catches both the class-incremental jumps (stream51-like)
//! and accumulated random-walk drift (abc/examiner-like).

/// Drift detection interface.
pub trait DriftDetector: Send {
    /// Observe one item; returns true if drift was detected at this item
    /// (the detector re-baselines itself after firing).
    fn observe(&mut self, item: &[f32]) -> bool;

    /// Number of drift events so far.
    fn events(&self) -> usize;

    fn reset(&mut self);
}

/// A detector that never fires (iid streams).
#[derive(Default, Debug)]
pub struct NoDrift {
    _priv: (),
}

impl DriftDetector for NoDrift {
    fn observe(&mut self, _item: &[f32]) -> bool {
        false
    }

    fn events(&self) -> usize {
        0
    }

    fn reset(&mut self) {}
}

/// Windowed mean-shift detector.
pub struct MeanShiftDetector {
    dim: usize,
    window: usize,
    /// Fire when ||mean_cur − mean_ref||₂ > threshold × (scale_ref + ε).
    threshold: f64,
    /// Reference window statistics (frozen after warmup).
    ref_mean: Vec<f64>,
    ref_scale: f64,
    ref_count: usize,
    /// Current sliding accumulation.
    cur_sum: Vec<f64>,
    cur_count: usize,
    events: usize,
    warmed: bool,
    /// Scratch accumulation of squared norms for the reference scale.
    ref_sq_sum: f64,
}

impl MeanShiftDetector {
    /// `window`: items per comparison window; `threshold`: shift multiple
    /// (≈2–4 works well; lower = more sensitive).
    pub fn new(dim: usize, window: usize, threshold: f64) -> Self {
        assert!(dim > 0 && window > 0 && threshold > 0.0);
        MeanShiftDetector {
            dim,
            window,
            threshold,
            ref_mean: vec![0.0; dim],
            ref_scale: 0.0,
            ref_count: 0,
            cur_sum: vec![0.0; dim],
            cur_count: 0,
            events: 0,
            warmed: false,
            ref_sq_sum: 0.0,
        }
    }

    fn rebaseline(&mut self) {
        self.ref_mean.iter_mut().for_each(|v| *v = 0.0);
        self.ref_scale = 0.0;
        self.ref_count = 0;
        self.ref_sq_sum = 0.0;
        self.cur_sum.iter_mut().for_each(|v| *v = 0.0);
        self.cur_count = 0;
        self.warmed = false;
    }
}

impl DriftDetector for MeanShiftDetector {
    fn observe(&mut self, item: &[f32]) -> bool {
        debug_assert_eq!(item.len(), self.dim);
        if !self.warmed {
            // Build the reference window.
            let mut sq = 0.0;
            for (j, &v) in item.iter().enumerate() {
                self.ref_mean[j] += v as f64;
                sq += (v as f64) * (v as f64);
            }
            self.ref_sq_sum += sq;
            self.ref_count += 1;
            if self.ref_count == self.window {
                let n = self.window as f64;
                for v in self.ref_mean.iter_mut() {
                    *v /= n;
                }
                let mean_norm2: f64 = self.ref_mean.iter().map(|v| v * v).sum();
                // Pooled per-item scale: sqrt(E||x||² − ||mean||²) — a
                // d-dimensional standard-deviation analogue.
                self.ref_scale = (self.ref_sq_sum / n - mean_norm2).max(1e-12).sqrt();
                self.warmed = true;
            }
            return false;
        }

        for (j, &v) in item.iter().enumerate() {
            self.cur_sum[j] += v as f64;
        }
        self.cur_count += 1;
        if self.cur_count < self.window {
            return false;
        }

        // Compare windows.
        let n = self.cur_count as f64;
        let mut shift2 = 0.0;
        for j in 0..self.dim {
            let dmean = self.cur_sum[j] / n - self.ref_mean[j];
            shift2 += dmean * dmean;
        }
        let fired = shift2.sqrt() > self.threshold * self.ref_scale / (n.sqrt());
        if fired {
            self.events += 1;
            self.rebaseline();
        } else {
            // Slide: current window becomes the fresh accumulation.
            self.cur_sum.iter_mut().for_each(|v| *v = 0.0);
            self.cur_count = 0;
        }
        fired
    }

    fn events(&self) -> usize {
        self.events
    }

    fn reset(&mut self) {
        self.events = 0;
        self.rebaseline();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn feed_gaussian(det: &mut dyn DriftDetector, rng: &mut Rng, mean: f64, n: usize, d: usize) {
        for _ in 0..n {
            let item: Vec<f32> = (0..d).map(|_| (mean + rng.normal()) as f32).collect();
            det.observe(&item);
        }
    }

    #[test]
    fn no_false_positives_on_stationary_stream() {
        let d = 8;
        let mut det = MeanShiftDetector::new(d, 50, 4.0);
        let mut rng = Rng::seed_from(1);
        feed_gaussian(&mut det, &mut rng, 0.0, 2000, d);
        assert_eq!(det.events(), 0, "stationary stream must not fire");
    }

    #[test]
    fn detects_abrupt_mean_shift() {
        let d = 8;
        let mut det = MeanShiftDetector::new(d, 50, 4.0);
        let mut rng = Rng::seed_from(2);
        feed_gaussian(&mut det, &mut rng, 0.0, 500, d);
        feed_gaussian(&mut det, &mut rng, 3.0, 500, d);
        assert!(det.events() >= 1, "3-sigma jump must fire");
    }

    #[test]
    fn rebaselines_after_event() {
        let d = 4;
        let mut det = MeanShiftDetector::new(d, 40, 4.0);
        let mut rng = Rng::seed_from(3);
        feed_gaussian(&mut det, &mut rng, 0.0, 300, d);
        feed_gaussian(&mut det, &mut rng, 5.0, 300, d);
        let after_jump = det.events();
        assert!(after_jump >= 1);
        // Stay at the new level: no further events.
        feed_gaussian(&mut det, &mut rng, 5.0, 1500, d);
        assert_eq!(det.events(), after_jump, "must adapt to the new regime");
    }

    #[test]
    fn no_drift_detector_is_silent() {
        let mut det = NoDrift::default();
        for _ in 0..100 {
            assert!(!det.observe(&[1.0, 2.0]));
        }
        assert_eq!(det.events(), 0);
    }

    #[test]
    fn reset_clears_events() {
        let d = 4;
        let mut det = MeanShiftDetector::new(d, 20, 3.0);
        let mut rng = Rng::seed_from(4);
        feed_gaussian(&mut det, &mut rng, 0.0, 100, d);
        feed_gaussian(&mut det, &mut rng, 4.0, 100, d);
        assert!(det.events() > 0);
        det.reset();
        assert_eq!(det.events(), 0);
    }
}
