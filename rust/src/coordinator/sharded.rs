//! Threshold-sharded ThreeSieves — the paper's scale-out note made real:
//! "If more memory is available, one may improve the performance of
//! ThreeSieves by running multiple instances of ThreeSieves in parallel on
//! different sets of thresholds" (§3).
//!
//! The geometric grid `O` is split into `shards` contiguous partitions;
//! each shard runs an independent ThreeSieves restricted to its partition
//! (starting at that partition's top threshold). The output is the best
//! shard's summary. Memory grows to `shards × K`, queries to `shards` per
//! element; the coarse shards converge down their partitions faster than a
//! single instance walks the whole grid, improving small-T robustness.

use crate::algorithms::three_sieves::SieveTuning;
use crate::algorithms::{
    count_range_tasks, push_range_tasks, run_solve_tasks, sieve_threshold, SolveGrid, SolveSrc,
    SolveTask, StreamingAlgorithm,
};
use crate::exec::ExecContext;
use crate::functions::SubmodularFunction;
use crate::metrics::AlgoStats;
use crate::util::mathx::threshold_grid;

/// One shard: a threshold partition walked top-down, ThreeSieves-style.
///
/// A shard is fully self-contained (its own oracle, threshold walk and
/// gain-panel scratch), which is what lets the exec pool run shards on
/// worker threads with nothing to merge afterwards but counters.
struct Shard {
    grid: Vec<f64>, // ascending; active popped from the back
    v: f64,
    t: usize,
    oracle: Box<dyn SubmodularFunction>,
    /// Per-shard gain-panel scratch (each shard owns its own so the
    /// parallel path needs no shared buffers).
    scratch: Vec<f64>,
    /// Shard index, used as the `sieve` id in decision events.
    tag: u32,
    /// Decision telemetry (advanced only while obs recording is on;
    /// excluded from stats equality like the wall-time fields).
    accepts: u64,
    rejects: u64,
    threshold_moves: u64,
}

impl Shard {
    fn new(mut grid: Vec<f64>, proto: &dyn SubmodularFunction, tag: u32) -> Self {
        let v = grid.pop().expect("non-empty shard partition");
        Shard {
            grid,
            v,
            t: 0,
            oracle: proto.clone_empty(),
            scratch: Vec::new(),
            tag,
            accepts: 0,
            rejects: 0,
            threshold_moves: 0,
        }
    }

    fn process(&mut self, item: &[f32], k: usize, t_budget: usize) {
        let len = self.oracle.len();
        if len >= k {
            return;
        }
        let thresh = sieve_threshold(self.v, self.oracle.current_value(), k, len);
        let gain = self.oracle.peek_gain(item);
        let accepted = gain >= thresh;
        self.note_decision(accepted, gain, thresh);
        if accepted {
            self.oracle.accept(item);
            self.t = 0;
        } else {
            self.t += 1;
            if self.t >= t_budget {
                self.budget_fire();
            }
        }
    }

    /// Log one accept/reject decision (obs-gated; one relaxed load off).
    /// The event's `element` is this shard's decision ordinal — every
    /// shard judges every stream element, so it tracks stream position.
    #[inline]
    fn note_decision(&mut self, accepted: bool, gain: f64, tau: f64) {
        if !crate::obs::enabled() {
            return;
        }
        let element = self.accepts + self.rejects;
        if accepted {
            self.accepts += 1;
            crate::obs::emit_event(crate::obs::Event::Accept {
                element,
                sieve: self.tag,
                gain,
                tau,
            });
        } else {
            self.rejects += 1;
            crate::obs::emit_event(crate::obs::Event::Reject {
                element,
                sieve: self.tag,
                gain,
                tau,
            });
        }
    }

    /// T-budget certificate fired: walk down if this partition has
    /// thresholds left (a `ThresholdMove`), else restart confidence on the
    /// final threshold (a `ConfidenceReset` — the partition keeps sieving
    /// with its last v). Returns true when the threshold moved.
    fn budget_fire(&mut self) -> bool {
        let t_hit = self.t as u64;
        self.t = 0;
        match self.grid.pop() {
            Some(v) => {
                if crate::obs::enabled() {
                    self.threshold_moves += 1;
                    crate::obs::emit_event(crate::obs::Event::ThresholdMove {
                        sieve: self.tag,
                        from: self.v,
                        to: v,
                    });
                }
                self.v = v;
                true
            }
            None => {
                crate::obs::emit_event(crate::obs::Event::ConfidenceReset {
                    sieve: self.tag,
                    t: t_hit,
                });
                false
            }
        }
    }

    /// Batched [`process`](Self::process) over a whole chunk: one gain
    /// panel per rejection run against the shard's current summary. Gains
    /// depend only on the summary, so a threshold pop mid-scan just
    /// recomputes the threshold and keeps consuming the same panel; only
    /// an acceptance invalidates the remaining gains and forces a
    /// re-batch. Returns the speculative gain evaluations (past an
    /// acceptance) for the caller to exclude from query stats.
    fn process_batch(&mut self, chunk: &[f32], dim: usize, k: usize, t_budget: usize) -> u64 {
        let total = chunk.len() / dim;
        let mut pos = 0usize;
        let mut wasted = 0u64;
        while pos < total {
            if self.oracle.len() >= k {
                return wasted; // full: the scalar path stops querying too
            }
            let remaining = total - pos;
            self.oracle.peek_gain_batch(&chunk[pos * dim..], remaining, &mut self.scratch);
            match self.consume_gains(chunk, dim, k, t_budget, pos, remaining) {
                Some(j) => {
                    wasted += (remaining - (j + 1)) as u64;
                    pos += j + 1;
                }
                None => return wasted,
            }
        }
        wasted
    }

    /// Scan one rejection run's gains (`self.scratch[..count]`, chunk
    /// positions `pos..pos+count`) with the T-budget threshold walk and
    /// accept the first passing item. Returns the accepted index relative
    /// to `pos`, or `None` when the whole run rejects. The single scan
    /// definition shared by the coarse per-shard path and the 2-D
    /// (shard × candidate-range) grid, so the two can never drift.
    fn consume_gains(
        &mut self,
        chunk: &[f32],
        dim: usize,
        k: usize,
        t_budget: usize,
        pos: usize,
        count: usize,
    ) -> Option<usize> {
        let mut thresh = sieve_threshold(self.v, self.oracle.current_value(), k, self.oracle.len());
        for j in 0..count {
            let gain = self.scratch[j];
            let accepted = gain >= thresh;
            self.note_decision(accepted, gain, thresh);
            if accepted {
                self.oracle.accept(&chunk[(pos + j) * dim..(pos + j + 1) * dim]);
                self.t = 0;
                return Some(j);
            }
            self.t += 1;
            if self.t >= t_budget && self.budget_fire() {
                thresh =
                    sieve_threshold(self.v, self.oracle.current_value(), k, self.oracle.len());
            }
        }
        None
    }
}

/// Parallel-threshold ThreeSieves.
pub struct ShardedThreeSieves {
    shards: Vec<Shard>,
    k: usize,
    epsilon: f64,
    t_budget: usize,
    dim: usize,
    elements: u64,
    /// Speculative batch gains past a shard's acceptance (see
    /// `Shard::process_batch`); excluded from reported query stats.
    speculative_queries: u64,
    peak_stored: usize,
    /// Scratch pool for the 2-D (shard × candidate-range) solve grid.
    solve_pool: SolveGrid,
    /// Parallel execution context: shards fan out across its pool when one
    /// is attached (see [`StreamingAlgorithm::set_exec`]).
    exec: ExecContext,
}

impl ShardedThreeSieves {
    pub fn new(
        proto: Box<dyn SubmodularFunction>,
        k: usize,
        epsilon: f64,
        tuning: SieveTuning,
        shards: usize,
    ) -> Self {
        assert!(k > 0 && epsilon > 0.0 && shards > 0);
        let m = proto.max_singleton_value();
        let grid = threshold_grid(epsilon, m, k as f64 * m);
        assert!(!grid.is_empty(), "empty threshold grid");
        let shards_n = shards.min(grid.len());
        let chunk = grid.len().div_ceil(shards_n);
        let shard_vec: Vec<Shard> = grid
            .chunks(chunk)
            .enumerate()
            .map(|(i, part)| Shard::new(part.to_vec(), proto.as_ref(), i as u32))
            .collect();
        ShardedThreeSieves {
            shards: shard_vec,
            k,
            epsilon,
            t_budget: tuning.t(),
            dim: proto.dim(),
            elements: 0,
            speculative_queries: 0,
            peak_stored: 0,
            solve_pool: SolveGrid::default(),
            exec: ExecContext::sequential(),
        }
    }

    /// Fold per-shard chunk outcomes back into coordinator-level
    /// accounting. Per-shard speculative counts arrive **in shard order**
    /// from both the sequential loop and the pool's order-preserving map,
    /// and each shard owns its oracle outright, so this merge is the only
    /// cross-shard state — which is why query accounting stays
    /// bit-identical to sequential execution at every thread count.
    fn merge_stats(&mut self, speculative_per_shard: &[u64]) {
        for &wasted in speculative_per_shard {
            self.speculative_queries += wasted;
        }
        // Stored elements only grow within a chunk, so the end-of-chunk
        // peak equals the scalar per-item peak.
        let stored: usize = self.shards.iter().map(|s| s.oracle.len()).sum();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }

    fn best(&self) -> &Shard {
        self.shards
            .iter()
            .max_by(|a, b| {
                // total_cmp: NaN must surface as a broken best, not a panic
                a.oracle.current_value().total_cmp(&b.oracle.current_value())
            })
            .expect("at least one shard")
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The 2-D (shard × candidate-range) chunk driver: round-synchronized
    /// rejection runs whose kernel+solve work fans out as pure range
    /// tasks on the pool, with each shard's decisions and accounting
    /// identical to [`Shard::process_batch`] by construction — the gains
    /// are range-split-invariant, the scan is the shared
    /// [`Shard::consume_gains`], and the coordinator charges each run's
    /// `count` queries and `count × |S|` kernel evals exactly as
    /// `peek_gain_batch` would. Returns the chunk's speculative gain
    /// evaluations.
    fn process_batch_grid(&mut self, chunk: &[f32], d: usize, k: usize, t_budget: usize) -> u64 {
        let total = chunk.len() / d;
        if total == 0 {
            return 0;
        }
        let threads = self.exec.threads();
        let mut pos = vec![0usize; self.shards.len()];
        let mut need: Vec<bool> = self.shards.iter().map(|s| s.oracle.len() < k).collect();
        let mut wasted = 0u64;
        loop {
            let units = need.iter().filter(|&&x| x).count();
            if units == 0 {
                return wasted;
            }
            // Phase A: one pure kernel+solve task per (shard, range).
            let mut n_tasks = 0usize;
            for (si, live) in need.iter().enumerate() {
                if *live {
                    n_tasks += count_range_tasks(total - pos[si], units, threads);
                }
            }
            let mut scratches = self.solve_pool.reserve(n_tasks);
            let mut tasks: Vec<SolveTask<'_>> = Vec::with_capacity(n_tasks);
            for (si, s) in self.shards.iter_mut().enumerate() {
                if !need[si] {
                    continue;
                }
                let count = total - pos[si];
                if s.scratch.len() < count {
                    s.scratch.resize(count, 0.0);
                }
                let Shard { oracle, scratch, .. } = s;
                let ps = oracle.panel_sharing_ref().expect("grid gated on the capability");
                push_range_tasks(
                    &mut tasks,
                    &mut scratches,
                    ps,
                    &mut scratch[..count],
                    pos[si],
                    units,
                    threads,
                    |from, len| SolveSrc::Kernel { items: &chunk[from * d..(from + len) * d] },
                );
            }
            run_solve_tasks(&self.exec, &mut tasks);
            drop(tasks);
            // Charge + Phase B: scan/accept sequentially in shard order —
            // bit-identical decisions and counters to the coarse path.
            for si in 0..self.shards.len() {
                if !need[si] {
                    continue;
                }
                let count = total - pos[si];
                let s = &mut self.shards[si];
                let evals = count as u64 * s.oracle.len() as u64;
                s.oracle.panel_sharing().expect("capability checked").charge(count as u64, evals);
                match s.consume_gains(chunk, d, k, t_budget, pos[si], count) {
                    Some(j) => {
                        wasted += (count - (j + 1)) as u64;
                        pos[si] += j + 1;
                        need[si] = s.oracle.len() < k && pos[si] < total;
                    }
                    None => need[si] = false,
                }
            }
        }
    }
}

impl StreamingAlgorithm for ShardedThreeSieves {
    fn name(&self) -> String {
        format!("ShardedThreeSieves(p={},T={})", self.shards.len(), self.t_budget)
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        for s in self.shards.iter_mut() {
            s.process(item, self.k, self.t_budget);
        }
        let stored: usize = self.shards.iter().map(|s| s.oracle.len()).sum();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }

    /// Batched ingestion: shards are fully independent, so each consumes
    /// the chunk through [`Shard::process_batch`] — sequentially, or on
    /// the exec pool's worker threads when a context is attached. Either
    /// way each shard runs the identical instruction sequence on the
    /// state it owns and [`Self::merge_stats`] folds the per-shard
    /// outcomes in shard order, so summaries, objective values and query
    /// counts are bit-identical at every thread count
    /// (`rust/tests/exec_parity.rs`).
    ///
    /// When the pool has more workers than shards can occupy (the ROADMAP
    /// work-stealing-granularity item), each shard's rejection runs split
    /// into candidate sub-ranges instead: one 2-D (shard ×
    /// candidate-range) task grid of pure kernel+solve range tasks
    /// ([`crate::functions::PanelSharing::solve_batch_range`]) per round,
    /// with the T-budget scan ([`Shard::consume_gains`]) and all
    /// accounting unchanged — the coordinator charges each run's queries
    /// and kernel evals exactly as `peek_gain_batch` would.
    fn process_batch(&mut self, chunk: &[f32]) {
        let d = self.dim;
        debug_assert_eq!(chunk.len() % d, 0, "chunk not row-aligned");
        self.elements += (chunk.len() / d) as u64;
        let k = self.k;
        let t_budget = self.t_budget;
        let use_grid = self.exec.is_parallel()
            && self.exec.threads() * 2 > self.shards.len()
            && self.shards.iter().all(|s| s.oracle.panel_sharing_ref().is_some());
        if use_grid {
            let wasted = self.process_batch_grid(chunk, d, k, t_budget);
            self.merge_stats(&[wasted]);
            return;
        }
        // Inline when sequential, worker threads when a pool is attached
        // (`set_exec` gated it on `parallel_safe()`).
        let wasted =
            self.exec.map_units(&mut self.shards, |s| s.process_batch(chunk, d, k, t_budget));
        self.merge_stats(&wasted);
    }

    fn set_exec(&mut self, exec: ExecContext) {
        self.exec = exec.gated(self.shards[0].oracle.as_ref());
    }

    fn value(&self) -> f64 {
        self.best().oracle.current_value()
    }

    fn summary(&self) -> Vec<f32> {
        self.best().oracle.summary().to_vec()
    }

    fn summary_len(&self) -> usize {
        self.best().oracle.len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        let stored: usize = self.shards.iter().map(|s| s.oracle.len()).sum();
        let charged: u64 = self.shards.iter().map(|s| s.oracle.queries()).sum();
        AlgoStats {
            queries: charged.saturating_sub(self.speculative_queries),
            kernel_evals: self.shards.iter().map(|s| s.oracle.kernel_evals()).sum(),
            elements: self.elements,
            stored,
            peak_stored: self.peak_stored.max(stored),
            instances: self.shards.len(),
            wall_kernel_ns: self.shards.iter().map(|s| s.oracle.wall_kernel_ns()).sum(),
            wall_solve_ns: self.shards.iter().map(|s| s.oracle.wall_solve_ns()).sum(),
            wall_scan_ns: 0,
            accepts: self.shards.iter().map(|s| s.accepts).sum(),
            rejects: self.shards.iter().map(|s| s.rejects).sum(),
            defers: 0,
            threshold_moves: self.shards.iter().map(|s| s.threshold_moves).sum(),
        }
    }

    fn reset(&mut self) {
        // Rebuild the pristine grid partitioning from the stored config.
        let proto = self.shards[0].oracle.clone_empty();
        let m = proto.max_singleton_value();
        let grid = threshold_grid(self.epsilon, m, self.k as f64 * m);
        let shards_n = self.shards.len();
        let chunk = grid.len().div_ceil(shards_n).max(1);
        self.shards = grid
            .chunks(chunk)
            .enumerate()
            .map(|(i, part)| Shard::new(part.to_vec(), proto.as_ref(), i as u32))
            .collect();
        self.elements = 0;
        self.speculative_queries = 0;
        self.peak_stored = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;
    use crate::algorithms::ThreeSieves;

    #[test]
    fn covers_the_full_grid() {
        let algo = ShardedThreeSieves::new(
            testkit::oracle(10),
            10,
            0.1,
            SieveTuning::FixedT(100),
            4,
        );
        assert_eq!(algo.shard_count(), 4);
    }

    #[test]
    fn never_worse_than_single_instance_with_small_t() {
        // With a small T the single instance can race past good thresholds;
        // sharding starts lower partitions immediately.
        let ds = testkit::clustered(2500, 7);
        let k = 8;
        let t = 30;
        let mut single =
            ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(t));
        let mut sharded = ShardedThreeSieves::new(
            testkit::oracle(k),
            k,
            0.01,
            SieveTuning::FixedT(t),
            4,
        );
        testkit::run(&mut single, &ds);
        testkit::run(&mut sharded, &ds);
        assert!(
            sharded.value() >= single.value() * 0.98,
            "sharded {} vs single {}",
            sharded.value(),
            single.value()
        );
    }

    #[test]
    fn memory_scales_with_shards() {
        let ds = testkit::clustered(1000, 8);
        let k = 5;
        let mut algo = ShardedThreeSieves::new(
            testkit::oracle(k),
            k,
            0.05,
            SieveTuning::FixedT(20),
            3,
        );
        testkit::run(&mut algo, &ds);
        let st = algo.stats();
        assert!(st.peak_stored <= 3 * k);
        assert_eq!(st.instances, 3);
    }

    #[test]
    fn more_shards_than_grid_points_is_clamped() {
        let algo = ShardedThreeSieves::new(
            testkit::oracle(3),
            3,
            0.5, // coarse grid -> few points
            SieveTuning::FixedT(10),
            1000,
        );
        assert!(algo.shard_count() <= 1000);
        assert!(algo.shard_count() >= 1);
    }

    #[test]
    fn pool_driven_batches_match_sequential_bitwise() {
        use crate::exec::{ExecContext, Parallelism};
        let ds = testkit::clustered(1200, 10);
        let k = 6;
        let build = || {
            ShardedThreeSieves::new(testkit::oracle(k), k, 0.05, SieveTuning::FixedT(20), 4)
        };
        let mut seq = build();
        let mut par = build();
        par.set_exec(ExecContext::new(Parallelism::Threads(3)));
        for chunk in ds.raw().chunks(37 * testkit::DIM) {
            seq.process_batch(chunk);
            par.process_batch(chunk);
        }
        assert_eq!(seq.value().to_bits(), par.value().to_bits());
        assert_eq!(seq.summary(), par.summary());
        assert_eq!(seq.stats(), par.stats());
    }

    #[test]
    fn reset_preserves_shard_count() {
        let ds = testkit::clustered(500, 9);
        let mut algo = ShardedThreeSieves::new(
            testkit::oracle(5),
            5,
            0.05,
            SieveTuning::FixedT(25),
            3,
        );
        testkit::run(&mut algo, &ds);
        let n = algo.shard_count();
        algo.reset();
        assert_eq!(algo.shard_count(), n);
        assert_eq!(algo.summary_len(), 0);
        testkit::run(&mut algo, &ds);
        assert!(algo.value() > 0.0);
    }
}
