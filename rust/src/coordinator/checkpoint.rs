//! Summary checkpointing: persist a selected summary (+ metadata) so a
//! pipeline can restart, or downstream consumers (dashboards, assignment
//! services) can load the latest summary without touching the pipeline.
//!
//! Format **v2** (`TSCKPT2\n`): a small JSON header line, then row-major
//! little-endian f32s, then a trailing little-endian **FNV-1a-64
//! checksum** over everything before it (magic, header length, header,
//! payload). Format v1 (`TSCKPT1\n`, no checksum) still loads — a legacy
//! file is simply unverifiable, not corrupt.
//!
//! Saves are **crash-safe**: the bytes go to a `<path>.tmp` sibling
//! first, are `sync_all`ed, renamed over the target, and the parent
//! directory is fsynced after the rename — so neither a torn write nor a
//! crash between write and rename can ever leave a *published*
//! checkpoint torn, and the rename itself is durable. What a mid-write
//! crash *can* leave behind — a stale `.tmp`, a truncated or bit-flipped
//! file from outside interference — is what [`sweep_dir`] recovers from
//! at service startup: good checkpoints are counted, corrupt ones are
//! [`quarantine`]d to a `.corrupt` sibling (kept for forensics, out of
//! the resume path) so a fresh `OPEN` under the same id proceeds.
//!
//! Every IO step is a named fault site ([`crate::fault::site`]), so the
//! chaos suite can force torn writes, rename failures and read errors on
//! a deterministic schedule.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::fault;
use crate::util::json::Json;

/// A persisted summary.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub algorithm: String,
    pub dim: usize,
    pub k: usize,
    pub value: f64,
    /// Stream elements consumed when the checkpoint was taken.
    pub elements: u64,
    /// Drift events observed so far.
    pub drift_events: usize,
    /// Opaque resumable-algorithm state
    /// ([`StreamingAlgorithm::snapshot_state`](crate::algorithms::StreamingAlgorithm::snapshot_state)),
    /// or [`Json::Null`] when the algorithm is not resumable — the summary
    /// alone still loads everywhere a plain summary artifact is expected.
    pub state: Json,
    /// Row-major `n × dim` summary features.
    pub summary: Vec<f32>,
}

/// Why a checkpoint failed to load — the corruption taxonomy behind
/// [`CheckpointError::Corrupt`]. Every variant is recoverable by
/// quarantine + fresh `OPEN`; none should ever abort a process.
#[derive(Debug, PartialEq, Eq)]
pub enum Corruption {
    /// The file ends before the named section is complete.
    Truncated(&'static str),
    /// The first 8 bytes are not a `TSCKPT*` magic at all.
    BadMagic,
    /// A `TSCKPT` magic with a version this build does not speak.
    UnsupportedVersion(u8),
    /// The v2 trailer does not match the FNV-1a-64 of the body — a torn
    /// or bit-flipped file.
    ChecksumMismatch { stored: u64, computed: u64 },
    /// The JSON header is unreadable or missing a required field.
    Header(String),
    /// The f32 payload size disagrees with the header's `rows × dim`.
    PayloadSize { got: usize, want: usize },
}

impl std::fmt::Display for Corruption {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Corruption::Truncated(what) => write!(f, "truncated: short {what}"),
            Corruption::BadMagic => write!(f, "bad magic"),
            Corruption::UnsupportedVersion(v) => {
                write!(f, "unsupported format version {:?}", *v as char)
            }
            Corruption::ChecksumMismatch { stored, computed } => {
                write!(f, "checksum mismatch: stored {stored:016x}, computed {computed:016x}")
            }
            Corruption::Header(msg) => write!(f, "header: {msg}"),
            Corruption::PayloadSize { got, want } => {
                write!(f, "payload {got} bytes, expected {want}")
            }
        }
    }
}

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    Corrupt(Corruption),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::Corrupt(c) => write!(f, "corrupt checkpoint: {c}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            CheckpointError::Corrupt(_) => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

impl From<Corruption> for CheckpointError {
    fn from(c: Corruption) -> Self {
        CheckpointError::Corrupt(c)
    }
}

const MAGIC_V1: &[u8; 8] = b"TSCKPT1\n";
const MAGIC_V2: &[u8; 8] = b"TSCKPT2\n";

/// FNV-1a 64-bit over `bytes` — the v2 trailer hash. Std-only, one
/// multiply per byte; collision resistance is irrelevant here (we defend
/// against torn writes and bit rot, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl Checkpoint {
    pub fn summary_len(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.summary.len() / self.dim
        }
    }

    /// Serialize to the on-disk v2 byte image (magic + header-len +
    /// header + payload + FNV trailer).
    pub fn encode(&self) -> Vec<u8> {
        let header = Json::obj(vec![
            ("algorithm", Json::str(self.algorithm.clone())),
            ("dim", Json::num(self.dim as f64)),
            ("k", Json::num(self.k as f64)),
            ("value", Json::num(self.value)),
            ("elements", Json::num(self.elements as f64)),
            ("drift_events", Json::num(self.drift_events as f64)),
            ("state", self.state.clone()),
            ("rows", Json::num(self.summary_len() as f64)),
        ])
        .to_string();
        let mut buf = Vec::with_capacity(8 + 4 + header.len() + self.summary.len() * 4 + 8);
        buf.extend_from_slice(MAGIC_V2);
        buf.extend_from_slice(&(header.len() as u32).to_le_bytes());
        buf.extend_from_slice(header.as_bytes());
        for v in &self.summary {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let sum = fnv1a64(&buf);
        buf.extend_from_slice(&sum.to_le_bytes());
        buf
    }

    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        let _g = crate::obs::span("checkpoint-save");
        crate::obs::emit_event(crate::obs::Event::CheckpointSave { elements: self.elements });
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let buf = self.encode();
        // Append `.tmp` to the *whole* file name rather than replacing the
        // extension: `with_extension` would map both `a.1.ckpt` and
        // `a.2.ckpt` onto `a.tmp`, so two concurrent saves of *different*
        // checkpoints (the service evicts many sessions into one
        // directory) could clobber each other's staging file.
        let tmp = match path.file_name() {
            Some(name) => {
                let mut tmp_name = name.to_os_string();
                tmp_name.push(".tmp");
                path.with_file_name(tmp_name)
            }
            None => path.with_extension("tmp"),
        };
        {
            let mut f = std::fs::File::create(&tmp)?;
            match fault::check(fault::site::CKPT_WRITE) {
                Some(fault::FaultKind::TornWrite { bytes }) => {
                    // A mid-write crash: a synced prefix of the staging
                    // file survives, the publish rename never happens.
                    f.write_all(&buf[..bytes.min(buf.len())])?;
                    f.sync_all()?;
                    return Err(fault::io_error(std::io::ErrorKind::WriteZero).into());
                }
                Some(_) => {
                    drop(f);
                    let _ = std::fs::remove_file(&tmp);
                    return Err(fault::io_error(std::io::ErrorKind::Other).into());
                }
                None => {}
            }
            f.write_all(&buf)?;
            f.sync_all()?;
        }
        if fault::check(fault::site::CKPT_RENAME).is_some() {
            // A crash between staging and publish: the stale `.tmp` is
            // left behind for the recovery sweep to clean up.
            return Err(fault::io_error(std::io::ErrorKind::Other).into());
        }
        // Atomic replace so readers never see a torn checkpoint…
        std::fs::rename(&tmp, path)?;
        // …and a directory fsync so the rename itself survives a crash.
        sync_parent_dir(path);
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self, CheckpointError> {
        let _g = crate::obs::span("checkpoint-restore");
        if fault::check(fault::site::CKPT_LOAD).is_some() {
            return Err(fault::io_error(std::io::ErrorKind::Other).into());
        }
        let bytes = std::fs::read(path)?;
        let ck = Checkpoint::decode(&bytes)?;
        crate::obs::emit_event(crate::obs::Event::CheckpointRestore { elements: ck.elements });
        Ok(ck)
    }

    /// Parse an on-disk byte image (either format version), verifying
    /// the v2 checksum. The inverse of [`Checkpoint::encode`].
    pub fn decode(bytes: &[u8]) -> Result<Self, CheckpointError> {
        if bytes.len() < 8 {
            return Err(Corruption::Truncated("magic").into());
        }
        let magic = &bytes[..8];
        let body = if magic == MAGIC_V2 {
            // Minimum v2: magic + header-len + empty header + trailer.
            if bytes.len() < 8 + 4 + 8 {
                return Err(Corruption::Truncated("checksum trailer").into());
            }
            let (body, trailer) = bytes.split_at(bytes.len() - 8);
            let stored = u64::from_le_bytes(trailer.try_into().expect("8-byte trailer"));
            let computed = fnv1a64(body);
            if stored != computed {
                return Err(Corruption::ChecksumMismatch { stored, computed }.into());
            }
            body
        } else if magic == MAGIC_V1 {
            // Legacy format: no trailer, nothing to verify.
            bytes
        } else if let Some(version) = magic.strip_prefix(b"TSCKPT") {
            return Err(Corruption::UnsupportedVersion(version[0]).into());
        } else {
            return Err(Corruption::BadMagic.into());
        };
        if body.len() < 12 {
            return Err(Corruption::Truncated("header length").into());
        }
        let hlen = u32::from_le_bytes(body[8..12].try_into().expect("4-byte len")) as usize;
        if body.len() < 12 + hlen {
            return Err(Corruption::Truncated("header").into());
        }
        let header = std::str::from_utf8(&body[12..12 + hlen])
            .map_err(|_| Corruption::Header("not utf-8".into()))?;
        let j = Json::parse(header)
            .map_err(|e| Corruption::Header(format!("json: {e}")))?;
        let dim = j.get("dim").as_usize().ok_or_else(|| corrupt("dim"))?;
        let rows = j.get("rows").as_usize().ok_or_else(|| corrupt("rows"))?;
        let payload = &body[12 + hlen..];
        if payload.len() != rows * dim * 4 {
            return Err(Corruption::PayloadSize { got: payload.len(), want: rows * dim * 4 }.into());
        }
        let summary: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let elements = j.get("elements").as_f64().unwrap_or(0.0) as u64;
        Ok(Checkpoint {
            algorithm: j.get("algorithm").as_str().unwrap_or("?").to_string(),
            dim,
            k: j.get("k").as_usize().ok_or_else(|| corrupt("k"))?,
            value: j.get("value").as_f64().unwrap_or(0.0),
            elements,
            drift_events: j.get("drift_events").as_usize().unwrap_or(0),
            // Absent in pre-state checkpoints; Null = summary-only.
            state: j.get("state").clone(),
            summary,
        })
    }
}

fn corrupt(field: &str) -> CheckpointError {
    Corruption::Header(format!("missing field {field:?}")).into()
}

/// Fsync `path`'s parent directory so a just-renamed entry is durable.
/// Best-effort on platforms where directories cannot be opened.
fn sync_parent_dir(path: &Path) {
    if let Some(parent) = path.parent() {
        if let Ok(d) = std::fs::File::open(parent) {
            let _ = d.sync_all();
        }
    }
}

/// The `.corrupt` sibling a quarantined checkpoint is moved to.
pub fn quarantine_path(path: &Path) -> PathBuf {
    match path.file_name() {
        Some(name) => {
            let mut q = name.to_os_string();
            q.push(".corrupt");
            path.with_file_name(q)
        }
        None => path.with_extension("corrupt"),
    }
}

/// Move an unloadable checkpoint out of the resume path to its
/// `.corrupt` sibling (replacing any previous quarantine of the same
/// file) and return the new location. The bytes are preserved for
/// forensics; the original path is free for a fresh `OPEN` to reuse.
pub fn quarantine(path: &Path) -> std::io::Result<PathBuf> {
    let dst = quarantine_path(path);
    std::fs::rename(path, &dst)?;
    sync_parent_dir(path);
    crate::obs::emit_event(crate::obs::Event::CheckpointQuarantine);
    Ok(dst)
}

/// What a [`sweep_dir`] recovery pass found.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepReport {
    /// Checkpoints that load cleanly and are available for resume.
    pub good: usize,
    /// Corrupt checkpoints moved to `.corrupt` quarantine.
    pub quarantined: usize,
    /// Stale `.tmp` staging files (interrupted saves) removed.
    pub stale_tmp: usize,
}

/// Startup recovery sweep over a checkpoint directory: verify every
/// `*.ckpt` (quarantining corrupt ones via [`quarantine`]) and delete
/// stale `*.tmp` staging leftovers from interrupted saves. Missing or
/// unreadable directories yield an empty report — recovery never blocks
/// startup. Deterministic: entries are processed in sorted order.
pub fn sweep_dir(dir: &Path) -> SweepReport {
    let mut report = SweepReport::default();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return report,
    };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        let name = match p.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => continue,
        };
        if name.ends_with(".tmp") {
            // An interrupted staging write: the publish rename never ran,
            // so the real checkpoint (if any) is intact next to it.
            if std::fs::remove_file(&p).is_ok() {
                report.stale_tmp += 1;
            }
            continue;
        }
        if !name.ends_with(".ckpt") {
            continue;
        }
        match Checkpoint::load(&p) {
            Ok(_) => report.good += 1,
            Err(CheckpointError::Corrupt(c)) => {
                if let Ok(dst) = quarantine(&p) {
                    eprintln!(
                        "checkpoint recovery: quarantined {} ({c}) -> {}",
                        p.display(),
                        dst.display()
                    );
                    report.quarantined += 1;
                }
            }
            // Unreadable right now (permissions, transient IO): leave it
            // alone — a later OPEN will retry and decide.
            Err(CheckpointError::Io(_)) => {}
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            algorithm: "ThreeSieves(T=500)".into(),
            dim: 3,
            k: 4,
            value: 2.5,
            elements: 1000,
            drift_events: 2,
            state: Json::Null,
            summary: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ts_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let p = tmp("rt");
        let ck = sample();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.summary_len(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_truncation() {
        let p = tmp("trunc");
        sample().save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(matches!(Checkpoint::load(&p), Err(CheckpointError::Corrupt(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_bad_magic() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTMAGIC rest").unwrap();
        assert!(matches!(
            Checkpoint::load(&p),
            Err(CheckpointError::Corrupt(Corruption::BadMagic))
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn checksum_detects_single_bit_flip() {
        let p = tmp("bitflip");
        sample().save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // Flip one payload bit (past magic + header length).
        let idx = bytes.len() - 12;
        bytes[idx] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(
            Checkpoint::load(&p),
            Err(CheckpointError::Corrupt(Corruption::ChecksumMismatch { .. }))
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_unknown_version_header() {
        let p = tmp("version");
        sample().save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[6] = b'9';
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(
            Checkpoint::load(&p),
            Err(CheckpointError::Corrupt(Corruption::UnsupportedVersion(b'9')))
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn legacy_v1_still_loads() {
        let p = tmp("v1");
        let ck = sample();
        // A v1 file is the v2 image with the old magic and no trailer.
        let mut bytes = ck.encode();
        bytes.truncate(bytes.len() - 8);
        bytes[..8].copy_from_slice(MAGIC_V1);
        std::fs::write(&p, &bytes).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_file_is_truncated_not_panic() {
        let p = tmp("emptyfile");
        std::fs::write(&p, b"").unwrap();
        assert!(matches!(
            Checkpoint::load(&p),
            Err(CheckpointError::Corrupt(Corruption::Truncated(_)))
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_summary_roundtrips() {
        let p = tmp("empty");
        let mut ck = sample();
        ck.summary.clear();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.summary_len(), 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn state_blob_roundtrips_exactly() {
        let p = tmp("state");
        let mut ck = sample();
        // Non-integral f64s must survive bit-for-bit (resume depends on it).
        ck.state = Json::obj(vec![
            ("v", Json::num(0.123456789012345678)),
            ("grid_len", Json::num(1234.0)),
            ("m", Json::num(std::f64::consts::LN_2 / 2.0)),
        ]);
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
        let (a, b) = (back.state.get("v").as_f64().unwrap(), ck.state.get("v").as_f64().unwrap());
        assert_eq!(a.to_bits(), b.to_bits());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn stateless_checkpoint_loads_with_null_state() {
        let p = tmp("nullstate");
        sample().save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap().state, Json::Null);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn staging_file_appends_tmp_to_full_name() {
        // Dotted file names must not collide on a shared `.tmp` stem: the
        // staging path is `<full name>.tmp`, and it is gone after save.
        let dir = std::env::temp_dir().join(format!("ts_ckpt_tmpdir_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let a = dir.join("sess.a.ckpt");
        sample().save(&a).unwrap();
        assert!(a.exists());
        assert!(!dir.join("sess.a.ckpt.tmp").exists(), "staging file must be renamed away");
        assert!(!dir.join("sess.tmp").exists(), "must not use with_extension-style staging");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quarantine_moves_to_corrupt_sibling() {
        let dir = std::env::temp_dir().join(format!("ts_ckpt_qdir_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, b"garbage").unwrap();
        let dst = quarantine(&p).unwrap();
        assert_eq!(dst, dir.join("bad.ckpt.corrupt"));
        assert!(!p.exists());
        assert_eq!(std::fs::read(&dst).unwrap(), b"garbage");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_quarantines_corrupt_and_removes_stale_tmp() {
        let dir = std::env::temp_dir().join(format!("ts_ckpt_sweep_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        sample().save(&dir.join("good.ckpt")).unwrap();
        std::fs::write(dir.join("bad.ckpt"), b"TSCKPT2\ntorn").unwrap();
        std::fs::write(dir.join("stale.ckpt.tmp"), b"half a checkpoint").unwrap();
        std::fs::write(dir.join("notes.txt"), b"ignore me").unwrap();
        let report = sweep_dir(&dir);
        assert_eq!(report, SweepReport { good: 1, quarantined: 1, stale_tmp: 1 });
        assert!(dir.join("good.ckpt").exists());
        assert!(dir.join("bad.ckpt.corrupt").exists());
        assert!(!dir.join("bad.ckpt").exists());
        assert!(!dir.join("stale.ckpt.tmp").exists());
        assert!(dir.join("notes.txt").exists(), "sweep only touches ckpt artifacts");
        // A second sweep is a no-op on the quarantined leftovers.
        assert_eq!(sweep_dir(&dir), SweepReport { good: 1, quarantined: 0, stale_tmp: 0 });
        std::fs::remove_dir_all(&dir).ok();
    }
}
