//! Summary checkpointing: persist a selected summary (+ metadata) so a
//! pipeline can restart, or downstream consumers (dashboards, assignment
//! services) can load the latest summary without touching the pipeline.
//!
//! Format: a small JSON header line, then row-major little-endian f32s.

use std::io::{Read, Write};
use std::path::Path;

use crate::util::json::Json;

/// A persisted summary.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub algorithm: String,
    pub dim: usize,
    pub k: usize,
    pub value: f64,
    /// Stream elements consumed when the checkpoint was taken.
    pub elements: u64,
    /// Drift events observed so far.
    pub drift_events: usize,
    /// Row-major `n × dim` summary features.
    pub summary: Vec<f32>,
}

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    Corrupt(String),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::Corrupt(msg) => write!(f, "corrupt checkpoint: {msg}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            CheckpointError::Corrupt(_) => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

const MAGIC: &[u8; 8] = b"TSCKPT1\n";

impl Checkpoint {
    pub fn summary_len(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.summary.len() / self.dim
        }
    }

    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let header = Json::obj(vec![
            ("algorithm", Json::str(self.algorithm.clone())),
            ("dim", Json::num(self.dim as f64)),
            ("k", Json::num(self.k as f64)),
            ("value", Json::num(self.value)),
            ("elements", Json::num(self.elements as f64)),
            ("drift_events", Json::num(self.drift_events as f64)),
            ("rows", Json::num(self.summary_len() as f64)),
        ])
        .to_string();
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(MAGIC)?;
            f.write_all(&(header.len() as u32).to_le_bytes())?;
            f.write_all(header.as_bytes())?;
            for v in &self.summary {
                f.write_all(&v.to_le_bytes())?;
            }
            f.sync_all()?;
        }
        // Atomic replace so readers never see a torn checkpoint.
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self, CheckpointError> {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic).map_err(|_| CheckpointError::Corrupt("short magic".into()))?;
        if &magic != MAGIC {
            return Err(CheckpointError::Corrupt("bad magic".into()));
        }
        let mut len_bytes = [0u8; 4];
        f.read_exact(&mut len_bytes)
            .map_err(|_| CheckpointError::Corrupt("short header len".into()))?;
        let hlen = u32::from_le_bytes(len_bytes) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf).map_err(|_| CheckpointError::Corrupt("short header".into()))?;
        let header = String::from_utf8(hbuf)
            .map_err(|_| CheckpointError::Corrupt("header not utf-8".into()))?;
        let j = Json::parse(&header)
            .map_err(|e| CheckpointError::Corrupt(format!("header json: {e}")))?;
        let dim = j.get("dim").as_usize().ok_or_else(|| corrupt("dim"))?;
        let rows = j.get("rows").as_usize().ok_or_else(|| corrupt("rows"))?;
        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;
        if payload.len() != rows * dim * 4 {
            return Err(CheckpointError::Corrupt(format!(
                "payload {} bytes, expected {}",
                payload.len(),
                rows * dim * 4
            )));
        }
        let summary: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint {
            algorithm: j.get("algorithm").as_str().unwrap_or("?").to_string(),
            dim,
            k: j.get("k").as_usize().ok_or_else(|| corrupt("k"))?,
            value: j.get("value").as_f64().unwrap_or(0.0),
            elements: j.get("elements").as_f64().unwrap_or(0.0) as u64,
            drift_events: j.get("drift_events").as_usize().unwrap_or(0),
            summary,
        })
    }
}

fn corrupt(field: &str) -> CheckpointError {
    CheckpointError::Corrupt(format!("missing field {field:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            algorithm: "ThreeSieves(T=500)".into(),
            dim: 3,
            k: 4,
            value: 2.5,
            elements: 1000,
            drift_events: 2,
            summary: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ts_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let p = tmp("rt");
        let ck = sample();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.summary_len(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_truncation() {
        let p = tmp("trunc");
        sample().save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(matches!(Checkpoint::load(&p), Err(CheckpointError::Corrupt(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_bad_magic() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTMAGIC rest").unwrap();
        assert!(matches!(Checkpoint::load(&p), Err(CheckpointError::Corrupt(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_summary_roundtrips() {
        let p = tmp("empty");
        let mut ck = sample();
        ck.summary.clear();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.summary_len(), 0);
        std::fs::remove_file(&p).ok();
    }
}
