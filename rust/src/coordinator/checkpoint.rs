//! Summary checkpointing: persist a selected summary (+ metadata) so a
//! pipeline can restart, or downstream consumers (dashboards, assignment
//! services) can load the latest summary without touching the pipeline.
//!
//! Format: a small JSON header line, then row-major little-endian f32s.
//!
//! Saves are **atomic**: the bytes go to a `<path>.tmp` sibling first and
//! are renamed over the target only after a successful `sync_all`, so a
//! crash or eviction mid-write can never leave a torn checkpoint for a
//! reader (or the service's re-`OPEN` resume path) to trip over.

use std::io::{Read, Write};
use std::path::Path;

use crate::util::json::Json;

/// A persisted summary.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub algorithm: String,
    pub dim: usize,
    pub k: usize,
    pub value: f64,
    /// Stream elements consumed when the checkpoint was taken.
    pub elements: u64,
    /// Drift events observed so far.
    pub drift_events: usize,
    /// Opaque resumable-algorithm state
    /// ([`StreamingAlgorithm::snapshot_state`](crate::algorithms::StreamingAlgorithm::snapshot_state)),
    /// or [`Json::Null`] when the algorithm is not resumable — the summary
    /// alone still loads everywhere a plain summary artifact is expected.
    pub state: Json,
    /// Row-major `n × dim` summary features.
    pub summary: Vec<f32>,
}

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    Corrupt(String),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io: {e}"),
            CheckpointError::Corrupt(msg) => write!(f, "corrupt checkpoint: {msg}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            CheckpointError::Corrupt(_) => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

const MAGIC: &[u8; 8] = b"TSCKPT1\n";

impl Checkpoint {
    pub fn summary_len(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.summary.len() / self.dim
        }
    }

    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        let _g = crate::obs::span("checkpoint-save");
        crate::obs::emit_event(crate::obs::Event::CheckpointSave { elements: self.elements });
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let header = Json::obj(vec![
            ("algorithm", Json::str(self.algorithm.clone())),
            ("dim", Json::num(self.dim as f64)),
            ("k", Json::num(self.k as f64)),
            ("value", Json::num(self.value)),
            ("elements", Json::num(self.elements as f64)),
            ("drift_events", Json::num(self.drift_events as f64)),
            ("state", self.state.clone()),
            ("rows", Json::num(self.summary_len() as f64)),
        ])
        .to_string();
        // Append `.tmp` to the *whole* file name rather than replacing the
        // extension: `with_extension` would map both `a.1.ckpt` and
        // `a.2.ckpt` onto `a.tmp`, so two concurrent saves of *different*
        // checkpoints (the service evicts many sessions into one
        // directory) could clobber each other's staging file.
        let tmp = match path.file_name() {
            Some(name) => {
                let mut tmp_name = name.to_os_string();
                tmp_name.push(".tmp");
                path.with_file_name(tmp_name)
            }
            None => path.with_extension("tmp"),
        };
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(MAGIC)?;
            f.write_all(&(header.len() as u32).to_le_bytes())?;
            f.write_all(header.as_bytes())?;
            for v in &self.summary {
                f.write_all(&v.to_le_bytes())?;
            }
            f.sync_all()?;
        }
        // Atomic replace so readers never see a torn checkpoint.
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self, CheckpointError> {
        let _g = crate::obs::span("checkpoint-restore");
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic).map_err(|_| CheckpointError::Corrupt("short magic".into()))?;
        if &magic != MAGIC {
            return Err(CheckpointError::Corrupt("bad magic".into()));
        }
        let mut len_bytes = [0u8; 4];
        f.read_exact(&mut len_bytes)
            .map_err(|_| CheckpointError::Corrupt("short header len".into()))?;
        let hlen = u32::from_le_bytes(len_bytes) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf).map_err(|_| CheckpointError::Corrupt("short header".into()))?;
        let header = String::from_utf8(hbuf)
            .map_err(|_| CheckpointError::Corrupt("header not utf-8".into()))?;
        let j = Json::parse(&header)
            .map_err(|e| CheckpointError::Corrupt(format!("header json: {e}")))?;
        let dim = j.get("dim").as_usize().ok_or_else(|| corrupt("dim"))?;
        let rows = j.get("rows").as_usize().ok_or_else(|| corrupt("rows"))?;
        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;
        if payload.len() != rows * dim * 4 {
            return Err(CheckpointError::Corrupt(format!(
                "payload {} bytes, expected {}",
                payload.len(),
                rows * dim * 4
            )));
        }
        let summary: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let elements = j.get("elements").as_f64().unwrap_or(0.0) as u64;
        crate::obs::emit_event(crate::obs::Event::CheckpointRestore { elements });
        Ok(Checkpoint {
            algorithm: j.get("algorithm").as_str().unwrap_or("?").to_string(),
            dim,
            k: j.get("k").as_usize().ok_or_else(|| corrupt("k"))?,
            value: j.get("value").as_f64().unwrap_or(0.0),
            elements,
            drift_events: j.get("drift_events").as_usize().unwrap_or(0),
            // Absent in pre-state checkpoints; Null = summary-only.
            state: j.get("state").clone(),
            summary,
        })
    }
}

fn corrupt(field: &str) -> CheckpointError {
    CheckpointError::Corrupt(format!("missing field {field:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            algorithm: "ThreeSieves(T=500)".into(),
            dim: 3,
            k: 4,
            value: 2.5,
            elements: 1000,
            drift_events: 2,
            state: Json::Null,
            summary: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ts_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let p = tmp("rt");
        let ck = sample();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.summary_len(), 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_truncation() {
        let p = tmp("trunc");
        sample().save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(matches!(Checkpoint::load(&p), Err(CheckpointError::Corrupt(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_bad_magic() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTMAGIC rest").unwrap();
        assert!(matches!(Checkpoint::load(&p), Err(CheckpointError::Corrupt(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_summary_roundtrips() {
        let p = tmp("empty");
        let mut ck = sample();
        ck.summary.clear();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.summary_len(), 0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn state_blob_roundtrips_exactly() {
        let p = tmp("state");
        let mut ck = sample();
        // Non-integral f64s must survive bit-for-bit (resume depends on it).
        ck.state = Json::obj(vec![
            ("v", Json::num(0.123456789012345678)),
            ("grid_len", Json::num(1234.0)),
            ("m", Json::num(std::f64::consts::LN_2 / 2.0)),
        ]);
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, ck);
        let (a, b) = (back.state.get("v").as_f64().unwrap(), ck.state.get("v").as_f64().unwrap());
        assert_eq!(a.to_bits(), b.to_bits());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn stateless_checkpoint_loads_with_null_state() {
        let p = tmp("nullstate");
        sample().save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap().state, Json::Null);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn staging_file_appends_tmp_to_full_name() {
        // Dotted file names must not collide on a shared `.tmp` stem: the
        // staging path is `<full name>.tmp`, and it is gone after save.
        let dir = std::env::temp_dir().join(format!("ts_ckpt_tmpdir_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let a = dir.join("sess.a.ckpt");
        sample().save(&a).unwrap();
        assert!(a.exists());
        assert!(!dir.join("sess.a.ckpt.tmp").exists(), "staging file must be renamed away");
        assert!(!dir.join("sess.tmp").exists(), "must not use with_extension-style staging");
        std::fs::remove_dir_all(&dir).ok();
    }
}
