//! Page-Hinkley drift detector — the classic sequential change-point test,
//! provided alongside [`MeanShiftDetector`](super::MeanShiftDetector) so the
//! pipeline can be configured with either (the ablation bench compares
//! them on the drift surrogates).
//!
//! The test tracks the cumulative deviation of a univariate statistic from
//! its running mean; drift fires when the deviation exceeds `lambda`. We
//! monitor `‖x‖` shifts *and* the distance of each item to the running mean
//! vector, which catches both scale and location drift.

use super::drift::DriftDetector;

/// Page-Hinkley test over the item-to-running-mean distance.
pub struct PageHinkleyDetector {
    dim: usize,
    /// Forgetting factor for the running mean vector.
    alpha: f64,
    /// Minimum magnitude change to accumulate (the PH `delta`).
    delta: f64,
    /// Detection threshold (the PH `lambda`).
    lambda: f64,
    /// Running mean of the feature vector.
    mean: Vec<f64>,
    /// Running mean of the monitored statistic.
    stat_mean: f64,
    /// Cumulative PH sum and its running minimum.
    m_t: f64,
    m_min: f64,
    t: u64,
    warmup: u64,
    events: usize,
}

impl PageHinkleyDetector {
    /// `delta`: tolerated drift magnitude per step; `lambda`: alarm level.
    /// `warmup`: items consumed before the test arms itself.
    pub fn new(dim: usize, delta: f64, lambda: f64, warmup: u64) -> Self {
        assert!(dim > 0 && delta >= 0.0 && lambda > 0.0);
        PageHinkleyDetector {
            dim,
            alpha: 0.005,
            delta,
            lambda,
            mean: vec![0.0; dim],
            stat_mean: 0.0,
            m_t: 0.0,
            m_min: 0.0,
            t: 0,
            warmup,
            events: 0,
        }
    }

    fn rearm(&mut self) {
        self.m_t = 0.0;
        self.m_min = 0.0;
        self.stat_mean = 0.0;
        self.t = 0;
        self.mean.iter_mut().for_each(|m| *m = 0.0);
    }
}

impl DriftDetector for PageHinkleyDetector {
    fn observe(&mut self, item: &[f32]) -> bool {
        debug_assert_eq!(item.len(), self.dim);
        self.t += 1;
        // Monitored statistic: distance of the item to the running mean.
        let mut d2 = 0.0;
        for (m, &x) in self.mean.iter().zip(item) {
            let diff = x as f64 - m;
            d2 += diff * diff;
        }
        let stat = d2.sqrt();
        // Update running structures (EWMA mean vector; CMA statistic mean).
        for (m, &x) in self.mean.iter_mut().zip(item) {
            *m += self.alpha * (x as f64 - *m);
        }
        let t = self.t as f64;
        self.stat_mean += (stat - self.stat_mean) / t;

        if self.t <= self.warmup {
            return false;
        }
        // PH accumulation.
        self.m_t += stat - self.stat_mean - self.delta;
        if self.m_t < self.m_min {
            self.m_min = self.m_t;
        }
        if self.m_t - self.m_min > self.lambda {
            self.events += 1;
            self.rearm();
            return true;
        }
        false
    }

    fn events(&self) -> usize {
        self.events
    }

    fn reset(&mut self) {
        self.events = 0;
        self.rearm();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn feed(det: &mut PageHinkleyDetector, rng: &mut Rng, mean: f64, n: usize, d: usize) {
        for _ in 0..n {
            let item: Vec<f32> = (0..d).map(|_| (mean + rng.normal()) as f32).collect();
            det.observe(&item);
        }
    }

    #[test]
    fn quiet_on_stationary_stream() {
        let d = 8;
        let mut det = PageHinkleyDetector::new(d, 0.05, 80.0, 200);
        let mut rng = Rng::seed_from(1);
        feed(&mut det, &mut rng, 0.0, 5000, d);
        assert_eq!(det.events(), 0);
    }

    #[test]
    fn fires_on_level_shift() {
        let d = 8;
        let mut det = PageHinkleyDetector::new(d, 0.05, 80.0, 200);
        let mut rng = Rng::seed_from(2);
        feed(&mut det, &mut rng, 0.0, 1000, d);
        feed(&mut det, &mut rng, 4.0, 1500, d);
        assert!(det.events() >= 1, "4-sigma level shift must alarm");
    }

    #[test]
    fn rearms_and_adapts() {
        let d = 6;
        let mut det = PageHinkleyDetector::new(d, 0.05, 60.0, 150);
        let mut rng = Rng::seed_from(3);
        feed(&mut det, &mut rng, 0.0, 800, d);
        feed(&mut det, &mut rng, 5.0, 800, d);
        let e = det.events();
        assert!(e >= 1);
        // After settling into the new regime, no runaway alarms.
        feed(&mut det, &mut rng, 5.0, 4000, d);
        assert!(det.events() <= e + 2, "detector must adapt: {} alarms", det.events());
    }

    #[test]
    fn warmup_suppresses_early_alarms() {
        let d = 4;
        let mut det = PageHinkleyDetector::new(d, 0.0, 1.0, 1_000_000);
        let mut rng = Rng::seed_from(4);
        feed(&mut det, &mut rng, 0.0, 500, d);
        feed(&mut det, &mut rng, 100.0, 500, d);
        assert_eq!(det.events(), 0, "warmup must gate the test");
    }
}
