//! Wall-clock timing scopes and a small statistics accumulator.
//!
//! `cargo bench` targets in this repo use a hand-rolled harness (criterion
//! is not vendored in this environment); [`BenchStats`] provides the
//! mean / stddev / percentile summary those harnesses print.

use std::time::{Duration, Instant};

/// A running timer; `elapsed_s` for seconds as f64.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates sample values (e.g. per-iteration latencies in seconds).
#[derive(Clone, Debug, Default)]
pub struct BenchStats {
    samples: Vec<f64>,
}

impl BenchStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, p in [0, 100] (NaN when empty).
    /// Delegates to the crate-wide quantile convention so bench summaries
    /// and the obs histograms agree on what "p99" means.
    pub fn percentile(&self, p: f64) -> f64 {
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        crate::obs::quantile::percentile_sorted(&xs, p)
    }

    /// One-line summary used by the bench harnesses.
    pub fn summary(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.4}{u} sd={:.4}{u} min={:.4}{u} p50={:.4}{u} p99={:.4}{u} max={:.4}{u}",
            self.len(),
            self.mean(),
            self.stddev(),
            self.min(),
            self.percentile(50.0),
            self.percentile(99.0),
            self.max(),
            u = unit,
        )
    }
}

/// Run `f` repeatedly: `warmup` unmeasured iterations then `iters` measured,
/// returning per-iteration seconds.
pub fn bench_loop<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut stats = BenchStats::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        stats.push(t.elapsed().as_secs_f64());
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = BenchStats::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.min() - 1.0).abs() < 1e-12);
        assert!((s.max() - 4.0).abs() < 1e-12);
        assert!((s.percentile(50.0) - 2.5).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_of_constant_is_zero() {
        let mut s = BenchStats::new();
        for _ in 0..5 {
            s.push(7.0);
        }
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn bench_loop_counts() {
        let mut calls = 0;
        let stats = bench_loop(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(stats.len(), 5);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_s() > 0.0);
    }
}
