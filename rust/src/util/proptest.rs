//! A small seeded property-testing harness (the `proptest` crate is not
//! vendored in this environment, so we provide the subset we need: random
//! case generation from a deterministic seed, failure reporting with the
//! reproducing seed, and greedy shrinking).

use std::fmt::Debug;

use crate::util::rng::Rng;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` against `cases` random inputs drawn by `gen`.
///
/// Panics with the failing case (Debug), its index and the master seed, so
/// a failure line can be reproduced exactly.
pub fn check<T, G, P>(name: &str, cases: usize, seed: u64, gen: G, prop: P)
where
    T: Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> PropResult,
{
    check_shrink(name, cases, seed, gen, |_| Vec::new(), prop);
}

/// Like [`check`], but on failure greedily applies `shrink` (candidate
/// smaller inputs) while the property still fails, reporting the minimal
/// failing case found.
pub fn check_shrink<T, G, S, P>(
    name: &str,
    cases: usize,
    seed: u64,
    mut gen: G,
    mut shrink: S,
    mut prop: P,
) where
    T: Debug,
    G: FnMut(&mut Rng) -> T,
    S: FnMut(&T) -> Vec<T>,
    P: FnMut(&T) -> PropResult,
{
    let mut rng = Rng::seed_from(seed);
    for case_idx in 0..cases {
        let case = gen(&mut rng);
        if let Err(first_msg) = prop(&case) {
            // Greedy shrink: keep the first shrunk candidate that still fails.
            let mut current = case;
            let mut msg = first_msg;
            let mut budget = 200; // cap shrink steps
            'outer: while budget > 0 {
                budget -= 1;
                for cand in shrink(&current) {
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case_idx}, seed {seed}):\n  \
                 input: {current:?}\n  error: {msg}"
            );
        }
    }
}

/// Helper: assert within tolerance inside a property.
pub fn prop_close(what: &str, a: f64, b: f64, rtol: f64, atol: f64) -> PropResult {
    if crate::util::mathx::close(a, b, rtol, atol) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (rtol={rtol}, atol={atol})"))
    }
}

/// Helper: assert a boolean condition inside a property.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, 1, |r| (r.below(100), r.below(100)), |&(a, b)| {
            prop_assert(a + b == b + a, "addition must commute")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, 2, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "input: 0")]
    fn shrinking_reaches_minimal_case() {
        // Property fails for every n; shrink n -> n-1 should land on 0.
        check_shrink(
            "shrinks-to-zero",
            1,
            3,
            |r| r.below(50) + 10,
            |&n| if n > 0 { vec![n - 1, n / 2] } else { vec![] },
            |_| Err("always".into()),
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seen = Vec::new();
        check("record", 10, 7, |r| r.below(1000), |&v| {
            seen.push(v);
            Ok(())
        });
        let mut seen2 = Vec::new();
        check("record", 10, 7, |r| r.below(1000), |&v| {
            seen2.push(v);
            Ok(())
        });
        assert_eq!(seen, seen2);
    }
}
