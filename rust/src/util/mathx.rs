//! Numerically careful scalar helpers shared across the crate.

/// ln(1+x) accurate for small x (delegates to the libm-quality std impl).
#[inline]
pub fn ln1p(x: f64) -> f64 {
    x.ln_1p()
}

/// Clamp to a tiny positive floor before ln/sqrt — mirrors `_EPS` in the L2
/// python model so the native and PJRT oracles agree bit-for-bit-ish.
pub const GAIN_EPS: f64 = 1e-6;

#[inline]
pub fn floor_eps(x: f64) -> f64 {
    if x > GAIN_EPS {
        x
    } else {
        GAIN_EPS
    }
}

/// Relative difference |a-b| / max(1, |a|, |b|).
#[inline]
pub fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / 1f64.max(a.abs()).max(b.abs())
}

/// True if a and b agree to the given relative + absolute tolerance.
#[inline]
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Geometric threshold grid O = { (1+eps)^i : lo <= (1+eps)^i <= hi }.
///
/// This is the grid shared by SieveStreaming, SieveStreaming++, Salsa and
/// ThreeSieves (paper Alg. 1 line 1). Returned ascending. `lo` and `hi`
/// must be positive; the grid includes the first power >= lo and the last
/// power <= hi (with a tolerance so hi itself is kept when it is an exact
/// power).
pub fn threshold_grid(eps: f64, lo: f64, hi: f64) -> Vec<f64> {
    assert!(eps > 0.0, "threshold_grid: eps must be > 0");
    assert!(lo > 0.0 && hi > 0.0, "threshold_grid: bounds must be positive");
    if lo > hi {
        return Vec::new();
    }
    let base = 1.0 + eps;
    let i_lo = (lo.ln() / base.ln()).ceil() as i64;
    let i_hi = (hi.ln() / base.ln() * (1.0 + 1e-12)).floor() as i64;
    let mut out = Vec::with_capacity((i_hi - i_lo + 1).max(0) as usize);
    for i in i_lo..=i_hi {
        out.push(base.powi(i as i32));
    }
    out
}

/// Dot product (f32 inputs, f64 accumulation — matters for long vectors).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc
}

/// Squared euclidean distance with f64 accumulation.
#[inline]
pub fn sq_dist_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        let d = a[i] as f64 - b[i] as f64;
        acc += d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_brackets_bounds() {
        let g = threshold_grid(0.1, 1.0, 10.0);
        assert!(!g.is_empty());
        assert!(g[0] >= 1.0 - 1e-12);
        assert!(*g.last().unwrap() <= 10.0 + 1e-9);
        // ascending
        for w in g.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn grid_is_geometric() {
        let eps = 0.05;
        let g = threshold_grid(eps, 0.5, 50.0);
        for w in g.windows(2) {
            let r = w[1] / w[0];
            assert!((r - (1.0 + eps)).abs() < 1e-9);
        }
    }

    #[test]
    fn grid_matches_paper_size_estimate() {
        // |O| = O(log(K)/eps): for K*m/m = K = 100, eps = 0.01 the grid has
        // ~ log(100)/log(1.01) ≈ 463 entries.
        let g = threshold_grid(0.01, 1.0, 100.0);
        let expected = (100f64.ln() / 1.01f64.ln()).floor() as usize + 1;
        assert!((g.len() as i64 - expected as i64).abs() <= 1, "{} vs {}", g.len(), expected);
    }

    #[test]
    fn grid_empty_when_lo_above_hi() {
        assert!(threshold_grid(0.1, 5.0, 1.0).is_empty());
    }

    #[test]
    fn grid_includes_exact_hi_power() {
        // hi = (1+eps)^k exactly representable-ish: make sure it's kept.
        let eps = 1.0; // grid = powers of 2
        let g = threshold_grid(eps, 1.0, 8.0);
        assert_eq!(g.len(), 4); // 1, 2, 4, 8
        assert!((g[3] - 8.0).abs() < 1e-9);
    }

    #[test]
    fn dot_and_dist() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert!((dot_f32(&a, &b) - 32.0).abs() < 1e-9);
        assert!((sq_dist_f32(&a, &b) - 27.0).abs() < 1e-9);
    }

    #[test]
    fn floor_eps_floors() {
        assert_eq!(floor_eps(-1.0), GAIN_EPS);
        assert_eq!(floor_eps(0.5), 0.5);
    }
}
