//! Dependency-free substrates: PRNG, JSON, timing, math helpers.
//!
//! The build environment vendors only the `xla` crate closure, so the usual
//! ecosystem crates (`rand`, `serde`, `serde_json`, `criterion`, `proptest`)
//! are unavailable. Per the reproduction ground rules ("if the paper needs a
//! substrate, build it") these modules implement the pieces we need, each
//! with its own unit tests:
//!
//! * [`rng`] — xoshiro256++ PRNG with uniform / normal / categorical draws.
//! * [`json`] — minimal JSON parser + writer (artifact manifest, metrics).
//! * [`timer`] — wall-clock scopes + a tiny stats accumulator.
//! * [`mathx`] — numerically careful scalar helpers.
//! * [`proptest`] — a small seeded property-testing harness with shrinking.

pub mod json;
pub mod mathx;
pub mod proptest;
pub mod rng;
pub mod timer;
