//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), metric
//! dumps and experiment result files. Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (sufficient for our ASCII
//! manifests); numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").as_str(), Some("x"));
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b").as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"cfg":[{"d":16,"gamma":32.5,"name":"q"}],"ok":true}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn get_on_missing_returns_null() {
        let j = Json::parse("{}").unwrap();
        assert_eq!(*j.get("nope"), Json::Null);
    }

    #[test]
    fn usize_accessor_rejects_fractions() {
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("3").unwrap().as_usize(), Some(3));
    }
}
