//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every experiment in this repository is seeded, so results in
//! EXPERIMENTS.md are bit-reproducible. The generator is the reference
//! xoshiro256++ by Blackman & Vigna (public domain), which passes BigCrush
//! and is the default in several language runtimes.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box-Muller draw.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// Stateless counter mixer: the SplitMix64 finalizer over `a + b·φ`.
///
/// Unlike [`Rng`], which is sequential, `mix64(seed, index)` is a pure
/// function of its arguments — a streaming decision keyed on an element's
/// absolute stream index is therefore invariant to batch size, thread
/// count and pause/resume boundaries *by construction*. The subsampled
/// streaming wrapper ([`crate::algorithms::Subsampled`]) rests on this.
#[inline]
pub fn mix64(a: u64, b: u64) -> u64 {
    let mut z = a.wrapping_add(b.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// [`mix64`] mapped to [0, 1) with full double precision (53 high bits).
#[inline]
pub fn mix_unit(a: u64, b: u64) -> f64 {
    (mix64(a, b) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

impl Rng {
    /// Seed deterministically from a single u64 (SplitMix64 expansion).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from(self.next_u64())
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi) — panics if lo >= hi.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box-Muller (caches the second sample).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with iid N(0,1) f32 samples.
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: all-zero weights");
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::seed_from(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::seed_from(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::seed_from(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::seed_from(123);
        let mut a = parent.fork();
        let mut b = parent.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
