//! # threesieves — Very Fast Streaming Submodular Function Maximization
//!
//! A full reproduction of Buschjäger, Honysz, Pfahler & Morik (2020):
//! streaming submodular maximization with the **ThreeSieves** algorithm and
//! the complete baseline family from the paper (Greedy, Random,
//! StreamGreedy, PreemptionStreaming, IndependentSetImprovement,
//! SieveStreaming, SieveStreaming++, Salsa, QuickStream) plus the
//! competitor-field extensions StreamClipper and subsampled streaming.
//! Every algorithm is registered in [`algorithms::registry`] — the single
//! table behind config parsing, the CLI, the service OPEN grammar and the
//! experiment sweeps.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the streaming coordinator: algorithms, stream
//!   sources, batching, backpressure, drift-triggered re-selection, metrics,
//!   the experiment harness reproducing every table/figure, and the
//!   multi-tenant [`service`] (session manager + line-protocol TCP server)
//!   that hosts many independent streams per process.
//! * **L2 (`python/compile/model.py`)** — the submodular gain oracle
//!   (`Δf(e|S)` for the IVM log-determinant) as a JAX graph, AOT-lowered to
//!   HLO text at build time (`make artifacts`).
//! * **L1 (`python/compile/kernels/rbf_slab.py`)** — the RBF kernel slab as
//!   a Pallas kernel (MXU-shaped matmul decomposition), lowered into the
//!   same HLO module.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO
//! artifacts through the PJRT C API (`xla` crate) and
//! [`functions::PjrtLogDet`] exposes them behind the same
//! [`functions::SubmodularFunction`] trait as the pure-Rust
//! [`functions::NativeLogDet`] oracle.
//!
//! ## Quickstart
//!
//! ```no_run
//! use threesieves::prelude::*;
//!
//! let ds = threesieves::data::registry::get("creditfraud-like", 5_000, 42).unwrap();
//! let f = NativeLogDet::new(LogDetConfig::for_batch(ds.dim(), 20));
//! let mut algo = ThreeSieves::new(Box::new(f), 20, 0.001, SieveTuning::FixedT(1_000));
//! for row in ds.iter() {
//!     algo.process(row);
//! }
//! println!("f(S) = {}", algo.value());
//! ```

pub mod algorithms;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod experiments;
pub mod fault;
pub mod functions;
pub mod kernels;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod service;
pub mod simd;
pub mod util;

/// Convenience re-exports for the common workflow.
pub mod prelude {
    pub use crate::algorithms::registry::{AlgoSpec, ParamValue};
    pub use crate::algorithms::three_sieves::SieveTuning;
    pub use crate::algorithms::{
        Greedy, IndependentSetImprovement, PreemptionStreaming, QuickStream, RandomReservoir,
        Salsa, SieveStreaming, SieveStreamingPP, StreamClipper, StreamGreedy, StreamingAlgorithm,
        Subsampled, ThreeSieves,
    };
    pub use crate::data::{Dataset, StreamSource};
    pub use crate::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
    pub use crate::kernels::Kernel;
    pub use crate::metrics::AlgoStats;
}
