//! The persistent worker pool: std `thread` + `mpsc` only, scoped
//! fork-join calls with deterministic result ordering.
//!
//! Workers are spawned once and live for the pool's lifetime; every
//! scoped call ([`WorkerPool::map`], [`WorkerPool::for_each_mut`],
//! [`WorkerPool::run_tasks`]) injects up to `threads` jobs that drain a
//! shared atomic index counter, then blocks the caller until every job
//! has finished — so borrowed data never outlives the call, and chunk
//! after chunk reuses the same threads (no per-chunk spawn cost).
//!
//! The pool is `Sync`: multiple threads (e.g. race lanes) may issue
//! scoped calls concurrently; jobs from different scopes interleave on
//! the workers and each scope waits only on its own completion latch.
//!
//! Worker panics are caught, forwarded to the scope's caller and
//! re-raised there (`resume_unwind`), after the latch has been released —
//! a panicking task never deadlocks or poisons the pool.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of queued work (lifetime-erased; see [`WorkerPool::run_tasks`]).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Completion latch + panic slot for one scoped call.
struct Scope {
    remaining: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// Persistent worker pool (see module docs).
pub struct WorkerPool {
    injector: Sender<Job>,
    threads: usize,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` persistent workers (floored at 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("ts-exec-{i}"))
                    .spawn(move || loop {
                        // Take the next job with the receiver lock released
                        // before running it, so long jobs don't serialize
                        // the queue.
                        let job = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break, // a worker panicked holding the lock
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawn worker thread")
            })
            .collect();
        WorkerPool { injector: tx, threads, workers }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fire-and-forget: queue `job` for execution on a pool worker and
    /// return immediately. Unlike the scoped calls there is no completion
    /// latch — the job must own its data (`'static`) and the caller learns
    /// about completion through whatever channel the job itself provides.
    ///
    /// This is the service's connection-dispatch primitive: each accepted
    /// TCP connection becomes one queued job, so at most `threads()`
    /// connections are served concurrently and the rest wait in the
    /// injector queue (admission control by pool size). Panics inside the
    /// job are caught and discarded so a misbehaving connection can never
    /// kill a worker thread out from under the scoped calls.
    pub fn spawn<F>(&self, job: F)
    where
        F: FnOnce() + Send + 'static,
    {
        let guarded: Job = Box::new(move || {
            let _ = catch_unwind(AssertUnwindSafe(job));
        });
        self.injector.send(guarded).expect("worker pool has shut down");
    }

    /// Run `f(0..tasks)` across the pool and block until all calls have
    /// returned. Each index is claimed by exactly one worker; at most
    /// `threads` run concurrently. Panics inside `f` are re-raised here
    /// after every in-flight call has finished.
    ///
    /// This is the scoped core: `f` may borrow from the caller's stack
    /// because the call does not return while any job still references it.
    pub fn run_tasks<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if tasks == 0 {
            return;
        }
        if self.threads <= 1 || tasks == 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        let jobs = self.threads.min(tasks);
        let scope = Arc::new(Scope {
            remaining: Mutex::new(jobs),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        let next = Arc::new(AtomicUsize::new(0));
        // Lifetime erasure: ship `&f` as an address. Sound because this
        // function blocks on the latch below until every job that could
        // dereference it has completed (panics included — the latch is
        // decremented outside the catch).
        let f_addr = &f as *const F as usize;
        for _ in 0..jobs {
            let scope = Arc::clone(&scope);
            let next = Arc::clone(&next);
            let job: Job = Box::new(move || {
                let f = unsafe { &*(f_addr as *const F) };
                let outcome = catch_unwind(AssertUnwindSafe(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= tasks {
                        break;
                    }
                    f(i);
                }));
                if let Err(payload) = outcome {
                    if let Ok(mut slot) = scope.panic.lock() {
                        slot.get_or_insert(payload);
                    }
                }
                let mut remaining = scope.remaining.lock().expect("latch mutex");
                *remaining -= 1;
                if *remaining == 0 {
                    scope.done.notify_all();
                }
            });
            self.injector.send(job).expect("worker pool has shut down");
        }
        let mut remaining = scope.remaining.lock().expect("latch mutex");
        while *remaining > 0 {
            remaining = scope.done.wait(remaining).expect("latch wait");
        }
        drop(remaining);
        if let Some(payload) = scope.panic.lock().expect("panic slot").take() {
            resume_unwind(payload);
        }
    }

    /// Apply `f` to every item of `items` in parallel and return the
    /// results **in item order** (deterministic regardless of completion
    /// order). Each item is handed to exactly one task, which gets
    /// exclusive `&mut` access.
    pub fn map<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let items_addr = items.as_mut_ptr() as usize;
        let out_addr = out.as_mut_ptr() as usize;
        self.run_tasks(n, |i| {
            // SAFETY: run_tasks hands each index to exactly one task, so
            // the `&mut` derived from base+offset is exclusive; both
            // buffers outlive the blocking run_tasks call.
            let item = unsafe { &mut *(items_addr as *mut T).add(i) };
            let slot = unsafe { &mut *(out_addr as *mut Option<R>).add(i) };
            *slot = Some(f(i, item));
        });
        out.into_iter().map(|r| r.expect("every index ran")).collect()
    }

    /// [`map`](Self::map) without results: mutate every item in place.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let items_addr = items.as_mut_ptr() as usize;
        self.run_tasks(items.len(), |i| {
            // SAFETY: as in `map` — exclusive index, outlived borrow.
            let item = unsafe { &mut *(items_addr as *mut T).add(i) };
            f(i, item);
        });
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Replace the injector with a dead channel so workers' `recv`
        // errors out, then join them.
        let (dead, _) = channel();
        self.injector = dead;
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_preserves_order() {
        let pool = WorkerPool::new(4);
        let mut items: Vec<usize> = (0..100).collect();
        let out = pool.map(&mut items, |i, v| {
            assert_eq!(i, *v);
            *v * 2
        });
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn for_each_mut_mutates_every_item() {
        let pool = WorkerPool::new(3);
        let mut items = vec![0u64; 57];
        pool.for_each_mut(&mut items, |i, v| *v = i as u64 + 1);
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, i as u64 + 1);
        }
    }

    #[test]
    fn pool_is_reused_across_calls() {
        let pool = WorkerPool::new(2);
        let hits = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run_tasks(8, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 400);
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn zero_and_one_tasks_run_inline() {
        let pool = WorkerPool::new(4);
        pool.run_tasks(0, |_| panic!("must not run"));
        let mut ran = vec![false];
        pool.for_each_mut(&mut ran, |_, v| *v = true);
        assert!(ran[0]);
    }

    #[test]
    fn single_thread_pool_degrades_to_inline() {
        let pool = WorkerPool::new(1);
        let mut items = vec![1u32, 2, 3];
        let out = pool.map(&mut items, |_, v| *v + 10);
        assert_eq!(out, vec![11, 12, 13]);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run_tasks(4, |i| {
                if i == 2 {
                    panic!("task 2 exploded");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        // The pool keeps working after a panicked scope.
        let mut items = vec![0usize; 8];
        pool.for_each_mut(&mut items, |i, v| *v = i);
        assert_eq!(items[7], 7);
    }

    #[test]
    fn spawn_runs_detached_jobs_and_survives_panics() {
        use std::sync::mpsc::channel;
        let pool = WorkerPool::new(2);
        let (tx, rx) = channel();
        // A panicking detached job must not take a worker down...
        pool.spawn(|| panic!("connection handler exploded"));
        for i in 0..8 {
            let tx = tx.clone();
            pool.spawn(move || tx.send(i).unwrap());
        }
        let mut got: Vec<i32> = (0..8).map(|_| rx.recv().unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
        // ...and the scoped calls still work afterwards.
        let mut items = vec![0usize; 4];
        pool.for_each_mut(&mut items, |i, v| *v = i + 1);
        assert_eq!(items, vec![1, 2, 3, 4]);
    }

    #[test]
    fn concurrent_scopes_from_multiple_threads() {
        let pool = Arc::new(WorkerPool::new(4));
        let total = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                std::thread::spawn(move || {
                    for _ in 0..20 {
                        pool.run_tasks(5, |i| {
                            total.fetch_add(i as u64, Ordering::Relaxed);
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 3 threads × 20 scopes × (0+1+2+3+4)
        assert_eq!(total.load(Ordering::Relaxed), 3 * 20 * 10);
    }

    #[test]
    fn borrowed_state_is_safe_across_the_scope() {
        // The scoped contract: tasks may borrow caller-stack data.
        let pool = WorkerPool::new(4);
        let base: Vec<u64> = (0..64).collect();
        let mut sums = vec![0u64; 16];
        pool.for_each_mut(&mut sums, |i, out| {
            *out = base[i * 4..(i + 1) * 4].iter().sum();
        });
        let total: u64 = sums.iter().sum();
        assert_eq!(total, (0..64).sum::<u64>());
    }
}
