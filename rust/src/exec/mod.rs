//! Parallel execution subsystem: a dependency-free (std `thread` +
//! channels) persistent worker pool driving the layers whose work
//! decomposes into independent coarse units — ShardedThreeSieves shards,
//! SieveStreaming/Salsa sieves, race lanes, the shared kernel-panel
//! broker's row-ranges (`NativeLogDet::build_chunk_panel` splits each
//! chunk panel into several ranges per worker — finer than the
//! one-chunk×unit granularity of the sieve fan-out, so fast workers pick
//! up the tail instead of idling), and the 2-D (unit × candidate-range)
//! solve grid (`crate::algorithms::offer_chunk_grid` and friends split
//! each rejection run's blocked solves into candidate ranges when live
//! units cannot occupy the pool).
//!
//! ## Determinism contract
//!
//! The pool only changes *where* a unit of work runs, never *what* it
//! computes or in what per-unit order results are folded:
//!
//! * [`WorkerPool::map`] / [`WorkerPool::for_each_mut`] hand each slice
//!   index to exactly one task and return results **in index order**,
//!   regardless of which worker finished first.
//! * Each unit (one shard, one sieve) evolves exactly the state it owns,
//!   with the same floating-point instruction sequence as the sequential
//!   loop — so summaries, objective values and per-element query counts
//!   are bit-identical at every thread count, including `off`
//!   (`rust/tests/exec_parity.rs` pins this).
//!
//! ## Thread-safety contract
//!
//! [`SubmodularFunction`](crate::functions::SubmodularFunction) is
//! deliberately not `Send` (the PJRT oracle shares an `Rc`'d engine
//! between clones). Algorithms therefore gate the parallel path on
//! [`SubmodularFunction::parallel_safe`](crate::functions::SubmodularFunction::parallel_safe)
//! — a per-implementation promise that instances may be *moved* between
//! threads for the duration of a scoped pool call, enforced once in
//! [`ExecContext::gated`] — and cross the `Send` boundary only inside
//! [`ExecContext::map_units`], the crate's single audited erasure site
//! (the private `AssertThreadSafe` wrapper). Oracles that cannot make
//! the promise (PJRT) simply keep the sequential path; no configuration
//! can force them onto the pool.

pub mod pool;

pub use pool::WorkerPool;

use std::sync::Arc;

use crate::functions::SubmodularFunction;

/// How many worker threads the execution layer may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Parallelism {
    /// Sequential execution on the calling thread (the default).
    #[default]
    Off,
    /// One worker per available hardware thread.
    Auto,
    /// Exactly `n` workers (`0` and `1` degrade to [`Parallelism::Off`]).
    Threads(usize),
}

impl Parallelism {
    /// The worker-thread count this setting resolves to (`<= 1` means no
    /// pool is built and everything runs inline).
    pub fn resolve(&self) -> usize {
        match *self {
            Parallelism::Off => 1,
            Parallelism::Auto => {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            }
            Parallelism::Threads(n) => n.max(1),
        }
    }

    /// Parse a CLI/config value: `off` | `auto` | a thread count.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim() {
            "off" | "0" | "1" => Ok(Parallelism::Off),
            "auto" => Ok(Parallelism::Auto),
            n => n
                .parse::<usize>()
                .map(|n| if n <= 1 { Parallelism::Off } else { Parallelism::Threads(n) })
                .map_err(|_| format!("bad parallelism {s:?}: expected off|auto|<threads>")),
        }
    }
}

impl std::str::FromStr for Parallelism {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Parallelism::parse(s)
    }
}

impl std::fmt::Display for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Parallelism::Off => write!(f, "off"),
            Parallelism::Auto => write!(f, "auto"),
            Parallelism::Threads(n) => write!(f, "{n}"),
        }
    }
}

/// A shareable handle to the execution layer: either sequential or a
/// reference-counted [`WorkerPool`] that persists across chunks (and is
/// shared between race lanes). Cloning shares the same pool.
#[derive(Clone, Default)]
pub struct ExecContext {
    pool: Option<Arc<WorkerPool>>,
}

impl ExecContext {
    /// Sequential execution (no pool, no threads).
    pub fn sequential() -> Self {
        ExecContext { pool: None }
    }

    /// Build a context for `par`; `off`/1 thread stays sequential.
    pub fn new(par: Parallelism) -> Self {
        let threads = par.resolve();
        if threads <= 1 {
            Self::sequential()
        } else {
            ExecContext { pool: Some(Arc::new(WorkerPool::new(threads))) }
        }
    }

    /// This context, demoted to sequential unless `oracle` promises
    /// [`parallel_safe`](SubmodularFunction::parallel_safe).
    ///
    /// The single implementation of the thread-safety gate the pool's
    /// `Send` erasure depends on: every
    /// [`StreamingAlgorithm::set_exec`](crate::algorithms::StreamingAlgorithm::set_exec)
    /// override routes the incoming context through this before storing
    /// it, so an algorithm holding a pool-backed context is proof its
    /// oracle family opted in (native oracles do; PJRT does not and stays
    /// sequential regardless of configuration).
    #[must_use]
    pub fn gated(self, oracle: &dyn SubmodularFunction) -> ExecContext {
        if oracle.parallel_safe() {
            self
        } else {
            ExecContext::sequential()
        }
    }

    /// The pool, if parallel execution is on *and* there are at least two
    /// units to fan out (a single unit always runs inline).
    pub fn pool(&self, units: usize) -> Option<&WorkerPool> {
        if units < 2 {
            return None;
        }
        self.pool.as_deref()
    }

    /// Run `f` over every unit — on the pool's worker threads when one is
    /// attached (and there are at least two units), inline otherwise —
    /// returning the results **in unit order** either way.
    ///
    /// This is the crate's single audited `Send`-erasure site: units are
    /// wrapped in the private `AssertThreadSafe` here and nowhere else,
    /// and the method is deliberately `pub(crate)` so external code
    /// cannot reach it with units that were never vetted. The contract is
    /// that a context holding a pool was routed through
    /// [`gated`](Self::gated) — every `set_exec` override does — so the
    /// units being moved hold only oracles that promised
    /// [`parallel_safe`](SubmodularFunction::parallel_safe).
    pub(crate) fn map_units<T, R, F>(&self, units: &mut [T], f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(&mut T) -> R + Sync,
    {
        if crate::obs::enabled() {
            static UNITS: std::sync::OnceLock<Arc<crate::obs::Counter>> =
                std::sync::OnceLock::new();
            UNITS.get_or_init(|| crate::obs::counter("exec.units")).add(units.len() as u64);
        }
        match self.pool(units.len()) {
            Some(pool) => {
                let mut work: Vec<AssertThreadSafe<&mut T>> =
                    units.iter_mut().map(AssertThreadSafe).collect();
                pool.map(&mut work, |_, unit| f(&mut *unit.0))
            }
            None => units.iter_mut().map(f).collect(),
        }
    }

    /// A shared handle to the underlying pool, if one is attached. The
    /// service's accept loop uses this to [`WorkerPool::spawn`] detached
    /// connection handlers; `None` means the caller should fall back to
    /// dedicated threads (or inline execution).
    pub fn pool_handle(&self) -> Option<Arc<WorkerPool>> {
        self.pool.clone()
    }

    /// Worker-thread count (1 = sequential).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.threads()).unwrap_or(1)
    }

    /// True when a pool is attached.
    pub fn is_parallel(&self) -> bool {
        self.pool.is_some()
    }
}

impl std::fmt::Debug for ExecContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ExecContext(threads={})", self.threads())
    }
}

/// Asserts that the wrapped value may cross a thread boundary for the
/// duration of one scoped pool call even though its type is not `Send`.
///
/// Private on purpose: [`ExecContext::map_units`] is the only
/// construction site, so the soundness argument lives in exactly one
/// audited place. Algorithm sub-units (shards, sieves) hold
/// `Box<dyn SubmodularFunction>`, which is not `Send` because the PJRT
/// oracle shares `Rc`'d state between clones; wrapping is sound only for
/// units whose oracle returned
/// [`parallel_safe()`](SubmodularFunction::parallel_safe) `== true` —
/// i.e. plain owned data that tolerates being *used* from another thread
/// while no other thread mutates it — which [`ExecContext::gated`]
/// enforces before a pool ever reaches an algorithm. The scoped pool
/// calls guarantee exclusive `&mut` access per task and completion
/// before returning, so no wrapped value ever outlives its borrow.
///
/// Two aliasing regimes ride on this one argument: the coarse unit
/// fan-out (each task exclusively owns its unit, nothing is shared) and
/// the 2-D solve grid, whose tasks share one unit's oracle by `&`
/// (several candidate-ranges read the same factor concurrently through
/// the pure `solve_*_range` methods) while every `&mut` — gains slice,
/// solve scratch — is disjoint per task. Shared `&` reads of a
/// `parallel_safe` oracle are race-free by the same promise: plain owned
/// data with no interior mutability outside the row store's `Mutex`.
struct AssertThreadSafe<T>(T);

// SAFETY: see the type-level docs — `map_units` only runs over units
// vetted by the `gated`/`parallel_safe` contract, and the pool's scoped
// calls give each wrapped value to exactly one task at a time.
unsafe impl<T> Send for AssertThreadSafe<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelism_parses() {
        assert_eq!(Parallelism::parse("off").unwrap(), Parallelism::Off);
        assert_eq!(Parallelism::parse("auto").unwrap(), Parallelism::Auto);
        assert_eq!(Parallelism::parse("4").unwrap(), Parallelism::Threads(4));
        assert_eq!(Parallelism::parse("1").unwrap(), Parallelism::Off);
        assert_eq!(Parallelism::parse("0").unwrap(), Parallelism::Off);
        assert!(Parallelism::parse("lots").is_err());
    }

    #[test]
    fn resolve_floors_at_one() {
        assert_eq!(Parallelism::Off.resolve(), 1);
        assert_eq!(Parallelism::Threads(0).resolve(), 1);
        assert_eq!(Parallelism::Threads(3).resolve(), 3);
        assert!(Parallelism::Auto.resolve() >= 1);
    }

    #[test]
    fn display_roundtrips() {
        for p in [Parallelism::Off, Parallelism::Auto, Parallelism::Threads(8)] {
            assert_eq!(Parallelism::parse(&p.to_string()).unwrap(), p);
        }
    }

    #[test]
    fn sequential_context_has_no_pool() {
        let ctx = ExecContext::sequential();
        assert!(!ctx.is_parallel());
        assert_eq!(ctx.threads(), 1);
        assert!(ctx.pool(100).is_none());
        let ctx = ExecContext::new(Parallelism::Off);
        assert!(!ctx.is_parallel());
    }

    #[test]
    fn parallel_context_gates_on_unit_count() {
        let ctx = ExecContext::new(Parallelism::Threads(2));
        assert!(ctx.is_parallel());
        assert_eq!(ctx.threads(), 2);
        assert!(ctx.pool(0).is_none(), "no units, no fan-out");
        assert!(ctx.pool(1).is_none(), "one unit runs inline");
        assert!(ctx.pool(2).is_some());
    }

    /// Minimal oracle that leaves `parallel_safe` at the trait default
    /// (`false`) — stands in for thread-confined backends like PJRT.
    struct SequentialOnly;

    impl SubmodularFunction for SequentialOnly {
        fn dim(&self) -> usize {
            1
        }

        fn len(&self) -> usize {
            0
        }

        fn current_value(&self) -> f64 {
            0.0
        }

        fn max_singleton_value(&self) -> f64 {
            0.0
        }

        fn peek_gain(&mut self, _item: &[f32]) -> f64 {
            0.0
        }

        fn accept(&mut self, _item: &[f32]) {}

        fn remove(&mut self, _idx: usize) {}

        fn summary(&self) -> &[f32] {
            &[]
        }

        fn reset(&mut self) {}

        fn queries(&self) -> u64 {
            0
        }

        fn clone_empty(&self) -> Box<dyn SubmodularFunction> {
            Box::new(SequentialOnly)
        }
    }

    #[test]
    fn gated_demotes_unless_oracle_opts_in() {
        use crate::functions::{LogDetConfig, NativeLogDet};
        let native = NativeLogDet::new(LogDetConfig::with_gamma(2, 2, 1.0, 1.0));
        let kept = ExecContext::new(Parallelism::Threads(2)).gated(&native);
        assert!(kept.is_parallel(), "native oracle opts in");
        let demoted = ExecContext::new(Parallelism::Threads(2)).gated(&SequentialOnly);
        assert!(!demoted.is_parallel(), "trait-default parallel_safe=false must demote");
    }

    #[test]
    fn map_units_parallel_matches_inline() {
        let seq = ExecContext::sequential();
        let par = ExecContext::new(Parallelism::Threads(3));
        let mut a: Vec<u64> = (0..20).collect();
        let mut b = a.clone();
        let f = |v: &mut u64| {
            *v += 1;
            *v * 2
        };
        let ra = seq.map_units(&mut a, f);
        let rb = par.map_units(&mut b, f);
        assert_eq!(ra, rb, "results in unit order on both paths");
        assert_eq!(a, b, "mutations applied on both paths");
    }

    #[test]
    fn clones_share_the_pool() {
        let ctx = ExecContext::new(Parallelism::Threads(2));
        let clone = ctx.clone();
        assert!(std::ptr::eq(ctx.pool(2).unwrap(), clone.pool(2).unwrap()));
    }
}
