//! Experiment configuration: which algorithms, datasets and parameter grids
//! an experiment driver should sweep. JSON-backed (see `util::json`) so
//! configs can be checked into `configs/` and passed via `--config`.
//!
//! Algorithm selection is registry-backed: [`AlgoSpec`] is the single
//! table-driven spec from [`crate::algorithms::registry`], so the config
//! parser, CLI, service wire protocol and sweep expansion all accept the
//! same names and typed parameters.

use std::path::Path;

use crate::exec::Parallelism;
use crate::simd::BackendChoice;
use crate::util::json::{Json, JsonError};

pub use crate::algorithms::registry::{AlgoSpec, ParamValue};

/// Parse an optional `"kernel_backend": "scalar"|"simd"|"auto"` field —
/// shared by [`ExperimentConfig`] and [`ServiceConfig`] so the accepted
/// strings cannot drift from [`BackendChoice::parse`]. `None` means the
/// config leaves the choice to the CLI flag / `TS_KERNEL_BACKEND` env
/// var (every backend is bitwise identical — see [`crate::simd`]).
fn kernel_backend_field(j: &Json) -> Result<Option<BackendChoice>, String> {
    match j.get("kernel_backend").as_str() {
        None => Ok(None),
        Some(s) => BackendChoice::parse(s)
            .map(Some)
            .ok_or_else(|| format!("kernel_backend = {s:?}: expected scalar|simd|auto")),
    }
}

/// A full experiment sweep description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub datasets: Vec<String>,
    /// Stream length per dataset (surrogate size).
    pub n: usize,
    pub ks: Vec<usize>,
    pub epsilons: Vec<f64>,
    pub ts: Vec<usize>,
    pub seed: u64,
    pub algos: Vec<AlgoSpec>,
    /// Stream chunk size for batched ingestion (1 = per-item processing).
    /// Semantics-preserving — see `StreamingAlgorithm::process_batch`.
    pub batch_size: usize,
    /// Worker threads for shard/sieve fan-out (`"off"` | `"auto"` | n).
    /// Results are bit-identical at every setting — see [`crate::exec`].
    pub parallelism: Parallelism,
    /// Kernel/solve SIMD backend (`"scalar"` | `"simd"` | `"auto"`);
    /// `None` defers to `TS_KERNEL_BACKEND`, then auto-detection.
    /// Results are bit-identical under every backend — see [`crate::simd`].
    pub kernel_backend: Option<BackendChoice>,
    /// Output directory for CSV/JSON results.
    pub out_dir: String,
}

/// Multi-tenant streaming service limits and knobs (see
/// [`crate::service`]). JSON-loadable alongside [`ExperimentConfig`] so a
/// deployment can be checked into `configs/` and passed to `serve`.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Admission control: maximum concurrently open sessions.
    pub max_sessions: usize,
    /// Admission control: cap on the total stored-element *reservation*,
    /// Σ K over open sessions — each session's memory contract is at most
    /// `K` stored elements (`K·d` f32s), so this bounds worst-case service
    /// memory regardless of how full individual summaries are.
    pub max_total_stored: usize,
    /// Sessions idle longer than this are checkpoint-evicted by the LRU
    /// sweep (zero disables idle eviction).
    pub idle_timeout: std::time::Duration,
    /// Where evicted/closed sessions persist their checkpoints (`<id>.ckpt`
    /// per session); `None` disables persistence — eviction then discards.
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Connection-handler fan-out: the accept loop dispatches each
    /// connection onto this worker pool (`off` = one dedicated thread per
    /// connection instead).
    pub parallelism: Parallelism,
    /// Kernel/solve SIMD backend (`"scalar"` | `"simd"` | `"auto"`);
    /// `None` defers to `TS_KERNEL_BACKEND`, then auto-detection.
    /// Summaries are bit-identical under every backend — see
    /// [`crate::simd`].
    pub kernel_backend: Option<BackendChoice>,
    /// Deterministic fault-injection schedule for chaos drills, in the
    /// [`crate::fault::FaultPlan::parse`] spec grammar (e.g.
    /// `"checkpoint.write=torn:32@2;conn.read=reset@5"`). Validated at
    /// config load; armed by the `serve` CLI before the listener starts.
    /// `None` (the default) leaves injection disarmed — the hot path then
    /// pays a single relaxed atomic load per site.
    pub fault_spec: Option<String>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_sessions: 1024,
            max_total_stored: 1 << 20,
            idle_timeout: std::time::Duration::from_secs(300),
            checkpoint_dir: None,
            parallelism: Parallelism::Off,
            kernel_backend: None,
            fault_spec: None,
        }
    }
}

impl ServiceConfig {
    pub fn from_json_text(text: &str) -> Result<Self, String> {
        let j = Json::parse(text).map_err(|e: JsonError| e.to_string())?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let d = ServiceConfig::default();
        let idle_timeout = match j.get("idle_timeout_s").as_f64() {
            // try_from_secs_f64 rejects negative/NaN/overflowing values
            // instead of panicking like from_secs_f64 does.
            Some(s) => std::time::Duration::try_from_secs_f64(s)
                .map_err(|e| format!("idle_timeout_s = {s}: {e}"))?,
            None => d.idle_timeout,
        };
        let pj = j.get("parallelism");
        let parallelism = if let Some(s) = pj.as_str() {
            Parallelism::parse(s)?
        } else if let Some(n) = pj.as_usize() {
            Parallelism::parse(&n.to_string())?
        } else {
            d.parallelism
        };
        // Reject a bad schedule at load time, not at the first injected
        // fault hours into a chaos drill.
        let fault_spec = match j.get("fault_spec").as_str() {
            Some(s) => {
                crate::fault::FaultPlan::parse(s).map_err(|e| format!("fault_spec: {e}"))?;
                Some(s.to_string())
            }
            None => None,
        };
        Ok(ServiceConfig {
            max_sessions: j.get("max_sessions").as_usize().unwrap_or(d.max_sessions).max(1),
            max_total_stored: j
                .get("max_total_stored")
                .as_usize()
                .unwrap_or(d.max_total_stored)
                .max(1),
            idle_timeout,
            checkpoint_dir: j.get("checkpoint_dir").as_str().map(std::path::PathBuf::from),
            parallelism,
            kernel_backend: kernel_backend_field(j)?,
            fault_spec,
        })
    }
}

impl ExperimentConfig {
    pub fn from_json_text(text: &str) -> Result<Self, String> {
        let j = Json::parse(text).map_err(|e: JsonError| e.to_string())?;
        let strs = |key: &str| -> Vec<String> {
            j.get(key)
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default()
        };
        let nums = |key: &str| -> Vec<f64> {
            j.get(key)
                .as_arr()
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default()
        };
        let algos = match j.get("algos").as_arr() {
            Some(arr) => arr.iter().map(AlgoSpec::from_json).collect::<Result<Vec<_>, _>>()?,
            None => Vec::new(),
        };
        // "parallelism": "off" | "auto" | "4" | 4 (number form accepted).
        let pj = j.get("parallelism");
        let parallelism = if let Some(s) = pj.as_str() {
            Parallelism::parse(s)?
        } else if let Some(n) = pj.as_usize() {
            Parallelism::parse(&n.to_string())?
        } else {
            Parallelism::Off
        };
        Ok(ExperimentConfig {
            name: j.get("name").as_str().unwrap_or("experiment").to_string(),
            datasets: strs("datasets"),
            n: j.get("n").as_usize().unwrap_or(10_000),
            ks: nums("ks").into_iter().map(|v| v as usize).collect(),
            epsilons: nums("epsilons"),
            ts: nums("ts").into_iter().map(|v| v as usize).collect(),
            seed: j.get("seed").as_f64().unwrap_or(42.0) as u64,
            algos,
            batch_size: j.get("batch_size").as_usize().unwrap_or(1).max(1),
            parallelism,
            kernel_backend: kernel_backend_field(&j)?,
            out_dir: j.get("out_dir").as_str().unwrap_or("results").to_string(),
        })
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_json_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::from_json_text(
            r#"{
              "name": "fig2",
              "datasets": ["forestcover-like", "kddcup-like"],
              "n": 5000,
              "ks": [5, 10, 20],
              "epsilons": [0.001],
              "ts": [500, 1000],
              "seed": 7,
              "out_dir": "results/fig2",
              "algos": [
                {"algo": "greedy"},
                {"algo": "three-sieves", "epsilon": 0.001, "t": 500},
                {"algo": "salsa", "epsilon": 0.001},
                {"algo": "quickstream", "c": 4},
                {"algo": "stream-clipper", "clipper_alpha": 1.0},
                {"algo": "subsampled-three-sieves", "subsample_p": 0.25}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "fig2");
        assert_eq!(cfg.datasets.len(), 2);
        assert_eq!(cfg.ks, vec![5, 10, 20]);
        assert_eq!(cfg.algos.len(), 6);
        assert_eq!(cfg.algos[1].id(), "three-sieves-t500");
        assert_eq!(cfg.algos[3].id(), "quickstream-c4");
        assert_eq!(cfg.algos[4].id(), "stream-clipper");
        assert_eq!(cfg.algos[5].num("subsample_p"), 0.25);
    }

    #[test]
    fn unknown_algo_rejected() {
        let err = ExperimentConfig::from_json_text(
            r#"{"algos": [{"algo": "magic"}]}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown algo"));
    }

    #[test]
    fn mistyped_algo_param_rejected_with_field_name() {
        // Pre-registry, "nu": "abc" silently became the 1e-4 default.
        let err = ExperimentConfig::from_json_text(
            r#"{"algos": [{"algo": "stream-greedy", "nu": "abc"}]}"#,
        )
        .unwrap_err();
        assert!(err.contains("nu"), "error must name the field: {err}");
    }

    #[test]
    fn defaults_are_sane() {
        let cfg = ExperimentConfig::from_json_text("{}").unwrap();
        assert_eq!(cfg.n, 10_000);
        assert_eq!(cfg.seed, 42);
        assert!(cfg.algos.is_empty());
        assert_eq!(cfg.batch_size, 1);
    }

    #[test]
    fn parallelism_parses_all_forms() {
        let cfg = ExperimentConfig::from_json_text(r#"{"parallelism": "auto"}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Auto);
        let cfg = ExperimentConfig::from_json_text(r#"{"parallelism": "4"}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Threads(4));
        let cfg = ExperimentConfig::from_json_text(r#"{"parallelism": 4}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Threads(4));
        let cfg = ExperimentConfig::from_json_text(r#"{"parallelism": "off"}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Off);
        let cfg = ExperimentConfig::from_json_text("{}").unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Off);
        assert!(ExperimentConfig::from_json_text(r#"{"parallelism": "many"}"#).is_err());
    }

    #[test]
    fn batch_size_parses_and_floors_at_one() {
        let cfg = ExperimentConfig::from_json_text(r#"{"batch_size": 64}"#).unwrap();
        assert_eq!(cfg.batch_size, 64);
        let cfg = ExperimentConfig::from_json_text(r#"{"batch_size": 0}"#).unwrap();
        assert_eq!(cfg.batch_size, 1);
    }

    #[test]
    fn service_config_defaults_and_parsing() {
        let d = ServiceConfig::default();
        assert_eq!(d.max_sessions, 1024);
        assert!(d.checkpoint_dir.is_none());
        let cfg = ServiceConfig::from_json_text(
            r#"{
              "max_sessions": 8,
              "max_total_stored": 256,
              "idle_timeout_s": 1.5,
              "checkpoint_dir": "/tmp/svc",
              "parallelism": 4
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.max_sessions, 8);
        assert_eq!(cfg.max_total_stored, 256);
        assert!((cfg.idle_timeout.as_secs_f64() - 1.5).abs() < 1e-9);
        assert_eq!(cfg.checkpoint_dir.as_deref(), Some(std::path::Path::new("/tmp/svc")));
        assert_eq!(cfg.parallelism, Parallelism::Threads(4));
        assert!(ServiceConfig::from_json_text(r#"{"idle_timeout_s": -1}"#).is_err());
        // Finite-but-overflowing values must error, not panic.
        assert!(ServiceConfig::from_json_text(r#"{"idle_timeout_s": 1e30}"#).is_err());
        // Zero caps floor at one (a service with no admissible session is
        // a config error, not a valid deployment).
        let cfg = ServiceConfig::from_json_text(r#"{"max_sessions": 0}"#).unwrap();
        assert_eq!(cfg.max_sessions, 1);
    }

    #[test]
    fn fault_spec_validates_at_load_time() {
        assert_eq!(ServiceConfig::default().fault_spec, None);
        let cfg = ServiceConfig::from_json_text(
            r#"{"fault_spec": "checkpoint.write=torn:32@2;conn.read=reset@5"}"#,
        )
        .unwrap();
        assert_eq!(
            cfg.fault_spec.as_deref(),
            Some("checkpoint.write=torn:32@2;conn.read=reset@5")
        );
        let err = ServiceConfig::from_json_text(r#"{"fault_spec": "nowhere=explode"}"#)
            .unwrap_err();
        assert!(err.contains("fault_spec"), "{err}");
    }

    #[test]
    fn kernel_backend_parses_and_rejects_unknown() {
        let cfg = ExperimentConfig::from_json_text(r#"{"kernel_backend": "scalar"}"#).unwrap();
        assert_eq!(cfg.kernel_backend, Some(BackendChoice::Scalar));
        let cfg = ExperimentConfig::from_json_text(r#"{"kernel_backend": "simd"}"#).unwrap();
        assert_eq!(cfg.kernel_backend, Some(BackendChoice::Simd));
        let cfg = ExperimentConfig::from_json_text("{}").unwrap();
        assert_eq!(cfg.kernel_backend, None);
        let err =
            ExperimentConfig::from_json_text(r#"{"kernel_backend": "avx512"}"#).unwrap_err();
        assert!(err.contains("kernel_backend"), "{err}");

        let cfg = ServiceConfig::from_json_text(r#"{"kernel_backend": "auto"}"#).unwrap();
        assert_eq!(cfg.kernel_backend, Some(BackendChoice::Auto));
        assert_eq!(ServiceConfig::default().kernel_backend, None);
        assert!(ServiceConfig::from_json_text(r#"{"kernel_backend": "mmx"}"#).is_err());
    }

    #[test]
    fn algo_spec_roundtrip_ids() {
        let specs = [
            AlgoSpec::greedy(),
            AlgoSpec::three_sieves(0.01, 2500),
            AlgoSpec::sieve_streaming_pp(0.1),
        ];
        let ids: Vec<String> = specs.iter().map(|s| s.id()).collect();
        assert_eq!(ids, vec!["greedy", "three-sieves-t2500", "sieve-streaming-pp"]);
    }
}
