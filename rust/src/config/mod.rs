//! Experiment and service configuration (JSON-backed).

pub mod experiment;

pub use experiment::{AlgoSpec, ExperimentConfig, ParamValue, ServiceConfig};
