//! Deterministic fault injection (PR 10): make IO, peers and tenants
//! *fail on schedule* so the hardening layers can be proven, not hoped.
//!
//! The service's robustness story (crash-safe checkpoints, session
//! quarantine, client retry — see `docs/robustness.md`) is only credible
//! if the failure paths actually run under test. This module plants named
//! **fault sites** on the hot paths (checkpoint write/rename/load,
//! connection read/write, PUSH ingestion, the session handler) and lets a
//! seeded [`FaultPlan`] force a typed [`FaultKind`] at deterministic hit
//! counts: IO errors, short/torn writes, connection resets, slow reads,
//! oracle-poisoning non-finite values, handler panics.
//!
//! Gating mirrors [`crate::obs`] exactly: one process-wide relaxed
//! [`AtomicBool`]. Disarmed — the production default — every
//! [`check`] is a single relaxed load and an immediate return; no lock,
//! no string compare, no counter. `benches/micro_hotpath.rs
//! --fault-json` pins the disarmed PUSH path within the same ≤ 1.03
//! overhead gate as `obs_overhead`. Armed, [`check`] takes the plan lock
//! (the chaos path does not care about nanoseconds) and consults each
//! rule for the site in plan order.
//!
//! Determinism: a rule fires on *hit counts*, not clocks — `after` skips
//! the first N hits, `every` fires each Mth hit after that, `count` caps
//! total injections; the seeded mode drives the decision from a per-rule
//! LCG advanced once per hit, so a given `(seed, hit sequence)` always
//! yields the same schedule. Under a single-threaded driver the whole
//! fault schedule is a pure function of the request sequence — which is
//! what lets the chaos suite demand *bit-identical* surviving sessions.
//!
//! Arming is process-global (like the obs toggle): tests that arm plans
//! must serialize on a shared lock and disarm when done.

use std::io;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// The named fault sites this crate plants. A [`FaultPlan`] rule may name
/// any string, but only these are consulted anywhere.
pub mod site {
    /// Checkpoint staging-file write (`.ckpt.tmp` body + sync).
    pub const CKPT_WRITE: &str = "checkpoint.write";
    /// Checkpoint publish rename (`.tmp` → `.ckpt`).
    pub const CKPT_RENAME: &str = "checkpoint.rename";
    /// Checkpoint file read-back.
    pub const CKPT_LOAD: &str = "checkpoint.load";
    /// Server side, one hit per complete request line received.
    pub const CONN_READ: &str = "conn.read";
    /// Server side, one hit per reply line written.
    pub const CONN_WRITE: &str = "conn.write";
    /// PUSH ingestion, one hit per batch, before validation — `nan`
    /// poisons the decoded rows so the non-finite policy is exercised.
    pub const PUSH_ROWS: &str = "push.rows";
    /// Inside the per-session handler, under the session lock — `panic`
    /// here proves the quarantine path.
    pub const SESSION_HANDLER: &str = "session.handler";

    /// Every site the crate consults, for docs and validation.
    pub const ALL: [&str; 7] =
        [CKPT_WRITE, CKPT_RENAME, CKPT_LOAD, CONN_READ, CONN_WRITE, PUSH_ROWS, SESSION_HANDLER];
}

/// What a firing rule forces at its site. Sites ignore kinds they cannot
/// express (e.g. `TornWrite` at a read site injects nothing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// A generic `io::Error` (kind `Other`, tagged [`INJECTED_MSG`]).
    IoError,
    /// Write only the first `bytes` bytes, sync them, then fail — the
    /// torn prefix stays on disk exactly as a mid-write crash leaves it.
    TornWrite { bytes: usize },
    /// `io::ErrorKind::ConnectionReset` — the peer vanished.
    ConnReset,
    /// Stall the site for `ms` milliseconds before proceeding normally.
    SlowRead { ms: u64 },
    /// Poison decoded f32 input with a NaN before validation.
    PoisonNan,
    /// Panic at the site (the session handler catches and quarantines).
    Panic,
}

/// When a rule fires, as a function of its per-rule hit counter.
#[derive(Clone, Copy, Debug)]
enum When {
    /// Skip `after` hits, then fire every `every`th hit, at most `count`
    /// times total.
    Nth { after: u64, every: u64, count: u64 },
    /// Per-hit coin from a rule-local LCG: fires when the draw lands on
    /// `0 (mod period)`, at most `count` times. Same seed + same hit
    /// sequence ⇒ same schedule.
    Seeded { period: u64, count: u64 },
}

/// One (site, kind, schedule) entry of a [`FaultPlan`].
#[derive(Debug)]
pub struct FaultRule {
    site: String,
    kind: FaultKind,
    when: When,
    /// Hits checked against this rule (1-based in the firing math).
    hits: AtomicU64,
    /// Times this rule actually injected.
    fired: AtomicU64,
    /// Seeded-mode generator state.
    lcg: AtomicU64,
}

const LCG_MUL: u64 = 6364136223846793005;
const LCG_INC: u64 = 1442695040888963407;

impl FaultRule {
    fn new(site: &str, kind: FaultKind, when: When, seed: u64) -> FaultRule {
        FaultRule {
            site: site.to_string(),
            kind,
            when,
            hits: AtomicU64::new(0),
            fired: AtomicU64::new(0),
            lcg: AtomicU64::new(seed),
        }
    }

    /// Count one hit and decide whether this rule injects on it.
    fn fire(&self) -> bool {
        let n = self.hits.fetch_add(1, Ordering::SeqCst) + 1;
        match self.when {
            When::Nth { after, every, count } => {
                if n <= after {
                    return false;
                }
                if (n - after - 1) % every.max(1) != 0 {
                    return false;
                }
                self.take_slot(count)
            }
            When::Seeded { period, count } => {
                let draw = self.lcg_step();
                if draw % period.max(1) != 0 {
                    return false;
                }
                self.take_slot(count)
            }
        }
    }

    /// Advance the rule's LCG by one step and return the draw (high bits,
    /// which are the well-mixed ones for this multiplier).
    fn lcg_step(&self) -> u64 {
        let mut cur = self.lcg.load(Ordering::SeqCst);
        loop {
            let next = cur.wrapping_mul(LCG_MUL).wrapping_add(LCG_INC);
            match self.lcg.compare_exchange(cur, next, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => return next >> 33,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Claim one of the rule's `count` injection slots, atomically.
    fn take_slot(&self, count: u64) -> bool {
        let mut cur = self.fired.load(Ordering::SeqCst);
        loop {
            if cur >= count {
                return false;
            }
            match self.fired.compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// An ordered set of [`FaultRule`]s. Build programmatically
/// ([`FaultPlan::nth`] / [`FaultPlan::seeded`]) or from the CLI spec
/// grammar ([`FaultPlan::parse`]), then [`arm`] it.
#[derive(Debug, Default)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Fire `kind` at `site` once, on the first hit.
    pub fn once(self, site: &str, kind: FaultKind) -> FaultPlan {
        self.nth(site, kind, 0, 1, 1)
    }

    /// Fire `kind` at `site`: skip `after` hits, then every `every`th
    /// hit, at most `count` times (`u64::MAX` ≈ unlimited).
    pub fn nth(
        mut self,
        site: &str,
        kind: FaultKind,
        after: u64,
        every: u64,
        count: u64,
    ) -> FaultPlan {
        self.rules.push(FaultRule::new(site, kind, When::Nth { after, every, count }, 0));
        self
    }

    /// Fire `kind` at `site` on a seeded pseudo-random ~`1/period` of
    /// hits, at most `count` times. Deterministic per (seed, hit order).
    pub fn seeded(
        mut self,
        site: &str,
        kind: FaultKind,
        seed: u64,
        period: u64,
        count: u64,
    ) -> FaultPlan {
        self.rules.push(FaultRule::new(site, kind, When::Seeded { period, count }, seed));
        self
    }

    /// Parse the CLI spec grammar (`--fault-plan`):
    ///
    /// ```text
    /// spec  = rule *( ";" rule )
    /// rule  = site "=" kind [ "@" after ] [ "/" every ] [ "x" ( count / "*" ) ]
    ///         [ "~" seed [ ":" period ] ]
    /// kind  = "io" / "torn" [ ":" bytes ] / "reset" / "slow" [ ":" ms ]
    ///       / "nan" / "panic"
    /// ```
    ///
    /// Defaults: `after=0`, `every=1`, `count=1`, torn `bytes=16`, slow
    /// `ms=50`; `~seed[:period]` switches the rule to seeded mode
    /// (default `period=2`). Examples: `checkpoint.write=torn:32@2`
    /// fires a 32-byte torn write on the third checkpoint write;
    /// `conn.read=reset@5x2` resets the 6th and 7th request reads;
    /// `push.rows=nan~7:50x*` poisons ~1/50 of batches from seed 7,
    /// forever.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (site, rest) = part
                .split_once('=')
                .ok_or_else(|| format!("fault rule `{part}`: expected site=kind"))?;
            let site = site.trim();
            if site.is_empty() {
                return Err(format!("fault rule `{part}`: empty site"));
            }
            let rest = rest.trim();
            // Kind token runs to the first scheduling modifier.
            let kind_end =
                rest.find(['@', '/', 'x', '~']).unwrap_or(rest.len());
            let (kind_tok, mut mods) = rest.split_at(kind_end);
            let kind = parse_kind(kind_tok.trim())
                .map_err(|e| format!("fault rule `{part}`: {e}"))?;
            let (mut after, mut every, mut count) = (0u64, 1u64, 1u64);
            let mut seeded: Option<(u64, u64)> = None;
            while !mods.is_empty() {
                let tag = mods.as_bytes()[0];
                mods = &mods[1..];
                match tag {
                    b'@' => after = take_u64(&mut mods, part)?,
                    b'/' => every = take_u64(&mut mods, part)?,
                    b'x' => {
                        if let Some(stripped) = mods.strip_prefix('*') {
                            mods = stripped;
                            count = u64::MAX;
                        } else {
                            count = take_u64(&mut mods, part)?;
                        }
                    }
                    b'~' => {
                        let seed = take_u64(&mut mods, part)?;
                        let period = if let Some(stripped) = mods.strip_prefix(':') {
                            mods = stripped;
                            take_u64(&mut mods, part)?
                        } else {
                            2
                        };
                        seeded = Some((seed, period));
                    }
                    other => {
                        return Err(format!(
                            "fault rule `{part}`: unexpected `{}`",
                            other as char
                        ));
                    }
                }
            }
            plan = match seeded {
                Some((seed, period)) => plan.seeded(site, kind, seed, period, count),
                None => plan.nth(site, kind, after, every, count),
            };
        }
        if plan.is_empty() {
            return Err("fault plan spec is empty".to_string());
        }
        Ok(plan)
    }
}

fn parse_kind(tok: &str) -> Result<FaultKind, String> {
    let (name, arg) = match tok.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (tok, None),
    };
    let num = |default: u64| -> Result<u64, String> {
        match arg {
            None => Ok(default),
            Some(a) => a.parse::<u64>().map_err(|_| format!("bad numeric arg `{a}`")),
        }
    };
    match name {
        "io" => Ok(FaultKind::IoError),
        "torn" => Ok(FaultKind::TornWrite { bytes: num(16)? as usize }),
        "reset" => Ok(FaultKind::ConnReset),
        "slow" => Ok(FaultKind::SlowRead { ms: num(50)? }),
        "nan" => Ok(FaultKind::PoisonNan),
        "panic" => Ok(FaultKind::Panic),
        other => Err(format!(
            "unknown fault kind `{other}` (expected io, torn[:bytes], reset, slow[:ms], nan, panic)"
        )),
    }
}

/// Consume a leading decimal u64 from `*s`, advancing it past the digits.
fn take_u64(s: &mut &str, rule: &str) -> Result<u64, String> {
    let digits = s.len() - s.trim_start_matches(|c: char| c.is_ascii_digit()).len();
    if digits == 0 {
        return Err(format!("fault rule `{rule}`: expected a number at `{s}`"));
    }
    let (num, rest) = s.split_at(digits);
    *s = rest;
    num.parse::<u64>().map_err(|_| format!("fault rule `{rule}`: number `{num}` out of range"))
}

// ---------------------------------------------------------------------------
// Global arming — one relaxed AtomicBool, exactly like `obs::enabled`.
// ---------------------------------------------------------------------------

static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);
static INJECTED: AtomicU64 = AtomicU64::new(0);

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Whether a fault plan is armed (one relaxed load).
#[inline(always)]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Install `plan` and arm every fault site. Process-global.
pub fn arm(plan: FaultPlan) {
    *lock(&PLAN) = Some(plan);
    ARMED.store(true, Ordering::SeqCst);
}

/// Disarm all sites and drop the plan. The disarmed [`check`] is again a
/// single relaxed load.
pub fn disarm() {
    ARMED.store(false, Ordering::SeqCst);
    *lock(&PLAN) = None;
}

/// Poll a fault site. Disarmed: one relaxed load, `None`. Armed: the
/// first rule for `site` whose schedule fires decides the injected kind;
/// rules are consulted (and count the hit) in plan order.
#[inline]
pub fn check(site: &str) -> Option<FaultKind> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    check_slow(site)
}

#[cold]
fn check_slow(site: &str) -> Option<FaultKind> {
    let guard = lock(&PLAN);
    let plan = guard.as_ref()?;
    for rule in &plan.rules {
        if rule.site == site && rule.fire() {
            INJECTED.fetch_add(1, Ordering::Relaxed);
            if crate::obs::enabled() {
                crate::obs::counter("fault.injected").add(1);
            }
            return Some(rule.kind);
        }
    }
    None
}

/// Total injections fired since process start (all sites, all plans).
pub fn injected_total() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Message tag carried by every injected `io::Error`, so logs and tests
/// can tell scheduled faults from real ones.
pub const INJECTED_MSG: &str = "fault-injected";

/// Build the `io::Error` for an injected fault of the given kind.
pub fn io_error(kind: io::ErrorKind) -> io::Error {
    io::Error::new(kind, INJECTED_MSG)
}

/// Serializer for tests that arm plans: the toggle is process-global, so
/// in-crate tests take this lock (and disarm on exit) the same way obs
/// tests take `obs::test_toggle_lock`.
#[cfg(test)]
pub fn test_plan_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    lock(&LOCK)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_check_is_none() {
        let _guard = test_plan_lock();
        disarm();
        assert!(!armed());
        assert_eq!(check(site::CONN_READ), None);
    }

    #[test]
    fn nth_schedule_fires_deterministically() {
        let _guard = test_plan_lock();
        // Skip 2 hits, then every 3rd hit, at most 2 firings:
        // hits 3, 6 fire; 9 would but the count cap stops it.
        let run = || -> Vec<u64> {
            arm(FaultPlan::new().nth(site::CONN_READ, FaultKind::ConnReset, 2, 3, 2));
            let hits: Vec<u64> =
                (1u64..=10).filter(|_| check(site::CONN_READ).is_some()).collect();
            disarm();
            hits
        };
        assert_eq!(run(), vec![3, 6], "hits 3 and 6 fire; 9 is stopped by count=2");
        assert_eq!(run(), vec![3, 6], "a fresh identical plan replays exactly");
    }

    #[test]
    fn sites_are_independent() {
        let _guard = test_plan_lock();
        arm(FaultPlan::new().once(site::CKPT_WRITE, FaultKind::IoError));
        assert_eq!(check(site::CONN_READ), None, "other sites untouched");
        assert_eq!(check(site::CKPT_WRITE), Some(FaultKind::IoError));
        assert_eq!(check(site::CKPT_WRITE), None, "count=1 exhausted");
        disarm();
    }

    #[test]
    fn seeded_schedule_replays_bit_identically() {
        let _guard = test_plan_lock();
        let run = || -> Vec<bool> {
            arm(FaultPlan::new().seeded(site::PUSH_ROWS, FaultKind::PoisonNan, 7, 4, u64::MAX));
            let fires: Vec<bool> =
                (0..64).map(|_| check(site::PUSH_ROWS).is_some()).collect();
            disarm();
            fires
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed + same hit order must replay exactly");
        assert!(a.iter().any(|&f| f), "1/4 period over 64 hits should fire");
        assert!(a.iter().any(|&f| !f), "and should not fire every time");
    }

    #[test]
    fn spec_grammar_roundtrips() {
        let plan = FaultPlan::parse(
            "checkpoint.write=torn:32@2; conn.read=reset@5x2; push.rows=nan~7:50x*; \
             session.handler=panic; conn.write=slow:5/10x3",
        )
        .expect("spec must parse");
        assert_eq!(plan.rules.len(), 5);
        assert_eq!(plan.rules[0].kind, FaultKind::TornWrite { bytes: 32 });
        assert!(matches!(plan.rules[0].when, When::Nth { after: 2, every: 1, count: 1 }));
        assert_eq!(plan.rules[1].kind, FaultKind::ConnReset);
        assert!(matches!(plan.rules[1].when, When::Nth { after: 5, every: 1, count: 2 }));
        assert_eq!(plan.rules[2].kind, FaultKind::PoisonNan);
        assert!(
            matches!(plan.rules[2].when, When::Seeded { period: 50, count: u64::MAX })
        );
        assert_eq!(plan.rules[3].kind, FaultKind::Panic);
        assert_eq!(plan.rules[4].kind, FaultKind::SlowRead { ms: 5 });
        assert!(matches!(plan.rules[4].when, When::Nth { after: 0, every: 10, count: 3 }));

        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("siteonly").is_err());
        assert!(FaultPlan::parse("a=warp").is_err());
        assert!(FaultPlan::parse("a=io@x").is_err());
    }
}
