//! Named dataset surrogates mirroring the paper's Table 2.
//!
//! Each entry reproduces the *dimensionality* and the stream-structure
//! characteristics of the paper's dataset (see DESIGN.md §3); sizes are
//! scaled to keep single-machine experiment sweeps tractable — pass a
//! larger `n` to scale up.

use crate::data::synthetic::{
    ClassIncrementalSource, Mixture, MixtureSource, RandomWalkDriftSource,
};
use crate::data::{Dataset, StreamSource};
use crate::util::rng::Rng;

/// Descriptor of one surrogate (printed by `experiment datasets` → Table 2).
#[derive(Clone, Debug)]
pub struct DatasetInfo {
    pub name: &'static str,
    pub paper_name: &'static str,
    pub paper_size: usize,
    pub dim: usize,
    pub drift: &'static str,
}

/// The registry, in the paper's Table 2 order.
pub const REGISTRY: &[DatasetInfo] = &[
    DatasetInfo {
        name: "forestcover-like",
        paper_name: "ForestCover",
        paper_size: 286_048,
        dim: 10,
        drift: "none (iid)",
    },
    DatasetInfo {
        name: "creditfraud-like",
        paper_name: "Creditfraud",
        paper_size: 284_807,
        dim: 29,
        drift: "none (iid, rare-cluster skew)",
    },
    DatasetInfo {
        name: "fact-highlevel-like",
        paper_name: "FACT Highlevel",
        paper_size: 200_000,
        dim: 16,
        drift: "none (iid)",
    },
    DatasetInfo {
        name: "fact-lowlevel-like",
        paper_name: "FACT Lowlevel",
        paper_size: 200_000,
        dim: 256,
        drift: "none (iid)",
    },
    DatasetInfo {
        name: "kddcup-like",
        paper_name: "KDDCup99",
        paper_size: 60_632,
        dim: 41,
        drift: "none (iid, heavy skew)",
    },
    DatasetInfo {
        name: "stream51-like",
        paper_name: "stream51",
        paper_size: 150_736,
        dim: 64, // paper: 2048-dim CNN embeddings; scaled for runtime
        drift: "class-incremental + AR(1) frames",
    },
    DatasetInfo {
        name: "abc-like",
        paper_name: "abc",
        paper_size: 1_186_018,
        dim: 50, // paper: 300-dim GloVe; scaled
        drift: "gradual (random-walk topics)",
    },
    DatasetInfo {
        name: "examiner-like",
        paper_name: "examiner",
        paper_size: 3_089_781,
        dim: 50,
        drift: "gradual (random-walk topics)",
    },
];

/// Look up a surrogate descriptor.
pub fn info(name: &str) -> Option<&'static DatasetInfo> {
    REGISTRY.iter().find(|i| i.name == name)
}

/// All surrogate names.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|i| i.name).collect()
}

/// Mixture calibrated against the paper's RBF length scales.
///
/// The paper's gammas are huge for z-scored data (`γ = 2d` batch, `d/2`
/// streaming); between independent points `‖x−y‖² ≈ 2d`, so the kernel
/// vanishes and the log-det saturates at `K·m` for *any* diverse set —
/// no algorithm could be distinguished. Real corpora avoid this because
/// they are full of near-duplicates (video frames, repeated headlines,
/// background events). We reproduce that: unit per-dim variance overall
/// (normalization is then ~identity), with the *within-cluster* variance
/// share `σ²_n = κ/(2d²)` so the within-cluster kernel is `exp(−2κ)`
/// under the batch gamma and `exp(−κ/2)` under the streaming gamma.
/// κ ≈ 1 ⇒ same-cluster items are visibly related, cross-cluster items
/// are orthogonal — summarization = cover the clusters, which is the
/// regime where the paper's relative orderings emerge.
fn calibrated(d: usize, clusters: usize, kappa: f64, rng: &mut Rng) -> Mixture {
    let sigma2n = (kappa / (2.0 * (d * d) as f64)).min(0.5);
    let noise = sigma2n.sqrt();
    let spread = (d as f64 * (1.0 - sigma2n)).sqrt();
    Mixture::random(d, clusters, spread, noise, rng)
}

/// Build the stream source for a surrogate.
pub fn source(name: &str, n: usize, seed: u64) -> Option<Box<dyn StreamSource>> {
    let mut rng = Rng::seed_from(seed ^ 0xD5A7_A5E7_0000 ^ fxhash(name));
    Some(match name {
        "forestcover-like" => {
            let mix = calibrated(10, 60, 0.25, &mut rng);
            Box::new(MixtureSource::new(mix, n, seed))
        }
        "creditfraud-like" => {
            // Dominant "legit" clusters + rare fraud clusters (heavy skew).
            let mix = calibrated(29, 45, 0.25, &mut rng).with_skew(0.92);
            Box::new(MixtureSource::new(mix, n, seed))
        }
        "fact-highlevel-like" => {
            let mix = calibrated(16, 80, 0.25, &mut rng);
            Box::new(MixtureSource::new(mix, n, seed))
        }
        "fact-lowlevel-like" => {
            let mix = calibrated(256, 64, 0.5, &mut rng);
            Box::new(MixtureSource::new(mix, n, seed))
        }
        "kddcup-like" => {
            let mix = calibrated(41, 70, 0.25, &mut rng).with_skew(0.9);
            Box::new(MixtureSource::new(mix, n, seed))
        }
        "stream51-like" => {
            // 51 classes as in the paper, appearing segment by segment with
            // AR(1)-correlated frames.
            let clusters = 51;
            let mix = calibrated(64, clusters, 1.0, &mut rng);
            let seg = (n / clusters).max(1);
            Box::new(ClassIncrementalSource::new(mix, n, seg, 0.7, seed))
        }
        "abc-like" => {
            let mix = calibrated(50, 40, 0.25, &mut rng);
            Box::new(RandomWalkDriftSource::new(mix, n, 0.001, seed))
        }
        "examiner-like" => {
            let mix = calibrated(50, 30, 0.25, &mut rng);
            Box::new(RandomWalkDriftSource::new(mix, n, 0.002, seed))
        }
        _ => return None,
    })
}

/// Materialize a surrogate as a normalized in-memory dataset.
pub fn get(name: &str, n: usize, seed: u64) -> Option<Dataset> {
    let mut src = source(name, n, seed)?;
    let mut ds = src.materialize(name, n);
    ds.normalize();
    Some(ds)
}

/// Tiny stable string hash for seed mixing.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_paper_table2() {
        assert_eq!(REGISTRY.len(), 8);
        assert_eq!(info("forestcover-like").unwrap().dim, 10);
        assert_eq!(info("creditfraud-like").unwrap().dim, 29);
        assert_eq!(info("kddcup-like").unwrap().dim, 41);
    }

    #[test]
    fn all_registered_sources_build() {
        for i in REGISTRY {
            let ds = get(i.name, 100, 1).unwrap_or_else(|| panic!("{} failed", i.name));
            assert_eq!(ds.len(), 100, "{}", i.name);
            assert_eq!(ds.dim(), i.dim, "{}", i.name);
        }
    }

    #[test]
    fn unknown_name_returns_none() {
        assert!(get("nope", 10, 1).is_none());
        assert!(source("nope", 10, 1).is_none());
        assert!(info("nope").is_none());
    }

    #[test]
    fn seeded_reproducibility() {
        let a = get("fact-highlevel-like", 50, 3).unwrap();
        let b = get("fact-highlevel-like", 50, 3).unwrap();
        assert_eq!(a.raw(), b.raw());
        let c = get("fact-highlevel-like", 50, 4).unwrap();
        assert_ne!(a.raw(), c.raw());
    }

    #[test]
    fn different_datasets_differ() {
        let a = get("abc-like", 30, 1).unwrap();
        let b = get("examiner-like", 30, 1).unwrap();
        assert_ne!(a.raw(), b.raw());
    }
}
