//! File-backed dataset loaders: CSV (headerless, numeric) and a raw binary
//! f32 format (`.f32bin`: u32 LE dim, then row-major little-endian f32s).
//! These let downstream users feed real corpora into the same harness.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use crate::data::Dataset;

/// Errors from dataset loading.
#[derive(Debug)]
pub enum LoadError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
    Ragged { line: usize, got: usize, expected: usize },
    Empty,
    Corrupt(String),
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::Io(e) => write!(f, "io error: {e}"),
            LoadError::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            LoadError::Ragged { line, got, expected } => write!(
                f,
                "inconsistent row width at line {line}: got {got}, expected {expected}"
            ),
            LoadError::Empty => write!(f, "empty dataset"),
            LoadError::Corrupt(msg) => write!(f, "corrupt binary file: {msg}"),
        }
    }
}

impl std::error::Error for LoadError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LoadError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LoadError {
    fn from(e: std::io::Error) -> Self {
        LoadError::Io(e)
    }
}

/// Load a headerless numeric CSV. Empty lines and `#` comments are skipped.
pub fn load_csv(path: &Path) -> Result<Dataset, LoadError> {
    let file = std::fs::File::open(path)?;
    let reader = BufReader::new(file);
    let mut rows: Vec<f32> = Vec::new();
    let mut dim = 0usize;
    let mut n = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let mut width = 0usize;
        for tok in trimmed.split(',') {
            let v: f32 = tok.trim().parse().map_err(|e| LoadError::Parse {
                line: lineno + 1,
                msg: format!("{tok:?}: {e}"),
            })?;
            rows.push(v);
            width += 1;
        }
        if dim == 0 {
            dim = width;
        } else if width != dim {
            return Err(LoadError::Ragged { line: lineno + 1, got: width, expected: dim });
        }
        n += 1;
    }
    if n == 0 {
        return Err(LoadError::Empty);
    }
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("csv").to_string();
    Ok(Dataset::new(name, dim, rows))
}

/// Write the `.f32bin` format.
pub fn save_f32bin(ds: &Dataset, path: &Path) -> Result<(), LoadError> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&(ds.dim() as u32).to_le_bytes())?;
    for v in ds.raw() {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load the `.f32bin` format.
pub fn load_f32bin(path: &Path) -> Result<Dataset, LoadError> {
    let mut f = std::fs::File::open(path)?;
    let mut hdr = [0u8; 4];
    f.read_exact(&mut hdr).map_err(|_| LoadError::Corrupt("missing header".into()))?;
    let dim = u32::from_le_bytes(hdr) as usize;
    if dim == 0 {
        return Err(LoadError::Corrupt("dim = 0".into()));
    }
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    if bytes.len() % 4 != 0 {
        return Err(LoadError::Corrupt("payload not a multiple of 4 bytes".into()));
    }
    let count = bytes.len() / 4;
    if count % dim != 0 {
        return Err(LoadError::Corrupt(format!("{count} floats not divisible by dim {dim}")));
    }
    if count == 0 {
        return Err(LoadError::Empty);
    }
    let mut rows = Vec::with_capacity(count);
    for chunk in bytes.chunks_exact(4) {
        rows.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("bin").to_string();
    Ok(Dataset::new(name, dim, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ts_loader_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn csv_roundtrip() {
        let dir = tmpdir();
        let p = dir.join("a.csv");
        std::fs::write(&p, "# comment\n1.0, 2.0\n3.5,-4.5\n\n").unwrap();
        let ds = load_csv(&p).unwrap();
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.row(1), &[3.5, -4.5]);
    }

    #[test]
    fn csv_rejects_ragged() {
        let dir = tmpdir();
        let p = dir.join("r.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        match load_csv(&p) {
            Err(LoadError::Ragged { line: 2, got: 1, expected: 2 }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn csv_rejects_garbage() {
        let dir = tmpdir();
        let p = dir.join("g.csv");
        std::fs::write(&p, "1,notanumber\n").unwrap();
        assert!(matches!(load_csv(&p), Err(LoadError::Parse { .. })));
    }

    #[test]
    fn csv_rejects_empty() {
        let dir = tmpdir();
        let p = dir.join("e.csv");
        std::fs::write(&p, "# nothing\n").unwrap();
        assert!(matches!(load_csv(&p), Err(LoadError::Empty)));
    }

    #[test]
    fn f32bin_roundtrip() {
        let dir = tmpdir();
        let p = dir.join("a.f32bin");
        let ds = Dataset::new("x", 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        save_f32bin(&ds, &p).unwrap();
        let back = load_f32bin(&p).unwrap();
        assert_eq!(back.dim(), 3);
        assert_eq!(back.raw(), ds.raw());
    }

    #[test]
    fn f32bin_detects_corruption() {
        let dir = tmpdir();
        let p = dir.join("c.f32bin");
        std::fs::write(&p, [2u8, 0, 0, 0, 1, 2, 3]).unwrap(); // 3 payload bytes
        assert!(matches!(load_f32bin(&p), Err(LoadError::Corrupt(_))));
        let p2 = dir.join("c2.f32bin");
        std::fs::write(&p2, [0u8, 0, 0, 0]).unwrap(); // dim = 0
        assert!(matches!(load_f32bin(&p2), Err(LoadError::Corrupt(_))));
    }
}
