//! Dataset diagnostics: the quantities that decide whether a workload can
//! distinguish the algorithm family at all (see `registry::calibrated` —
//! if the kernel saturates, every summary looks equally good).
//!
//! Used by `threesieves datasets --stats` and by tests that pin the
//! surrogate calibration.

use crate::data::Dataset;
use crate::util::rng::Rng;

/// Summary statistics of a dataset under a given RBF gamma.
#[derive(Clone, Debug)]
pub struct DatasetDiagnostics {
    pub n: usize,
    pub dim: usize,
    /// Mean / min / max per-dimension standard deviation.
    pub dim_std_mean: f64,
    pub dim_std_min: f64,
    pub dim_std_max: f64,
    /// Sampled pairwise squared-distance quantiles (q10, q50, q90).
    pub dist2_q10: f64,
    pub dist2_q50: f64,
    pub dist2_q90: f64,
    /// Sampled kernel-value quantiles under `gamma` (q50, q90, q99).
    pub kernel_q50: f64,
    pub kernel_q90: f64,
    pub kernel_q99: f64,
}

/// Compute diagnostics from `pairs` sampled point pairs.
pub fn diagnose(ds: &Dataset, gamma: f64, pairs: usize, seed: u64) -> DatasetDiagnostics {
    let (n, d) = (ds.len(), ds.dim());
    assert!(n >= 2, "need at least two rows");
    // Per-dimension std.
    let mut stds = Vec::with_capacity(d);
    for j in 0..d {
        let mut mean = 0.0f64;
        for i in 0..n {
            mean += ds.row(i)[j] as f64;
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for i in 0..n {
            let c = ds.row(i)[j] as f64 - mean;
            var += c * c;
        }
        stds.push((var / n as f64).sqrt());
    }
    let dim_std_mean = stds.iter().sum::<f64>() / d as f64;
    let dim_std_min = stds.iter().cloned().fold(f64::INFINITY, f64::min);
    let dim_std_max = stds.iter().cloned().fold(f64::NEG_INFINITY, f64::max);

    // Sampled pairwise distances.
    let mut rng = Rng::seed_from(seed);
    let mut d2s = Vec::with_capacity(pairs);
    for _ in 0..pairs {
        let i = rng.range(0, n);
        let mut j = rng.range(0, n);
        if j == i {
            j = (j + 1) % n;
        }
        d2s.push(crate::util::mathx::sq_dist_f32(ds.row(i), ds.row(j)));
    }
    d2s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| d2s[((p * (d2s.len() - 1) as f64).round() as usize).min(d2s.len() - 1)];
    let (q10, q50, q90) = (q(0.10), q(0.50), q(0.90));
    // Kernel quantiles: high kernel values live in the *low* distance tail.
    let kq = |p: f64| (-gamma * q(1.0 - p)).exp();

    DatasetDiagnostics {
        n,
        dim: d,
        dim_std_mean,
        dim_std_min,
        dim_std_max,
        dist2_q10: q10,
        dist2_q50: q50,
        dist2_q90: q90,
        kernel_q50: (-gamma * q50).exp(),
        kernel_q90: kq(0.90),
        kernel_q99: kq(0.99),
    }
}

impl DatasetDiagnostics {
    /// True when the workload has usable kernel structure: the typical pair
    /// is (near-)orthogonal but a visible fraction of pairs is related.
    pub fn has_kernel_structure(&self) -> bool {
        self.kernel_q50 < 0.05 && self.kernel_q99 > 0.1
    }

    pub fn to_row(&self, name: &str) -> String {
        format!(
            "{:<22} n={:<7} d={:<4} dimstd={:.2}[{:.2},{:.2}] d2(q10/50/90)={:.1}/{:.1}/{:.1} \
             k(q50/90/99)={:.3}/{:.3}/{:.3}",
            name,
            self.n,
            self.dim,
            self.dim_std_mean,
            self.dim_std_min,
            self.dim_std_max,
            self.dist2_q10,
            self.dist2_q50,
            self.dist2_q90,
            self.kernel_q50,
            self.kernel_q90,
            self.kernel_q99,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;

    #[test]
    fn surrogates_have_kernel_structure() {
        // The calibration contract: every registered surrogate must expose
        // near-duplicate structure under its *streaming* gamma, otherwise
        // the figure sweeps degenerate (all algorithms identical).
        for info in registry::REGISTRY {
            let ds = registry::get(info.name, 2_000, 7).unwrap();
            let gamma = info.dim as f64 / 2.0;
            let diag = diagnose(&ds, gamma, 4_000, 1);
            assert!(
                diag.has_kernel_structure(),
                "{}: {}",
                info.name,
                diag.to_row(info.name)
            );
        }
    }

    #[test]
    fn normalized_data_has_unit_dim_std() {
        let ds = registry::get("forestcover-like", 1_000, 3).unwrap();
        let diag = diagnose(&ds, 1.0, 500, 2);
        assert!((diag.dim_std_mean - 1.0).abs() < 0.05, "{}", diag.dim_std_mean);
    }

    #[test]
    fn quantiles_are_ordered() {
        let ds = registry::get("kddcup-like", 500, 5).unwrap();
        let diag = diagnose(&ds, 2.0, 1_000, 3);
        assert!(diag.dist2_q10 <= diag.dist2_q50);
        assert!(diag.dist2_q50 <= diag.dist2_q90);
        assert!(diag.kernel_q50 <= diag.kernel_q90 + 1e-12);
        assert!(diag.kernel_q90 <= diag.kernel_q99 + 1e-12);
    }

    #[test]
    #[should_panic(expected = "at least two rows")]
    fn rejects_singleton_dataset() {
        let ds = Dataset::new("one", 2, vec![1.0, 2.0]);
        diagnose(&ds, 1.0, 10, 1);
    }
}
