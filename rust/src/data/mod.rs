//! Datasets and stream sources.
//!
//! The paper's corpora (ForestCover, Creditfraud, FACT, KDDCup99, stream51,
//! abc, examiner) are not redistributable inside this environment; the
//! [`registry`] provides seeded synthetic surrogates with matching
//! dimensionalities and the stream-structure knobs that drive relative
//! algorithm behaviour (cluster count, rare-cluster skew, drift mode).
//! See DESIGN.md §3 for the substitution rationale.

pub mod loader;
pub mod registry;
pub mod stats;
pub mod synthetic;

use crate::util::rng::Rng;

/// An in-memory dataset: `n` rows of `dim` f32 features, row-major.
#[derive(Clone, Debug)]
pub struct Dataset {
    name: String,
    dim: usize,
    rows: Vec<f32>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, dim: usize, rows: Vec<f32>) -> Self {
        assert!(dim > 0, "dim must be positive");
        assert!(rows.len() % dim == 0, "row data not divisible by dim");
        Dataset { name: name.into(), dim, rows }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn len(&self) -> usize {
        self.rows.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.rows[i * self.dim..(i + 1) * self.dim]
    }

    pub fn raw(&self) -> &[f32] {
        &self.rows
    }

    /// Iterate rows in stream order.
    pub fn iter(&self) -> impl Iterator<Item = &[f32]> {
        self.rows.chunks_exact(self.dim)
    }

    /// Z-score normalize each feature in place (matches the paper's
    /// preprocessing so RBF length scales are comparable across datasets).
    pub fn normalize(&mut self) {
        let (n, d) = (self.len(), self.dim);
        if n == 0 {
            return;
        }
        for j in 0..d {
            let mut mean = 0.0f64;
            for i in 0..n {
                mean += self.rows[i * d + j] as f64;
            }
            mean /= n as f64;
            let mut var = 0.0f64;
            for i in 0..n {
                let c = self.rows[i * d + j] as f64 - mean;
                var += c * c;
            }
            var /= n as f64;
            let std = var.sqrt().max(1e-12);
            for i in 0..n {
                let v = (self.rows[i * d + j] as f64 - mean) / std;
                self.rows[i * d + j] = v as f32;
            }
        }
    }

    /// Random subsample of `count` rows (without replacement, seeded).
    pub fn subsample(&self, count: usize, seed: u64) -> Dataset {
        let n = self.len();
        let count = count.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = Rng::seed_from(seed);
        rng.shuffle(&mut idx);
        idx.truncate(count);
        let mut rows = Vec::with_capacity(count * self.dim);
        for &i in &idx {
            rows.extend_from_slice(self.row(i));
        }
        Dataset::new(format!("{}[{}]", self.name, count), self.dim, rows)
    }
}

/// A pull-based stream of feature vectors. Implementations must be
/// deterministic given their seed so experiments are reproducible.
pub trait StreamSource: Send {
    /// Feature dimensionality.
    fn dim(&self) -> usize;

    /// Produce the next item into `out` (must be `dim()` long).
    /// Returns false when the stream is exhausted.
    fn next_into(&mut self, out: &mut [f32]) -> bool;

    /// Total length if known (finite replay streams know it).
    fn len_hint(&self) -> Option<usize> {
        None
    }

    /// Drain the whole stream into a Dataset (testing / batch algorithms).
    fn materialize(&mut self, name: &str, limit: usize) -> Dataset {
        let d = self.dim();
        let mut rows = Vec::new();
        let mut buf = vec![0.0f32; d];
        let mut taken = 0;
        while taken < limit && self.next_into(&mut buf) {
            rows.extend_from_slice(&buf);
            taken += 1;
        }
        Dataset::new(name, d, rows)
    }
}

/// Replay a materialized dataset as a stream (the batch experiments).
pub struct ReplaySource<'a> {
    ds: &'a Dataset,
    pos: usize,
}

impl<'a> ReplaySource<'a> {
    pub fn new(ds: &'a Dataset) -> Self {
        ReplaySource { ds, pos: 0 }
    }
}

impl<'a> StreamSource for ReplaySource<'a> {
    fn dim(&self) -> usize {
        self.ds.dim()
    }

    fn next_into(&mut self, out: &mut [f32]) -> bool {
        if self.pos >= self.ds.len() {
            return false;
        }
        out.copy_from_slice(self.ds.row(self.pos));
        self.pos += 1;
        true
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.ds.len() - self.pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new("toy", 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn basic_accessors() {
        let ds = toy();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        assert_eq!(ds.iter().count(), 3);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn rejects_ragged_rows() {
        Dataset::new("bad", 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn normalize_zero_mean_unit_var() {
        let mut ds = toy();
        ds.normalize();
        for j in 0..2 {
            let vals: Vec<f64> = (0..3).map(|i| ds.row(i)[j] as f64).collect();
            let mean: f64 = vals.iter().sum::<f64>() / 3.0;
            let var: f64 = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 3.0;
            assert!(mean.abs() < 1e-6);
            assert!((var - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn subsample_is_subset_and_seeded() {
        let ds = toy();
        let a = ds.subsample(2, 9);
        let b = ds.subsample(2, 9);
        assert_eq!(a.raw(), b.raw());
        assert_eq!(a.len(), 2);
        for i in 0..a.len() {
            let row = a.row(i);
            assert!((0..ds.len()).any(|j| ds.row(j) == row));
        }
    }

    #[test]
    fn replay_source_streams_in_order() {
        let ds = toy();
        let mut src = ReplaySource::new(&ds);
        assert_eq!(src.len_hint(), Some(3));
        let mut buf = [0.0f32; 2];
        let mut seen = Vec::new();
        while src.next_into(&mut buf) {
            seen.extend_from_slice(&buf);
        }
        assert_eq!(seen, ds.raw());
        assert!(!src.next_into(&mut buf));
    }

    #[test]
    fn materialize_respects_limit() {
        let ds = toy();
        let mut src = ReplaySource::new(&ds);
        let m = src.materialize("m", 2);
        assert_eq!(m.len(), 2);
    }
}
