//! Seeded synthetic data generators.
//!
//! Three stream regimes, matching the paper's experimental axes:
//!
//! * [`MixtureSource`] — iid draws from a fixed Gaussian mixture (the batch
//!   datasets: ForestCover-like, Creditfraud-like, FACT-like, KDDCup-like).
//!   Rare-cluster skew controls how "sparse" high-gain items are, which is
//!   the knob that separates SieveStreaming-style thresholding behaviours.
//! * [`ClassIncrementalSource`] — stream51-like: classes (clusters) appear
//!   one after another in segments, and consecutive frames are AR(1)
//!   correlated within a segment (violates iid two ways).
//! * [`RandomWalkDriftSource`] — abc/examiner-like: cluster centroids
//!   perform a slow random walk, giving gradual topical drift.

use crate::data::StreamSource;
use crate::util::rng::Rng;

/// A Gaussian mixture specification.
#[derive(Clone, Debug)]
pub struct Mixture {
    pub dim: usize,
    /// Row-major `c × dim` cluster centers.
    pub centers: Vec<f32>,
    /// Mixture weights (unnormalized).
    pub weights: Vec<f64>,
    /// Isotropic within-cluster standard deviation.
    pub noise: f64,
}

impl Mixture {
    /// Random mixture: `clusters` centers on a sphere of radius `spread`.
    pub fn random(dim: usize, clusters: usize, spread: f64, noise: f64, rng: &mut Rng) -> Self {
        assert!(clusters > 0);
        let mut centers = vec![0.0f32; clusters * dim];
        for c in 0..clusters {
            let mut norm = 0.0f64;
            let row = &mut centers[c * dim..(c + 1) * dim];
            for v in row.iter_mut() {
                *v = rng.normal() as f32;
                norm += (*v as f64) * (*v as f64);
            }
            let scale = spread / norm.sqrt().max(1e-9);
            for v in row.iter_mut() {
                *v = (*v as f64 * scale) as f32;
            }
        }
        Mixture { dim, centers, weights: vec![1.0; clusters], noise }
    }

    /// Skew the weights so cluster `i` has weight `decay^i` — a heavy head
    /// and a rare tail ("sparse" streams in the Salsa terminology).
    pub fn with_skew(mut self, decay: f64) -> Self {
        let c = self.weights.len();
        for i in 0..c {
            self.weights[i] = decay.powi(i as i32);
        }
        self
    }

    pub fn clusters(&self) -> usize {
        self.weights.len()
    }

    fn sample_into(&self, cluster: usize, rng: &mut Rng, out: &mut [f32]) {
        let row = &self.centers[cluster * self.dim..(cluster + 1) * self.dim];
        for (o, c) in out.iter_mut().zip(row) {
            *o = (*c as f64 + self.noise * rng.normal()) as f32;
        }
    }
}

/// iid mixture stream of fixed length.
pub struct MixtureSource {
    mix: Mixture,
    rng: Rng,
    remaining: usize,
    total: usize,
}

impl MixtureSource {
    pub fn new(mix: Mixture, n: usize, seed: u64) -> Self {
        MixtureSource { mix, rng: Rng::seed_from(seed), remaining: n, total: n }
    }
}

impl StreamSource for MixtureSource {
    fn dim(&self) -> usize {
        self.mix.dim
    }

    fn next_into(&mut self, out: &mut [f32]) -> bool {
        if self.remaining == 0 {
            return false;
        }
        self.remaining -= 1;
        let c = self.rng.categorical(&self.mix.weights);
        self.mix.sample_into(c, &mut self.rng, out);
        true
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.remaining)
    }
}

impl MixtureSource {
    pub fn total(&self) -> usize {
        self.total
    }
}

/// stream51-like class-incremental stream: the class sequence is a fixed
/// schedule of segments; within a segment items follow an AR(1) path around
/// the class center (consecutive frames are highly dependent).
pub struct ClassIncrementalSource {
    mix: Mixture,
    rng: Rng,
    /// Items per class segment.
    segment_len: usize,
    /// AR(1) coefficient in [0,1): 0 = iid, →1 = frozen frames.
    rho: f64,
    remaining: usize,
    pos_in_segment: usize,
    current_class: usize,
    /// Current AR state (deviation from the class center).
    state: Vec<f64>,
}

impl ClassIncrementalSource {
    pub fn new(mix: Mixture, n: usize, segment_len: usize, rho: f64, seed: u64) -> Self {
        assert!(segment_len > 0);
        assert!((0.0..1.0).contains(&rho));
        let dim = mix.dim;
        ClassIncrementalSource {
            mix,
            rng: Rng::seed_from(seed),
            segment_len,
            rho,
            remaining: n,
            pos_in_segment: 0,
            current_class: 0,
            state: vec![0.0; dim],
        }
    }
}

impl StreamSource for ClassIncrementalSource {
    fn dim(&self) -> usize {
        self.mix.dim
    }

    fn next_into(&mut self, out: &mut [f32]) -> bool {
        if self.remaining == 0 {
            return false;
        }
        self.remaining -= 1;
        if self.pos_in_segment == self.segment_len {
            self.pos_in_segment = 0;
            self.current_class = (self.current_class + 1) % self.mix.clusters();
            self.state.iter_mut().for_each(|s| *s = 0.0);
        }
        self.pos_in_segment += 1;
        let c = self.current_class;
        let center = &self.mix.centers[c * self.mix.dim..(c + 1) * self.mix.dim];
        let sigma = self.mix.noise * (1.0 - self.rho * self.rho).sqrt();
        for j in 0..self.mix.dim {
            self.state[j] = self.rho * self.state[j] + sigma * self.rng.normal();
            out[j] = (center[j] as f64 + self.state[j]) as f32;
        }
        true
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.remaining)
    }
}

/// abc/examiner-like gradual drift: centroids random-walk each step.
pub struct RandomWalkDriftSource {
    mix: Mixture,
    rng: Rng,
    /// Per-step centroid step size (fraction of noise).
    walk_sigma: f64,
    remaining: usize,
}

impl RandomWalkDriftSource {
    pub fn new(mix: Mixture, n: usize, walk_sigma: f64, seed: u64) -> Self {
        RandomWalkDriftSource { mix, rng: Rng::seed_from(seed), walk_sigma, remaining: n }
    }
}

impl StreamSource for RandomWalkDriftSource {
    fn dim(&self) -> usize {
        self.mix.dim
    }

    fn next_into(&mut self, out: &mut [f32]) -> bool {
        if self.remaining == 0 {
            return false;
        }
        self.remaining -= 1;
        // Drift every centroid slightly.
        let d = self.mix.dim;
        for v in self.mix.centers.iter_mut() {
            *v = (*v as f64 + self.walk_sigma * self.rng.normal()) as f32;
        }
        let c = self.rng.categorical(&self.mix.weights);
        let mut tmp = vec![0.0f32; d];
        self.mix.sample_into(c, &mut self.rng, &mut tmp);
        out.copy_from_slice(&tmp);
        true
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.remaining)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mathx::sq_dist_f32;

    fn base_mix(seed: u64) -> Mixture {
        let mut rng = Rng::seed_from(seed);
        Mixture::random(4, 3, 5.0, 0.3, &mut rng)
    }

    #[test]
    fn mixture_stream_is_deterministic() {
        let mix = base_mix(1);
        let mut a = MixtureSource::new(mix.clone(), 50, 7);
        let mut b = MixtureSource::new(mix, 50, 7);
        let da = a.materialize("a", usize::MAX);
        let db = b.materialize("b", usize::MAX);
        assert_eq!(da.raw(), db.raw());
        assert_eq!(da.len(), 50);
    }

    #[test]
    fn mixture_items_cluster_near_centers() {
        let mix = base_mix(2);
        let centers = mix.centers.clone();
        let dim = mix.dim;
        let mut src = MixtureSource::new(mix, 200, 3);
        let ds = src.materialize("c", usize::MAX);
        for i in 0..ds.len() {
            let row = ds.row(i);
            let min_d2 = (0..3)
                .map(|c| sq_dist_f32(row, &centers[c * dim..(c + 1) * dim]))
                .fold(f64::INFINITY, f64::min);
            // within ~6 sigma of some center
            assert!(min_d2.sqrt() < 0.3 * 8.0, "item {i} too far: {}", min_d2.sqrt());
        }
    }

    #[test]
    fn skew_makes_tail_rare() {
        let mix = base_mix(3).with_skew(0.2);
        assert!(mix.weights[0] > mix.weights[2] * 10.0);
    }

    #[test]
    fn class_incremental_visits_classes_in_order() {
        let mix = base_mix(4);
        let centers = mix.centers.clone();
        let dim = mix.dim;
        let mut src = ClassIncrementalSource::new(mix, 60, 20, 0.8, 5);
        let ds = src.materialize("ci", usize::MAX);
        // First segment items nearest to center 0, second to 1, third to 2.
        for (i, expected_class) in [(5usize, 0usize), (25, 1), (45, 2)] {
            let row = ds.row(i);
            let nearest = (0..3)
                .min_by(|&a, &b| {
                    sq_dist_f32(row, &centers[a * dim..(a + 1) * dim])
                        .partial_cmp(&sq_dist_f32(row, &centers[b * dim..(b + 1) * dim]))
                        .unwrap()
                })
                .unwrap();
            assert_eq!(nearest, expected_class, "item {i}");
        }
    }

    #[test]
    fn ar1_consecutive_frames_are_correlated() {
        let mix = base_mix(6);
        let mut src = ClassIncrementalSource::new(mix.clone(), 100, 100, 0.95, 8);
        let ds = src.materialize("ar", usize::MAX);
        let mut iid = MixtureSource::new(mix, 100, 8);
        let di = iid.materialize("iid", usize::MAX);
        let avg_step = |d: &crate::data::Dataset| {
            (1..d.len()).map(|i| sq_dist_f32(d.row(i), d.row(i - 1)).sqrt()).sum::<f64>()
                / (d.len() - 1) as f64
        };
        // AR(1) steps must be much smaller than iid within-cluster jumps
        // (ignoring segment switches — one big jump can't close a 3x gap).
        assert!(avg_step(&ds) < avg_step(&di));
    }

    #[test]
    fn random_walk_drifts_centroids() {
        let mix = base_mix(9);
        let start_centers = mix.centers.clone();
        let mut src = RandomWalkDriftSource::new(mix, 500, 0.05, 10);
        let mut buf = vec![0.0f32; 4];
        while src.next_into(&mut buf) {}
        let moved = sq_dist_f32(&src.mix.centers, &start_centers).sqrt();
        assert!(moved > 0.5, "centroids did not drift: {moved}");
    }

    #[test]
    fn sources_respect_length() {
        let mix = base_mix(11);
        let mut s = RandomWalkDriftSource::new(mix, 10, 0.01, 1);
        let mut buf = vec![0.0f32; 4];
        let mut count = 0;
        while s.next_into(&mut buf) {
            count += 1;
        }
        assert_eq!(count, 10);
    }
}
