//! The one shared quantile definition.
//!
//! Both ends of the crate's latency reporting — `util::timer::BenchStats`
//! percentiles over raw samples and [`super::Histogram`]'s bucket-walk
//! extraction — resolve a percentile to the same fractional rank and the
//! same linear interpolation, so bench output and service histograms can
//! never disagree about what "p99" means.

/// Fractional rank of percentile `p` (0–100) among `n` ordered samples:
/// `(p/100)·(n−1)`, the linear-interpolation convention.
pub fn rank(n: usize, p: f64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64
}

/// Linearly interpolated percentile over an **ascending-sorted** slice.
/// Empty input yields NaN (nothing to summarize).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let r = rank(sorted.len(), p);
    let lo = r.floor() as usize;
    let hi = r.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = r - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_convention() {
        assert_eq!(rank(0, 50.0), 0.0);
        assert_eq!(rank(1, 99.0), 0.0);
        assert!((rank(4, 50.0) - 1.5).abs() < 1e-12);
        assert!((rank(4, 100.0) - 3.0).abs() < 1e-12);
        // Out-of-range percentiles clamp instead of indexing out of bounds.
        assert_eq!(rank(4, -5.0), 0.0);
        assert!((rank(4, 250.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert!((percentile_sorted(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 100.0), 4.0);
        assert!(percentile_sorted(&[], 50.0).is_nan());
        assert_eq!(percentile_sorted(&[7.0], 99.0), 7.0);
    }
}
