//! Typed decision-event log (PR 8): *why* a summary ended up the way it
//! did, not just how long it took.
//!
//! ThreeSieves' pitch is a probabilistic certificate — it commits to a
//! threshold after T observations without improvement — so the signals
//! that explain a run are decisions: accept/reject/defer verdicts, the
//! T-counter's rise and reset, threshold-grid moves, sieve births and
//! deaths, drift resets and checkpoint traffic. This module records them
//! as a typed [`Event`] stream behind the same single relaxed-atomic gate
//! as spans ([`super::enabled`]): when observability is off, [`emit`] is
//! one relaxed load and nothing else — no clock, no ring write, no
//! counter bump — so every bit-parity suite holds with events on and the
//! disarmed hot path stays within the ≤ 1.03 overhead gate.
//!
//! Storage mirrors [`super::trace`]: fixed-capacity per-thread rings
//! (recording never contends across threads; the oldest events are
//! overwritten past [`EVENT_RING_CAPACITY`] so long runs keep the tail),
//! plus cumulative per-kind totals that survive ring overwrite — the
//! `WATCH` frames and the Perfetto instant-event fold-in read those.
//! Export is NDJSON (one JSON object per line, `--events-out`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use crate::util::json::Json;

/// Per-thread event-ring capacity; past this, the oldest are overwritten.
pub const EVENT_RING_CAPACITY: usize = 65536;

/// One algorithm/coordinator decision. Fields carry the stream element
/// index, the sieve (or shard / threshold-grid) id, the marginal gain and
/// the active threshold τ where the site has them; sites without a
/// natural value report 0. `element` indices are algorithm-local stream
/// positions, `sieve` ids are instance-local (a sieve's position in its
/// owner's roster, a shard's index, or 0 for single-instance algorithms).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// An item cleared the sieve rule `Δf(e|S) ≥ τ` and joined a summary.
    Accept { element: u64, sieve: u32, gain: f64, tau: f64 },
    /// An item fell short of the sieve rule.
    Reject { element: u64, sieve: u32, gain: f64, tau: f64 },
    /// An item landed between a two-threshold pair and was buffered for a
    /// second look (StreamClipper).
    Defer { element: u64, gain: f64, tau: f64 },
    /// A T-budget certificate fired: the threshold walked `from → to`
    /// down the geometric grid.
    ThresholdMove { sieve: u32, from: f64, to: f64 },
    /// The T-counter reset at budget with no lower threshold left to
    /// move to (grid exhausted): confidence restarts on the same τ.
    ConfidenceReset { sieve: u32, t: u64 },
    /// A sieve was born (initial grid or a window refresh spawn).
    SieveSpawn { sieve: u32, v: f64 },
    /// A sieve was pruned (its OPT guess fell below the live lower bound).
    SieveRetire { sieve: u32, v: f64 },
    /// A drift detector fired and the algorithm was reset.
    DriftReset { elements: u64 },
    /// A checkpoint was persisted.
    CheckpointSave { elements: u64 },
    /// A checkpoint was loaded back.
    CheckpointRestore { elements: u64 },
    /// A faulted session (poisoned lock or handler panic) was fenced
    /// off — subsequent verbs on it draw `ERR quarantined` while every
    /// other tenant keeps running (PR 10, `docs/robustness.md`).
    SessionQuarantine { elements: u64 },
    /// A corrupt/truncated checkpoint was moved to `.corrupt`
    /// quarantine so a fresh `OPEN` can proceed under the same id.
    CheckpointQuarantine,
}

/// Number of event kinds in the schema (the `Event` variant count).
pub const KINDS: usize = 12;

/// Stable schema names in kind order — the NDJSON `type` values, the
/// Perfetto instant-event suffixes, and the `WATCH` frame cell order.
pub const KIND_NAMES: [&str; KINDS] = [
    "accept",
    "reject",
    "defer",
    "threshold_move",
    "confidence_reset",
    "sieve_spawn",
    "sieve_retire",
    "drift_reset",
    "checkpoint_save",
    "checkpoint_restore",
    "session_quarantine",
    "checkpoint_quarantine",
];

impl Event {
    fn kind(&self) -> usize {
        match self {
            Event::Accept { .. } => 0,
            Event::Reject { .. } => 1,
            Event::Defer { .. } => 2,
            Event::ThresholdMove { .. } => 3,
            Event::ConfidenceReset { .. } => 4,
            Event::SieveSpawn { .. } => 5,
            Event::SieveRetire { .. } => 6,
            Event::DriftReset { .. } => 7,
            Event::CheckpointSave { .. } => 8,
            Event::CheckpointRestore { .. } => 9,
            Event::SessionQuarantine { .. } => 10,
            Event::CheckpointQuarantine => 11,
        }
    }

    /// Stable schema name (`accept`, `threshold_move`, …) — the NDJSON
    /// `type` field and the Perfetto instant-event suffix.
    pub fn kind_name(&self) -> &'static str {
        KIND_NAMES[self.kind()]
    }

    /// Event-specific payload fields, in schema order.
    fn fields(&self) -> Vec<(&'static str, Json)> {
        let n = |v: f64| Json::num(v);
        let u = |v: u64| Json::num(v as f64);
        match *self {
            Event::Accept { element, sieve, gain, tau }
            | Event::Reject { element, sieve, gain, tau } => vec![
                ("element", u(element)),
                ("sieve", u(sieve as u64)),
                ("gain", n(gain)),
                ("tau", n(tau)),
            ],
            Event::Defer { element, gain, tau } => {
                vec![("element", u(element)), ("gain", n(gain)), ("tau", n(tau))]
            }
            Event::ThresholdMove { sieve, from, to } => {
                vec![("sieve", u(sieve as u64)), ("from", n(from)), ("to", n(to))]
            }
            Event::ConfidenceReset { sieve, t } => {
                vec![("sieve", u(sieve as u64)), ("t", u(t))]
            }
            Event::SieveSpawn { sieve, v } | Event::SieveRetire { sieve, v } => {
                vec![("sieve", u(sieve as u64)), ("v", n(v))]
            }
            Event::DriftReset { elements }
            | Event::CheckpointSave { elements }
            | Event::CheckpointRestore { elements }
            | Event::SessionQuarantine { elements } => vec![("elements", u(elements))],
            Event::CheckpointQuarantine => vec![],
        }
    }
}

/// A ring-recorded event: the decision plus its microsecond offset from
/// the shared tracing epoch (so events line up with spans in the trace).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Recorded {
    pub ts_us: u64,
    pub event: Event,
}

/// Cumulative per-kind emission totals since process start. Unlike the
/// rings these never overwrite, so they are the authoritative counts for
/// `WATCH` frames and the Perfetto fold-in even on long runs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EventTotals {
    pub accepts: u64,
    pub rejects: u64,
    pub defers: u64,
    pub threshold_moves: u64,
    pub confidence_resets: u64,
    pub sieve_spawns: u64,
    pub sieve_retires: u64,
    pub drift_resets: u64,
    pub checkpoint_saves: u64,
    pub checkpoint_restores: u64,
    pub session_quarantines: u64,
    pub checkpoint_quarantines: u64,
}

impl EventTotals {
    /// Total events emitted across every kind.
    pub fn logged(&self) -> u64 {
        self.as_array().iter().sum()
    }

    /// Per-kind counts in schema order (the `WATCH` frame cell order).
    pub fn as_array(&self) -> [u64; KINDS] {
        [
            self.accepts,
            self.rejects,
            self.defers,
            self.threshold_moves,
            self.confidence_resets,
            self.sieve_spawns,
            self.sieve_retires,
            self.drift_resets,
            self.checkpoint_saves,
            self.checkpoint_restores,
            self.session_quarantines,
            self.checkpoint_quarantines,
        ]
    }

    /// Rebuild totals from schema-order counts (the wire-parse inverse of
    /// [`EventTotals::as_array`]).
    pub fn from_array(a: [u64; KINDS]) -> EventTotals {
        EventTotals {
            accepts: a[0],
            rejects: a[1],
            defers: a[2],
            threshold_moves: a[3],
            confidence_resets: a[4],
            sieve_spawns: a[5],
            sieve_retires: a[6],
            drift_resets: a[7],
            checkpoint_saves: a[8],
            checkpoint_restores: a[9],
            session_quarantines: a[10],
            checkpoint_quarantines: a[11],
        }
    }

    /// `(schema name, cumulative count)` pairs in schema order.
    pub fn named(&self) -> Vec<(&'static str, u64)> {
        KIND_NAMES.iter().copied().zip(self.as_array()).collect()
    }
}

struct Ring {
    events: Vec<Recorded>,
    /// Next overwrite slot once `events` is at capacity.
    head: usize,
}

impl Ring {
    fn push(&mut self, ev: Recorded) {
        if self.events.len() < EVENT_RING_CAPACITY {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % EVENT_RING_CAPACITY;
        }
    }
}

static RINGS: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());
static TOTALS: [AtomicU64; KINDS] = [const { AtomicU64::new(0) }; KINDS];

thread_local! {
    static LOCAL: Arc<Mutex<Ring>> = {
        let ring = Arc::new(Mutex::new(Ring { events: Vec::new(), head: 0 }));
        lock(&RINGS).push(Arc::clone(&ring));
        ring
    };
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Record one decision event. One relaxed load and an immediate return
/// when observability is off; when on, a timestamped ring write under
/// the calling thread's own (uncontended) lock plus one relaxed add.
#[inline]
pub fn emit(ev: Event) {
    if !super::enabled() {
        return;
    }
    record(ev);
}

#[cold]
fn record(ev: Event) {
    TOTALS[ev.kind()].fetch_add(1, Ordering::Relaxed);
    let rec = Recorded { ts_us: super::trace::now_us(), event: ev };
    LOCAL.with(|ring| lock(ring).push(rec));
}

/// Total decision events currently held across all thread rings (the
/// ring tail — see [`totals`] for overwrite-proof cumulative counts).
pub fn count() -> usize {
    lock(&RINGS).iter().map(|r| lock(r).events.len()).sum()
}

/// Cumulative per-kind emission totals since process start.
pub fn totals() -> EventTotals {
    let t: Vec<u64> = TOTALS.iter().map(|c| c.load(Ordering::Relaxed)).collect();
    EventTotals {
        accepts: t[0],
        rejects: t[1],
        defers: t[2],
        threshold_moves: t[3],
        confidence_resets: t[4],
        sieve_spawns: t[5],
        sieve_retires: t[6],
        drift_resets: t[7],
        checkpoint_saves: t[8],
        checkpoint_restores: t[9],
        session_quarantines: t[10],
        checkpoint_quarantines: t[11],
    }
}

/// Drain every ring (destructive) and return all events, time-ordered.
/// Cumulative [`totals`] are unaffected.
pub fn drain() -> Vec<Recorded> {
    let mut out = Vec::new();
    for ring in lock(&RINGS).iter() {
        let mut r = lock(ring);
        out.append(&mut r.events);
        r.head = 0;
    }
    out.sort_by_key(|e| e.ts_us);
    out
}

/// Copy every ring's events (non-destructive), time-ordered.
pub fn snapshot() -> Vec<Recorded> {
    let mut out = Vec::new();
    for ring in lock(&RINGS).iter() {
        out.extend(lock(ring).events.iter().cloned());
    }
    out.sort_by_key(|e| e.ts_us);
    out
}

/// One event as its NDJSON object (the `--events-out` line format):
/// `{"ts_us":…,"type":"accept",…payload…}`.
pub fn to_json(rec: &Recorded) -> Json {
    let mut fields: Vec<(&'static str, Json)> = vec![
        ("ts_us", Json::num(rec.ts_us as f64)),
        ("type", Json::str(rec.event.kind_name())),
    ];
    fields.extend(rec.event.fields());
    Json::obj(fields)
}

/// Write all recorded events to `path` as NDJSON — one JSON object per
/// line, time-ordered. Non-destructive, so a trace export alongside
/// still sees the same rings.
pub fn write_ndjson(path: &std::path::Path) -> std::io::Result<()> {
    let mut out = String::new();
    for rec in snapshot() {
        out.push_str(&to_json(&rec).to_string());
        out.push('\n');
    }
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Events and spans share the global toggle; this flips it under
    /// [`crate::obs::test_toggle_lock`] and uses distinctive payloads and
    /// non-destructive reads so it cannot disturb concurrent tests.
    #[test]
    fn emit_records_and_serializes() {
        let _toggle = crate::obs::test_toggle_lock();
        let before = totals();
        crate::obs::set_enabled(true);
        emit(Event::Accept { element: 421_773, sieve: 3, gain: 1.5, tau: 0.75 });
        emit(Event::ThresholdMove { sieve: 3, from: 2.0, to: 1.5 });
        crate::obs::set_enabled(false);
        // Disabled: a further emit is a no-op.
        emit(Event::DriftReset { elements: 999_999_001 });
        let after = totals();
        assert_eq!(after.accepts, before.accepts + 1);
        assert_eq!(after.threshold_moves, before.threshold_moves + 1);
        assert_eq!(after.drift_resets, before.drift_resets, "disabled emit must not count");
        let snap = snapshot();
        let mine = snap
            .iter()
            .find(|r| matches!(r.event, Event::Accept { element: 421_773, .. }))
            .expect("accept event must land in the ring");
        let line = to_json(mine).to_string();
        assert!(line.contains("\"type\":\"accept\""), "{line}");
        assert!(line.contains("\"element\":421773"), "{line}");
        assert!(
            !snap.iter().any(|r| matches!(r.event, Event::DriftReset { elements: 999_999_001 })),
            "disabled emit must not reach the rings"
        );
    }

    #[test]
    fn totals_name_every_kind() {
        let named = totals().named();
        assert_eq!(named.len(), KINDS);
        assert_eq!(named[0].0, "accept");
        assert_eq!(named[9].0, "checkpoint_restore");
        assert_eq!(named[10].0, "session_quarantine");
        assert_eq!(named[11].0, "checkpoint_quarantine");
    }
}
