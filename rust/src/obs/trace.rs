//! Scoped tracing spans into fixed-capacity per-thread ring buffers,
//! exported as Chrome/Perfetto trace-event JSON.
//!
//! Each thread owns one ring (registered globally on first use) so span
//! recording never contends across threads: when tracing is on, a span
//! costs one `Instant::now()` pair plus a ring write under the thread's
//! own (uncontended) lock. When off, [`super::span`] hands out a
//! disarmed guard and no clock is read at all. Rings overwrite their
//! oldest events past [`RING_CAPACITY`], so long runs keep the tail.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

use crate::util::json::Json;

/// Per-thread ring capacity; past this, the oldest events are overwritten.
pub const RING_CAPACITY: usize = 65536;

/// One completed span: a named `[start, start+dur)` interval on a thread.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    /// Microseconds since the process tracing epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Dense per-thread id (assigned in ring-creation order).
    pub tid: u64,
}

struct Ring {
    tid: u64,
    events: Vec<SpanEvent>,
    /// Next overwrite slot once `events` is at capacity.
    head: usize,
}

impl Ring {
    fn push(&mut self, ev: SpanEvent) {
        if self.events.len() < RING_CAPACITY {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % RING_CAPACITY;
        }
    }
}

static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static RINGS: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL: Arc<Mutex<Ring>> = {
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let ring = Arc::new(Mutex::new(Ring { tid, events: Vec::new(), head: 0 }));
        lock(&RINGS).push(Arc::clone(&ring));
        ring
    };
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Pin the trace epoch to "now" if not already set. Called when tracing
/// is first enabled so `start_us` offsets are small and monotone.
pub(super) fn init_epoch() {
    EPOCH.get_or_init(Instant::now);
}

/// Microseconds since the tracing epoch (pinning it now if unset) — the
/// shared timebase for spans and decision events, so both line up on the
/// same Perfetto timeline.
pub(super) fn now_us() -> u64 {
    let now = Instant::now();
    let epoch = *EPOCH.get_or_init(|| now);
    now.saturating_duration_since(epoch).as_micros() as u64
}

/// RAII span handle: measures from construction to drop, then records
/// into the current thread's ring. A disarmed guard (tracing off) is a
/// no-op and never reads the clock.
pub struct SpanGuard {
    live: Option<(&'static str, Instant)>,
}

impl SpanGuard {
    pub(super) fn armed(name: &'static str) -> SpanGuard {
        SpanGuard { live: Some((name, Instant::now())) }
    }

    pub(super) const fn disarmed() -> SpanGuard {
        SpanGuard { live: None }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some((name, start)) = self.live.take() else { return };
        let dur_us = start.elapsed().as_micros() as u64;
        let epoch = *EPOCH.get().unwrap_or(&start);
        let start_us = start.saturating_duration_since(epoch).as_micros() as u64;
        LOCAL.with(|ring| {
            let mut r = lock(ring);
            let tid = r.tid;
            r.push(SpanEvent { name, start_us, dur_us, tid });
        });
    }
}

/// Total events currently held across all thread rings.
pub fn event_count() -> usize {
    lock(&RINGS).iter().map(|r| lock(r).events.len()).sum()
}

/// Drain every ring (destructive) and return all events, start-ordered.
pub fn drain() -> Vec<SpanEvent> {
    let mut out = Vec::new();
    for ring in lock(&RINGS).iter() {
        let mut r = lock(ring);
        out.append(&mut r.events);
        r.head = 0;
    }
    out.sort_by_key(|e| e.start_us);
    out
}

/// Copy every ring's events (non-destructive), start-ordered.
pub fn snapshot() -> Vec<SpanEvent> {
    let mut out = Vec::new();
    for ring in lock(&RINGS).iter() {
        out.extend(lock(ring).events.iter().cloned());
    }
    out.sort_by_key(|e| e.start_us);
    out
}

/// Write all recorded spans to `path` as a Chrome trace-event JSON
/// document (complete-event `"ph": "X"` records; open the file at
/// `chrome://tracing` or <https://ui.perfetto.dev>). Decision-event
/// totals ([`super::events`]) fold in as global instant events
/// (`"ph": "i"`, one `events.<kind>` marker per kind with a nonzero
/// cumulative count), so the trace shows the decision mix next to the
/// wall-time spans. Non-destructive.
pub fn write_chrome_trace(path: &std::path::Path) -> std::io::Result<()> {
    let mut events: Vec<Json> = snapshot()
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::str(e.name)),
                ("cat", Json::str("threesieves")),
                ("ph", Json::str("X")),
                ("ts", Json::num(e.start_us as f64)),
                ("dur", Json::num(e.dur_us as f64)),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(e.tid as f64)),
            ])
        })
        .collect();
    let ts = now_us() as f64;
    for (kind, count) in super::events::totals().named() {
        if count == 0 {
            continue;
        }
        events.push(Json::obj(vec![
            ("name", Json::str(format!("events.{kind}"))),
            ("cat", Json::str("threesieves-events")),
            ("ph", Json::str("i")),
            ("s", Json::str("g")),
            ("ts", Json::num(ts)),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("count", Json::num(count as f64))])),
        ]));
    }
    let doc = Json::obj(vec![("traceEvents", Json::Arr(events))]);
    std::fs::write(path, doc.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Flips the global toggle under [`crate::obs::test_toggle_lock`] and
    /// uses a unique span name plus the non-destructive `snapshot()` so it
    /// can't disturb (or be disturbed by) concurrent tests.
    #[test]
    fn span_records_and_exports() {
        let _toggle = crate::obs::test_toggle_lock();
        crate::obs::set_enabled(true);
        {
            let _g = crate::obs::span("obs-unit-test-span");
            std::hint::black_box(0u64);
        }
        let events = snapshot();
        assert!(
            events.iter().any(|e| e.name == "obs-unit-test-span"),
            "armed span must land in the ring"
        );

        let path = std::env::temp_dir().join("obs_unit_trace.json");
        write_chrome_trace(&path).expect("write trace");
        let text = std::fs::read_to_string(&path).expect("read trace back");
        let doc = Json::parse(&text).expect("trace must be valid JSON");
        let names: Vec<&str> = doc
            .get("traceEvents")
            .as_arr()
            .expect("traceEvents array")
            .iter()
            .filter_map(|e| e.get("name").as_str())
            .collect();
        assert!(names.contains(&"obs-unit-test-span"));
        crate::obs::set_enabled(false);
        let _ = std::fs::remove_file(&path);
    }
}
