//! Process-wide metrics registry: named counters, gauges and log-bucketed
//! latency histograms.
//!
//! Handles are interned by `&'static str` name on first use and shared
//! behind `Arc`, so call sites can cache them in a `OnceLock` and record
//! with one relaxed atomic op. Recording is always allowed; sites on hot
//! paths gate their `Instant::now()` pairs on [`super::enabled`] so the
//! whole layer costs a single relaxed load when observability is off.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use super::quantile;

/// Monotonic event counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value.
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

const BUCKETS: usize = 64;

/// Log-bucketed histogram of u64 samples (nanoseconds by convention).
///
/// Bucket `i` holds values in `[2^i, 2^{i+1})` (0 joins bucket 0), so 64
/// buckets cover the whole u64 range with ≤ 2× relative resolution per
/// bucket; the exact observed min/max pin the tails. Percentile
/// extraction walks the bucket counts to the shared fractional rank
/// ([`quantile::rank`] — the same convention `BenchStats` uses on raw
/// samples) and interpolates linearly inside the bucket's bounds, clamped
/// to [min, max]. All state is relaxed atomics: `observe` is lock-free
/// and safe from any thread.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl Histogram {
    fn new() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    #[inline]
    fn bucket(v: u64) -> usize {
        v.max(1).ilog2() as usize
    }

    pub fn observe(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[Self::bucket(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        if self.count() == 0 {
            0
        } else {
            self.max.load(Ordering::Relaxed)
        }
    }

    pub fn min(&self) -> u64 {
        if self.count() == 0 {
            0
        } else {
            self.min.load(Ordering::Relaxed)
        }
    }

    /// Arithmetic mean of the observed samples (0 when empty, matching
    /// the all-zero empty-snapshot convention).
    pub fn mean(&self) -> f64 {
        let count = self.count();
        if count == 0 {
            0.0
        } else {
            self.sum() as f64 / count as f64
        }
    }

    /// Percentile estimate from the bucket counts (NaN when empty): the
    /// shared fractional rank locates a bucket, a linear walk inside the
    /// bucket's `[2^i, 2^{i+1})` span resolves the value, and the exact
    /// min/max clamp the result.
    pub fn percentile(&self, p: f64) -> f64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return f64::NAN;
        }
        let mn = self.min.load(Ordering::Relaxed) as f64;
        let mx = self.max.load(Ordering::Relaxed) as f64;
        let r = quantile::rank(total as usize, p);
        let mut before = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            // This bucket holds the samples at ranks [before, before+c-1].
            if r <= (before + c - 1) as f64 {
                let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
                let hi = (1u128 << (i + 1)) as f64;
                let within = if c == 1 {
                    0.0
                } else {
                    ((r - before as f64) / (c - 1) as f64).clamp(0.0, 1.0)
                };
                return (lo + (hi - lo) * within).clamp(mn, mx);
            }
            before += c;
        }
        mx
    }

    /// Summary snapshot for the wire (`METRICS HIST`) and bench output.
    /// Empty histograms snapshot as all-zero rather than NaN so the text
    /// protocol roundtrips exactly.
    pub fn snapshot(&self, name: &str) -> HistSnapshot {
        let count = self.count();
        let pct = |p: f64| if count == 0 { 0.0 } else { self.percentile(p) };
        HistSnapshot {
            name: name.to_string(),
            count,
            p50: pct(50.0),
            p90: pct(90.0),
            p99: pct(99.0),
            max: self.max(),
            min: self.min(),
            mean: self.mean(),
        }
    }
}

/// One histogram's point-in-time summary (the `METRICS HIST` wire unit).
/// `min`/`mean` joined the snapshot in PR 8; wire parsers default both
/// to 0 when a pre-PR-8 peer omits them.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSnapshot {
    pub name: String,
    pub count: u64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: u64,
    pub min: u64,
    pub mean: f64,
}

type Registry<T> = Mutex<BTreeMap<&'static str, Arc<T>>>;

static COUNTERS: Registry<Counter> = Mutex::new(BTreeMap::new());
static GAUGES: Registry<Gauge> = Mutex::new(BTreeMap::new());
static HISTOGRAMS: Registry<Histogram> = Mutex::new(BTreeMap::new());

fn lock<T>(reg: &Registry<T>) -> MutexGuard<'_, BTreeMap<&'static str, Arc<T>>> {
    reg.lock().unwrap_or_else(PoisonError::into_inner)
}

fn intern<T>(reg: &Registry<T>, name: &'static str, mk: fn() -> T) -> Arc<T> {
    Arc::clone(lock(reg).entry(name).or_insert_with(|| Arc::new(mk())))
}

/// Process-wide counter handle for `name` (created on first use).
pub fn counter(name: &'static str) -> Arc<Counter> {
    intern(&COUNTERS, name, Counter::default)
}

/// Process-wide gauge handle for `name` (created on first use).
pub fn gauge(name: &'static str) -> Arc<Gauge> {
    intern(&GAUGES, name, Gauge::default)
}

/// Process-wide histogram handle for `name` (created on first use).
pub fn histogram(name: &'static str) -> Arc<Histogram> {
    intern(&HISTOGRAMS, name, Histogram::new)
}

/// Snapshot every registered histogram in one pass, name-ordered — the
/// service's `METRICS HIST` reply.
pub fn histogram_snapshots() -> Vec<HistSnapshot> {
    lock(&HISTOGRAMS).iter().map(|(name, h)| h.snapshot(name)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = counter("obs.test.counter");
        let before = c.get();
        c.add(3);
        c.add(2);
        assert_eq!(c.get(), before + 5);
        // Interning: the same name yields the same cell.
        counter("obs.test.counter").add(1);
        assert_eq!(c.get(), before + 6);
        let g = gauge("obs.test.gauge");
        g.set(41);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn histogram_single_bucket_is_exact() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.observe(64);
        }
        // All mass in one bucket, min == max == 64: every percentile
        // clamps to the exact value.
        assert_eq!(h.percentile(50.0), 64.0);
        assert_eq!(h.percentile(99.0), 64.0);
        assert_eq!(h.max(), 64);
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum(), 6400);
    }

    #[test]
    fn histogram_percentiles_are_monotonic_and_bounded() {
        let h = Histogram::new();
        for v in [3u64, 17, 90, 250, 1_000, 4_096, 60_000, 1_000_000] {
            h.observe(v);
        }
        let (p50, p90, p99) = (h.percentile(50.0), h.percentile(90.0), h.percentile(99.0));
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert!((3.0..=1_000_000.0).contains(&p50));
        assert!(p99 <= 1_000_000.0);
        // Log-bucket resolution: each estimate is within 2x of a true
        // sample's bucket, so p50 must land in the right decade.
        assert!((90.0..=2_000.0).contains(&p50), "p50 = {p50}");
    }

    #[test]
    fn empty_histogram_snapshot_is_zero() {
        let h = Histogram::new();
        assert!(h.percentile(50.0).is_nan());
        let s = h.snapshot("empty");
        assert_eq!(s.name, "empty");
        assert_eq!(s.count, 0);
        assert_eq!((s.p50, s.p90, s.p99), (0.0, 0.0, 0.0));
        assert_eq!(s.max, 0);
        assert_eq!(s.min, 0, "empty min must read 0, not u64::MAX");
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn min_and_mean_track_samples() {
        let h = Histogram::new();
        for v in [10u64, 20, 90] {
            h.observe(v);
        }
        assert_eq!(h.min(), 10);
        assert_eq!(h.max(), 90);
        assert_eq!(h.mean(), 40.0);
        let s = h.snapshot("mm");
        assert_eq!((s.min, s.max), (10, 90));
        assert_eq!(s.mean, 40.0);
        assert!(s.min as f64 <= s.p50 && s.p50 <= s.max as f64);
    }

    #[test]
    fn registry_snapshot_contains_registered_names() {
        histogram("obs.test.hist").observe(1234);
        let snaps = histogram_snapshots();
        let mine = snaps.iter().find(|s| s.name == "obs.test.hist").expect("registered");
        assert!(mine.count >= 1);
        // Name-ordered (BTreeMap iteration).
        let names: Vec<&str> = snaps.iter().map(|s| s.name.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted);
    }
}
