//! Observability: metrics registry, scoped tracing spans (PR 7) and the
//! typed decision-event log (PR 8).
//!
//! Std-only and zero-dependency. One process-global toggle gates
//! everything: when off, [`span`] returns a disarmed guard, [`clock`]
//! returns `None` and [`events::emit`] returns immediately, so an
//! instrumented hot path costs exactly one relaxed atomic load — no
//! clock reads, no ring writes, no histogram updates. When on, spans
//! record into per-thread ring buffers ([`trace`]), decision events
//! into their own rings ([`events`]), and wall-time deltas accumulate
//! into the stats counters and the metrics registry ([`metrics`]).
//! Instrumentation never alters arithmetic or accounting, so every
//! bit-parity suite holds with tracing and events enabled.

pub mod events;
pub mod metrics;
pub mod quantile;
pub mod trace;

pub use events::{emit as emit_event, Event, EventTotals};
pub use metrics::{
    counter, gauge, histogram, histogram_snapshots, Counter, Gauge, HistSnapshot, Histogram,
};
pub use trace::{drain, event_count, snapshot, write_chrome_trace, SpanEvent, SpanGuard};

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is observability recording on? One relaxed load — cheap enough for
/// any hot path.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span/wall recording on or off at runtime. Enabling pins the
/// trace epoch on first use.
pub fn set_enabled(on: bool) {
    if on {
        trace::init_epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Serializes the unit tests that flip the process-global toggle — a
/// concurrent `set_enabled(false)` from one test would disarm another
/// mid-window. Every lib test that calls [`set_enabled`] must hold this.
#[cfg(test)]
pub(crate) fn test_toggle_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Open a scoped span: records `name` with wall duration when the
/// returned guard drops. Disarmed (free) when observability is off.
#[must_use = "span measures until the guard is dropped"]
pub fn span(name: &'static str) -> SpanGuard {
    if enabled() {
        SpanGuard::armed(name)
    } else {
        SpanGuard::disarmed()
    }
}

/// Start a wall-time measurement: `Some(now)` when recording, `None`
/// when off (no clock read). Pair with [`lap`].
#[inline]
pub fn clock() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Elapsed nanoseconds since a [`clock`] start, or 0 if it was off.
#[inline]
pub fn lap(start: Option<Instant>) -> u64 {
    match start {
        Some(t) => t.elapsed().as_nanos() as u64,
        None => 0,
    }
}

/// Bench-driver observability hookup: the fig/table/field bench binaries
/// construct one of these first thing in `main` and call
/// [`BenchObs::finish`] last. Output paths come from `--trace-out PATH`
/// / `--events-out PATH` after the cargo-bench `--` separator, or the
/// `TS_TRACE_OUT` / `TS_EVENTS_OUT` environment variables; either one
/// turns recording on for the whole run. With neither set this is inert
/// and the bench numbers are untouched (recording stays off).
#[must_use = "call finish() to write the requested trace/event files"]
pub struct BenchObs {
    trace: Option<std::path::PathBuf>,
    events: Option<std::path::PathBuf>,
}

impl BenchObs {
    /// Parse the process args/environment and enable recording if any
    /// output was requested.
    pub fn from_env() -> BenchObs {
        let args: Vec<String> = std::env::args().collect();
        let flag = |name: &str, env: &str| -> Option<std::path::PathBuf> {
            let from_args = args
                .iter()
                .position(|a| a == name)
                .and_then(|i| args.get(i + 1))
                .cloned();
            from_args.or_else(|| std::env::var(env).ok()).map(std::path::PathBuf::from)
        };
        let obs = BenchObs {
            trace: flag("--trace-out", "TS_TRACE_OUT"),
            events: flag("--events-out", "TS_EVENTS_OUT"),
        };
        if obs.trace.is_some() || obs.events.is_some() {
            set_enabled(true);
        }
        obs
    }

    /// Write whatever was requested (Perfetto trace JSON and/or decision
    /// NDJSON) and report the paths on stdout.
    pub fn finish(self) {
        if let Some(path) = &self.trace {
            match write_chrome_trace(path) {
                Ok(()) => println!("trace written to {}", path.display()),
                Err(e) => eprintln!("trace write failed ({}): {e}", path.display()),
            }
        }
        if let Some(path) = &self.events {
            match events::write_ndjson(path) {
                Ok(()) => println!(
                    "decision events written to {} ({} logged)",
                    path.display(),
                    events::totals().logged()
                ),
                Err(e) => eprintln!("events write failed ({}): {e}", path.display()),
            }
        }
    }
}
