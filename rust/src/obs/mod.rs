//! Observability: metrics registry + scoped tracing spans (PR 7).
//!
//! Std-only and zero-dependency. One process-global toggle gates
//! everything: when off, [`span`] returns a disarmed guard and
//! [`clock`] returns `None`, so an instrumented hot path costs exactly
//! one relaxed atomic load — no clock reads, no ring writes, no
//! histogram updates. When on, spans record into per-thread ring
//! buffers ([`trace`]) and wall-time deltas accumulate into the stats
//! counters and the metrics registry ([`metrics`]). Instrumentation
//! never alters arithmetic or accounting, so every bit-parity suite
//! holds with tracing enabled.

pub mod metrics;
pub mod quantile;
pub mod trace;

pub use metrics::{
    counter, gauge, histogram, histogram_snapshots, Counter, Gauge, HistSnapshot, Histogram,
};
pub use trace::{drain, event_count, snapshot, write_chrome_trace, SpanEvent, SpanGuard};

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is observability recording on? One relaxed load — cheap enough for
/// any hot path.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span/wall recording on or off at runtime. Enabling pins the
/// trace epoch on first use.
pub fn set_enabled(on: bool) {
    if on {
        trace::init_epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Open a scoped span: records `name` with wall duration when the
/// returned guard drops. Disarmed (free) when observability is off.
#[must_use = "span measures until the guard is dropped"]
pub fn span(name: &'static str) -> SpanGuard {
    if enabled() {
        SpanGuard::armed(name)
    } else {
        SpanGuard::disarmed()
    }
}

/// Start a wall-time measurement: `Some(now)` when recording, `None`
/// when off (no clock read). Pair with [`lap`].
#[inline]
pub fn clock() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Elapsed nanoseconds since a [`clock`] start, or 0 if it was off.
#[inline]
pub fn lap(start: Option<Instant>) -> u64 {
    match start {
        Some(t) => t.elapsed().as_nanos() as u64,
        None => 0,
    }
}
