//! **StreamGreedy** (Gomes & Krause 2010), paper Alg. 5: fill the summary
//! unconditionally, then swap an incoming element for the summary element
//! whose replacement improves `f` the most, if the improvement is ≥ ν.
//! O(K) queries per element; only reaches ½−ε with multiple passes, which
//! is why the paper excludes it from the main comparison (we include it in
//! the Table 1 resource bench).

use crate::functions::{swap_delta, SubmodularFunction};
use crate::metrics::AlgoStats;

use super::StreamingAlgorithm;

/// Swap-based streaming greedy with a fixed improvement threshold ν.
pub struct StreamGreedy {
    oracle: Box<dyn SubmodularFunction>,
    k: usize,
    nu: f64,
    elements: u64,
    peak_stored: usize,
}

impl StreamGreedy {
    pub fn new(oracle: Box<dyn SubmodularFunction>, k: usize, nu: f64) -> Self {
        assert!(k > 0);
        assert!(nu >= 0.0, "improvement threshold must be non-negative");
        StreamGreedy { oracle, k, nu, elements: 0, peak_stored: 0 }
    }
}

impl StreamingAlgorithm for StreamGreedy {
    fn name(&self) -> String {
        "StreamGreedy".into()
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        if self.oracle.len() < self.k {
            self.oracle.accept(item);
        } else {
            // Best swap: argmax_u f(S \ {u} ∪ {e}). swap_delta(0, ·) probes
            // the front element and rotates it to the back, so K probes of
            // position 0 evaluate every element exactly once *and* restore
            // the original order — keeping index bookkeeping trivial.
            let mut best = (f64::NEG_INFINITY, usize::MAX);
            for idx in 0..self.k {
                let delta = swap_delta(self.oracle.as_mut(), 0, item);
                if delta > best.0 {
                    best = (delta, idx);
                }
            }
            if best.0 >= self.nu {
                self.oracle.remove(best.1);
                self.oracle.accept(item);
            }
        }
        if self.oracle.len() > self.peak_stored {
            self.peak_stored = self.oracle.len();
        }
    }

    fn value(&self) -> f64 {
        self.oracle.current_value()
    }

    fn summary(&self) -> Vec<f32> {
        self.oracle.summary().to_vec()
    }

    fn summary_len(&self) -> usize {
        self.oracle.len()
    }

    fn dim(&self) -> usize {
        self.oracle.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        AlgoStats {
            queries: self.oracle.queries(),
            kernel_evals: self.oracle.kernel_evals(),
            elements: self.elements,
            stored: self.oracle.len(),
            peak_stored: self.peak_stored,
            instances: 1,
            wall_kernel_ns: self.oracle.wall_kernel_ns(),
            wall_solve_ns: self.oracle.wall_solve_ns(),
            wall_scan_ns: 0,
            ..Default::default()
        }
    }

    fn reset(&mut self) {
        self.oracle.reset();
        self.elements = 0;
        self.peak_stored = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn fills_then_improves() {
        let ds = testkit::clustered(600, 1);
        let k = 6;
        let mut algo = StreamGreedy::new(testkit::oracle(k), k, 1e-4);
        // Value after the first K items:
        for i in 0..k {
            algo.process(ds.row(i));
        }
        let v0 = algo.value();
        for i in k..ds.len() {
            algo.process(ds.row(i));
        }
        assert!(algo.value() >= v0 - 1e-9, "swaps must never decrease f");
        assert_eq!(algo.summary_len(), k);
    }

    #[test]
    fn swap_requires_nu_improvement() {
        let k = 3;
        let d = testkit::DIM;
        // Huge nu: no swap ever fires.
        let mut algo = StreamGreedy::new(testkit::oracle(k), k, 1e9);
        let base = vec![0.0f32; d];
        for _ in 0..k {
            algo.process(&base);
        }
        let v0 = algo.value();
        let far = vec![50.0f32; d];
        algo.process(&far);
        assert!((algo.value() - v0).abs() < 1e-12, "nu = 1e9 must block swaps");
    }

    #[test]
    fn queries_are_order_k_per_element() {
        let ds = testkit::clustered(120, 2);
        let k = 5;
        let mut algo = StreamGreedy::new(testkit::oracle(k), k, 1e-4);
        testkit::run(&mut algo, &ds);
        let qpe = algo.stats().queries_per_element();
        // swap_delta costs ~3 oracle ops per index -> ~3K per element.
        assert!(qpe > k as f64, "qpe {qpe} should exceed K={k}");
        assert!(qpe < (5 * k) as f64, "qpe {qpe} unexpectedly large");
    }

    #[test]
    fn memory_stays_at_k() {
        let ds = testkit::clustered(200, 3);
        let k = 4;
        let mut algo = StreamGreedy::new(testkit::oracle(k), k, 1e-3);
        testkit::run(&mut algo, &ds);
        assert_eq!(algo.stats().peak_stored, k);
    }
}
