//! **Stream Clipper** (Zhou & Bilmes 2016): single-threshold streaming
//! with a two-sided clip. Each arriving item's marginal gain is compared
//! against *two* bars derived from the running SieveStreaming threshold
//! `τ = (v/2 − f(S)) / (K − |S|)`:
//!
//! * `Δ ≥ α·τ` — accept immediately (the classic sieve decision,
//!   tightened by `α ≥ 1` or loosened by `α < 1`);
//! * `β·τ ≤ Δ < α·τ` — *defer*: the item lands in a bounded buffer
//!   (capacity `2K`, min-gain eviction) instead of being discarded;
//! * `Δ < β·τ` — reject outright.
//!
//! At budget exhaustion ([`StreamingAlgorithm::finalize`]) the deferred
//! buffer is drained in two stages: unfilled summary slots are topped up
//! greedily from the buffer, then each remaining deferred row challenges
//! the summary's weakest member (smallest recorded accept-time
//! contribution) and swaps in when its current marginal gain strictly
//! beats that contribution. The paper's bound-tracking swap test is
//! rendered here with recorded contributions — stale after earlier swaps,
//! which is the usual one-pass compromise and is documented where it
//! matters.
//!
//! The whole algorithm is one [`Sieve`] on the shared chassis: the OPT
//! anchor is the upper grid point `v = K·max_singleton`, so batching
//! (`peek_gain_batch` rejection runs), the shared kernel-panel broker
//! (`begin_shared_chunk`/`gains_shared`) and the 2-D
//! (unit × candidate-range) solve grid all apply unchanged. The deferred
//! buffer is a pure side effect of the shared first-hit scan
//! ([`clip_first_hit`]), so the scalar path, the unit-serial batched
//! path and the grid's Phase B produce bit-identical buffers by
//! construction.

use std::cell::RefCell;

use crate::exec::ExecContext;
use crate::functions::{ChunkPanel, PanelScratch, SharedRowStore, SubmodularFunction};
use crate::metrics::AlgoStats;
use crate::util::json::Json;

use super::{
    build_union_panel, offer_chunk_grid, sieve_threshold, union_row_ids, Sieve, SolveGrid,
    StreamingAlgorithm,
};

/// Bounded deferred-item buffer: row-major feature rows plus the
/// defer-time gain that admitted each. At capacity, a new row replaces
/// the current minimum-gain entry (first such slot on ties) only when
/// its gain is *strictly* larger — ties keep the incumbent, so the
/// buffer contents are a deterministic function of the decision
/// sequence.
struct ClipBuffer {
    dim: usize,
    cap: usize,
    rows: Vec<f32>,
    gains: Vec<f64>,
    /// Clip-zone decisions observed while obs recording was on. A defer
    /// is *also* a chassis reject (the item did not enter the summary),
    /// so `defers <= rejects` in the reported stats. Counts decisions,
    /// not occupancy: evicting pushes still count.
    deferred: u64,
}

impl ClipBuffer {
    fn new(dim: usize, cap: usize) -> Self {
        ClipBuffer { dim, cap, rows: Vec::new(), gains: Vec::new(), deferred: 0 }
    }

    fn len(&self) -> usize {
        self.gains.len()
    }

    fn is_empty(&self) -> bool {
        self.gains.is_empty()
    }

    /// Defer a row. Returns whether it was kept (insert or eviction).
    fn push(&mut self, row: &[f32], gain: f64) -> bool {
        debug_assert_eq!(row.len(), self.dim);
        if self.len() < self.cap {
            self.rows.extend_from_slice(row);
            self.gains.push(gain);
            return true;
        }
        let mut i_min = 0usize;
        for (i, &g) in self.gains.iter().enumerate().skip(1) {
            if g < self.gains[i_min] {
                i_min = i;
            }
        }
        if gain > self.gains[i_min] {
            // The replacement inherits the evicted slot, so later drains
            // see a deterministic order on every path.
            self.rows[i_min * self.dim..(i_min + 1) * self.dim].copy_from_slice(row);
            self.gains[i_min] = gain;
            return true;
        }
        false
    }

    /// Remove and return entry `i` (shifts later entries down).
    fn remove(&mut self, i: usize) -> Vec<f32> {
        self.gains.remove(i);
        self.rows.drain(i * self.dim..(i + 1) * self.dim).collect()
    }

    fn clear(&mut self) {
        self.rows.clear();
        self.gains.clear();
    }
}

/// The two-bar first-hit scan shared by the scalar path,
/// [`consume_chunk`], [`consume_chunk_shared`] and the grid driver's
/// Phase B: returns the first index (relative to `gains[0]`, which sits
/// at chunk-absolute `pos`) whose gain clears the accept bar `α·τ`,
/// deferring every scanned item in the clip zone `[β·τ, α·τ)` into the
/// buffer along the way. The grid calls this exactly once per rejection
/// run with authoritative oracle state, so the buffer side effect is
/// identical across execution strategies. `base` is the absolute stream
/// index of the chunk's first row, used only for defer telemetry (the
/// emitted `tau` is the *unscaled* sieve threshold; the clip zone is
/// `[β·τ, α·τ)`).
#[allow(clippy::too_many_arguments)]
fn clip_first_hit(
    alpha: f64,
    beta: f64,
    v: f64,
    oracle: &dyn SubmodularFunction,
    k: usize,
    gains: &[f64],
    chunk: &[f32],
    dim: usize,
    pos: usize,
    base: u64,
    buffer: &mut ClipBuffer,
) -> Option<usize> {
    let tau = sieve_threshold(v, oracle.current_value(), k, oracle.len());
    for (j, &g) in gains.iter().enumerate() {
        if g >= alpha * tau {
            return Some(j);
        }
        if g >= beta * tau {
            if crate::obs::enabled() {
                buffer.deferred += 1;
                crate::obs::emit_event(crate::obs::Event::Defer {
                    element: base + (pos + j) as u64,
                    gain: g,
                    tau,
                });
            }
            buffer.push(&chunk[(pos + j) * dim..(pos + j + 1) * dim], g);
        }
    }
    None
}

/// One chunk through the clip sieve: one gain panel per rejection run,
/// an acceptance re-batches from the next item (τ depends on the new
/// summary). Returns the speculative gain evaluations past acceptances
/// (see `Sieve::offer_batch` for the accounting argument).
#[allow(clippy::too_many_arguments)]
fn consume_chunk(
    sieve: &mut Sieve,
    buffer: &mut ClipBuffer,
    contributions: &mut Vec<f64>,
    alpha: f64,
    beta: f64,
    chunk: &[f32],
    d: usize,
    k: usize,
    base: u64,
) -> u64 {
    let total = chunk.len() / d;
    let mut pos = 0usize;
    let mut wasted = 0u64;
    while pos < total {
        if sieve.oracle.len() >= k {
            break; // full: the scalar path stops querying too
        }
        let remaining = total - pos;
        sieve.oracle.peek_gain_batch(&chunk[pos * d..], remaining, &mut sieve.scratch);
        let hit = clip_first_hit(
            alpha,
            beta,
            sieve.v,
            sieve.oracle.as_ref(),
            k,
            &sieve.scratch[..remaining],
            chunk,
            d,
            pos,
            base,
            buffer,
        );
        if crate::obs::enabled() {
            // Decision telemetry against the accept bar α·τ (pre-accept
            // oracle state; defers were already logged by the scan).
            let tau =
                sieve_threshold(sieve.v, sieve.oracle.current_value(), k, sieve.oracle.len());
            sieve.note_run(remaining, hit, alpha * tau);
        }
        match hit {
            Some(j) => {
                let gain = sieve.scratch[j];
                sieve.oracle.accept(&chunk[(pos + j) * d..(pos + j + 1) * d]);
                contributions.push(gain);
                wasted += (remaining - (j + 1)) as u64;
                pos += j + 1;
            }
            None => {
                pos = total;
            }
        }
    }
    wasted
}

/// [`consume_chunk`] under the shared kernel-panel broker: identical
/// decisions, buffer contents and query accounting, gains gathered from
/// the chunk panel. Falls back to the per-sieve path if the sieve cannot
/// bind.
#[allow(clippy::too_many_arguments)]
fn consume_chunk_shared(
    sieve: &mut Sieve,
    buffer: &mut ClipBuffer,
    contributions: &mut Vec<f64>,
    alpha: f64,
    beta: f64,
    panel: &ChunkPanel,
    chunk: &[f32],
    d: usize,
    k: usize,
    base: u64,
) -> u64 {
    if sieve.oracle.len() >= k {
        return 0;
    }
    if !sieve.begin_shared_chunk(panel) {
        return consume_chunk(sieve, buffer, contributions, alpha, beta, chunk, d, k, base);
    }
    let total = chunk.len() / d;
    let mut pos = 0usize;
    let mut wasted = 0u64;
    while pos < total {
        if sieve.oracle.len() >= k {
            break;
        }
        let remaining = total - pos;
        sieve.gains_shared(panel, pos, remaining);
        let hit = clip_first_hit(
            alpha,
            beta,
            sieve.v,
            sieve.oracle.as_ref(),
            k,
            &sieve.scratch[..remaining],
            chunk,
            d,
            pos,
            base,
            buffer,
        );
        if crate::obs::enabled() {
            let tau =
                sieve_threshold(sieve.v, sieve.oracle.current_value(), k, sieve.oracle.len());
            sieve.note_run(remaining, hit, alpha * tau);
        }
        match hit {
            Some(j) => {
                let gain = sieve.scratch[j];
                sieve.accept_shared(panel, chunk, d, pos + j);
                contributions.push(gain);
                wasted += (remaining - (j + 1)) as u64;
                pos += j + 1;
            }
            None => {
                pos = total;
            }
        }
    }
    wasted
}

/// The Stream Clipper algorithm (see module docs).
pub struct StreamClipper {
    proto: Box<dyn SubmodularFunction>,
    k: usize,
    /// Accept-bar multiplier on the sieve threshold (`Δ ≥ α·τ`).
    alpha: f64,
    /// Defer-bar multiplier (`Δ ≥ β·τ` lands in the buffer).
    beta: f64,
    sieve: Sieve,
    buffer: ClipBuffer,
    /// Accept-time marginal gain per summary row, in oracle row order —
    /// the "weakest member" record the finalize swap stage challenges.
    contributions: Vec<f64>,
    elements: u64,
    /// Speculative batch gains past an acceptance; excluded from
    /// reported query stats (see `Sieve::offer_batch`).
    speculative_queries: u64,
    /// Kernel entries spent on shared chunk panels (once per chunk).
    panel_evals: u64,
    /// Broker toggle (bench/parity hook).
    share_panels: bool,
    peak_stored: usize,
    /// Pre-restore counters carried across checkpoint/resume (the
    /// ThreeSieves rebasing convention).
    restored_queries: u64,
    restored_kernel_evals: u64,
    discounted_kernel_evals: u64,
    panel_scratch: PanelScratch,
    solve_pool: SolveGrid,
    exec: ExecContext,
}

impl StreamClipper {
    /// `alpha`/`beta` scale the running sieve threshold into the accept
    /// and defer bars; the paper's regime is `α ≥ 1 ≥ β > 0` but any
    /// `α ≥ β > 0` is accepted. The OPT anchor is `v = K·max_singleton`
    /// (the top of the sieve grid), so the clip buffer — not a threshold
    /// grid — absorbs the guess error.
    pub fn new(mut proto: Box<dyn SubmodularFunction>, k: usize, alpha: f64, beta: f64) -> Self {
        assert!(k > 0, "k must be positive");
        assert!(alpha >= beta && beta > 0.0, "need alpha >= beta > 0");
        let dim = proto.dim();
        if let Some(ps) = proto.panel_sharing() {
            ps.attach_row_store(SharedRowStore::new(dim));
        }
        let v = k as f64 * proto.max_singleton_value();
        let sieve = Sieve::new(v, proto.as_ref());
        StreamClipper {
            proto,
            k,
            alpha,
            beta,
            sieve,
            buffer: ClipBuffer::new(dim, 2 * k),
            contributions: Vec::new(),
            elements: 0,
            speculative_queries: 0,
            panel_evals: 0,
            share_panels: true,
            peak_stored: 0,
            restored_queries: 0,
            restored_kernel_evals: 0,
            discounted_kernel_evals: 0,
            panel_scratch: PanelScratch::default(),
            solve_pool: SolveGrid::default(),
            exec: ExecContext::sequential(),
        }
    }

    /// Force the per-sieve panel path (`false`) or restore the default
    /// shared-broker path (`true`). Bit-identical either way; only
    /// `kernel_evals` moves.
    pub fn set_panel_sharing(&mut self, on: bool) {
        self.share_panels = on;
    }

    /// Deferred-buffer occupancy (bench/test hook).
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    fn build_shared_panel(&mut self, chunk: &[f32]) -> Option<ChunkPanel> {
        if !self.share_panels || chunk.is_empty() || self.sieve.oracle.len() >= self.k {
            return None;
        }
        let ids = union_row_ids(std::iter::once(&mut self.sieve.oracle), self.k)?;
        build_union_panel(&mut self.proto, &ids, chunk, &self.exec, &mut self.panel_scratch)
    }

    fn note_peak(&mut self) {
        let stored = self.sieve.oracle.len() + self.buffer.len();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }
}

impl StreamingAlgorithm for StreamClipper {
    fn name(&self) -> String {
        "StreamClipper".into()
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        if self.sieve.oracle.len() >= self.k {
            // Full summaries stop scanning (sieve semantics); the swap
            // stage works off the already-buffered deferrals.
            return;
        }
        let (alpha, beta, k) = (self.alpha, self.beta, self.k);
        let d = self.proto.dim();
        let base = self.elements - 1;
        let StreamClipper { sieve, buffer, contributions, .. } = self;
        let gain = sieve.oracle.peek_gain(item);
        let hit = clip_first_hit(
            alpha,
            beta,
            sieve.v,
            sieve.oracle.as_ref(),
            k,
            &[gain],
            item,
            d,
            0,
            base,
            buffer,
        );
        if crate::obs::enabled() {
            let tau =
                sieve_threshold(sieve.v, sieve.oracle.current_value(), k, sieve.oracle.len());
            sieve.note_one(hit.is_some(), gain, alpha * tau);
        }
        if hit.is_some() {
            sieve.oracle.accept(item);
            contributions.push(gain);
        }
        self.note_peak();
    }

    /// Batched ingestion on the shared chassis: one gain panel per
    /// rejection run, the broker's chunk panel when attached, and the
    /// 2-D solve grid when an exec pool is attached — all bit-identical
    /// to the scalar path (including the deferred buffer, which is a
    /// side effect of the shared [`clip_first_hit`] scan).
    fn process_batch(&mut self, chunk: &[f32]) {
        let d = self.proto.dim();
        debug_assert_eq!(chunk.len() % d, 0, "chunk not row-aligned");
        self.elements += (chunk.len() / d) as u64;
        let base = self.elements - (chunk.len() / d) as u64;
        let (alpha, beta, k) = (self.alpha, self.beta, self.k);
        let shared = self.build_shared_panel(chunk);
        let wasted: u64 = match &shared {
            Some(panel) => {
                let grid = if self.exec.is_parallel() {
                    let StreamClipper { sieve, buffer, contributions, solve_pool, exec, .. } =
                        self;
                    // Phase B of the grid is sequential, so the RefCells
                    // are never contended — they only satisfy the Fn
                    // closure bound.
                    let buffer = RefCell::new(buffer);
                    let contributions = RefCell::new(contributions);
                    let mut refs = [&mut *sieve];
                    offer_chunk_grid(
                        &mut refs,
                        panel,
                        chunk,
                        d,
                        k,
                        exec,
                        solve_pool,
                        |_, v, oracle, gains, pos| {
                            let hit = clip_first_hit(
                                alpha,
                                beta,
                                v,
                                oracle,
                                k,
                                gains,
                                chunk,
                                d,
                                pos,
                                base,
                                &mut buffer.borrow_mut(),
                            );
                            if let Some(j) = hit {
                                contributions.borrow_mut().push(gains[j]);
                            }
                            hit
                        },
                    )
                } else {
                    None
                };
                match grid {
                    Some(w) => w,
                    None => {
                        let StreamClipper { sieve, buffer, contributions, .. } = self;
                        consume_chunk_shared(
                            sieve,
                            buffer,
                            contributions,
                            alpha,
                            beta,
                            panel,
                            chunk,
                            d,
                            k,
                            base,
                        )
                    }
                }
            }
            None => {
                let StreamClipper { sieve, buffer, contributions, .. } = self;
                consume_chunk(sieve, buffer, contributions, alpha, beta, chunk, d, k, base)
            }
        };
        if let Some(panel) = shared {
            self.panel_evals += panel.evals();
            self.panel_scratch.recycle(panel);
        }
        self.speculative_queries += wasted;
        self.note_peak();
    }

    /// Budget-exhaustion drain of the deferred buffer, idempotent (the
    /// buffer empties). Stage 1 tops up unfilled slots greedily; stage 2
    /// lets every remaining deferral challenge the weakest member by
    /// recorded contribution and swap in when its *current* gain
    /// strictly beats it. Runs sequentially on every path, so batched
    /// and scalar runs finalize identically.
    fn finalize(&mut self) {
        let k = self.k;
        let StreamClipper { sieve, buffer, contributions, .. } = self;
        // Stage 1: fill remaining slots with the best buffered rows.
        while sieve.oracle.len() < k && !buffer.is_empty() {
            let n = buffer.len();
            sieve.oracle.peek_gain_batch(&buffer.rows, n, &mut sieve.scratch);
            let mut best = 0usize;
            for j in 1..n {
                if sieve.scratch[j] > sieve.scratch[best] {
                    best = j;
                }
            }
            let gain = sieve.scratch[best];
            let row = buffer.remove(best);
            sieve.oracle.accept(&row);
            contributions.push(gain);
        }
        // Stage 2: swap-in challenges, in buffer order. The recorded
        // contributions go stale as swaps land — the standard one-pass
        // compromise for a streaming swap rule.
        while !buffer.is_empty() {
            let row = buffer.remove(0);
            debug_assert!(!contributions.is_empty(), "full summary implies contributions");
            let gain = sieve.oracle.peek_gain(&row);
            let mut i_min = 0usize;
            for (i, &c) in contributions.iter().enumerate().skip(1) {
                if c < contributions[i_min] {
                    i_min = i;
                }
            }
            if gain > contributions[i_min] {
                sieve.oracle.remove(i_min);
                contributions.remove(i_min);
                sieve.oracle.accept(&row);
                contributions.push(gain);
            }
        }
    }

    fn set_exec(&mut self, exec: ExecContext) {
        self.exec = exec.gated(self.proto.as_ref());
    }

    fn value(&self) -> f64 {
        self.sieve.oracle.current_value()
    }

    fn summary(&self) -> Vec<f32> {
        self.sieve.oracle.summary().to_vec()
    }

    fn summary_len(&self) -> usize {
        self.sieve.oracle.len()
    }

    fn dim(&self) -> usize {
        self.proto.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        let stored = self.sieve.oracle.len() + self.buffer.len();
        AlgoStats {
            queries: (self.sieve.oracle.queries() + self.restored_queries)
                .saturating_sub(self.speculative_queries),
            kernel_evals: (self.sieve.oracle.kernel_evals()
                + self.panel_evals
                + self.restored_kernel_evals)
                .saturating_sub(self.discounted_kernel_evals),
            elements: self.elements,
            stored,
            peak_stored: self.peak_stored.max(stored),
            instances: 1,
            wall_kernel_ns: self.sieve.oracle.wall_kernel_ns(),
            wall_solve_ns: self.sieve.oracle.wall_solve_ns(),
            wall_scan_ns: self.sieve.scan_ns,
            accepts: self.sieve.accepts,
            rejects: self.sieve.rejects,
            // Defers are a subset of rejects: a clip-zone item is
            // buffered *and* counted as a chassis reject.
            defers: self.buffer.deferred,
            threshold_moves: 0,
        }
    }

    fn reset(&mut self) {
        // Reported query/kernel totals stay cumulative across a drift
        // reset (the ThreeSieves convention): fold the current totals
        // into the restored baseline, then rebuild from scratch with a
        // fresh row store so dropped rows don't pin the broker's memory.
        let st = self.stats();
        self.restored_queries = st.queries;
        self.restored_kernel_evals = st.kernel_evals;
        self.speculative_queries = 0;
        self.discounted_kernel_evals = 0;
        self.panel_evals = 0;
        self.elements = 0;
        self.peak_stored = 0;
        self.buffer.clear();
        // Decision telemetry restarts with the rebuilt sieve (whose
        // accept/reject counters zero below), unlike the cumulative
        // query totals.
        self.buffer.deferred = 0;
        self.contributions.clear();
        let dim = self.proto.dim();
        if let Some(ps) = self.proto.panel_sharing() {
            ps.attach_row_store(SharedRowStore::new(dim));
        }
        self.sieve = Sieve::new(self.sieve.v, self.proto.as_ref());
    }

    /// Resumable state: the deferred buffer and the accept-time
    /// contribution record ride along with the counters — the summary
    /// rows themselves travel via the checkpoint's summary payload and
    /// are replayed through `accept` on restore, which reproduces the
    /// Cholesky factor bit-for-bit.
    fn snapshot_state(&self) -> Option<Json> {
        if !self.sieve.v.is_finite() {
            return None;
        }
        let st = self.stats();
        let nums = |xs: &[f64]| Json::Arr(xs.iter().map(|&x| Json::num(x)).collect());
        let rows = Json::Arr(self.buffer.rows.iter().map(|&x| Json::num(x as f64)).collect());
        Some(Json::obj(vec![
            ("algo", Json::str("stream-clipper")),
            ("k", Json::num(self.k as f64)),
            ("dim", Json::num(self.proto.dim() as f64)),
            ("alpha", Json::num(self.alpha)),
            ("beta", Json::num(self.beta)),
            ("v", Json::num(self.sieve.v)),
            ("elements", Json::num(self.elements as f64)),
            ("queries", Json::num(st.queries as f64)),
            ("kernel_evals", Json::num(st.kernel_evals as f64)),
            ("peak_stored", Json::num(self.peak_stored as f64)),
            ("buffer_rows", rows),
            ("buffer_gains", nums(&self.buffer.gains)),
            ("contributions", nums(&self.contributions)),
        ]))
    }

    fn restore_state(&mut self, state: &Json, summary: &[f32]) -> Result<(), String> {
        let field = |name: &str| -> Result<f64, String> {
            state.get(name).as_f64().ok_or_else(|| format!("checkpoint state missing {name:?}"))
        };
        let floats = |name: &str| -> Result<Vec<f64>, String> {
            let arr = state
                .get(name)
                .as_arr()
                .ok_or_else(|| format!("checkpoint state missing {name:?}"))?;
            arr.iter()
                .map(|j| j.as_f64().ok_or_else(|| format!("checkpoint {name} holds a non-number")))
                .collect()
        };
        match state.get("algo").as_str() {
            Some("stream-clipper") => {}
            _ => return Err("checkpoint algo mismatch (want stream-clipper)".into()),
        }
        let d = self.proto.dim();
        if field("k")? as usize != self.k {
            return Err("checkpoint k mismatch".into());
        }
        if field("dim")? as usize != d {
            return Err("checkpoint dim mismatch".into());
        }
        let same = |name: &str, mine: f64| -> Result<(), String> {
            if field(name)?.to_bits() != mine.to_bits() {
                return Err(format!("checkpoint {name} mismatch"));
            }
            Ok(())
        };
        same("alpha", self.alpha)?;
        same("beta", self.beta)?;
        same("v", self.sieve.v)?;
        if summary.len() % d != 0 || summary.len() / d > self.k {
            return Err("checkpoint summary malformed".into());
        }
        let elements = field("elements")? as u64;
        let queries = field("queries")? as u64;
        let kernel_evals = state.get("kernel_evals").as_f64().unwrap_or(0.0) as u64;
        let peak = field("peak_stored")? as usize;
        let rows = floats("buffer_rows")?;
        let gains = floats("buffer_gains")?;
        let contributions = floats("contributions")?;
        if rows.len() != gains.len() * d {
            return Err("checkpoint buffer rows/gains inconsistent".into());
        }
        if gains.len() > self.buffer.cap {
            return Err("checkpoint buffer exceeds capacity".into());
        }
        if contributions.len() != summary.len() / d {
            return Err("checkpoint contributions/summary inconsistent".into());
        }
        // All fields validated — mutate. A fresh store + sieve, then a
        // replay of the summary through `accept`, reproduces the exact
        // factor the snapshot saw.
        if let Some(ps) = self.proto.panel_sharing() {
            ps.attach_row_store(SharedRowStore::new(d));
        }
        self.sieve = Sieve::new(self.sieve.v, self.proto.as_ref());
        for row in summary.chunks_exact(d) {
            self.sieve.oracle.accept(row);
        }
        self.buffer.rows = rows.into_iter().map(|x| x as f32).collect();
        self.buffer.gains = gains;
        self.contributions = contributions;
        self.elements = elements;
        self.peak_stored = peak;
        self.panel_evals = 0;
        // Rebase: replay work is bookkeeping, not new queries.
        self.speculative_queries = self.sieve.oracle.queries();
        self.restored_queries = queries;
        self.discounted_kernel_evals = self.sieve.oracle.kernel_evals();
        self.restored_kernel_evals = kernel_evals;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn clip_buffer_evicts_min_gain_strictly() {
        let mut b = ClipBuffer::new(2, 2);
        assert!(b.push(&[1.0, 0.0], 1.0));
        assert!(b.push(&[2.0, 0.0], 2.0));
        // At capacity: 1.5 strictly beats the min (1.0) and takes its slot.
        assert!(b.push(&[3.0, 0.0], 1.5));
        assert_eq!(b.gains, vec![1.5, 2.0]);
        assert_eq!(b.rows, vec![3.0, 0.0, 2.0, 0.0]);
        // Below the min: rejected.
        assert!(!b.push(&[4.0, 0.0], 0.5));
        // Equal to the min: ties keep the incumbent.
        assert!(!b.push(&[4.0, 0.0], 1.5));
        assert_eq!(b.gains, vec![1.5, 2.0]);
    }

    #[test]
    fn fills_summary_and_tracks_greedy() {
        let ds = testkit::clustered(2500, 1);
        let k = 8;
        let greedy = testkit::greedy_value(&ds, k);
        let mut algo = StreamClipper::new(testkit::oracle(k), k, 1.0, 0.5);
        testkit::run(&mut algo, &ds);
        assert_eq!(algo.summary_len(), k);
        assert!(algo.buffered() == 0, "finalize must drain the buffer");
        let rel = algo.value() / greedy;
        assert!(rel > 0.5, "relative performance {rel:.3}");
        // Memory bound: summary + bounded buffer, never more.
        assert!(algo.stats().peak_stored <= 3 * k);
    }

    #[test]
    fn buffer_swap_fills_at_budget_exhaustion() {
        // An accept bar nothing clears (alpha = 10 on top of the v = K·m
        // anchor) forces every admitted item through the deferred buffer,
        // so the summary is built *entirely* by the finalize swap-in.
        let ds = testkit::clustered(600, 2);
        let k = 5;
        let mut algo = StreamClipper::new(testkit::oracle(k), k, 10.0, 0.01);
        for row in ds.iter() {
            algo.process(row);
        }
        assert_eq!(algo.summary_len(), 0, "nothing passes the accept bar");
        assert_eq!(algo.buffered(), 2 * k, "buffer fills to capacity");
        algo.finalize();
        assert_eq!(algo.summary_len(), k, "swap-in fills the summary");
        assert_eq!(algo.buffered(), 0);
        assert!(algo.value() > 0.0);
    }

    #[test]
    fn batched_matches_scalar_bitwise() {
        let ds = testkit::clustered(900, 3);
        let k = 6;
        let d = testkit::DIM;
        let mut scalar = StreamClipper::new(testkit::oracle(k), k, 1.0, 0.5);
        let mut batched = StreamClipper::new(testkit::oracle(k), k, 1.0, 0.5);
        for row in ds.iter() {
            scalar.process(row);
        }
        for chunk in ds.raw().chunks(37 * d) {
            batched.process_batch(chunk);
        }
        assert_eq!(scalar.value().to_bits(), batched.value().to_bits());
        assert_eq!(scalar.summary(), batched.summary());
        assert_eq!(scalar.stats().queries, batched.stats().queries);
        assert_eq!(scalar.buffered(), batched.buffered());
        scalar.finalize();
        batched.finalize();
        assert_eq!(scalar.value().to_bits(), batched.value().to_bits());
        assert_eq!(scalar.summary(), batched.summary());
    }

    #[test]
    fn shared_panels_match_plain_batches_bitwise() {
        let ds = testkit::clustered(1100, 4);
        let k = 6;
        let d = testkit::DIM;
        let mut shared = StreamClipper::new(testkit::oracle(k), k, 1.0, 0.5);
        let mut plain = StreamClipper::new(testkit::oracle(k), k, 1.0, 0.5);
        plain.set_panel_sharing(false);
        for chunk in ds.raw().chunks(64 * d) {
            shared.process_batch(chunk);
            plain.process_batch(chunk);
        }
        assert_eq!(shared.value().to_bits(), plain.value().to_bits());
        assert_eq!(shared.summary(), plain.summary());
        let (a, b) = (shared.stats(), plain.stats());
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.peak_stored, b.peak_stored);
        assert!(a.kernel_evals <= b.kernel_evals);
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        let ds = testkit::clustered(1000, 5);
        let k = 6;
        let d = testkit::DIM;
        let half = ds.len() / 2 * d;
        let mut full = StreamClipper::new(testkit::oracle(k), k, 1.0, 0.5);
        for chunk in ds.raw().chunks(64 * d) {
            full.process_batch(chunk);
        }
        let mut first = StreamClipper::new(testkit::oracle(k), k, 1.0, 0.5);
        for chunk in ds.raw()[..half].chunks(64 * d) {
            first.process_batch(chunk);
        }
        let state = first.snapshot_state().expect("resumable state");
        let summary = first.summary();
        let mut resumed = StreamClipper::new(testkit::oracle(k), k, 1.0, 0.5);
        resumed.restore_state(&state, &summary).unwrap();
        for chunk in ds.raw()[half..].chunks(64 * d) {
            resumed.process_batch(chunk);
        }
        assert_eq!(resumed.value().to_bits(), full.value().to_bits());
        assert_eq!(resumed.summary(), full.summary());
        let (a, b) = (resumed.stats(), full.stats());
        assert_eq!(a.queries, b.queries, "queries continue across the pause");
        assert_eq!(a.elements, b.elements);
        assert_eq!(a.stored, b.stored);
        assert_eq!(a.peak_stored, b.peak_stored);
        // The deferred buffer must survive the roundtrip bitwise, so the
        // eventual finalize drains identically.
        resumed.finalize();
        full.finalize();
        assert_eq!(resumed.value().to_bits(), full.value().to_bits());
        assert_eq!(resumed.summary(), full.summary());
    }

    #[test]
    fn restore_rejects_mismatched_state() {
        let k = 4;
        let mut algo = StreamClipper::new(testkit::oracle(k), k, 1.0, 0.5);
        let err = algo.restore_state(&Json::obj(vec![("algo", Json::str("three-sieves"))]), &[]);
        assert!(err.unwrap_err().contains("algo mismatch"));
        let mut other = StreamClipper::new(testkit::oracle(k), k, 2.0, 0.5);
        let state = other.snapshot_state().unwrap();
        let err = algo.restore_state(&state, &other.summary()).unwrap_err();
        assert!(err.contains("alpha"), "{err}");
    }

    #[test]
    fn reset_clears_selection_but_keeps_query_totals() {
        let ds = testkit::clustered(400, 6);
        let k = 5;
        let mut algo = StreamClipper::new(testkit::oracle(k), k, 1.0, 0.5);
        for row in ds.iter() {
            algo.process(row);
        }
        let before = algo.stats();
        assert!(before.queries > 0);
        algo.reset();
        let after = algo.stats();
        assert_eq!(after.elements, 0);
        assert_eq!(after.stored, 0);
        assert_eq!(algo.buffered(), 0);
        assert_eq!(after.queries, before.queries, "totals stay cumulative across drift resets");
    }
}
