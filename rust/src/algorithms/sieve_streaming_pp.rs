//! **SieveStreaming++** (Kazemi et al. 2019), paper Alg. 9: like
//! SieveStreaming, but the best sieve's value LB is a live lower bound for
//! OPT, so sieves with `v < τ_min = max(LB, m)/(2K) · 2K`-equivalent cutoff
//! are deleted and new ones are spawned as the window `[max(LB,m), K·m]`
//! tightens. Same ½−ε guarantee, memory drops to O(K/ε).

use crate::exec::ExecContext;
use crate::functions::{ChunkPanel, PanelScratch, SharedRowStore, SubmodularFunction};
use crate::metrics::AlgoStats;
use crate::util::mathx::threshold_grid;

use super::{
    build_union_panel, gather_gains_grid, sieve_first_hit, sieve_stats, sieve_threshold,
    union_row_ids, Sieve, SolveGrid, StreamingAlgorithm,
};

/// Post-accept bookkeeping shared by the scalar and batched paths: fold the
/// sieve's new value into the OPT lower bound and the champion snapshot.
/// One definition keeps `process` and `process_batch` from drifting apart —
/// the parity contract forbids any divergence between them.
fn record_accept(
    oracle: &dyn SubmodularFunction,
    lb: &mut f64,
    lb_improved: &mut bool,
    best_value: &mut f64,
    best_summary: &mut Vec<f32>,
) {
    let v = oracle.current_value();
    if v > *lb {
        *lb = v;
        *lb_improved = true;
    }
    if v > *best_value {
        *best_value = v;
        *best_summary = oracle.summary().to_vec();
    }
}

/// Dynamic-window multi-sieve thresholding.
pub struct SieveStreamingPP {
    proto: Box<dyn SubmodularFunction>,
    k: usize,
    epsilon: f64,
    sieves: Vec<Sieve>,
    /// Best function value over all sieves so far (the LB of Alg. 9).
    lb: f64,
    m: f64,
    elements: u64,
    peak_stored: usize,
    /// Cumulative queries of sieves that were pruned (so totals stay true).
    retired_queries: u64,
    /// Cumulative kernel evals of pruned sieves (same preservation for
    /// the measured [`AlgoStats::kernel_evals`] counter).
    retired_kernel_evals: u64,
    /// Decision counters carried by pruned sieves (same preservation, for
    /// the obs-gated `AlgoStats::accepts`/`rejects` telemetry).
    retired_accepts: u64,
    retired_rejects: u64,
    /// Next decision-event roster tag — pruning keeps minting fresh ids so
    /// retired and live sieves stay distinguishable in the event log.
    next_tag: u32,
    /// Speculative batch gains past a round's earliest acceptance
    /// (see `process_batch`); excluded from reported query stats.
    speculative_queries: u64,
    /// Kernel entries spent on shared chunk panels (once per chunk).
    panel_evals: u64,
    /// Cross-sieve panel sharing toggle (bench/parity hook).
    share_panels: bool,
    /// Scratch for `process_batch` gain panels (per-sieve fallback path).
    gain_buf: Vec<f64>,
    /// Recycled chunk-panel storage (allocation-free broker path).
    panel_scratch: PanelScratch,
    /// Scratch pool for the 2-D (sieve × candidate-range) solve grid.
    solve_pool: SolveGrid,
    /// Snapshot of the best summary ever observed. Pruning deletes sieves
    /// whose OPT guess fell below LB — which can include the sieve that
    /// *produced* LB. The guarantee says a surviving sieve catches up given
    /// enough remaining stream, but on finite streams the reported output
    /// must never regress, so we keep the champion's summary here.
    best_value: f64,
    best_summary: Vec<f32>,
    /// Execution context: ++'s chunk consumption is inherently coordinated
    /// (the LB refresh couples sieves), so the pool only accelerates the
    /// broker's panel build — see [`StreamingAlgorithm::set_exec`].
    exec: ExecContext,
}

impl SieveStreamingPP {
    pub fn new(mut proto: Box<dyn SubmodularFunction>, k: usize, epsilon: f64) -> Self {
        assert!(k > 0 && epsilon > 0.0);
        let dim = proto.dim();
        if let Some(ps) = proto.panel_sharing() {
            // The broker's row store — shared by every sieve the window
            // spawns, across all prune/spawn refreshes.
            ps.attach_row_store(SharedRowStore::new(dim));
        }
        let m = proto.max_singleton_value();
        let mut s = SieveStreamingPP {
            proto,
            k,
            epsilon,
            sieves: Vec::new(),
            lb: 0.0,
            m,
            elements: 0,
            peak_stored: 0,
            retired_queries: 0,
            retired_kernel_evals: 0,
            retired_accepts: 0,
            retired_rejects: 0,
            next_tag: 0,
            speculative_queries: 0,
            panel_evals: 0,
            share_panels: true,
            gain_buf: Vec::new(),
            panel_scratch: PanelScratch::default(),
            solve_pool: SolveGrid::default(),
            best_value: 0.0,
            best_summary: Vec::new(),
            exec: ExecContext::sequential(),
        };
        s.refresh_sieves();
        s
    }

    /// Force the per-sieve panel path (`false`) or restore the default
    /// shared-broker path (`true`). Both are bit-identical in summaries,
    /// values and reported queries — only `kernel_evals` moves.
    pub fn set_panel_sharing(&mut self, on: bool) {
        self.share_panels = on;
    }

    /// Prune dominated sieves and spawn the grid over the live window
    /// `[max(LB, m), K·m]`.
    fn refresh_sieves(&mut self) {
        let lo = self.lb.max(self.m);
        let hi = self.k as f64 * self.m;
        // Delete sieves whose OPT guess is no longer achievable. Alg. 9
        // removes v once v/(2K)-style thresholds fall below τ_min; in grid
        // terms: v < lo (their summaries can never beat the LB).
        let eps = 1e-12;
        let mut retired_q = 0u64;
        let mut retired_e = 0u64;
        for s in self.sieves.iter().filter(|s| s.v < lo * (1.0 - eps)) {
            retired_q += s.oracle.queries();
            retired_e += s.oracle.kernel_evals();
            self.retired_accepts += s.accepts;
            self.retired_rejects += s.rejects;
            crate::obs::emit_event(crate::obs::Event::SieveRetire { sieve: s.tag, v: s.v });
        }
        self.retired_queries += retired_q;
        self.retired_kernel_evals += retired_e;
        self.sieves.retain(|s| s.v >= lo * (1.0 - eps));
        for v in threshold_grid(self.epsilon, lo, hi) {
            let exists = self.sieves.iter().any(|s| (s.v / v - 1.0).abs() < 1e-9);
            if !exists {
                let mut s = Sieve::new(v, self.proto.as_ref());
                s.tag = self.next_tag;
                self.next_tag += 1;
                crate::obs::emit_event(crate::obs::Event::SieveSpawn { sieve: s.tag, v });
                self.sieves.push(s);
            }
        }
        self.sieves.sort_by(|a, b| a.v.total_cmp(&b.v));
    }

    fn best_sieve(&self) -> Option<&Sieve> {
        // total_cmp, not partial_cmp().unwrap(): a NaN objective must not
        // panic mid-stream (it sorts above every real and surfaces as a
        // visibly broken best instead).
        self.sieves
            .iter()
            .max_by(|a, b| a.oracle.current_value().total_cmp(&b.oracle.current_value()))
    }

    pub fn sieve_count(&self) -> usize {
        self.sieves.len()
    }

    /// Current OPT lower bound (telemetry).
    pub fn lower_bound(&self) -> f64 {
        self.lb
    }

    /// One chunk panel across the union of the live sieves' interned
    /// summary rows (see `SieveStreaming::build_shared_panel`).
    fn build_shared_panel(&mut self, chunk: &[f32]) -> Option<ChunkPanel> {
        if !self.share_panels || chunk.is_empty() {
            return None;
        }
        let ids = union_row_ids(self.sieves.iter_mut().map(|s| &mut s.oracle), self.k)?;
        build_union_panel(&mut self.proto, &ids, chunk, &self.exec, &mut self.panel_scratch)
    }
}

impl StreamingAlgorithm for SieveStreamingPP {
    fn name(&self) -> String {
        "SieveStreaming++".into()
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        let mut lb_improved = false;
        for s in self.sieves.iter_mut() {
            if s.offer(item, self.k) {
                record_accept(
                    s.oracle.as_ref(),
                    &mut self.lb,
                    &mut lb_improved,
                    &mut self.best_value,
                    &mut self.best_summary,
                );
            }
        }
        if lb_improved {
            self.refresh_sieves();
        }
        let stored: usize = self.sieves.iter().map(|s| s.oracle.len()).sum();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }

    /// Batched ingestion. Unlike plain SieveStreaming, ++ couples sieves
    /// through the LB refresh (an acceptance can prune sieves and spawn new
    /// ones that must see the *rest* of the stream), so a sieve cannot
    /// consume the whole chunk on its own. Instead each round scans every
    /// live sieve for its first would-accept position, advances all of
    /// them to the earliest such position p* (items before p* are pure
    /// rejections for every sieve — identical to the scalar order), applies
    /// the acceptances at p* in sieve order, refreshes if LB improved, and
    /// restarts from p*+1.
    ///
    /// Non-accepting sieves **reuse** their gain panel's hit position
    /// across acceptance rounds: a sieve whose summary did not change at
    /// p* has an unchanged threshold and gains, so its cached first hit
    /// (strictly past p*, by p*'s minimality) is still its first hit from
    /// p*+1 — no re-panel. The cache is invalidated per sieve by its own
    /// acceptance, and wholesale across the LB refresh's prune/spawn/sort
    /// (summaries survive a refresh but indices don't, and spawned sieves
    /// must scan the remainder from scratch).
    ///
    /// Under the shared kernel-panel broker the chunk's kernel rows are
    /// computed once up front (union of all live sieves' rows) and every
    /// (re-)scan *gathers* from that panel — the gains, the hit cache and
    /// the accounting below are unchanged, only `kernel_evals` drops.
    /// Sieves spawned by a mid-chunk refresh start empty, so the
    /// chunk-start panel still covers every row they can reference; rows
    /// accepted mid-chunk bind to sieve-local kernel rows
    /// ([`Sieve::accept_shared`]). With a pool attached, each round's
    /// (re-)scans fan out as a 2-D (sieve × candidate-range) task grid
    /// ([`super::gather_gains_grid`]) before the serial hit computation —
    /// previously only the panel build used the pool here.
    ///
    /// Query accounting stays scalar-exact through a telescoping
    /// invariant: a panel taken at position `p` charges `total - p` raw
    /// queries; when it is invalidated after consuming through item `q-1`
    /// its unused tail `total - q` is added to `speculative_queries`, so
    /// its net charge is `q - p` — exactly the scalar path's evaluations
    /// over `[p, q)`. A panel that survives to the chunk end has consumed
    /// everything it charged (`rust/tests/batch_parity.rs` pins this).
    fn process_batch(&mut self, chunk: &[f32]) {
        let d = self.proto.dim();
        debug_assert_eq!(chunk.len() % d, 0, "chunk not row-aligned");
        let total = chunk.len() / d;
        self.elements += total as u64;
        let k = self.k;
        let mut panel = self.build_shared_panel(chunk);
        let bound = match &panel {
            Some(p) => {
                self.panel_evals += p.evals();
                self.sieves.iter_mut().all(|s| s.oracle.len() >= k || s.begin_shared_chunk(p))
            }
            None => true,
        };
        if !bound {
            panel = None; // defensive: keep the per-sieve path
        }
        let mut scratch = std::mem::take(&mut self.gain_buf);
        let mut pos = 0usize;
        // Hit cache, indexed like `self.sieves`: `None` = needs a panel;
        // `Some(h)` = valid panel whose first would-accept position is the
        // absolute chunk index `h` (`Some(None)` = rejects through chunk
        // end). Full sieves stay `None` and are skipped — they neither
        // query nor accept, same as the scalar path.
        let mut hits: Vec<Option<Option<usize>>> = vec![None; self.sieves.len()];
        while pos < total {
            let remaining = total - pos;
            // (Re-)panel only the sieves whose cache was invalidated.
            // Within a rejection run each sieve's threshold is constant
            // (its own f(S)/|S| only move on its own accept). Under a
            // parallel context the invalidated sieves' gathered solves
            // fan out first as one 2-D (sieve × candidate-range) task
            // grid — ++'s chunk consumption is otherwise
            // coordinator-serial (the LB refresh couples sieves), so the
            // grid is where its solve parallelism comes from. Gains and
            // query charges are identical to `gains_shared`
            // (`gather_gains_grid` documents the argument); the serial
            // loop below fills whatever the grid did not.
            let mut grid_filled = false;
            if let Some(p) = &panel {
                if self.exec.is_parallel() {
                    let mut runs: Vec<(usize, &mut Sieve)> = self
                        .sieves
                        .iter_mut()
                        .zip(hits.iter())
                        .filter(|(s, hit)| {
                            hit.is_none()
                                && s.oracle.len() < k
                                && s.oracle.panel_sharing_ref().is_some()
                        })
                        .map(|(s, _)| (pos, s))
                        .collect();
                    if !runs.is_empty() {
                        gather_gains_grid(&mut runs, p, total, &self.exec, &mut self.solve_pool);
                        grid_filled = true;
                    }
                }
            }
            for (s, hit) in self.sieves.iter_mut().zip(hits.iter_mut()) {
                if s.oracle.len() >= k || hit.is_some() {
                    continue;
                }
                let gains: &[f64] = match &panel {
                    Some(p) => {
                        if !(grid_filled && s.oracle.panel_sharing_ref().is_some()) {
                            s.gains_shared(p, pos, remaining);
                        }
                        &s.scratch[..remaining]
                    }
                    None => {
                        s.oracle.peek_gain_batch(&chunk[pos * d..], remaining, &mut scratch);
                        &scratch
                    }
                };
                *hit = Some(sieve_first_hit(s.v, s.oracle.as_ref(), k, gains).map(|j| pos + j));
            }
            let p_star = self
                .sieves
                .iter()
                .zip(&hits)
                .filter(|(s, _)| s.oracle.len() < k)
                .filter_map(|(_, hit)| (*hit).flatten())
                .min();
            let Some(j) = p_star else {
                // No sieve accepts anywhere in the rest of the chunk:
                // every live panel is consumed exactly to its scalar
                // extent — nothing is speculative.
                if crate::obs::enabled() {
                    let n = (total - pos) as u64;
                    for s in self.sieves.iter_mut().filter(|s| s.oracle.len() < k) {
                        s.rejects += n;
                    }
                }
                pos = total;
                continue;
            };
            // Items pos..j are rejections everywhere; item j is accepted
            // by every sieve whose first hit is exactly j. The coordinated
            // path resolves hits, not per-item gains, so decision
            // telemetry here is counters in bulk plus one Accept event per
            // acceptance (exact gain recovered as the value delta); the
            // scalar path logs the full per-item stream.
            if crate::obs::enabled() {
                let n_rej = (j - pos) as u64;
                for (s, hit) in self.sieves.iter_mut().zip(hits.iter()) {
                    if s.oracle.len() >= k {
                        continue;
                    }
                    s.rejects += n_rej;
                    if *hit != Some(Some(j)) {
                        s.rejects += 1; // j itself rejects here
                    }
                }
            }
            let item = &chunk[j * d..(j + 1) * d];
            let mut lb_improved = false;
            for (s, hit) in self.sieves.iter_mut().zip(hits.iter_mut()) {
                if s.oracle.len() >= k || *hit != Some(Some(j)) {
                    continue;
                }
                let noted = if crate::obs::enabled() {
                    let tau =
                        sieve_threshold(s.v, s.oracle.current_value(), k, s.oracle.len());
                    Some((s.oracle.current_value(), tau))
                } else {
                    None
                };
                match &panel {
                    Some(p) => s.accept_shared(p, chunk, d, j),
                    None => s.oracle.accept(item),
                }
                if let Some((v_before, tau)) = noted {
                    s.accepts += 1;
                    crate::obs::emit_event(crate::obs::Event::Accept {
                        element: s.accepts + s.rejects - 1,
                        sieve: s.tag,
                        gain: s.oracle.current_value() - v_before,
                        tau,
                    });
                }
                // The accept invalidates this sieve's panel; its unused
                // tail is work the scalar path never did.
                self.speculative_queries += (total - (j + 1)) as u64;
                *hit = None;
                record_accept(
                    s.oracle.as_ref(),
                    &mut self.lb,
                    &mut lb_improved,
                    &mut self.best_value,
                    &mut self.best_summary,
                );
            }
            if lb_improved {
                // Invalidate the whole cache across the prune/spawn/sort:
                // account every surviving panel's unused tail first (for
                // sieves about to be pruned this is also their scalar
                // extent — they stop being offered items after j).
                let live_panels = hits.iter().filter(|h| h.is_some()).count() as u64;
                self.speculative_queries += live_panels * (total - (j + 1)) as u64;
                self.refresh_sieves();
                // Re-bind the rebuilt sieve set to the chunk panel:
                // survivors keep their chunk-local rows, spawned sieves
                // start empty.
                let bound = match &panel {
                    Some(p) => self
                        .sieves
                        .iter_mut()
                        .all(|s| s.oracle.len() >= k || s.rebind_shared(p)),
                    None => true,
                };
                if !bound {
                    panel = None;
                }
                hits.clear();
                hits.resize(self.sieves.len(), None);
            }
            let stored: usize = self.sieves.iter().map(|s| s.oracle.len()).sum();
            if stored > self.peak_stored {
                self.peak_stored = stored;
            }
            pos = j + 1;
        }
        // No trailing stored/peak update: stored only changes at the
        // accept+refresh points above, each already recorded in-loop.
        self.gain_buf = scratch;
        if let Some(p) = panel {
            self.panel_scratch.recycle(p);
        }
    }

    fn set_exec(&mut self, exec: ExecContext) {
        self.exec = exec.gated(self.proto.as_ref());
    }

    fn value(&self) -> f64 {
        let live = self.best_sieve().map(|s| s.oracle.current_value()).unwrap_or(0.0);
        live.max(self.best_value)
    }

    fn summary(&self) -> Vec<f32> {
        let live = self.best_sieve().map(|s| s.oracle.current_value()).unwrap_or(0.0);
        if live >= self.best_value {
            self.best_sieve().map(|s| s.oracle.summary().to_vec()).unwrap_or_default()
        } else {
            self.best_summary.clone()
        }
    }

    fn summary_len(&self) -> usize {
        self.summary().len() / self.proto.dim().max(1)
    }

    fn dim(&self) -> usize {
        self.proto.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        let mut peak = self.peak_stored;
        let mut st = sieve_stats(&self.sieves, self.elements, self.retired_queries, &mut peak);
        st.queries = st.queries.saturating_sub(self.speculative_queries);
        st.kernel_evals += self.retired_kernel_evals + self.panel_evals;
        st.peak_stored = peak.max(self.peak_stored);
        st.accepts += self.retired_accepts;
        st.rejects += self.retired_rejects;
        st
    }

    fn reset(&mut self) {
        self.sieves.clear();
        self.lb = 0.0;
        self.elements = 0;
        self.peak_stored = 0;
        self.retired_queries = 0;
        self.retired_kernel_evals = 0;
        self.retired_accepts = 0;
        self.retired_rejects = 0;
        self.next_tag = 0;
        self.speculative_queries = 0;
        self.panel_evals = 0;
        self.best_value = 0.0;
        self.best_summary.clear();
        // Fresh row store (pruned rows would otherwise pin memory), then
        // respawn the initial window from the prototype.
        let dim = self.proto.dim();
        if let Some(ps) = self.proto.panel_sharing() {
            ps.attach_row_store(SharedRowStore::new(dim));
        }
        self.refresh_sieves();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn prunes_dominated_sieves() {
        let ds = testkit::clustered(2000, 1);
        let k = 8;
        let mut algo = SieveStreamingPP::new(testkit::oracle(k), k, 0.05);
        let before = algo.sieve_count();
        testkit::run(&mut algo, &ds);
        assert!(algo.lower_bound() > 0.0);
        assert!(
            algo.sieve_count() < before,
            "LB growth should prune low sieves: {} -> {}",
            before,
            algo.sieve_count()
        );
    }

    #[test]
    fn matches_sievestreaming_value_on_iid_data() {
        // Paper: "SieveStreaming and SieveStreaming++ show identical
        // behaviour" in maximization performance.
        let ds = testkit::clustered(2500, 2);
        let k = 10;
        let mut ss = super::super::SieveStreaming::new(testkit::oracle(k), k, 0.05);
        let mut pp = SieveStreamingPP::new(testkit::oracle(k), k, 0.05);
        testkit::run(&mut ss, &ds);
        testkit::run(&mut pp, &ds);
        let rel = pp.value() / ss.value();
        assert!(rel > 0.95, "++ {} vs plain {}", pp.value(), ss.value());
    }

    #[test]
    fn uses_less_memory_than_sievestreaming() {
        let ds = testkit::clustered(2500, 3);
        let k = 10;
        let eps = 0.02;
        let mut ss = super::super::SieveStreaming::new(testkit::oracle(k), k, eps);
        let mut pp = SieveStreamingPP::new(testkit::oracle(k), k, eps);
        testkit::run(&mut ss, &ds);
        testkit::run(&mut pp, &ds);
        assert!(
            pp.stats().peak_stored < ss.stats().peak_stored,
            "++ peak {} should undercut plain {}",
            pp.stats().peak_stored,
            ss.stats().peak_stored
        );
    }

    #[test]
    fn query_accounting_includes_retired_sieves() {
        let ds = testkit::clustered(800, 4);
        let k = 6;
        let mut algo = SieveStreamingPP::new(testkit::oracle(k), k, 0.1);
        testkit::run(&mut algo, &ds);
        let st = algo.stats();
        // Retired sieves' queries must be preserved in the total: the sum
        // is at least what the *surviving* sieves alone would report, and
        // strictly positive even if every live sieve filled early.
        assert!(st.queries > 0, "{st:?}");
        let live: u64 = st.queries; // includes retired_queries by contract
        assert!(live >= st.stored as u64, "{st:?}");
        assert!(st.kernel_evals > 0, "retired kernel evals must be preserved too: {st:?}");
    }

    #[test]
    fn shared_panels_match_per_sieve_batches_bitwise() {
        // The broker under ++'s prune/spawn coupling: same summaries,
        // values and reported queries; only kernel_evals may drop.
        let ds = testkit::clustered(1400, 6);
        let k = 6;
        let d = testkit::DIM;
        let mut shared = SieveStreamingPP::new(testkit::oracle(k), k, 0.05);
        let mut plain = SieveStreamingPP::new(testkit::oracle(k), k, 0.05);
        plain.set_panel_sharing(false);
        for chunk in ds.raw().chunks(53 * d) {
            shared.process_batch(chunk);
            plain.process_batch(chunk);
        }
        assert_eq!(shared.value().to_bits(), plain.value().to_bits());
        assert_eq!(shared.summary(), plain.summary());
        let (a, b) = (shared.stats(), plain.stats());
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.peak_stored, b.peak_stored);
        assert_eq!(a.instances, b.instances);
        assert!(
            a.kernel_evals <= b.kernel_evals,
            "shared panels must never evaluate more kernel entries: {} vs {}",
            a.kernel_evals,
            b.kernel_evals
        );
    }

    #[test]
    fn reset_restores_initial_window() {
        let ds = testkit::clustered(500, 5);
        let k = 5;
        let mut algo = SieveStreamingPP::new(testkit::oracle(k), k, 0.1);
        let n0 = algo.sieve_count();
        testkit::run(&mut algo, &ds);
        algo.reset();
        assert_eq!(algo.sieve_count(), n0);
        assert_eq!(algo.lower_bound(), 0.0);
    }
}
