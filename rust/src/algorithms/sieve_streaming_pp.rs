//! **SieveStreaming++** (Kazemi et al. 2019), paper Alg. 9: like
//! SieveStreaming, but the best sieve's value LB is a live lower bound for
//! OPT, so sieves with `v < τ_min = max(LB, m)/(2K) · 2K`-equivalent cutoff
//! are deleted and new ones are spawned as the window `[max(LB,m), K·m]`
//! tightens. Same ½−ε guarantee, memory drops to O(K/ε).

use crate::functions::SubmodularFunction;
use crate::metrics::AlgoStats;
use crate::util::mathx::threshold_grid;

use super::{sieve_stats, Sieve, StreamingAlgorithm};

/// Dynamic-window multi-sieve thresholding.
pub struct SieveStreamingPP {
    proto: Box<dyn SubmodularFunction>,
    k: usize,
    epsilon: f64,
    sieves: Vec<Sieve>,
    /// Best function value over all sieves so far (the LB of Alg. 9).
    lb: f64,
    m: f64,
    elements: u64,
    peak_stored: usize,
    /// Cumulative queries of sieves that were pruned (so totals stay true).
    retired_queries: u64,
    /// Snapshot of the best summary ever observed. Pruning deletes sieves
    /// whose OPT guess fell below LB — which can include the sieve that
    /// *produced* LB. The guarantee says a surviving sieve catches up given
    /// enough remaining stream, but on finite streams the reported output
    /// must never regress, so we keep the champion's summary here.
    best_value: f64,
    best_summary: Vec<f32>,
}

impl SieveStreamingPP {
    pub fn new(proto: Box<dyn SubmodularFunction>, k: usize, epsilon: f64) -> Self {
        assert!(k > 0 && epsilon > 0.0);
        let m = proto.max_singleton_value();
        let mut s = SieveStreamingPP {
            proto,
            k,
            epsilon,
            sieves: Vec::new(),
            lb: 0.0,
            m,
            elements: 0,
            peak_stored: 0,
            retired_queries: 0,
            best_value: 0.0,
            best_summary: Vec::new(),
        };
        s.refresh_sieves();
        s
    }

    /// Prune dominated sieves and spawn the grid over the live window
    /// `[max(LB, m), K·m]`.
    fn refresh_sieves(&mut self) {
        let lo = self.lb.max(self.m);
        let hi = self.k as f64 * self.m;
        // Delete sieves whose OPT guess is no longer achievable. Alg. 9
        // removes v once v/(2K)-style thresholds fall below τ_min; in grid
        // terms: v < lo (their summaries can never beat the LB).
        let eps = 1e-12;
        let retired: u64 = self
            .sieves
            .iter()
            .filter(|s| s.v < lo * (1.0 - eps))
            .map(|s| s.oracle.queries())
            .sum();
        self.retired_queries += retired;
        self.sieves.retain(|s| s.v >= lo * (1.0 - eps));
        for v in threshold_grid(self.epsilon, lo, hi) {
            let exists = self.sieves.iter().any(|s| (s.v / v - 1.0).abs() < 1e-9);
            if !exists {
                self.sieves.push(Sieve::new(v, self.proto.as_ref()));
            }
        }
        self.sieves.sort_by(|a, b| a.v.partial_cmp(&b.v).unwrap());
    }

    fn best_sieve(&self) -> Option<&Sieve> {
        self.sieves
            .iter()
            .max_by(|a, b| a.oracle.current_value().partial_cmp(&b.oracle.current_value()).unwrap())
    }

    pub fn sieve_count(&self) -> usize {
        self.sieves.len()
    }

    /// Current OPT lower bound (telemetry).
    pub fn lower_bound(&self) -> f64 {
        self.lb
    }
}

impl StreamingAlgorithm for SieveStreamingPP {
    fn name(&self) -> String {
        "SieveStreaming++".into()
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        let mut lb_improved = false;
        for s in self.sieves.iter_mut() {
            if s.offer(item, self.k) {
                let v = s.oracle.current_value();
                if v > self.lb {
                    self.lb = v;
                    lb_improved = true;
                }
                if v > self.best_value {
                    self.best_value = v;
                    self.best_summary = s.oracle.summary().to_vec();
                }
            }
        }
        if lb_improved {
            self.refresh_sieves();
        }
        let stored: usize = self.sieves.iter().map(|s| s.oracle.len()).sum();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }

    fn value(&self) -> f64 {
        let live = self.best_sieve().map(|s| s.oracle.current_value()).unwrap_or(0.0);
        live.max(self.best_value)
    }

    fn summary(&self) -> Vec<f32> {
        let live = self.best_sieve().map(|s| s.oracle.current_value()).unwrap_or(0.0);
        if live >= self.best_value {
            self.best_sieve().map(|s| s.oracle.summary().to_vec()).unwrap_or_default()
        } else {
            self.best_summary.clone()
        }
    }

    fn summary_len(&self) -> usize {
        self.summary().len() / self.proto.dim().max(1)
    }

    fn dim(&self) -> usize {
        self.proto.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        let mut peak = self.peak_stored;
        let mut st = sieve_stats(&self.sieves, self.elements, self.retired_queries, &mut peak);
        st.peak_stored = peak.max(self.peak_stored);
        st
    }

    fn reset(&mut self) {
        self.sieves.clear();
        self.lb = 0.0;
        self.elements = 0;
        self.peak_stored = 0;
        self.retired_queries = 0;
        self.best_value = 0.0;
        self.best_summary.clear();
        self.refresh_sieves();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn prunes_dominated_sieves() {
        let ds = testkit::clustered(2000, 1);
        let k = 8;
        let mut algo = SieveStreamingPP::new(testkit::oracle(k), k, 0.05);
        let before = algo.sieve_count();
        testkit::run(&mut algo, &ds);
        assert!(algo.lower_bound() > 0.0);
        assert!(
            algo.sieve_count() < before,
            "LB growth should prune low sieves: {} -> {}",
            before,
            algo.sieve_count()
        );
    }

    #[test]
    fn matches_sievestreaming_value_on_iid_data() {
        // Paper: "SieveStreaming and SieveStreaming++ show identical
        // behaviour" in maximization performance.
        let ds = testkit::clustered(2500, 2);
        let k = 10;
        let mut ss = super::super::SieveStreaming::new(testkit::oracle(k), k, 0.05);
        let mut pp = SieveStreamingPP::new(testkit::oracle(k), k, 0.05);
        testkit::run(&mut ss, &ds);
        testkit::run(&mut pp, &ds);
        let rel = pp.value() / ss.value();
        assert!(rel > 0.95, "++ {} vs plain {}", pp.value(), ss.value());
    }

    #[test]
    fn uses_less_memory_than_sievestreaming() {
        let ds = testkit::clustered(2500, 3);
        let k = 10;
        let eps = 0.02;
        let mut ss = super::super::SieveStreaming::new(testkit::oracle(k), k, eps);
        let mut pp = SieveStreamingPP::new(testkit::oracle(k), k, eps);
        testkit::run(&mut ss, &ds);
        testkit::run(&mut pp, &ds);
        assert!(
            pp.stats().peak_stored < ss.stats().peak_stored,
            "++ peak {} should undercut plain {}",
            pp.stats().peak_stored,
            ss.stats().peak_stored
        );
    }

    #[test]
    fn query_accounting_includes_retired_sieves() {
        let ds = testkit::clustered(800, 4);
        let k = 6;
        let mut algo = SieveStreamingPP::new(testkit::oracle(k), k, 0.1);
        testkit::run(&mut algo, &ds);
        let st = algo.stats();
        // Retired sieves' queries must be preserved in the total: the sum
        // is at least what the *surviving* sieves alone would report, and
        // strictly positive even if every live sieve filled early.
        assert!(st.queries > 0, "{st:?}");
        let live: u64 = st.queries; // includes retired_queries by contract
        assert!(live >= st.stored as u64, "{st:?}");
    }

    #[test]
    fn reset_restores_initial_window() {
        let ds = testkit::clustered(500, 5);
        let k = 5;
        let mut algo = SieveStreamingPP::new(testkit::oracle(k), k, 0.1);
        let n0 = algo.sieve_count();
        testkit::run(&mut algo, &ds);
        algo.reset();
        assert_eq!(algo.sieve_count(), n0);
        assert_eq!(algo.lower_bound(), 0.0);
    }
}
