//! **IndependentSetImprovement** (Chakrabarti & Kale 2014), paper Alg. 4:
//! store each element's marginal gain *at arrival time* as its weight;
//! replace the minimum-weight summary element when a new element's weight
//! is at least twice the minimum. ¼-approximation, O(1) queries/element.

use crate::functions::SubmodularFunction;
use crate::metrics::AlgoStats;

use super::StreamingAlgorithm;

/// Weight-based swap streaming (ISI).
pub struct IndependentSetImprovement {
    oracle: Box<dyn SubmodularFunction>,
    k: usize,
    /// Arrival-time weights, parallel to the oracle's summary order.
    weights: Vec<f64>,
    elements: u64,
    peak_stored: usize,
}

impl IndependentSetImprovement {
    pub fn new(oracle: Box<dyn SubmodularFunction>, k: usize) -> Self {
        assert!(k > 0);
        IndependentSetImprovement {
            oracle,
            k,
            weights: Vec::with_capacity(k),
            elements: 0,
            peak_stored: 0,
        }
    }

    fn argmin_weight(&self) -> usize {
        let mut best = 0;
        for i in 1..self.weights.len() {
            if self.weights[i] < self.weights[best] {
                best = i;
            }
        }
        best
    }
}

impl StreamingAlgorithm for IndependentSetImprovement {
    fn name(&self) -> String {
        "IndependentSetImprovement".into()
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        // Weight = marginal gain against the *current* summary at arrival.
        let w = self.oracle.peek_gain(item);
        if self.oracle.len() < self.k {
            self.oracle.accept(item);
            self.weights.push(w);
        } else {
            let m = self.argmin_weight();
            if w > 2.0 * self.weights[m] {
                self.oracle.remove(m);
                self.weights.remove(m);
                self.oracle.accept(item);
                self.weights.push(w);
            }
        }
        if self.oracle.len() > self.peak_stored {
            self.peak_stored = self.oracle.len();
        }
    }

    fn value(&self) -> f64 {
        self.oracle.current_value()
    }

    fn summary(&self) -> Vec<f32> {
        self.oracle.summary().to_vec()
    }

    fn summary_len(&self) -> usize {
        self.oracle.len()
    }

    fn dim(&self) -> usize {
        self.oracle.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        AlgoStats {
            queries: self.oracle.queries(),
            kernel_evals: self.oracle.kernel_evals(),
            elements: self.elements,
            stored: self.oracle.len(),
            peak_stored: self.peak_stored,
            instances: 1,
            wall_kernel_ns: self.oracle.wall_kernel_ns(),
            wall_solve_ns: self.oracle.wall_solve_ns(),
            wall_scan_ns: 0,
            ..Default::default()
        }
    }

    fn reset(&mut self) {
        self.oracle.reset();
        self.weights.clear();
        self.elements = 0;
        self.peak_stored = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn fills_then_swaps_only_on_double_weight() {
        let k = 3;
        let d = testkit::DIM;
        let mut algo = IndependentSetImprovement::new(testkit::oracle(k), k);
        // Fill with near-identical items (low incremental weight for later ones).
        let base = vec![0.1f32; d];
        for _ in 0..k {
            algo.process(&base);
        }
        assert_eq!(algo.summary_len(), k);
        let w_before = algo.weights.clone();
        // A duplicate has tiny weight -> no swap.
        algo.process(&base);
        assert_eq!(algo.weights, w_before);
        // A far-away item has weight ≈ m > 2*min(duplicate weights) -> swap:
        // the minimum-weight slot must be replaced by the new weight.
        let old_min = w_before.iter().cloned().fold(f64::INFINITY, f64::min);
        let far = vec![100.0f32; d];
        algo.process(&far);
        let new_min = algo.weights.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(new_min > old_min, "min weight must improve: {new_min} !> {old_min}");
        assert_eq!(algo.weights.len(), k);
    }

    #[test]
    fn constant_queries_per_element() {
        let ds = testkit::clustered(800, 1);
        let k = 6;
        let mut algo = IndependentSetImprovement::new(testkit::oracle(k), k);
        testkit::run(&mut algo, &ds);
        let st = algo.stats();
        // 1 peek per element + at most (K + #swaps)*2 update queries.
        assert!(st.queries_per_element() < 2.0, "{}", st.queries_per_element());
    }

    #[test]
    fn memory_bounded_by_k() {
        let ds = testkit::clustered(500, 2);
        let k = 5;
        let mut algo = IndependentSetImprovement::new(testkit::oracle(k), k);
        testkit::run(&mut algo, &ds);
        assert!(algo.stats().peak_stored <= k);
    }

    #[test]
    fn outperforms_random_on_clustered_data() {
        // The paper observes ISI > Random in most settings; verify on a
        // clearly clustered workload with a fixed seed.
        let ds = testkit::clustered(3000, 3);
        let k = 10;
        let mut isi = IndependentSetImprovement::new(testkit::oracle(k), k);
        let mut rnd = super::super::RandomReservoir::new(testkit::oracle(k), k, 1);
        testkit::run(&mut isi, &ds);
        testkit::run(&mut rnd, &ds);
        // The paper observes ISI ≥ Random in most (not all) settings; allow
        // a modest margin on this single seed.
        assert!(
            isi.value() >= rnd.value() * 0.85,
            "ISI {} should not trail Random {} badly",
            isi.value(),
            rnd.value()
        );
    }

    #[test]
    fn weights_stay_parallel_to_summary() {
        let ds = testkit::clustered(400, 4);
        let k = 7;
        let mut algo = IndependentSetImprovement::new(testkit::oracle(k), k);
        testkit::run(&mut algo, &ds);
        assert_eq!(algo.weights.len(), algo.summary_len());
    }
}
