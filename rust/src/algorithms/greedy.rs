//! Offline **Greedy** (Nemhauser et al. 1978) — the reference every other
//! algorithm's value is normalized against ("relative performance").
//!
//! Implemented as *lazy greedy* (Minoux's accelerated variant): stale upper
//! bounds sit in a max-heap and are only re-evaluated when they surface.
//! By submodularity this selects exactly the classic greedy summary while
//! skipping most gain queries — essential because Greedy anchors every
//! experiment sweep.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::data::Dataset;
use crate::functions::SubmodularFunction;
use crate::metrics::AlgoStats;

use super::StreamingAlgorithm;

struct HeapItem {
    /// Upper bound on Δf(e|S) (gain at the round it was last evaluated).
    bound: f64,
    idx: usize,
    /// Round (|S|) the bound was computed at.
    round: usize,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        self.bound.partial_cmp(&other.bound).unwrap_or(Ordering::Equal)
    }
}

/// Offline greedy selection of K elements.
pub struct Greedy {
    oracle: Box<dyn SubmodularFunction>,
    k: usize,
    selected: Vec<usize>,
    elements: u64,
    peak_stored: usize,
}

impl Greedy {
    pub fn new(oracle: Box<dyn SubmodularFunction>, k: usize) -> Self {
        assert!(k > 0);
        Greedy { oracle, k, selected: Vec::new(), elements: 0, peak_stored: 0 }
    }

    /// Select K elements from `ds` (lazy greedy). Returns the selected row
    /// indices in pick order.
    pub fn fit(&mut self, ds: &Dataset) -> &[usize] {
        assert_eq!(ds.dim(), self.oracle.dim(), "dataset dim != oracle dim");
        self.oracle.reset();
        self.selected.clear();
        self.elements = ds.len() as u64;

        let mut heap = BinaryHeap::with_capacity(ds.len());
        for i in 0..ds.len() {
            heap.push(HeapItem { bound: f64::INFINITY, idx: i, round: usize::MAX });
        }

        while self.oracle.len() < self.k && !heap.is_empty() {
            let round = self.oracle.len();
            let top = heap.pop().unwrap();
            if top.round == round {
                // Fresh bound — by submodularity nothing below can beat it.
                self.oracle.accept(ds.row(top.idx));
                self.selected.push(top.idx);
            } else {
                let gain = self.oracle.peek_gain(ds.row(top.idx));
                // Re-insert unless it still dominates the next candidate.
                match heap.peek() {
                    Some(next) if gain < next.bound => {
                        heap.push(HeapItem { bound: gain, idx: top.idx, round });
                    }
                    _ => {
                        self.oracle.accept(ds.row(top.idx));
                        self.selected.push(top.idx);
                    }
                }
            }
            if self.oracle.len() > self.peak_stored {
                self.peak_stored = self.oracle.len();
            }
        }
        &self.selected
    }

    /// Selected dataset row indices (pick order).
    pub fn selected(&self) -> &[usize] {
        &self.selected
    }
}

impl StreamingAlgorithm for Greedy {
    fn name(&self) -> String {
        "Greedy".into()
    }

    /// Greedy is offline; `process` is unsupported by design.
    fn process(&mut self, _item: &[f32]) {
        panic!("Greedy is an offline algorithm: call fit(&Dataset)");
    }

    fn value(&self) -> f64 {
        self.oracle.current_value()
    }

    fn summary(&self) -> Vec<f32> {
        self.oracle.summary().to_vec()
    }

    fn summary_len(&self) -> usize {
        self.oracle.len()
    }

    fn dim(&self) -> usize {
        self.oracle.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        AlgoStats {
            queries: self.oracle.queries(),
            kernel_evals: self.oracle.kernel_evals(),
            elements: self.elements,
            stored: self.oracle.len(),
            peak_stored: self.peak_stored,
            instances: 1,
            wall_kernel_ns: self.oracle.wall_kernel_ns(),
            wall_solve_ns: self.oracle.wall_solve_ns(),
            wall_scan_ns: 0,
            ..Default::default()
        }
    }

    fn reset(&mut self) {
        self.oracle.reset();
        self.selected.clear();
        self.elements = 0;
        self.peak_stored = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;
    use crate::functions::SubmodularFunction as _;

    /// Plain (non-lazy) greedy for cross-checking the lazy implementation.
    fn plain_greedy(ds: &Dataset, k: usize) -> (f64, Vec<usize>) {
        let mut oracle = testkit::oracle(k);
        let mut picked = Vec::new();
        for _ in 0..k {
            let mut best = (f64::NEG_INFINITY, usize::MAX);
            for i in 0..ds.len() {
                if picked.contains(&i) {
                    continue;
                }
                let g = oracle.peek_gain(ds.row(i));
                if g > best.0 {
                    best = (g, i);
                }
            }
            oracle.accept(ds.row(best.1));
            picked.push(best.1);
        }
        (oracle.current_value(), picked)
    }

    #[test]
    fn lazy_matches_plain_greedy() {
        let ds = testkit::clustered(300, 10);
        let k = 6;
        let (plain_value, _) = plain_greedy(&ds, k);
        let mut lazy = Greedy::new(testkit::oracle(k), k);
        lazy.fit(&ds);
        // Exact ties are common (items far from the whole summary all score
        // exactly m), and heap order breaks ties differently from the index
        // scan — so values match to tie-divergence tolerance, not ulps.
        assert!(
            (lazy.value() - plain_value).abs() < 1e-3 * plain_value,
            "lazy {} vs plain {plain_value}",
            lazy.value()
        );
    }

    #[test]
    fn lazy_uses_fewer_queries() {
        let ds = testkit::clustered(500, 11);
        let k = 8;
        let mut lazy = Greedy::new(testkit::oracle(k), k);
        lazy.fit(&ds);
        let naive_queries = (ds.len() * k) as u64;
        assert!(
            lazy.stats().queries < naive_queries / 2,
            "lazy greedy should skip most queries: {} vs naive {naive_queries}",
            lazy.stats().queries
        );
    }

    #[test]
    fn selects_exactly_k() {
        let ds = testkit::clustered(100, 12);
        let mut g = Greedy::new(testkit::oracle(5), 5);
        let sel = g.fit(&ds).to_vec();
        assert_eq!(sel.len(), 5);
        assert_eq!(g.summary_len(), 5);
        // Indices are distinct.
        let mut sorted = sel.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5);
    }

    #[test]
    fn k_larger_than_dataset() {
        let ds = testkit::clustered(3, 13);
        let mut g = Greedy::new(testkit::oracle(10), 10);
        g.fit(&ds);
        assert_eq!(g.summary_len(), 3);
    }

    #[test]
    #[should_panic(expected = "offline")]
    fn process_panics() {
        let mut g = Greedy::new(testkit::oracle(2), 2);
        g.process(&[0.0; testkit::DIM]);
    }

    #[test]
    fn refit_after_reset() {
        let ds = testkit::clustered(100, 14);
        let mut g = Greedy::new(testkit::oracle(4), 4);
        g.fit(&ds);
        let v1 = g.value();
        g.reset();
        assert_eq!(g.summary_len(), 0);
        g.fit(&ds);
        assert!((g.value() - v1).abs() < 1e-12);
    }
}
