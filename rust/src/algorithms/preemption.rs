//! **PreemptionStreaming** (Buchbinder et al. 2019), paper Alg. 6: like
//! StreamGreedy but with the *dynamic* improvement threshold `c·f(S)/K`
//! (c = 1 gives the ¼ guarantee via the c/(c+1)² bound). Superseded by
//! SieveStreaming++ in the paper's experiments; kept for Table 1.

use crate::functions::{swap_delta, SubmodularFunction};
use crate::metrics::AlgoStats;

use super::StreamingAlgorithm;

/// Swap streaming with the preemption threshold `c·f(S)/K`.
pub struct PreemptionStreaming {
    oracle: Box<dyn SubmodularFunction>,
    k: usize,
    c: f64,
    elements: u64,
    peak_stored: usize,
}

impl PreemptionStreaming {
    /// The paper's setting is `c = 1`.
    pub fn new(oracle: Box<dyn SubmodularFunction>, k: usize) -> Self {
        Self::with_c(oracle, k, 1.0)
    }

    pub fn with_c(oracle: Box<dyn SubmodularFunction>, k: usize, c: f64) -> Self {
        assert!(k > 0);
        assert!(c > 0.0);
        PreemptionStreaming { oracle, k, c, elements: 0, peak_stored: 0 }
    }
}

impl StreamingAlgorithm for PreemptionStreaming {
    fn name(&self) -> String {
        "PreemptionStreaming".into()
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        if self.oracle.len() < self.k {
            self.oracle.accept(item);
        } else {
            // K probes of position 0 rotate through every element and
            // restore order (see StreamGreedy for the rotation argument).
            let mut best = (f64::NEG_INFINITY, usize::MAX);
            for idx in 0..self.k {
                let delta = swap_delta(self.oracle.as_mut(), 0, item);
                if delta > best.0 {
                    best = (delta, idx);
                }
            }
            let threshold = self.c * self.oracle.current_value() / self.k as f64;
            if best.0 >= threshold {
                self.oracle.remove(best.1);
                self.oracle.accept(item);
            }
        }
        if self.oracle.len() > self.peak_stored {
            self.peak_stored = self.oracle.len();
        }
    }

    fn value(&self) -> f64 {
        self.oracle.current_value()
    }

    fn summary(&self) -> Vec<f32> {
        self.oracle.summary().to_vec()
    }

    fn summary_len(&self) -> usize {
        self.oracle.len()
    }

    fn dim(&self) -> usize {
        self.oracle.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        AlgoStats {
            queries: self.oracle.queries(),
            kernel_evals: self.oracle.kernel_evals(),
            elements: self.elements,
            stored: self.oracle.len(),
            peak_stored: self.peak_stored,
            instances: 1,
            wall_kernel_ns: self.oracle.wall_kernel_ns(),
            wall_solve_ns: self.oracle.wall_solve_ns(),
            wall_scan_ns: 0,
            ..Default::default()
        }
    }

    fn reset(&mut self) {
        self.oracle.reset();
        self.elements = 0;
        self.peak_stored = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn dynamic_threshold_tightens_as_value_grows() {
        let ds = testkit::clustered(500, 1);
        let k = 5;
        let mut algo = PreemptionStreaming::new(testkit::oracle(k), k);
        testkit::run(&mut algo, &ds);
        assert_eq!(algo.summary_len(), k);
        // The threshold at the end is f(S)/K > 0, so a duplicate of an
        // existing summary row (swap delta ≈ 0) cannot displace anything.
        let summary = algo.summary();
        let v = algo.value();
        algo.process(&summary[0..testkit::DIM]);
        assert!((algo.value() - v).abs() < 1e-9);
    }

    #[test]
    fn never_decreases_value_after_fill() {
        let ds = testkit::clustered(400, 2);
        let k = 4;
        let mut algo = PreemptionStreaming::new(testkit::oracle(k), k);
        let mut last = 0.0;
        for (i, row) in ds.iter().enumerate() {
            algo.process(row);
            if i >= k {
                assert!(algo.value() >= last - 1e-9, "value decreased at {i}");
            }
            last = algo.value();
        }
    }

    #[test]
    fn memory_stays_at_k() {
        let ds = testkit::clustered(300, 3);
        let k = 6;
        let mut algo = PreemptionStreaming::new(testkit::oracle(k), k);
        testkit::run(&mut algo, &ds);
        assert_eq!(algo.stats().peak_stored, k);
        assert_eq!(algo.stats().instances, 1);
    }

    #[test]
    fn queries_are_order_k() {
        let ds = testkit::clustered(150, 4);
        let k = 5;
        let mut algo = PreemptionStreaming::new(testkit::oracle(k), k);
        testkit::run(&mut algo, &ds);
        let qpe = algo.stats().queries_per_element();
        assert!(qpe > k as f64 && qpe < (5 * k) as f64, "qpe {qpe}");
    }
}
