//! **Salsa** (Norouzi-Fard et al. 2018), paper Alg. 8: a meta-algorithm
//! running several thresholding *rules* in parallel, each instantiated for
//! every OPT guess `v` from the geometric grid — the intuition being that
//! "dense" and "sparse" streams favour different rules. The output is the
//! best summary over all (rule, v) pairs.
//!
//! We implement the streaming variant (their Appendix E) with three rule
//! families, following the published constants where the extended paper
//! states them and documenting our rendering where it does not:
//!
//! * **Sieve rule** — the standard SieveStreaming condition
//!   `Δ ≥ (v/2 − f(S)) / (K − |S|)`.
//! * **Dense rule** — a flat per-slot bar `Δ ≥ v/(2K)`: dense streams keep
//!   offering good items, so a constant bar fills the summary with
//!   above-average items quickly.
//! * **Position-adaptive rule** — for streams of known length `n`, demand
//!   `Δ ≥ β·v/K` with `β` decaying from 0.7 to 0.25 as the stream position
//!   advances (early: picky; late: permissive). This mirrors their r-pass
//!   threshold schedule collapsed into one pass and is the component that
//!   needs the stream length hint — the paper's stated limitation of Salsa.

use crate::exec::ExecContext;
use crate::functions::{ChunkPanel, PanelScratch, SharedRowStore, SubmodularFunction};
use crate::metrics::AlgoStats;
use crate::util::mathx::threshold_grid;

use super::{
    build_union_panel, offer_chunk_grid, sieve_threshold, union_row_ids, Sieve, SolveGrid,
    StreamingAlgorithm,
};

/// Thresholding rule families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Rule {
    Sieve,
    Dense,
    Adaptive,
}

/// One (rule, v) unit: a rule family wrapped around the shared [`Sieve`]
/// chassis (oracle + OPT guess + gain scratch + broker gather state). The
/// composition keeps the broker plumbing in one place — Salsa only adds
/// the per-item threshold schedule on top.
struct RuleSieve {
    rule: Rule,
    sieve: Sieve,
}

/// Rule threshold as of stream position `elem` (1-based count of the item
/// being considered). A free function over the rule and the sieve pieces
/// (rather than a `Salsa` or `RuleSieve` method) so the scalar path, the
/// unit-serial batched path and the 2-D solve grid's scan all share one
/// definition and cannot drift.
fn rule_threshold(
    rule: Rule,
    v: f64,
    oracle: &dyn SubmodularFunction,
    k: usize,
    stream_len: Option<usize>,
    elem: u64,
) -> f64 {
    match rule {
        Rule::Sieve => sieve_threshold(v, oracle.current_value(), k, oracle.len()),
        Rule::Dense => v / (2.0 * k as f64),
        Rule::Adaptive => {
            let n = stream_len.unwrap_or(1).max(1);
            let pos = (elem as f64 / n as f64).min(1.0);
            let beta = 0.7 - 0.45 * pos; // 0.7 → 0.25 across the stream
            beta * v / k as f64
        }
    }
}

/// First would-accept position (relative to `gains[0]`, which sits at
/// chunk-absolute `pos`) under a rule's per-item threshold schedule — the
/// single scan shared by [`consume_chunk`], [`consume_chunk_shared`] and
/// the grid driver's Phase B.
#[allow(clippy::too_many_arguments)]
fn rule_first_hit(
    rule: Rule,
    v: f64,
    oracle: &dyn SubmodularFunction,
    gains: &[f64],
    pos: usize,
    k: usize,
    stream_len: Option<usize>,
    start_elements: u64,
) -> Option<usize> {
    for (j, &g) in gains.iter().enumerate() {
        let elem = start_elements + (pos + j) as u64 + 1;
        if g >= rule_threshold(rule, v, oracle, k, stream_len, elem) {
            return Some(j);
        }
    }
    None
}

/// Decision telemetry for one scanned run (obs-gated; one relaxed load
/// when recording is off). The event τ is the rule's bar at the run's
/// first item — exact for the position-independent rules, and a run-start
/// approximation for the adaptive rule's per-item schedule.
fn note_rule_run(
    s: &mut RuleSieve,
    len: usize,
    hit: Option<usize>,
    k: usize,
    stream_len: Option<usize>,
    first_elem: u64,
) {
    if !crate::obs::enabled() {
        return;
    }
    let tau = rule_threshold(s.rule, s.sieve.v, s.sieve.oracle.as_ref(), k, stream_len, first_elem);
    s.sieve.note_run(len, hit, tau);
}

/// One (rule, v) sieve consumes a whole chunk: one gain panel per
/// rejection run, thresholds recomputed per item from the chunk-start
/// stream position (the adaptive rule's position dependence), an
/// acceptance re-batches from the next item. Returns the speculative gain
/// evaluations past acceptances (see `Sieve::offer_batch` for the
/// accounting argument). The unit of work the exec pool fans out.
fn consume_chunk(
    s: &mut RuleSieve,
    chunk: &[f32],
    d: usize,
    k: usize,
    stream_len: Option<usize>,
    start_elements: u64,
) -> u64 {
    let total = chunk.len() / d;
    let mut pos = 0usize;
    let mut wasted = 0u64;
    while pos < total {
        if s.sieve.oracle.len() >= k {
            break; // full: the scalar path stops querying too
        }
        let remaining = total - pos;
        s.sieve.oracle.peek_gain_batch(&chunk[pos * d..], remaining, &mut s.sieve.scratch);
        let hit = rule_first_hit(
            s.rule,
            s.sieve.v,
            s.sieve.oracle.as_ref(),
            &s.sieve.scratch[..remaining],
            pos,
            k,
            stream_len,
            start_elements,
        );
        note_rule_run(s, remaining, hit, k, stream_len, start_elements + pos as u64 + 1);
        match hit {
            Some(j) => {
                let item = &chunk[(pos + j) * d..(pos + j + 1) * d];
                s.sieve.oracle.accept(item);
                wasted += (remaining - (j + 1)) as u64;
                pos += j + 1;
            }
            None => {
                pos = total;
            }
        }
    }
    wasted
}

/// [`consume_chunk`] under the shared kernel-panel broker: identical
/// decisions and query accounting, gains gathered from the chunk panel
/// instead of a fresh per-run kernel panel. Falls back to the per-sieve
/// path if the sieve cannot bind (defensive — the union covers every
/// live sieve by construction).
fn consume_chunk_shared(
    s: &mut RuleSieve,
    panel: &ChunkPanel,
    chunk: &[f32],
    d: usize,
    k: usize,
    stream_len: Option<usize>,
    start_elements: u64,
) -> u64 {
    if s.sieve.oracle.len() >= k {
        return 0; // full: neither path queries
    }
    if !s.sieve.begin_shared_chunk(panel) {
        return consume_chunk(s, chunk, d, k, stream_len, start_elements);
    }
    let total = chunk.len() / d;
    let mut pos = 0usize;
    let mut wasted = 0u64;
    while pos < total {
        if s.sieve.oracle.len() >= k {
            break;
        }
        let remaining = total - pos;
        s.sieve.gains_shared(panel, pos, remaining);
        let hit = rule_first_hit(
            s.rule,
            s.sieve.v,
            s.sieve.oracle.as_ref(),
            &s.sieve.scratch[..remaining],
            pos,
            k,
            stream_len,
            start_elements,
        );
        note_rule_run(s, remaining, hit, k, stream_len, start_elements + pos as u64 + 1);
        match hit {
            Some(j) => {
                s.sieve.accept_shared(panel, chunk, d, pos + j);
                wasted += (remaining - (j + 1)) as u64;
                pos += j + 1;
            }
            None => {
                pos = total;
            }
        }
    }
    wasted
}

/// The Salsa meta-algorithm.
pub struct Salsa {
    proto: Box<dyn SubmodularFunction>,
    k: usize,
    epsilon: f64,
    /// Expected stream length (None disables the adaptive rule).
    stream_len: Option<usize>,
    sieves: Vec<RuleSieve>,
    elements: u64,
    /// Speculative batch gains past a sieve's acceptance (see
    /// `process_batch`); excluded from reported query stats.
    speculative_queries: u64,
    /// Kernel entries spent on shared chunk panels (once per chunk).
    panel_evals: u64,
    /// Cross-sieve panel sharing toggle (bench/parity hook).
    share_panels: bool,
    peak_stored: usize,
    /// Recycled chunk-panel storage (allocation-free broker path).
    panel_scratch: PanelScratch,
    /// Scratch pool for the 2-D (sieve × candidate-range) solve grid.
    solve_pool: SolveGrid,
    /// Parallel execution context: (rule, v) sieves fan out across its
    /// pool when one is attached (see [`StreamingAlgorithm::set_exec`]).
    exec: ExecContext,
}

impl Salsa {
    /// `stream_len`: the length hint required by the adaptive rule; pass
    /// `None` when unknown (Salsa then runs only the first two families).
    pub fn new(
        mut proto: Box<dyn SubmodularFunction>,
        k: usize,
        epsilon: f64,
        stream_len: Option<usize>,
    ) -> Self {
        assert!(k > 0 && epsilon > 0.0);
        let dim = proto.dim();
        if let Some(ps) = proto.panel_sharing() {
            // The broker's row store, shared by every (rule, v) sieve —
            // Salsa's rule families overlap the most of the whole family
            // (three rules share each grid point's acceptances).
            ps.attach_row_store(SharedRowStore::new(dim));
        }
        let mut s = Salsa {
            proto,
            k,
            epsilon,
            stream_len,
            sieves: Vec::new(),
            elements: 0,
            speculative_queries: 0,
            panel_evals: 0,
            share_panels: true,
            peak_stored: 0,
            panel_scratch: PanelScratch::default(),
            solve_pool: SolveGrid::default(),
            exec: ExecContext::sequential(),
        };
        s.build_sieves();
        s
    }

    /// Force the per-sieve panel path (`false`) or restore the default
    /// shared-broker path (`true`). Both are bit-identical in summaries,
    /// values and reported queries — only `kernel_evals` moves.
    pub fn set_panel_sharing(&mut self, on: bool) {
        self.share_panels = on;
    }

    fn build_sieves(&mut self) {
        let m = self.proto.max_singleton_value();
        let grid = threshold_grid(self.epsilon, m, self.k as f64 * m);
        let mut rules = vec![Rule::Sieve, Rule::Dense];
        if self.stream_len.is_some() {
            rules.push(Rule::Adaptive);
        }
        self.sieves.clear();
        let mut tag = 0u32;
        for rule in rules {
            for &v in &grid {
                let mut sieve = Sieve::new(v, self.proto.as_ref());
                sieve.tag = tag;
                tag += 1;
                self.sieves.push(RuleSieve { rule, sieve });
            }
        }
    }

    fn threshold(&self, s: &RuleSieve) -> f64 {
        self.threshold_at(s, self.elements)
    }

    /// Rule threshold as of stream position `elements` — delegates to the
    /// free [`rule_threshold`] shared with the batched path.
    fn threshold_at(&self, s: &RuleSieve, elements: u64) -> f64 {
        let oracle = s.sieve.oracle.as_ref();
        rule_threshold(s.rule, s.sieve.v, oracle, self.k, self.stream_len, elements)
    }

    fn best(&self) -> Option<&RuleSieve> {
        // total_cmp, not partial_cmp().unwrap(): a NaN objective must not
        // panic mid-stream (it sorts above every real and surfaces as a
        // visibly broken best instead).
        let value = |s: &RuleSieve| s.sieve.oracle.current_value();
        self.sieves.iter().max_by(|a, b| value(a).total_cmp(&value(b)))
    }

    pub fn sieve_count(&self) -> usize {
        self.sieves.len()
    }

    /// One chunk panel across the union of the live (rule, v) sieves'
    /// interned summary rows (see `SieveStreaming::build_shared_panel`).
    fn build_shared_panel(&mut self, chunk: &[f32]) -> Option<ChunkPanel> {
        if !self.share_panels || chunk.is_empty() {
            return None;
        }
        let ids = union_row_ids(self.sieves.iter_mut().map(|s| &mut s.sieve.oracle), self.k)?;
        build_union_panel(&mut self.proto, &ids, chunk, &self.exec, &mut self.panel_scratch)
    }
}

impl StreamingAlgorithm for Salsa {
    fn name(&self) -> String {
        "Salsa".into()
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        let k = self.k;
        for i in 0..self.sieves.len() {
            if self.sieves[i].sieve.oracle.len() >= k {
                continue;
            }
            let thresh = self.threshold(&self.sieves[i]);
            let s = &mut self.sieves[i];
            let gain = s.sieve.oracle.peek_gain(item);
            let accepted = gain >= thresh;
            s.sieve.note_one(accepted, gain, thresh);
            if accepted {
                s.sieve.oracle.accept(item);
            }
        }
        let stored: usize = self.sieves.iter().map(|s| s.sieve.oracle.len()).sum();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }

    /// Batched ingestion: (rule, v) sieves are independent, so each one
    /// consumes the chunk on its own through [`consume_chunk`] — one gain
    /// panel per rejection run — sequentially, or fanned out on the exec
    /// pool's worker threads when a context is attached. The scan
    /// recomputes the rule threshold per item from the chunk-start stream
    /// position, which reproduces the adaptive rule's position dependence
    /// exactly; an acceptance ends the scan (the sieve rule's threshold
    /// and the capacity check depend on the new summary) and the remainder
    /// re-batches. Speculative gains past an acceptance are excluded from
    /// the reported query stats; they fold in sieve order, so results are
    /// bit-identical at every thread count.
    ///
    /// Under the shared kernel-panel broker ([`consume_chunk_shared`]) the
    /// chunk's kernel rows are computed once across all rule sieves and
    /// each rejection run gathers from the panel — same decisions, same
    /// queries, `kernel_evals` collapses from Σ-per-sieve to
    /// once-per-chunk. When live sieves cannot occupy the pool, the runs
    /// further split into the 2-D (sieve × candidate-range) solve grid
    /// ([`super::offer_chunk_grid`]) — bits unchanged, solves
    /// distributed.
    fn process_batch(&mut self, chunk: &[f32]) {
        let d = self.proto.dim();
        debug_assert_eq!(chunk.len() % d, 0, "chunk not row-aligned");
        let total = chunk.len() / d;
        let start_elements = self.elements;
        self.elements += total as u64;
        let k = self.k;
        let stream_len = self.stream_len;
        let shared = self.build_shared_panel(chunk);
        // Inline when sequential, worker threads when a pool is attached
        // (`set_exec` gated it on `parallel_safe()`); identical results
        // either way, speculative counts folded in sieve order. With
        // workers to spare, the broker path runs the 2-D
        // (sieve × candidate-range) solve grid instead of one coarse
        // chunk×sieve unit per worker — same decisions and accounting
        // (the scan is the shared `rule_first_hit`), distributed solves.
        let live = self.sieves.iter().filter(|s| s.sieve.oracle.len() < k).count();
        let use_grid = self.exec.is_parallel() && self.exec.threads() * 2 > live;
        let wasted: u64 = match &shared {
            Some(panel) => {
                let grid = if use_grid {
                    let mut rules: Vec<Rule> = Vec::with_capacity(self.sieves.len());
                    let mut refs: Vec<&mut Sieve> = Vec::with_capacity(self.sieves.len());
                    for rs in self.sieves.iter_mut() {
                        rules.push(rs.rule);
                        refs.push(&mut rs.sieve);
                    }
                    offer_chunk_grid(
                        &mut refs,
                        panel,
                        chunk,
                        d,
                        k,
                        &self.exec,
                        &mut self.solve_pool,
                        |si, v, oracle, gains, pos| {
                            rule_first_hit(
                                rules[si],
                                v,
                                oracle,
                                gains,
                                pos,
                                k,
                                stream_len,
                                start_elements,
                            )
                        },
                    )
                } else {
                    None
                };
                match grid {
                    Some(w) => w,
                    None => self
                        .exec
                        .map_units(&mut self.sieves, |s| {
                            consume_chunk_shared(s, panel, chunk, d, k, stream_len, start_elements)
                        })
                        .iter()
                        .sum(),
                }
            }
            None => self
                .exec
                .map_units(&mut self.sieves, |s| {
                    consume_chunk(s, chunk, d, k, stream_len, start_elements)
                })
                .iter()
                .sum(),
        };
        if let Some(panel) = shared {
            self.panel_evals += panel.evals();
            self.panel_scratch.recycle(panel);
        }
        self.speculative_queries += wasted;
        let stored: usize = self.sieves.iter().map(|s| s.sieve.oracle.len()).sum();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }

    fn set_exec(&mut self, exec: ExecContext) {
        self.exec = exec.gated(self.proto.as_ref());
    }

    fn value(&self) -> f64 {
        self.best().map(|s| s.sieve.oracle.current_value()).unwrap_or(0.0)
    }

    fn summary(&self) -> Vec<f32> {
        self.best().map(|s| s.sieve.oracle.summary().to_vec()).unwrap_or_default()
    }

    fn summary_len(&self) -> usize {
        self.best().map(|s| s.sieve.oracle.len()).unwrap_or(0)
    }

    fn dim(&self) -> usize {
        self.proto.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        let stored: usize = self.sieves.iter().map(|s| s.sieve.oracle.len()).sum();
        let charged: u64 = self.sieves.iter().map(|s| s.sieve.oracle.queries()).sum();
        let per_sieve_evals: u64 = self.sieves.iter().map(|s| s.sieve.oracle.kernel_evals()).sum();
        AlgoStats {
            queries: charged.saturating_sub(self.speculative_queries),
            kernel_evals: per_sieve_evals + self.panel_evals,
            elements: self.elements,
            stored,
            peak_stored: self.peak_stored.max(stored),
            instances: self.sieves.len(),
            wall_kernel_ns: self.sieves.iter().map(|s| s.sieve.oracle.wall_kernel_ns()).sum(),
            wall_solve_ns: self.sieves.iter().map(|s| s.sieve.oracle.wall_solve_ns()).sum(),
            wall_scan_ns: self.sieves.iter().map(|s| s.sieve.scan_ns).sum(),
            accepts: self.sieves.iter().map(|s| s.sieve.accepts).sum(),
            rejects: self.sieves.iter().map(|s| s.sieve.rejects).sum(),
            defers: 0,
            threshold_moves: 0,
        }
    }

    fn reset(&mut self) {
        self.elements = 0;
        self.speculative_queries = 0;
        self.panel_evals = 0;
        self.peak_stored = 0;
        // Fresh row store (dropped sieves' rows would otherwise pin
        // memory), then rebuild every (rule, v) pair from the prototype.
        let dim = self.proto.dim();
        if let Some(ps) = self.proto.panel_sharing() {
            ps.attach_row_store(SharedRowStore::new(dim));
        }
        self.build_sieves();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn runs_three_rule_families_with_length_hint() {
        let with_hint = Salsa::new(testkit::oracle(10), 10, 0.1, Some(1000));
        let without = Salsa::new(testkit::oracle(10), 10, 0.1, None);
        assert_eq!(with_hint.sieve_count() % 3, 0);
        assert_eq!(with_hint.sieve_count() / 3, without.sieve_count() / 2);
    }

    #[test]
    fn best_performer_close_to_greedy() {
        let ds = testkit::clustered(3000, 1);
        let k = 10;
        let greedy = testkit::greedy_value(&ds, k);
        let mut algo = Salsa::new(testkit::oracle(k), k, 0.02, Some(ds.len()));
        testkit::run(&mut algo, &ds);
        let rel = algo.value() / greedy;
        assert!(rel > 0.7, "relative performance {rel:.3}");
    }

    #[test]
    fn at_least_matches_plain_sievestreaming() {
        // Salsa contains the sieve rule as a sub-algorithm, so with the
        // same grid its best sieve can only be >= SieveStreaming's.
        let ds = testkit::clustered(2000, 2);
        let k = 8;
        let eps = 0.05;
        let mut ss = super::super::SieveStreaming::new(testkit::oracle(k), k, eps);
        let mut salsa = Salsa::new(testkit::oracle(k), k, eps, Some(ds.len()));
        testkit::run(&mut ss, &ds);
        testkit::run(&mut salsa, &ds);
        assert!(salsa.value() >= ss.value() - 1e-9);
    }

    #[test]
    fn uses_most_memory_of_the_family() {
        let ds = testkit::clustered(1500, 3);
        let k = 8;
        let eps = 0.05;
        let mut ss = super::super::SieveStreaming::new(testkit::oracle(k), k, eps);
        let mut salsa = Salsa::new(testkit::oracle(k), k, eps, Some(ds.len()));
        testkit::run(&mut ss, &ds);
        testkit::run(&mut salsa, &ds);
        assert!(salsa.stats().peak_stored >= ss.stats().peak_stored);
    }

    #[test]
    fn shared_panels_match_per_sieve_batches_bitwise() {
        // The broker under the three rule families (adaptive included):
        // same summaries, values and reported queries; only kernel_evals
        // may drop.
        let ds = testkit::clustered(1200, 6);
        let k = 6;
        let d = testkit::DIM;
        let mut shared = Salsa::new(testkit::oracle(k), k, 0.1, Some(ds.len()));
        let mut plain = Salsa::new(testkit::oracle(k), k, 0.1, Some(ds.len()));
        plain.set_panel_sharing(false);
        for chunk in ds.raw().chunks(64 * d) {
            shared.process_batch(chunk);
            plain.process_batch(chunk);
        }
        assert_eq!(shared.value().to_bits(), plain.value().to_bits());
        assert_eq!(shared.summary(), plain.summary());
        let (a, b) = (shared.stats(), plain.stats());
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.peak_stored, b.peak_stored);
        assert!(
            a.kernel_evals <= b.kernel_evals,
            "shared panels must never evaluate more kernel entries: {} vs {}",
            a.kernel_evals,
            b.kernel_evals
        );
    }

    #[test]
    fn reset_reinitializes() {
        let ds = testkit::clustered(300, 4);
        let mut algo = Salsa::new(testkit::oracle(5), 5, 0.1, Some(300));
        testkit::run(&mut algo, &ds);
        let n = algo.sieve_count();
        algo.reset();
        assert_eq!(algo.sieve_count(), n);
        assert_eq!(algo.value(), 0.0);
    }
}
