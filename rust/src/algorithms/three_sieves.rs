//! **ThreeSieves** — the paper's contribution (Algorithm 1 / 11).
//!
//! One summary, one active threshold. Start at the *top* of the geometric
//! grid `O = {(1+ε)^i : m ≤ (1+ε)^i ≤ K·m}` and lower the threshold to the
//! next grid value after `T` consecutive rejections. The Rule of Three
//! (Jovanovic & Levy 1997) bounds the acceptance probability after `T`
//! rejections by `−ln(α)/T`, giving the `(1−ε)(1−1/e)`-approximation with
//! probability `(1−α)^K` under the iid stream assumption (Theorem 1).
//!
//! Resources: exactly **one** oracle query per element and `O(K)` memory —
//! the smallest of the whole family (Table 1, last row).

use crate::functions::SubmodularFunction;
use crate::metrics::AlgoStats;
use crate::util::mathx::threshold_grid;

use super::{sieve_threshold, StreamingAlgorithm};

/// How to choose the rejection budget `T`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SieveTuning {
    /// Use `T` directly (the paper's recommended, hyperparameter-light mode).
    FixedT(usize),
    /// Derive `T = ⌈−ln(α)/τ⌉` from a confidence level `α` and a certainty
    /// margin `τ` (Eq. 3). Example: α=0.05, τ=0.003 → T≈1000.
    RuleOfThree { alpha: f64, tau: f64 },
}

impl SieveTuning {
    /// The effective rejection budget.
    pub fn t(&self) -> usize {
        match *self {
            SieveTuning::FixedT(t) => t.max(1),
            SieveTuning::RuleOfThree { alpha, tau } => {
                assert!((0.0..1.0).contains(&alpha) && alpha > 0.0, "alpha in (0,1)");
                assert!(tau > 0.0, "tau must be positive");
                ((-alpha.ln()) / tau).ceil() as usize
            }
        }
    }
}

/// The ThreeSieves algorithm.
pub struct ThreeSieves {
    oracle: Box<dyn SubmodularFunction>,
    k: usize,
    epsilon: f64,
    t_budget: usize,
    /// Remaining thresholds, ascending; the active one is popped from the back.
    grid: Vec<f64>,
    /// Active novelty threshold v.
    v: f64,
    /// Consecutive rejections at the current threshold.
    t: usize,
    /// Estimate m on the fly (paper §3 end): one extra singleton query per
    /// element; on a new maximum the summary restarts. Off by default
    /// because m is exact for the normalized-kernel log-det.
    estimate_m: bool,
    m: f64,
    hi_scale: f64,
    elements: u64,
    extra_queries: u64,
    peak_stored: usize,
}

impl ThreeSieves {
    /// ThreeSieves with the oracle's exact `m = max_e f({e})`.
    pub fn new(oracle: Box<dyn SubmodularFunction>, k: usize, epsilon: f64, tuning: SieveTuning) -> Self {
        Self::with_grid_scale(oracle, k, epsilon, tuning, 1.0)
    }

    /// ThreeSieves whose grid upper end is `hi_scale · K · m`.
    ///
    /// The paper builds `O` from the loose bound `m = 1 + aK` (§4.1) rather
    /// than the exact singleton value `½·ln(1+a)` — i.e. the grid *starts
    /// far above OPT* and the algorithm spends its early budget walking
    /// down through all-reject thresholds. That descent is what makes the
    /// eventual acceptances greedy-grade on duplicate-heavy streams: by the
    /// time the threshold is reachable at all, only top-gain items pass.
    /// `hi_scale = 1` gives the exact-`m` grid (fills fast, first-K-ish on
    /// easy data); `hi_scale > 1` trades descent time (≈ `T·ln(hi_scale·K·m
    /// / 2·OPT)/ε` rejections) for pickiness. The approximation theorem
    /// only needs `O` to cover `[m, OPT]`, which any `hi_scale ≥ 1` does.
    pub fn with_grid_scale(
        oracle: Box<dyn SubmodularFunction>,
        k: usize,
        epsilon: f64,
        tuning: SieveTuning,
        hi_scale: f64,
    ) -> Self {
        assert!(k > 0, "K must be positive");
        assert!(epsilon > 0.0, "epsilon must be positive");
        assert!(hi_scale >= 1.0, "hi_scale must be >= 1");
        let m = oracle.max_singleton_value();
        let grid = threshold_grid(epsilon, m, hi_scale * k as f64 * m);
        let mut ts = ThreeSieves {
            oracle,
            k,
            epsilon,
            t_budget: tuning.t(),
            grid,
            v: 0.0,
            t: 0,
            estimate_m: false,
            m,
            hi_scale,
            elements: 0,
            extra_queries: 0,
            peak_stored: 0,
        };
        ts.pop_threshold();
        ts
    }

    /// ThreeSieves that estimates `m` on the fly: starts from the first
    /// element's singleton value, and restarts the summary whenever a new
    /// maximum arrives (this preserves Theorem 1, see paper §3).
    pub fn with_m_estimation(
        oracle: Box<dyn SubmodularFunction>,
        k: usize,
        epsilon: f64,
        tuning: SieveTuning,
    ) -> Self {
        let mut ts = Self::new(oracle, k, epsilon, tuning);
        ts.estimate_m = true;
        ts.m = 0.0;
        ts.grid.clear();
        ts.v = f64::INFINITY; // reject everything until the first m estimate
        ts
    }

    fn pop_threshold(&mut self) {
        self.t = 0;
        self.v = self.grid.pop().unwrap_or(self.v.min(f64::MAX));
    }

    fn rebuild_grid(&mut self, m: f64) {
        self.m = m;
        self.grid = threshold_grid(self.epsilon, m, self.hi_scale * self.k as f64 * m);
        self.pop_threshold();
    }

    /// Active threshold (exposed for tests and the coordinator's telemetry).
    pub fn active_threshold(&self) -> f64 {
        self.v
    }

    /// Remaining grid size.
    pub fn grid_remaining(&self) -> usize {
        self.grid.len()
    }

    /// The rejection budget T in use.
    pub fn t_budget(&self) -> usize {
        self.t_budget
    }
}

impl StreamingAlgorithm for ThreeSieves {
    fn name(&self) -> String {
        format!("ThreeSieves(T={})", self.t_budget)
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;

        if self.estimate_m {
            // Singleton value f({e}) via an empty-summary probe: when the
            // summary is empty the gain *is* the singleton value, otherwise
            // we pay one extra query on a scratch oracle.
            let singleton = if self.oracle.is_empty() {
                // Reuse the main query below — just peek now.
                self.extra_queries += 1;
                let mut probe = self.oracle.clone_empty();
                probe.peek_gain(item)
            } else {
                self.extra_queries += 1;
                let mut probe = self.oracle.clone_empty();
                probe.peek_gain(item)
            };
            if singleton > self.m {
                // New maximum invalidates the running estimate: restart.
                self.oracle.reset();
                self.rebuild_grid(singleton);
            }
        }

        let len = self.oracle.len();
        if len >= self.k {
            return; // summary full — ThreeSieves stops looking
        }
        if !self.v.is_finite() {
            return; // m estimation hasn't seen the first element yet
        }

        let thresh = sieve_threshold(self.v, self.oracle.current_value(), self.k, len);
        let gain = self.oracle.peek_gain(item);
        if gain >= thresh {
            self.oracle.accept(item);
            self.t = 0;
        } else {
            self.t += 1;
            if self.t >= self.t_budget {
                if self.grid.is_empty() {
                    // Smallest threshold exhausted: keep v (the paper keeps
                    // sieving with the last threshold).
                    self.t = 0;
                } else {
                    self.pop_threshold();
                }
            }
        }
        if self.oracle.len() > self.peak_stored {
            self.peak_stored = self.oracle.len();
        }
    }

    fn value(&self) -> f64 {
        self.oracle.current_value()
    }

    fn summary(&self) -> Vec<f32> {
        self.oracle.summary().to_vec()
    }

    fn summary_len(&self) -> usize {
        self.oracle.len()
    }

    fn dim(&self) -> usize {
        self.oracle.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        AlgoStats {
            queries: self.oracle.queries() + self.extra_queries,
            elements: self.elements,
            stored: self.oracle.len(),
            peak_stored: self.peak_stored,
            instances: 1,
        }
    }

    fn reset(&mut self) {
        self.oracle.reset();
        self.elements = 0;
        self.extra_queries = 0;
        self.peak_stored = 0;
        self.t = 0;
        if self.estimate_m {
            self.m = 0.0;
            self.grid.clear();
            self.v = f64::INFINITY;
        } else {
            let m = self.oracle.max_singleton_value();
            self.rebuild_grid(m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn tuning_rule_of_three() {
        // alpha = 0.05, tau = 0.003 -> T ≈ ceil(2.9957/0.003) = 999
        let t = SieveTuning::RuleOfThree { alpha: 0.05, tau: 0.003 }.t();
        assert!((998..=1000).contains(&t), "T = {t}");
        assert_eq!(SieveTuning::FixedT(500).t(), 500);
        assert_eq!(SieveTuning::FixedT(0).t(), 1); // floor at 1
    }

    #[test]
    #[should_panic(expected = "alpha in (0,1)")]
    fn tuning_rejects_bad_alpha() {
        SieveTuning::RuleOfThree { alpha: 1.5, tau: 0.1 }.t();
    }

    #[test]
    fn selects_full_summary_on_clustered_data() {
        let ds = testkit::clustered(3000, 1);
        let k = 8;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(100));
        testkit::run(&mut algo, &ds);
        assert_eq!(algo.summary_len(), k);
        assert!(algo.value() > 0.0);
    }

    #[test]
    fn single_query_per_element() {
        let ds = testkit::clustered(1000, 2);
        let k = 5;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(50));
        testkit::run(&mut algo, &ds);
        let st = algo.stats();
        // At most 1 gain query per element + 1 update query per accept
        // (≤ K); once the summary is full ThreeSieves stops querying, so
        // the measured rate is ≤ 1, never above.
        assert!(st.queries <= st.elements + 2 * k as u64, "{st:?}");
        assert!(st.queries_per_element() <= 1.02, "{}", st.queries_per_element());
        assert!(st.queries > 0);
    }

    #[test]
    fn memory_is_k_elements() {
        let ds = testkit::clustered(2000, 3);
        let k = 10;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.005, SieveTuning::FixedT(200));
        testkit::run(&mut algo, &ds);
        assert!(algo.stats().peak_stored <= k);
        assert_eq!(algo.stats().instances, 1);
    }

    #[test]
    fn threshold_lowers_after_t_rejections() {
        // Large K keeps the summary from filling; repeated duplicates have
        // rapidly shrinking gains, so rejections accumulate and the active
        // threshold must walk down the grid.
        let k = 50;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.5, SieveTuning::FixedT(3));
        let v0 = algo.active_threshold();
        let item = vec![0.0f32; testkit::DIM];
        for _ in 0..200 {
            algo.process(&item);
        }
        assert!(algo.active_threshold() < v0, "{} !< {v0}", algo.active_threshold());
    }

    #[test]
    fn competitive_with_greedy_on_iid_data() {
        let ds = testkit::clustered(4000, 4);
        let k = 10;
        let greedy = testkit::greedy_value(&ds, k);
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.001, SieveTuning::FixedT(1000));
        // Paper batch protocol: re-iterate until full (at most K passes).
        let mut passes = 0;
        while !algo.is_full() && passes < k {
            testkit::run(&mut algo, &ds);
            passes += 1;
        }
        let rel = algo.value() / greedy;
        assert!(rel > 0.8, "relative performance {rel:.3} too low");
    }

    #[test]
    fn m_estimation_variant_matches_known_m_on_logdet() {
        // For the normalized-kernel log-det every singleton has the same
        // value, so the estimated-m variant must behave identically after
        // the first element.
        let ds = testkit::clustered(1500, 5);
        let k = 6;
        let mut known = ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(100));
        let mut est =
            ThreeSieves::with_m_estimation(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(100));
        testkit::run(&mut known, &ds);
        testkit::run(&mut est, &ds);
        assert!((known.value() - est.value()).abs() < 1e-9);
        assert_eq!(known.summary_len(), est.summary_len());
    }

    #[test]
    fn reset_clears_state() {
        let ds = testkit::clustered(500, 6);
        let k = 5;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(50));
        testkit::run(&mut algo, &ds);
        assert!(algo.summary_len() > 0);
        algo.reset();
        assert_eq!(algo.summary_len(), 0);
        assert_eq!(algo.stats().elements, 0);
        // Still functional after reset.
        testkit::run(&mut algo, &ds);
        assert!(algo.summary_len() > 0);
    }

    #[test]
    fn name_includes_t() {
        let algo = ThreeSieves::new(testkit::oracle(3), 3, 0.1, SieveTuning::FixedT(42));
        assert_eq!(algo.name(), "ThreeSieves(T=42)");
    }
}
