//! **ThreeSieves** — the paper's contribution (Algorithm 1 / 11).
//!
//! One summary, one active threshold. Start at the *top* of the geometric
//! grid `O = {(1+ε)^i : m ≤ (1+ε)^i ≤ K·m}` and lower the threshold to the
//! next grid value after `T` consecutive rejections. The Rule of Three
//! (Jovanovic & Levy 1997) bounds the acceptance probability after `T`
//! rejections by `−ln(α)/T`, giving the `(1−ε)(1−1/e)`-approximation with
//! probability `(1−α)^K` under the iid stream assumption (Theorem 1).
//!
//! Resources: exactly **one** oracle query per element and `O(K)` memory —
//! the smallest of the whole family (Table 1, last row).

use crate::functions::SubmodularFunction;
use crate::metrics::AlgoStats;
use crate::util::json::Json;
use crate::util::mathx::threshold_grid;

use super::{sieve_threshold, StreamingAlgorithm};

/// How to choose the rejection budget `T`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SieveTuning {
    /// Use `T` directly (the paper's recommended, hyperparameter-light mode).
    FixedT(usize),
    /// Derive `T = ⌈−ln(α)/τ⌉` from a confidence level `α` and a certainty
    /// margin `τ` (Eq. 3). Example: α=0.05, τ=0.003 → T≈1000.
    RuleOfThree { alpha: f64, tau: f64 },
}

impl SieveTuning {
    /// The effective rejection budget.
    pub fn t(&self) -> usize {
        match *self {
            SieveTuning::FixedT(t) => t.max(1),
            SieveTuning::RuleOfThree { alpha, tau } => {
                assert!((0.0..1.0).contains(&alpha) && alpha > 0.0, "alpha in (0,1)");
                assert!(tau > 0.0, "tau must be positive");
                ((-alpha.ln()) / tau).ceil() as usize
            }
        }
    }
}

/// The ThreeSieves algorithm.
pub struct ThreeSieves {
    oracle: Box<dyn SubmodularFunction>,
    k: usize,
    epsilon: f64,
    t_budget: usize,
    /// Remaining thresholds, ascending; the active one is popped from the back.
    grid: Vec<f64>,
    /// Active novelty threshold v.
    v: f64,
    /// Consecutive rejections at the current threshold.
    t: usize,
    /// Estimate m on the fly (paper §3 end): one extra singleton query per
    /// element; on a new maximum the summary restarts. Off by default
    /// because m is exact for the normalized-kernel log-det.
    estimate_m: bool,
    m: f64,
    hi_scale: f64,
    elements: u64,
    extra_queries: u64,
    /// Gain evaluations charged by `peek_gain_batch` past the point where
    /// the batch scan diverged — work the scalar path would not have done.
    /// Subtracted from reported query stats (see `process_batch`).
    speculative_queries: u64,
    /// Query total carried over by [`StreamingAlgorithm::restore_state`]:
    /// the resumed-from run's reported queries. Added to stats and — like
    /// the oracle's own counter — deliberately *not* cleared by `reset`,
    /// so accounting stays identical to a run that never paused even when
    /// a drift re-selection follows a resume.
    restored_queries: u64,
    /// Kernel-eval total carried over by `restore_state` (same rebase
    /// pattern as `restored_queries`, for the measured
    /// [`AlgoStats::kernel_evals`] counter).
    restored_kernel_evals: u64,
    /// Kernel evals the restore replay charged on the oracle — subtracted
    /// from stats so a resumed run reports exactly what the uninterrupted
    /// run would.
    discounted_kernel_evals: u64,
    /// Scratch for `process_batch` gain panels.
    gain_buf: Vec<f64>,
    peak_stored: usize,
    /// Wall-ns spent in the batch threshold scan, advanced only while
    /// [`obs`](crate::obs) recording is on. Cumulative like the oracle's
    /// query counter (not cleared by `reset`, not checkpointed).
    scan_ns: u64,
    /// Decision telemetry: sieve-rule accepts/rejects and T-budget
    /// threshold-grid walks. Advanced only while obs recording is on;
    /// cumulative like `scan_ns`.
    accepts: u64,
    rejects: u64,
    threshold_moves: u64,
}

impl ThreeSieves {
    /// ThreeSieves with the oracle's exact `m = max_e f({e})`.
    pub fn new(
        oracle: Box<dyn SubmodularFunction>,
        k: usize,
        epsilon: f64,
        tuning: SieveTuning,
    ) -> Self {
        Self::with_grid_scale(oracle, k, epsilon, tuning, 1.0)
    }

    /// ThreeSieves whose grid upper end is `hi_scale · K · m`.
    ///
    /// The paper builds `O` from the loose bound `m = 1 + aK` (§4.1) rather
    /// than the exact singleton value `½·ln(1+a)` — i.e. the grid *starts
    /// far above OPT* and the algorithm spends its early budget walking
    /// down through all-reject thresholds. That descent is what makes the
    /// eventual acceptances greedy-grade on duplicate-heavy streams: by the
    /// time the threshold is reachable at all, only top-gain items pass.
    /// `hi_scale = 1` gives the exact-`m` grid (fills fast, first-K-ish on
    /// easy data); `hi_scale > 1` trades descent time (≈ `T·ln(hi_scale·K·m
    /// / 2·OPT)/ε` rejections) for pickiness. The approximation theorem
    /// only needs `O` to cover `[m, OPT]`, which any `hi_scale ≥ 1` does.
    pub fn with_grid_scale(
        oracle: Box<dyn SubmodularFunction>,
        k: usize,
        epsilon: f64,
        tuning: SieveTuning,
        hi_scale: f64,
    ) -> Self {
        assert!(k > 0, "K must be positive");
        assert!(epsilon > 0.0, "epsilon must be positive");
        assert!(hi_scale >= 1.0, "hi_scale must be >= 1");
        let m = oracle.max_singleton_value();
        let grid = threshold_grid(epsilon, m, hi_scale * k as f64 * m);
        let mut ts = ThreeSieves {
            oracle,
            k,
            epsilon,
            t_budget: tuning.t(),
            grid,
            v: 0.0,
            t: 0,
            estimate_m: false,
            m,
            hi_scale,
            elements: 0,
            extra_queries: 0,
            speculative_queries: 0,
            restored_queries: 0,
            restored_kernel_evals: 0,
            discounted_kernel_evals: 0,
            gain_buf: Vec::new(),
            peak_stored: 0,
            scan_ns: 0,
            accepts: 0,
            rejects: 0,
            threshold_moves: 0,
        };
        ts.pop_threshold();
        ts
    }

    /// ThreeSieves that estimates `m` on the fly: starts from the first
    /// element's singleton value, and restarts the summary whenever a new
    /// maximum arrives (this preserves Theorem 1, see paper §3).
    pub fn with_m_estimation(
        oracle: Box<dyn SubmodularFunction>,
        k: usize,
        epsilon: f64,
        tuning: SieveTuning,
    ) -> Self {
        let mut ts = Self::new(oracle, k, epsilon, tuning);
        ts.estimate_m = true;
        ts.m = 0.0;
        ts.grid.clear();
        ts.v = f64::INFINITY; // reject everything until the first m estimate
        ts
    }

    fn pop_threshold(&mut self) {
        self.t = 0;
        self.v = self.grid.pop().unwrap_or(self.v.min(f64::MAX));
    }

    /// T-budget certificate fired with thresholds left: log the grid walk,
    /// then pop. The telemetry is obs-gated; the pop is unconditional.
    fn budget_pop(&mut self) {
        if crate::obs::enabled() {
            self.threshold_moves += 1;
            let to = *self.grid.last().expect("budget_pop needs a non-empty grid");
            crate::obs::emit_event(crate::obs::Event::ThresholdMove {
                sieve: 0,
                from: self.v,
                to,
            });
        }
        self.pop_threshold();
    }

    /// T-budget certificate fired with the grid exhausted: confidence
    /// restarts on the final threshold (the paper keeps sieving with the
    /// last v). `emit_event` gates itself, so this is one relaxed load
    /// when recording is off.
    fn budget_exhausted(&mut self) {
        crate::obs::emit_event(crate::obs::Event::ConfidenceReset { sieve: 0, t: self.t as u64 });
        self.t = 0;
    }

    /// Log one accept/reject decision (obs-gated; one relaxed load off).
    #[inline]
    fn note_decision(&mut self, accepted: bool, gain: f64, tau: f64) {
        if !crate::obs::enabled() {
            return;
        }
        let element = self.elements - 1;
        if accepted {
            self.accepts += 1;
            crate::obs::emit_event(crate::obs::Event::Accept { element, sieve: 0, gain, tau });
        } else {
            self.rejects += 1;
            crate::obs::emit_event(crate::obs::Event::Reject { element, sieve: 0, gain, tau });
        }
    }

    fn rebuild_grid(&mut self, m: f64) {
        self.m = m;
        self.grid = threshold_grid(self.epsilon, m, self.hi_scale * self.k as f64 * m);
        self.pop_threshold();
    }

    /// Active threshold (exposed for tests and the coordinator's telemetry).
    pub fn active_threshold(&self) -> f64 {
        self.v
    }

    /// Remaining grid size.
    pub fn grid_remaining(&self) -> usize {
        self.grid.len()
    }

    /// The rejection budget T in use.
    pub fn t_budget(&self) -> usize {
        self.t_budget
    }

    /// Speculative gain evaluations paid by the batched path beyond what
    /// the scalar path would have queried (telemetry; excluded from
    /// [`StreamingAlgorithm::stats`]).
    pub fn speculative_queries(&self) -> u64 {
        self.speculative_queries
    }
}

impl StreamingAlgorithm for ThreeSieves {
    fn name(&self) -> String {
        format!("ThreeSieves(T={})", self.t_budget)
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;

        // When the summary is empty the main gain query *is* the singleton
        // value f({e}) (Δf(e|∅) = f({e})), so m estimation rides along for
        // free; only a non-empty summary pays the extra probe query on a
        // scratch oracle.
        let mut precomputed: Option<f64> = None;
        if self.estimate_m {
            let singleton = if self.oracle.is_empty() {
                let g = self.oracle.peek_gain(item);
                precomputed = Some(g);
                g
            } else {
                self.extra_queries += 1;
                let mut probe = self.oracle.clone_empty();
                probe.peek_gain(item)
            };
            if singleton > self.m {
                // New maximum invalidates the running estimate: restart.
                // The reset empties the summary, so the pending gain query
                // below is again exactly the singleton value — reuse it.
                self.oracle.reset();
                self.rebuild_grid(singleton);
                precomputed = Some(singleton);
            }
        }

        let len = self.oracle.len();
        if len >= self.k {
            return; // summary full — ThreeSieves stops looking
        }
        if !self.v.is_finite() {
            return; // m estimation hasn't seen the first element yet
        }

        let thresh = sieve_threshold(self.v, self.oracle.current_value(), self.k, len);
        let gain = match precomputed {
            Some(g) => g,
            None => self.oracle.peek_gain(item),
        };
        let accepted = gain >= thresh;
        self.note_decision(accepted, gain, thresh);
        if accepted {
            self.oracle.accept(item);
            self.t = 0;
        } else {
            self.t += 1;
            if self.t >= self.t_budget {
                if self.grid.is_empty() {
                    // Smallest threshold exhausted: keep v (the paper keeps
                    // sieving with the last threshold).
                    self.budget_exhausted();
                } else {
                    self.budget_pop();
                }
            }
        }
        if self.oracle.len() > self.peak_stored {
            self.peak_stored = self.oracle.len();
        }
    }

    /// Batched ingestion (the tentpole path): evaluate the whole chunk's
    /// gains against the *current* summary in one
    /// [`peek_gain_batch`](SubmodularFunction::peek_gain_batch) call —
    /// which, since §Perf iteration 7, runs one blocked multi-RHS forward
    /// substitution for the whole chunk instead of per-candidate
    /// factor-streaming solves — and scan for the first acceptance.
    /// Gains depend only on the summary, so
    /// a T-exhaustion threshold drop mid-scan just recomputes the
    /// threshold and keeps consuming the same panel; only an acceptance
    /// invalidates the remaining gains, after which the rest of the chunk
    /// replays per item. The scan reproduces the scalar decisions exactly;
    /// speculative gains past an acceptance are tracked and excluded from
    /// `stats().queries`.
    fn process_batch(&mut self, chunk: &[f32]) {
        let d = self.oracle.dim();
        debug_assert_eq!(chunk.len() % d, 0, "chunk not row-aligned");
        let total = chunk.len() / d;
        if self.estimate_m {
            // m estimation needs its per-item singleton handling; replay.
            for row in chunk.chunks_exact(d) {
                self.process(row);
            }
            return;
        }
        if total == 0 {
            return;
        }
        if self.oracle.len() >= self.k {
            // Full summary: the scalar path only counts the elements.
            self.elements += total as u64;
            return;
        }
        // One panel, one scan, optional per-item replay — straight-line by
        // construction: the first acceptance hands the remainder to the
        // scalar path; threshold pops keep the scan going.
        let mut gains = std::mem::take(&mut self.gain_buf);
        self.oracle.peek_gain_batch(chunk, total, &mut gains);
        let mut thresh = sieve_threshold(
            self.v,
            self.oracle.current_value(),
            self.k,
            self.oracle.len(),
        );
        let mut consumed = 0usize;
        let mut accepted = false;
        let scan_span = crate::obs::span("sieve-scan");
        let scan_t = crate::obs::clock();
        for (j, &gain) in gains.iter().enumerate() {
            self.elements += 1;
            consumed = j + 1;
            let pass = gain >= thresh;
            self.note_decision(pass, gain, thresh);
            if pass {
                self.oracle.accept(&chunk[j * d..(j + 1) * d]);
                self.t = 0;
                if self.oracle.len() > self.peak_stored {
                    self.peak_stored = self.oracle.len();
                }
                accepted = true;
                break;
            }
            self.t += 1;
            if self.t >= self.t_budget {
                if self.grid.is_empty() {
                    self.budget_exhausted();
                } else {
                    self.budget_pop();
                    thresh = sieve_threshold(
                        self.v,
                        self.oracle.current_value(),
                        self.k,
                        self.oracle.len(),
                    );
                }
            }
        }
        self.scan_ns += crate::obs::lap(scan_t);
        drop(scan_span);
        self.speculative_queries += (total - consumed) as u64;
        self.gain_buf = gains;
        if accepted {
            // Per-item replay for the remainder of the chunk.
            for row in chunk[consumed * d..].chunks_exact(d) {
                self.process(row);
            }
        }
    }

    fn value(&self) -> f64 {
        self.oracle.current_value()
    }

    fn summary(&self) -> Vec<f32> {
        self.oracle.summary().to_vec()
    }

    fn summary_len(&self) -> usize {
        self.oracle.len()
    }

    fn dim(&self) -> usize {
        self.oracle.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        AlgoStats {
            queries: (self.oracle.queries() + self.extra_queries + self.restored_queries)
                .saturating_sub(self.speculative_queries),
            kernel_evals: (self.oracle.kernel_evals() + self.restored_kernel_evals)
                .saturating_sub(self.discounted_kernel_evals),
            elements: self.elements,
            stored: self.oracle.len(),
            peak_stored: self.peak_stored,
            instances: 1,
            wall_kernel_ns: self.oracle.wall_kernel_ns(),
            wall_solve_ns: self.oracle.wall_solve_ns(),
            wall_scan_ns: self.scan_ns,
            accepts: self.accepts,
            rejects: self.rejects,
            defers: 0,
            threshold_moves: self.threshold_moves,
        }
    }

    fn reset(&mut self) {
        self.oracle.reset();
        self.elements = 0;
        self.extra_queries = 0;
        // speculative_queries stays cumulative: the oracle's query counter
        // survives reset, so its speculative share must keep matching.
        self.peak_stored = 0;
        self.t = 0;
        if self.estimate_m {
            self.m = 0.0;
            self.grid.clear();
            self.v = f64::INFINITY;
        } else {
            let m = self.oracle.max_singleton_value();
            self.rebuild_grid(m);
        }
    }

    /// The full resumable state beyond the summary, in O(1) space: the
    /// remaining grid is always a *prefix* of `threshold_grid(ε, m,
    /// hi_scale·K·m)` (thresholds pop from the back and only whole-grid
    /// rebuilds replace it), so its length plus the grid inputs — all of
    /// which survive the JSON text roundtrip bit-for-bit — reconstruct it
    /// exactly. `queries` stores the *reported* stat; `restore_state`
    /// rebases the oracle's counter against it so accounting continues
    /// seamlessly across the pause.
    fn snapshot_state(&self) -> Option<Json> {
        if !self.v.is_finite() {
            // m estimation before the first element: nothing to resume yet
            // (and infinity does not survive JSON).
            return None;
        }
        Some(Json::obj(vec![
            ("algo", Json::str("three-sieves")),
            ("k", Json::num(self.k as f64)),
            ("dim", Json::num(self.oracle.dim() as f64)),
            ("epsilon", Json::num(self.epsilon)),
            ("hi_scale", Json::num(self.hi_scale)),
            ("t_budget", Json::num(self.t_budget as f64)),
            ("estimate_m", Json::Bool(self.estimate_m)),
            ("m", Json::num(self.m)),
            ("grid_len", Json::num(self.grid.len() as f64)),
            ("v", Json::num(self.v)),
            ("t", Json::num(self.t as f64)),
            ("elements", Json::num(self.elements as f64)),
            ("queries", Json::num(self.stats().queries as f64)),
            ("kernel_evals", Json::num(self.stats().kernel_evals as f64)),
            ("peak_stored", Json::num(self.peak_stored as f64)),
        ]))
    }

    fn restore_state(&mut self, state: &Json, summary: &[f32]) -> Result<(), String> {
        let field = |name: &str| {
            state.get(name).as_f64().ok_or_else(|| format!("checkpoint state missing {name:?}"))
        };
        if state.get("algo").as_str() != Some("three-sieves") {
            return Err(format!(
                "checkpoint state is for {:?}, not three-sieves",
                state.get("algo").as_str().unwrap_or("?")
            ));
        }
        let same = |name: &str, mine: f64| -> Result<(), String> {
            let theirs = field(name)?;
            if theirs.to_bits() != mine.to_bits() {
                return Err(format!("checkpoint {name} = {theirs} != configured {mine}"));
            }
            Ok(())
        };
        same("k", self.k as f64)?;
        same("dim", self.oracle.dim() as f64)?;
        same("epsilon", self.epsilon)?;
        same("hi_scale", self.hi_scale)?;
        same("t_budget", self.t_budget as f64)?;
        if state.get("estimate_m").as_bool() != Some(self.estimate_m) {
            return Err("checkpoint m-estimation mode differs from configured".into());
        }
        let d = self.oracle.dim();
        if summary.len() % d != 0 || summary.len() / d > self.k {
            return Err(format!(
                "checkpoint summary has {} floats, not <= {}x{d} rows",
                summary.len(),
                self.k
            ));
        }
        // Extract and validate EVERY field before touching any state: a
        // blob that fails mid-way (truncated, version-skewed) must leave
        // this instance exactly as it was, so callers can fall back to a
        // fresh start without inheriting a half-restored algorithm.
        let m = field("m")?;
        if !(m.is_finite() && m > 0.0) {
            return Err(format!("checkpoint m = {m} is not a positive finite value"));
        }
        let grid_len = field("grid_len")? as usize;
        let v = field("v")?;
        let t = field("t")? as usize;
        let elements = field("elements")? as u64;
        let peak_stored = field("peak_stored")? as usize;
        let queries = field("queries")? as u64;
        // Absent in checkpoints written before the kernel_evals counter
        // existed — default to 0 so old sessions still resume (the
        // measured counter restarts, the paper accounting is intact).
        let kernel_evals = state.get("kernel_evals").as_f64().unwrap_or(0.0) as u64;
        let mut grid = threshold_grid(self.epsilon, m, self.hi_scale * self.k as f64 * m);
        if grid_len > grid.len() {
            return Err(format!("checkpoint grid_len {grid_len} exceeds full grid {}", grid.len()));
        }
        grid.truncate(grid_len);

        // Replay the summary through a fresh oracle: accepting the same
        // rows in the same (insertion) order reproduces the incremental
        // Cholesky state bit-for-bit.
        self.oracle.reset();
        for row in summary.chunks_exact(d) {
            self.oracle.accept(row);
        }
        self.m = m;
        self.grid = grid;
        self.v = v;
        self.t = t;
        self.elements = elements;
        self.peak_stored = peak_stored.max(self.oracle.len());
        // Rebase accounting: reported queries = oracle + extra + restored −
        // speculative. Cancel the replay's oracle charges and carry the
        // checkpointed total in `restored_queries` (NOT `extra_queries`,
        // which a drift `reset` clears), so stats() continues exactly
        // where the paused run left off — including across later resets.
        self.speculative_queries = self.oracle.queries();
        self.extra_queries = 0;
        self.restored_queries = queries;
        // Same rebase for the measured kernel-eval counter: cancel the
        // replay's kernel rows and carry the checkpointed total.
        self.discounted_kernel_evals = self.oracle.kernel_evals();
        self.restored_kernel_evals = kernel_evals;
        self.gain_buf.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn tuning_rule_of_three() {
        // alpha = 0.05, tau = 0.003 -> T ≈ ceil(2.9957/0.003) = 999
        let t = SieveTuning::RuleOfThree { alpha: 0.05, tau: 0.003 }.t();
        assert!((998..=1000).contains(&t), "T = {t}");
        assert_eq!(SieveTuning::FixedT(500).t(), 500);
        assert_eq!(SieveTuning::FixedT(0).t(), 1); // floor at 1
    }

    #[test]
    #[should_panic(expected = "alpha in (0,1)")]
    fn tuning_rejects_bad_alpha() {
        SieveTuning::RuleOfThree { alpha: 1.5, tau: 0.1 }.t();
    }

    #[test]
    fn selects_full_summary_on_clustered_data() {
        let ds = testkit::clustered(3000, 1);
        let k = 8;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(100));
        testkit::run(&mut algo, &ds);
        assert_eq!(algo.summary_len(), k);
        assert!(algo.value() > 0.0);
    }

    #[test]
    fn single_query_per_element() {
        let ds = testkit::clustered(1000, 2);
        let k = 5;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(50));
        testkit::run(&mut algo, &ds);
        let st = algo.stats();
        // At most 1 gain query per element + 1 update query per accept
        // (≤ K); once the summary is full ThreeSieves stops querying, so
        // the measured rate is ≤ 1, never above.
        assert!(st.queries <= st.elements + 2 * k as u64, "{st:?}");
        assert!(st.queries_per_element() <= 1.02, "{}", st.queries_per_element());
        assert!(st.queries > 0);
    }

    #[test]
    fn memory_is_k_elements() {
        let ds = testkit::clustered(2000, 3);
        let k = 10;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.005, SieveTuning::FixedT(200));
        testkit::run(&mut algo, &ds);
        assert!(algo.stats().peak_stored <= k);
        assert_eq!(algo.stats().instances, 1);
    }

    #[test]
    fn threshold_lowers_after_t_rejections() {
        // Large K keeps the summary from filling; repeated duplicates have
        // rapidly shrinking gains, so rejections accumulate and the active
        // threshold must walk down the grid.
        let k = 50;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.5, SieveTuning::FixedT(3));
        let v0 = algo.active_threshold();
        let item = vec![0.0f32; testkit::DIM];
        for _ in 0..200 {
            algo.process(&item);
        }
        assert!(algo.active_threshold() < v0, "{} !< {v0}", algo.active_threshold());
    }

    #[test]
    fn competitive_with_greedy_on_iid_data() {
        let ds = testkit::clustered(4000, 4);
        let k = 10;
        let greedy = testkit::greedy_value(&ds, k);
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.001, SieveTuning::FixedT(1000));
        // Paper batch protocol: re-iterate until full (at most K passes).
        let mut passes = 0;
        while !algo.is_full() && passes < k {
            testkit::run(&mut algo, &ds);
            passes += 1;
        }
        let rel = algo.value() / greedy;
        assert!(rel > 0.8, "relative performance {rel:.3} too low");
    }

    #[test]
    fn m_estimation_variant_matches_known_m_on_logdet() {
        // For the normalized-kernel log-det every singleton has the same
        // value, so the estimated-m variant must behave identically after
        // the first element.
        let ds = testkit::clustered(1500, 5);
        let k = 6;
        let mut known = ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(100));
        let mut est =
            ThreeSieves::with_m_estimation(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(100));
        testkit::run(&mut known, &ds);
        testkit::run(&mut est, &ds);
        assert!((known.value() - est.value()).abs() < 1e-9);
        assert_eq!(known.summary_len(), est.summary_len());
    }

    #[test]
    fn m_estimation_empty_summary_probe_is_free() {
        // With an empty summary the main gain query doubles as the
        // singleton probe, so the first element costs exactly one gain
        // query plus the accept — no scratch-oracle probe.
        let k = 4;
        let mut algo =
            ThreeSieves::with_m_estimation(testkit::oracle(k), k, 0.1, SieveTuning::FixedT(10));
        let item = vec![0.2f32; testkit::DIM];
        algo.process(&item);
        // Grid starts at K·m, thresh = (K·m/2)/K = m/2 ≤ singleton: accept.
        assert_eq!(algo.summary_len(), 1, "first element must be accepted");
        let st = algo.stats();
        assert_eq!(st.queries, 2, "peek + accept only, no extra probe: {st:?}");
    }

    #[test]
    fn m_estimation_nonempty_summary_still_pays_one_probe() {
        let k = 4;
        let mut algo =
            ThreeSieves::with_m_estimation(testkit::oracle(k), k, 0.1, SieveTuning::FixedT(10));
        let a = vec![0.2f32; testkit::DIM];
        let mut b = vec![0.0f32; testkit::DIM];
        b[0] = 1.5;
        algo.process(&a); // 2 queries (peek + accept), summary non-empty
        let q_before = algo.stats().queries;
        algo.process(&b); // probe (1) + main peek (1) [+ accept if taken]
        let spent = algo.stats().queries - q_before;
        assert!(
            (2..=3).contains(&spent),
            "non-empty path pays probe + peek (+accept), got {spent}"
        );
    }

    #[test]
    fn reset_clears_state() {
        let ds = testkit::clustered(500, 6);
        let k = 5;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(50));
        testkit::run(&mut algo, &ds);
        assert!(algo.summary_len() > 0);
        algo.reset();
        assert_eq!(algo.summary_len(), 0);
        assert_eq!(algo.stats().elements, 0);
        // Still functional after reset.
        testkit::run(&mut algo, &ds);
        assert!(algo.summary_len() > 0);
    }

    #[test]
    fn name_includes_t() {
        let algo = ThreeSieves::new(testkit::oracle(3), 3, 0.1, SieveTuning::FixedT(42));
        assert_eq!(algo.name(), "ThreeSieves(T=42)");
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        let ds = testkit::clustered(2000, 11);
        let k = 6;
        let build = || ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(80));
        let mut whole = build();
        let mut first = build();
        let half = ds.len() / 2;
        for i in 0..half {
            whole.process(ds.row(i));
            first.process(ds.row(i));
        }
        // Snapshot → JSON text → parse → restore into a fresh instance:
        // the same roundtrip a checkpoint file performs.
        let state = first.snapshot_state().expect("exact-m ThreeSieves is resumable");
        let text = state.to_string();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let summary = first.summary();
        let mut resumed = build();
        resumed.restore_state(&parsed, &summary).unwrap();
        assert_eq!(resumed.value().to_bits(), first.value().to_bits());
        assert_eq!(resumed.stats(), first.stats());
        assert_eq!(resumed.active_threshold().to_bits(), first.active_threshold().to_bits());
        assert_eq!(resumed.grid_remaining(), first.grid_remaining());
        for i in half..ds.len() {
            whole.process(ds.row(i));
            resumed.process(ds.row(i));
        }
        assert_eq!(resumed.value().to_bits(), whole.value().to_bits());
        assert_eq!(resumed.summary(), whole.summary());
        assert_eq!(resumed.stats(), whole.stats());
    }

    #[test]
    fn snapshot_restore_survives_batched_continuation() {
        let ds = testkit::clustered(1600, 12);
        let k = 5;
        let build = || ThreeSieves::new(testkit::oracle(k), k, 0.02, SieveTuning::FixedT(60));
        let d = testkit::DIM;
        let half = ds.len() / 2 * d;
        let mut whole = build();
        let mut first = build();
        for chunk in ds.raw()[..half].chunks(37 * d) {
            whole.process_batch(chunk);
            first.process_batch(chunk);
        }
        let state = first.snapshot_state().unwrap();
        let mut resumed = build();
        resumed.restore_state(&state, &first.summary()).unwrap();
        for chunk in ds.raw()[half..].chunks(37 * d) {
            whole.process_batch(chunk);
            resumed.process_batch(chunk);
        }
        assert_eq!(resumed.value().to_bits(), whole.value().to_bits());
        assert_eq!(resumed.summary(), whole.summary());
        assert_eq!(resumed.stats(), whole.stats());
    }

    #[test]
    fn resume_then_reset_keeps_query_accounting() {
        // A drift re-selection after a resume must not drop the pre-pause
        // query count: the restored baseline survives reset() exactly like
        // the oracle's own cumulative counter does.
        let ds = testkit::clustered(1200, 14);
        let k = 5;
        let build = || ThreeSieves::new(testkit::oracle(k), k, 0.02, SieveTuning::FixedT(40));
        let mut whole = build();
        let mut first = build();
        let half = ds.len() / 2;
        for i in 0..half {
            whole.process(ds.row(i));
            first.process(ds.row(i));
        }
        let mut resumed = build();
        resumed.restore_state(&first.snapshot_state().unwrap(), &first.summary()).unwrap();
        // Drift fires on both timelines right after the pause point.
        whole.reset();
        resumed.reset();
        for i in half..ds.len() {
            whole.process(ds.row(i));
            resumed.process(ds.row(i));
        }
        assert_eq!(resumed.value().to_bits(), whole.value().to_bits());
        assert_eq!(resumed.summary(), whole.summary());
        assert_eq!(resumed.stats(), whole.stats(), "query accounting must survive reset");
    }

    #[test]
    fn failed_restore_leaves_state_untouched() {
        let ds = testkit::clustered(400, 13);
        let k = 4;
        let mut algo = ThreeSieves::new(testkit::oracle(k), k, 0.05, SieveTuning::FixedT(20));
        for i in 0..ds.len() {
            algo.process(ds.row(i));
        }
        let before_value = algo.value().to_bits();
        let before_stats = algo.stats();
        let before_thresh = algo.active_threshold().to_bits();
        // A blob that passes the config checks but is missing "v" (e.g.
        // version skew) must fail cleanly, not half-restore.
        let text = algo.snapshot_state().unwrap().to_string().replace("\"v\":", "\"v_gone\":");
        let broken = crate::util::json::Json::parse(&text).unwrap();
        let summary = algo.summary();
        assert!(algo.restore_state(&broken, &summary).is_err());
        assert_eq!(algo.value().to_bits(), before_value, "value must be untouched");
        assert_eq!(algo.stats(), before_stats, "accounting must be untouched");
        assert_eq!(algo.active_threshold().to_bits(), before_thresh);
        // And the instance still works.
        algo.process(ds.row(0));
        assert_eq!(algo.stats().elements, before_stats.elements + 1);
    }

    #[test]
    fn restore_rejects_mismatched_configuration() {
        let k = 4;
        let mut donor = ThreeSieves::new(testkit::oracle(k), k, 0.1, SieveTuning::FixedT(10));
        let item = vec![0.3f32; testkit::DIM];
        donor.process(&item);
        let state = donor.snapshot_state().unwrap();
        let summary = donor.summary();
        // Different K.
        let mut other = ThreeSieves::new(testkit::oracle(5), 5, 0.1, SieveTuning::FixedT(10));
        assert!(other.restore_state(&state, &summary).is_err());
        // Different epsilon.
        let mut other = ThreeSieves::new(testkit::oracle(k), k, 0.2, SieveTuning::FixedT(10));
        assert!(other.restore_state(&state, &summary).is_err());
        // Different T budget.
        let mut other = ThreeSieves::new(testkit::oracle(k), k, 0.1, SieveTuning::FixedT(11));
        assert!(other.restore_state(&state, &summary).is_err());
        // Ragged summary payload.
        let mut other = ThreeSieves::new(testkit::oracle(k), k, 0.1, SieveTuning::FixedT(10));
        assert!(other.restore_state(&state, &summary[..testkit::DIM - 1]).is_err());
        // Matching configuration still restores.
        let mut ok = ThreeSieves::new(testkit::oracle(k), k, 0.1, SieveTuning::FixedT(10));
        ok.restore_state(&state, &summary).unwrap();
        assert_eq!(ok.value().to_bits(), donor.value().to_bits());
    }
}
