//! **Subsampled streaming** ("Do Less, Get More", Feldman, Karbasi &
//! Kazemi 2018): thin the stream by keeping each element independently
//! with probability `p`, then feed the survivors to an inner streaming
//! algorithm. The expected number of oracle calls drops by the factor
//! `p` while the approximation guarantee degrades gracefully — the
//! paper's point is that the trade is strongly in favour of sampling.
//!
//! The coin for element `i` is [`crate::util::rng::mix_unit`]`(seed, i)`
//! — a *stateless* mixer keyed on the element's absolute stream index,
//! not a sequential RNG. A decision therefore depends only on
//! `(seed, index)`, which makes the thinned stream invariant to batch
//! size, thread count and pause/resume boundaries by construction: the
//! whole parity ladder reduces to the inner algorithm's, which the
//! wrapper inherits wholesale (`process_batch`, the shared kernel-panel
//! broker and the solve grid all run *inside* the inner algorithm on the
//! thinned stream).
//!
//! Query accounting: the inner algorithm only ever sees kept elements,
//! so its `AlgoStats::queries` *is* the reduced oracle-call count; the
//! wrapper overrides `elements` with the observed (pre-thinning) count
//! so the reduction is measurable against an unthinned baseline on the
//! same stream.

use crate::exec::ExecContext;
use crate::metrics::AlgoStats;
use crate::util::json::Json;
use crate::util::rng::mix_unit;

use super::StreamingAlgorithm;

/// The sampling wrapper (see module docs).
pub struct Subsampled {
    inner: Box<dyn StreamingAlgorithm>,
    /// Keep probability in (0, 1].
    p: f64,
    seed: u64,
    /// Absolute stream index of the next element — monotone across
    /// drift resets so coins never repeat within a session.
    index: u64,
    /// Elements observed (kept + dropped) since the last reset.
    observed: u64,
    /// Kept elements dropped this session (bench/test hook).
    kept: u64,
    /// Contiguous staging for kept rows of the current chunk.
    keep_buf: Vec<f32>,
}

impl Subsampled {
    pub fn new(inner: Box<dyn StreamingAlgorithm>, p: f64, seed: u64) -> Self {
        assert!(p > 0.0 && p <= 1.0, "keep probability must be in (0, 1]");
        Subsampled { inner, p, seed, index: 0, observed: 0, kept: 0, keep_buf: Vec::new() }
    }

    /// Kept-element count (the thinned stream's length so far).
    pub fn kept_count(&self) -> u64 {
        self.kept
    }

    #[inline]
    fn keep(&self, index: u64) -> bool {
        mix_unit(self.seed, index) < self.p
    }
}

impl StreamingAlgorithm for Subsampled {
    fn name(&self) -> String {
        format!("Subsampled(p={})+{}", self.p, self.inner.name())
    }

    fn process(&mut self, item: &[f32]) {
        let idx = self.index;
        self.index += 1;
        self.observed += 1;
        if self.keep(idx) {
            self.kept += 1;
            self.inner.process(item);
        }
    }

    /// Filter the chunk down to its kept rows (contiguously, preserving
    /// order) and hand the survivors to the inner algorithm as one
    /// batch. Because each coin is a pure function of the absolute
    /// index, the thinned stream — and therefore every inner decision —
    /// is identical for any chunking of the same input.
    fn process_batch(&mut self, chunk: &[f32]) {
        let d = self.inner.dim();
        debug_assert_eq!(chunk.len() % d, 0, "chunk not row-aligned");
        let total = chunk.len() / d;
        self.keep_buf.clear();
        for r in 0..total {
            if self.keep(self.index + r as u64) {
                self.keep_buf.extend_from_slice(&chunk[r * d..(r + 1) * d]);
            }
        }
        self.index += total as u64;
        self.observed += total as u64;
        self.kept += (self.keep_buf.len() / d) as u64;
        if !self.keep_buf.is_empty() {
            // Swap the staging buffer out so the inner call can't alias it.
            let staged = std::mem::take(&mut self.keep_buf);
            self.inner.process_batch(&staged);
            self.keep_buf = staged;
        }
    }

    fn finalize(&mut self) {
        self.inner.finalize();
    }

    fn set_exec(&mut self, exec: ExecContext) {
        self.inner.set_exec(exec);
    }

    fn value(&self) -> f64 {
        self.inner.value()
    }

    fn summary(&self) -> Vec<f32> {
        self.inner.summary()
    }

    fn summary_len(&self) -> usize {
        self.inner.summary_len()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn k(&self) -> usize {
        self.inner.k()
    }

    /// The inner stats, with `elements` rebased to the observed
    /// (pre-thinning) stream so `queries / elements` exposes the
    /// oracle-call reduction directly.
    fn stats(&self) -> AlgoStats {
        let mut st = self.inner.stats();
        st.elements = self.observed;
        st
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.observed = 0;
        self.kept = 0;
        // `index` deliberately survives: coins are keyed on the absolute
        // stream position, which keeps ticking across drift resets.
    }

    fn snapshot_state(&self) -> Option<Json> {
        let inner = self.inner.snapshot_state()?;
        Some(Json::obj(vec![
            ("algo", Json::str("subsampled")),
            ("p", Json::num(self.p)),
            ("seed", Json::num(self.seed as f64)),
            ("index", Json::num(self.index as f64)),
            ("observed", Json::num(self.observed as f64)),
            ("kept", Json::num(self.kept as f64)),
            ("inner", inner),
        ]))
    }

    fn restore_state(&mut self, state: &Json, summary: &[f32]) -> Result<(), String> {
        let field = |name: &str| -> Result<f64, String> {
            state.get(name).as_f64().ok_or_else(|| format!("checkpoint state missing {name:?}"))
        };
        match state.get("algo").as_str() {
            Some("subsampled") => {}
            _ => return Err("checkpoint algo mismatch (want subsampled)".into()),
        }
        if field("p")?.to_bits() != self.p.to_bits() {
            return Err("checkpoint p mismatch".into());
        }
        if field("seed")? as u64 != self.seed {
            return Err("checkpoint seed mismatch".into());
        }
        let index = field("index")? as u64;
        let observed = field("observed")? as u64;
        let kept = field("kept")? as u64;
        // The inner restore validates everything before mutating, so a
        // failure below leaves the wrapper untouched too.
        self.inner.restore_state(state.get("inner"), summary)?;
        self.index = index;
        self.observed = observed;
        self.kept = kept;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;
    use crate::algorithms::three_sieves::SieveTuning;
    use crate::algorithms::{SieveStreaming, ThreeSieves};

    fn wrapped(k: usize, p: f64, seed: u64) -> Subsampled {
        Subsampled::new(Box::new(SieveStreaming::new(testkit::oracle(k), k, 0.1)), p, seed)
    }

    #[test]
    fn same_seed_bit_identical_across_batch_sizes() {
        let ds = testkit::clustered(1200, 1);
        let k = 6;
        let d = testkit::DIM;
        let mut scalar = wrapped(k, 0.5, 7);
        for row in ds.iter() {
            scalar.process(row);
        }
        for rows in [7usize, 64, 257] {
            let mut batched = wrapped(k, 0.5, 7);
            for chunk in ds.raw().chunks(rows * d) {
                batched.process_batch(chunk);
            }
            assert_eq!(scalar.value().to_bits(), batched.value().to_bits(), "rows={rows}");
            assert_eq!(scalar.summary(), batched.summary(), "rows={rows}");
            assert_eq!(scalar.stats(), batched.stats(), "rows={rows}");
            assert_eq!(scalar.kept_count(), batched.kept_count(), "rows={rows}");
        }
    }

    #[test]
    fn thins_oracle_calls_by_roughly_p() {
        let ds = testkit::clustered(2000, 2);
        let k = 6;
        let d = testkit::DIM;
        let mut plain = SieveStreaming::new(testkit::oracle(k), k, 0.1);
        let mut thinned = wrapped(k, 0.5, 11);
        for chunk in ds.raw().chunks(64 * d) {
            plain.process_batch(chunk);
            thinned.process_batch(chunk);
        }
        let (a, b) = (thinned.stats(), plain.stats());
        assert_eq!(a.elements, b.elements, "observed stream length is unchanged");
        assert!(
            (a.queries as f64) < 0.7 * b.queries as f64,
            "thinned queries {} not clearly below plain {}",
            a.queries,
            b.queries
        );
        // The keep rate concentrates around p over 2000 coins.
        let rate = thinned.kept_count() as f64 / a.elements as f64;
        assert!((rate - 0.5).abs() < 0.08, "keep rate {rate:.3}");
    }

    #[test]
    fn different_seeds_make_different_decisions() {
        let ds = testkit::clustered(800, 3);
        let d = testkit::DIM;
        let mut a = wrapped(5, 0.5, 1);
        let mut b = wrapped(5, 0.5, 2);
        for chunk in ds.raw().chunks(64 * d) {
            a.process_batch(chunk);
            b.process_batch(chunk);
        }
        assert_ne!(
            (a.kept_count(), a.stats().queries),
            (b.kept_count(), b.stats().queries),
            "independent seeds must thin differently"
        );
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        let ds = testkit::clustered(1000, 4);
        let k = 5;
        let d = testkit::DIM;
        let half = ds.len() / 2 * d;
        let inner = |s: u64| {
            let ts = ThreeSieves::new(testkit::oracle(k), k, 0.01, SieveTuning::FixedT(50));
            Subsampled::new(Box::new(ts), 0.5, s)
        };
        let mut full = inner(9);
        for chunk in ds.raw().chunks(64 * d) {
            full.process_batch(chunk);
        }
        let mut first = inner(9);
        for chunk in ds.raw()[..half].chunks(64 * d) {
            first.process_batch(chunk);
        }
        let state = first.snapshot_state().expect("resumable state");
        let summary = first.summary();
        let mut resumed = inner(9);
        resumed.restore_state(&state, &summary).unwrap();
        for chunk in ds.raw()[half..].chunks(64 * d) {
            resumed.process_batch(chunk);
        }
        assert_eq!(resumed.value().to_bits(), full.value().to_bits());
        assert_eq!(resumed.summary(), full.summary());
        let (a, b) = (resumed.stats(), full.stats());
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.elements, b.elements);
        assert_eq!(a.stored, b.stored);
        assert_eq!(resumed.kept_count(), full.kept_count());
    }

    #[test]
    fn restore_rejects_mismatched_wrapper_state() {
        let mut a = wrapped(5, 0.5, 1);
        let bad = Json::obj(vec![("algo", Json::str("subsampled")), ("p", Json::num(0.25))]);
        let err = a.restore_state(&bad, &[]).unwrap_err();
        assert!(err.contains("p mismatch"), "{err}");
    }

    #[test]
    fn reset_keeps_the_coin_sequence_moving() {
        let ds = testkit::clustered(300, 5);
        let mut algo = wrapped(4, 0.5, 3);
        for row in ds.iter() {
            algo.process(row);
        }
        let kept_before = algo.kept_count();
        algo.reset();
        assert_eq!(algo.stats().elements, 0);
        assert_eq!(algo.kept_count(), 0);
        for row in ds.iter() {
            algo.process(row);
        }
        // Indices continued past the reset, so the second pass flips
        // different coins than the first.
        assert_ne!(algo.kept_count(), 0);
        assert!(algo.kept_count() != kept_before || algo.stats().elements == 300);
    }
}
