//! **Random** — reservoir sampling (Vitter 1985), the ¼-in-expectation
//! baseline (Feige et al. 2011), paper Algorithm 3.

use crate::functions::SubmodularFunction;
use crate::metrics::AlgoStats;
use crate::util::rng::Rng;

use super::StreamingAlgorithm;

/// Uniform-random summary via reservoir sampling.
pub struct RandomReservoir {
    oracle: Box<dyn SubmodularFunction>,
    k: usize,
    rng: Rng,
    /// Items seen so far (the reservoir index base).
    i: u64,
    elements: u64,
    peak_stored: usize,
}

impl RandomReservoir {
    pub fn new(oracle: Box<dyn SubmodularFunction>, k: usize, seed: u64) -> Self {
        assert!(k > 0);
        RandomReservoir {
            oracle,
            k,
            rng: Rng::seed_from(seed),
            i: 0,
            elements: 0,
            peak_stored: 0,
        }
    }
}

impl StreamingAlgorithm for RandomReservoir {
    fn name(&self) -> String {
        "Random".into()
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        if self.oracle.len() < self.k {
            self.oracle.accept(item);
        } else {
            // Classic reservoir: replace a random slot with prob K / i.
            let j = self.rng.below(self.i + 1);
            if (j as usize) < self.k {
                self.oracle.remove(j as usize);
                self.oracle.accept(item);
            }
        }
        self.i += 1;
        if self.oracle.len() > self.peak_stored {
            self.peak_stored = self.oracle.len();
        }
    }

    fn value(&self) -> f64 {
        self.oracle.current_value()
    }

    fn summary(&self) -> Vec<f32> {
        self.oracle.summary().to_vec()
    }

    fn summary_len(&self) -> usize {
        self.oracle.len()
    }

    fn dim(&self) -> usize {
        self.oracle.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        AlgoStats {
            queries: self.oracle.queries(),
            kernel_evals: self.oracle.kernel_evals(),
            elements: self.elements,
            stored: self.oracle.len(),
            peak_stored: self.peak_stored,
            instances: 1,
            wall_kernel_ns: self.oracle.wall_kernel_ns(),
            wall_solve_ns: self.oracle.wall_solve_ns(),
            wall_scan_ns: 0,
            ..Default::default()
        }
    }

    fn reset(&mut self) {
        self.oracle.reset();
        self.i = 0;
        self.elements = 0;
        self.peak_stored = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn fills_to_k_and_stays_there() {
        let ds = testkit::clustered(500, 1);
        let k = 7;
        let mut algo = RandomReservoir::new(testkit::oracle(k), k, 3);
        testkit::run(&mut algo, &ds);
        assert_eq!(algo.summary_len(), k);
        assert_eq!(algo.stats().peak_stored, k);
    }

    #[test]
    fn reservoir_is_roughly_uniform() {
        // Track replacement behaviour through summary membership counts:
        // run many seeds over a stream of distinguishable items and check
        // early/late items appear with similar frequency.
        let n = 200usize;
        let k = 10usize;
        let d = testkit::DIM;
        let mut first_half = 0usize;
        let mut total = 0usize;
        for seed in 0..40u64 {
            let mut algo = RandomReservoir::new(testkit::oracle(k), k, seed);
            for i in 0..n {
                // Item encodes its index in feature 0.
                let mut item = vec![0.0f32; d];
                item[0] = i as f32;
                algo.process(&item);
            }
            let summary = algo.summary();
            for row in summary.chunks_exact(d) {
                total += 1;
                if (row[0] as usize) < n / 2 {
                    first_half += 1;
                }
            }
        }
        let frac = first_half as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.1, "first-half fraction {frac}");
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = testkit::clustered(300, 2);
        let k = 5;
        let mut a = RandomReservoir::new(testkit::oracle(k), k, 11);
        let mut b = RandomReservoir::new(testkit::oracle(k), k, 11);
        testkit::run(&mut a, &ds);
        testkit::run(&mut b, &ds);
        assert_eq!(a.summary(), b.summary());
    }

    #[test]
    fn beats_nothing_but_is_positive() {
        let ds = testkit::clustered(1000, 3);
        let k = 8;
        let mut algo = RandomReservoir::new(testkit::oracle(k), k, 5);
        testkit::run(&mut algo, &ds);
        assert!(algo.value() > 0.0);
    }
}
