//! The single algorithm registry: one table describing every algorithm the
//! crate knows — canonical name, aliases, typed parameter definitions
//! (shared by JSON configs, CLI flags and the service wire protocol),
//! sweep metadata, doc strings and a build function.
//!
//! Everything that used to be an `AlgoSpec` enum match scattered across
//! config parsing, the experiment runner, the CLI, the service protocol
//! and the figures is routed through [`ENTRIES`]. Registering a future
//! algorithm means adding one [`AlgoEntry`] (plus its implementation
//! module) — the config parser, `--algo` flag set, OPEN grammar, sweep
//! expansion and README table all pick it up from here. The name-set
//! equality tests in `tests/registry_field.rs` and the protocol module
//! enforce that invariant.

use crate::functions::SubmodularFunction;
use crate::util::json::Json;

use super::three_sieves::SieveTuning;
use super::{
    Greedy, IndependentSetImprovement, PreemptionStreaming, QuickStream, RandomReservoir, Salsa,
    SieveStreaming, SieveStreamingPP, StreamClipper, StreamGreedy, StreamingAlgorithm, Subsampled,
    ThreeSieves,
};

/// Wire/JSON/CLI type of a parameter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    F64,
    UInt,
    Bool,
}

impl ParamKind {
    fn label(self) -> &'static str {
        match self {
            ParamKind::F64 => "number",
            ParamKind::UInt => "non-negative integer",
            ParamKind::Bool => "boolean",
        }
    }
}

/// A typed parameter value.
#[derive(Clone, Debug, PartialEq)]
pub enum ParamValue {
    F64(f64),
    UInt(u64),
    Bool(bool),
}

impl ParamValue {
    pub fn kind(&self) -> ParamKind {
        match self {
            ParamValue::F64(_) => ParamKind::F64,
            ParamValue::UInt(_) => ParamKind::UInt,
            ParamValue::Bool(_) => ParamKind::Bool,
        }
    }
}

/// One parameter an algorithm accepts: its JSON/wire key, optional CLI
/// flag spelling, type, default, and an optional wire pin.
#[derive(Clone, Copy, Debug)]
pub struct ParamDef {
    /// JSON config key and service-OPEN key.
    pub key: &'static str,
    /// CLI flag name (`--<flag> <value>`); `None` keeps the parameter off
    /// the command line.
    pub flag: Option<&'static str>,
    pub kind: ParamKind,
    pub default: ParamValue,
    /// `Some(v)` pins the parameter to `v` on the service wire: OPEN does
    /// not accept the key and the spec serializer omits it. Used for
    /// knobs that are meaningless in a service context (Salsa's stream
    /// length hint — sessions are unbounded streams).
    pub wire_pin: Option<ParamValue>,
    pub help: &'static str,
}

/// Config-grid dimensions `experiments::custom` sweeps for an entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sweep {
    Epsilon,
    T,
}

/// Construct the algorithm behind a spec. `stream_len` is the length hint
/// for Salsa's position-adaptive rule (`None` disables it).
pub type BuildFn = fn(
    &AlgoSpec,
    Box<dyn SubmodularFunction>,
    usize,
    Option<usize>,
) -> Box<dyn StreamingAlgorithm>;

/// One registered algorithm — the single place a new algorithm is added.
pub struct AlgoEntry {
    /// Canonical name: config `"algo"` value, CLI `--algo` value, and the
    /// service OPEN `algo=` token.
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// Offline/multi-pass reference — refused by the streaming service.
    pub offline: bool,
    pub params: &'static [ParamDef],
    /// `(label, key)` suffixes appended to [`AlgoSpec::id`], e.g.
    /// `("t", "t")` turning `three-sieves` into `three-sieves-t500`.
    id_params: &'static [(&'static str, &'static str)],
    pub sweeps: &'static [Sweep],
    /// Docs: approximation guarantee (README table column).
    pub guarantee: &'static str,
    /// Docs: memory bound (README table column).
    pub memory: &'static str,
    /// Docs: oracle queries per element (README table column).
    pub queries: &'static str,
    pub build: BuildFn,
}

const P_EPSILON: ParamDef = ParamDef {
    key: "epsilon",
    flag: Some("epsilon"),
    kind: ParamKind::F64,
    default: ParamValue::F64(0.001),
    wire_pin: None,
    help: "threshold-grid resolution ε",
};

const P_SEED: ParamDef = ParamDef {
    key: "seed",
    flag: Some("seed"),
    kind: ParamKind::UInt,
    default: ParamValue::UInt(42),
    wire_pin: None,
    help: "PRNG seed",
};

const P_NU: ParamDef = ParamDef {
    key: "nu",
    flag: Some("nu"),
    kind: ParamKind::F64,
    default: ParamValue::F64(1e-4),
    wire_pin: None,
    help: "multi-pass threshold decay ν",
};

const P_T: ParamDef = ParamDef {
    key: "t",
    flag: Some("t"),
    kind: ParamKind::UInt,
    default: ParamValue::UInt(1000),
    wire_pin: None,
    help: "ThreeSieves confidence window T",
};

const P_SHARDS: ParamDef = ParamDef {
    key: "shards",
    flag: Some("shards"),
    kind: ParamKind::UInt,
    default: ParamValue::UInt(4),
    wire_pin: None,
    help: "parallel threshold-partition shards",
};

const P_C: ParamDef = ParamDef {
    key: "c",
    flag: Some("c"),
    kind: ParamKind::UInt,
    default: ParamValue::UInt(2),
    wire_pin: None,
    help: "QuickStream buffer factor c",
};

const P_USE_LENGTH_HINT: ParamDef = ParamDef {
    key: "use_length_hint",
    flag: None,
    kind: ParamKind::Bool,
    default: ParamValue::Bool(true),
    // Service sessions are unbounded streams: no length hint exists, so
    // the wire pins the knob off rather than accepting a lie.
    wire_pin: Some(ParamValue::Bool(false)),
    help: "enable Salsa's position-adaptive rule (needs the stream length)",
};

const P_CLIPPER_ALPHA: ParamDef = ParamDef {
    key: "clipper_alpha",
    flag: Some("clipper-alpha"),
    kind: ParamKind::F64,
    default: ParamValue::F64(1.0),
    wire_pin: None,
    help: "accept multiplier: take an element when gain ≥ α·τ",
};

const P_CLIPPER_BETA: ParamDef = ParamDef {
    key: "clipper_beta",
    flag: Some("clipper-beta"),
    kind: ParamKind::F64,
    default: ParamValue::F64(0.5),
    wire_pin: None,
    help: "defer multiplier: buffer an element when β·τ ≤ gain < α·τ",
};

const P_SUBSAMPLE_P: ParamDef = ParamDef {
    key: "subsample_p",
    flag: Some("subsample-p"),
    kind: ParamKind::F64,
    default: ParamValue::F64(0.5),
    wire_pin: None,
    help: "probability of offering each element to the inner algorithm",
};

static ENTRIES: &[AlgoEntry] = &[
    AlgoEntry {
        name: "greedy",
        aliases: &[],
        offline: true,
        params: &[],
        id_params: &[],
        sweeps: &[],
        guarantee: "1 − 1/e (offline)",
        memory: "O(K)",
        queries: "O(1)",
        build: |_, oracle, k, _| Box::new(Greedy::new(oracle, k)),
    },
    AlgoEntry {
        name: "random",
        aliases: &[],
        offline: false,
        params: &[P_SEED],
        id_params: &[],
        sweeps: &[],
        guarantee: "¼ (expect.)",
        memory: "O(K)",
        queries: "O(1)",
        build: |s, oracle, k, _| Box::new(RandomReservoir::new(oracle, k, s.uint("seed"))),
    },
    AlgoEntry {
        name: "stream-greedy",
        aliases: &[],
        offline: false,
        params: &[P_NU],
        id_params: &[],
        sweeps: &[],
        guarantee: "½ − ε (multi-pass)",
        memory: "O(K)",
        queries: "O(K)",
        build: |s, oracle, k, _| Box::new(StreamGreedy::new(oracle, k, s.num("nu"))),
    },
    AlgoEntry {
        name: "preemption",
        aliases: &[],
        offline: false,
        params: &[],
        id_params: &[],
        sweeps: &[],
        guarantee: "¼",
        memory: "O(K)",
        queries: "O(K)",
        build: |_, oracle, k, _| Box::new(PreemptionStreaming::new(oracle, k)),
    },
    AlgoEntry {
        name: "isi",
        aliases: &["independent-set-improvement"],
        offline: false,
        params: &[],
        id_params: &[],
        sweeps: &[],
        guarantee: "¼",
        memory: "O(K)",
        queries: "O(1)",
        build: |_, oracle, k, _| Box::new(IndependentSetImprovement::new(oracle, k)),
    },
    AlgoEntry {
        name: "sieve-streaming",
        aliases: &[],
        offline: false,
        params: &[P_EPSILON],
        id_params: &[],
        sweeps: &[Sweep::Epsilon],
        guarantee: "½ − ε",
        memory: "O(K log K / ε)",
        queries: "O(log K / ε)",
        build: |s, oracle, k, _| Box::new(SieveStreaming::new(oracle, k, s.num("epsilon"))),
    },
    AlgoEntry {
        name: "sieve-streaming-pp",
        aliases: &[],
        offline: false,
        params: &[P_EPSILON],
        id_params: &[],
        sweeps: &[Sweep::Epsilon],
        guarantee: "½ − ε",
        memory: "O(K/ε)",
        queries: "O(log K / ε)",
        build: |s, oracle, k, _| Box::new(SieveStreamingPP::new(oracle, k, s.num("epsilon"))),
    },
    AlgoEntry {
        name: "salsa",
        aliases: &[],
        offline: false,
        params: &[P_EPSILON, P_USE_LENGTH_HINT],
        id_params: &[],
        sweeps: &[Sweep::Epsilon],
        guarantee: "½ − ε",
        memory: "O(K log K / ε)",
        queries: "O(log K / ε)",
        build: |s, oracle, k, len| {
            let hint = if s.flag("use_length_hint") { len } else { None };
            Box::new(Salsa::new(oracle, k, s.num("epsilon"), hint))
        },
    },
    AlgoEntry {
        name: "quickstream",
        aliases: &[],
        offline: false,
        params: &[P_C, P_EPSILON, P_SEED],
        id_params: &[("c", "c")],
        sweeps: &[],
        guarantee: "1/(4c) − ε",
        memory: "O(cK log K · log 1/ε)",
        queries: "O(⌈1/c⌉ + c)",
        build: |s, oracle, k, _| {
            Box::new(QuickStream::new(
                oracle,
                k,
                s.uint("c") as usize,
                s.num("epsilon"),
                s.uint("seed"),
            ))
        },
    },
    AlgoEntry {
        name: "three-sieves",
        aliases: &[],
        offline: false,
        params: &[P_EPSILON, P_T],
        id_params: &[("t", "t")],
        sweeps: &[Sweep::Epsilon, Sweep::T],
        guarantee: "(1−ε)(1−1/e) w.p. (1−α)^K",
        memory: "O(K)",
        queries: "O(1)",
        build: |s, oracle, k, _| {
            Box::new(ThreeSieves::new(
                oracle,
                k,
                s.num("epsilon"),
                SieveTuning::FixedT(s.uint("t") as usize),
            ))
        },
    },
    AlgoEntry {
        name: "sharded-three-sieves",
        aliases: &[],
        offline: false,
        params: &[P_EPSILON, P_T, P_SHARDS],
        id_params: &[("t", "t"), ("p", "shards")],
        sweeps: &[Sweep::Epsilon, Sweep::T],
        guarantee: "(1−ε)(1−1/e) w.p. (1−α)^K",
        memory: "O(K) per shard",
        queries: "O(1)",
        build: |s, oracle, k, _| {
            Box::new(crate::coordinator::ShardedThreeSieves::new(
                oracle,
                k,
                s.num("epsilon"),
                SieveTuning::FixedT(s.uint("t") as usize),
                s.uint("shards").max(1) as usize,
            ))
        },
    },
    AlgoEntry {
        name: "stream-clipper",
        aliases: &["streamclipper"],
        offline: false,
        params: &[P_CLIPPER_ALPHA, P_CLIPPER_BETA],
        id_params: &[],
        sweeps: &[],
        guarantee: "½ (buffered)",
        memory: "O(K) (summary + 2K buffer)",
        queries: "O(1)",
        build: |s, oracle, k, _| {
            Box::new(StreamClipper::new(oracle, k, s.num("clipper_alpha"), s.num("clipper_beta")))
        },
    },
    AlgoEntry {
        name: "subsampled-sieve-streaming",
        aliases: &["subsampled"],
        offline: false,
        params: &[P_EPSILON, P_SUBSAMPLE_P, P_SEED],
        id_params: &[],
        sweeps: &[Sweep::Epsilon],
        guarantee: "½ − ε on the sampled stream (expect.)",
        memory: "O(K log K / ε)",
        queries: "O(p · log K / ε)",
        build: |s, oracle, k, _| {
            let inner = Box::new(SieveStreaming::new(oracle, k, s.num("epsilon")));
            Box::new(Subsampled::new(inner, s.num("subsample_p"), s.uint("seed")))
        },
    },
    AlgoEntry {
        name: "subsampled-three-sieves",
        aliases: &[],
        offline: false,
        params: &[P_EPSILON, P_T, P_SUBSAMPLE_P, P_SEED],
        id_params: &[("t", "t")],
        sweeps: &[Sweep::Epsilon, Sweep::T],
        guarantee: "(1−ε)(1−1/e) w.p. (1−α)^K on the sampled stream",
        memory: "O(K)",
        queries: "O(p)",
        build: |s, oracle, k, _| {
            let inner = Box::new(ThreeSieves::new(
                oracle,
                k,
                s.num("epsilon"),
                SieveTuning::FixedT(s.uint("t") as usize),
            ));
            Box::new(Subsampled::new(inner, s.num("subsample_p"), s.uint("seed")))
        },
    },
];

/// Every registered algorithm, in table order.
pub fn entries() -> &'static [AlgoEntry] {
    ENTRIES
}

/// Resolve a name or alias to its entry.
pub fn lookup(name: &str) -> Option<&'static AlgoEntry> {
    ENTRIES.iter().find(|e| e.name == name || e.aliases.contains(&name))
}

/// Canonical names, in table order.
pub fn names() -> Vec<&'static str> {
    ENTRIES.iter().map(|e| e.name).collect()
}

/// Canonical names of streaming (service-admissible) algorithms.
pub fn streaming_names() -> Vec<&'static str> {
    ENTRIES.iter().filter(|e| !e.offline).map(|e| e.name).collect()
}

/// Union of all CLI flag names declared by registered parameters, deduped
/// in table order. The CLI appends these to its base flag spec.
pub fn cli_flags() -> Vec<&'static str> {
    let mut out: Vec<&'static str> = Vec::new();
    for entry in ENTRIES {
        for def in entry.params {
            if let Some(flag) = def.flag {
                if !out.contains(&flag) {
                    out.push(flag);
                }
            }
        }
    }
    out
}

/// Union of all wire-visible parameter keys (wire pins excluded), deduped
/// in table order. The service OPEN grammar accepts exactly these plus
/// `k`, `dim`, `algo` and `drift`.
pub fn wire_param_keys() -> Vec<&'static str> {
    let mut out: Vec<&'static str> = Vec::new();
    for entry in ENTRIES {
        for def in entry.params {
            if def.wire_pin.is_none() && !out.contains(&def.key) {
                out.push(def.key);
            }
        }
    }
    out
}

/// Edit distance (insert/delete/substitute) for did-you-mean suggestions.
pub fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Closest registered name (canonical or alias) within a tolerant edit
/// distance, for "did you mean" errors.
pub fn did_you_mean(name: &str) -> Option<&'static str> {
    ENTRIES
        .iter()
        .flat_map(|e| std::iter::once(e.name).chain(e.aliases.iter().copied()))
        .map(|n| (levenshtein(name, n), n))
        .min()
        .filter(|&(d, _)| d <= 2.max(name.len() / 3))
        .map(|(_, n)| n)
}

fn unknown_algo_error(name: &str) -> String {
    let mut msg = format!("unknown algo {name:?}");
    if let Some(suggestion) = did_you_mean(name) {
        msg.push_str(&format!("; did you mean {suggestion:?}?"));
    }
    msg.push_str(&format!(" (expected one of: {})", names().join(", ")));
    msg
}

/// The README "Algorithms" table, generated from the registry so docs
/// cannot drift from the code (a test pins README.md to this output).
pub fn markdown_table() -> String {
    let mut s = String::from(
        "| Algorithm | Parameters | Guarantee | Memory | Queries/elem |\n\
         |---|---|---|---|---|\n",
    );
    for e in ENTRIES {
        let params = if e.params.is_empty() {
            "—".to_string()
        } else {
            e.params
                .iter()
                .map(|p| format!("`{}`", p.key))
                .collect::<Vec<_>>()
                .join(", ")
        };
        s.push_str(&format!(
            "| `{}` | {} | {} | {} | {} |\n",
            e.name, params, e.guarantee, e.memory, e.queries
        ));
    }
    s
}

/// An algorithm selection with a fully-populated parameter list (every
/// registered parameter present, in definition order — equality and ids
/// are therefore deterministic).
#[derive(Clone, Debug, PartialEq)]
pub struct AlgoSpec {
    name: &'static str,
    params: Vec<(&'static str, ParamValue)>,
}

impl AlgoSpec {
    /// Build a spec for `name` (canonical or alias) with `overrides`
    /// applied over the registered defaults. Rejects unknown names,
    /// unknown keys and kind mismatches.
    pub fn of(name: &str, overrides: &[(&str, ParamValue)]) -> Result<AlgoSpec, String> {
        let entry = lookup(name).ok_or_else(|| unknown_algo_error(name))?;
        let mut params: Vec<(&'static str, ParamValue)> =
            entry.params.iter().map(|p| (p.key, p.default.clone())).collect();
        for (key, value) in overrides {
            let def = entry
                .params
                .iter()
                .find(|p| p.key == *key)
                .ok_or_else(|| format!("algo {:?} has no parameter {key:?}", entry.name))?;
            if value.kind() != def.kind {
                return Err(format!(
                    "parameter {key:?} of algo {:?} expects a {}",
                    entry.name,
                    def.kind.label()
                ));
            }
            let slot = params.iter_mut().find(|(k, _)| k == key).unwrap();
            slot.1 = value.clone();
        }
        // A zero shard count is a degenerate request, not a deployment:
        // floor it here so ids and builds agree (matches the pre-registry
        // parsers, which floored at parse time).
        if let Some(slot) =
            params.iter_mut().find(|(k, v)| *k == "shards" && *v == ParamValue::UInt(0))
        {
            slot.1 = ParamValue::UInt(1);
        }
        Ok(AlgoSpec { name: entry.name, params })
    }

    /// This spec with `overrides` applied on top (panics on unknown keys —
    /// callers pass registry-declared keys, e.g. sweep expansion).
    pub fn with(&self, overrides: &[(&str, ParamValue)]) -> AlgoSpec {
        let mut spec = self.clone();
        for (key, value) in overrides {
            let slot = spec
                .params
                .iter_mut()
                .find(|(k, _)| k == key)
                .unwrap_or_else(|| panic!("algo {:?} has no parameter {key:?}", spec.name));
            assert_eq!(slot.1.kind(), value.kind(), "kind mismatch for {key:?}");
            slot.1 = value.clone();
        }
        spec
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn entry(&self) -> &'static AlgoEntry {
        lookup(self.name).expect("specs are registry-built")
    }

    pub fn get(&self, key: &str) -> Option<&ParamValue> {
        self.params.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
    }

    /// Iterate `(key, value)` pairs in definition order.
    pub fn params(&self) -> impl Iterator<Item = (&'static str, &ParamValue)> {
        self.params.iter().map(|(k, v)| (*k, v))
    }

    /// F64 parameter (panics if absent — specs are registry-built).
    pub fn num(&self, key: &str) -> f64 {
        match self.get(key) {
            Some(ParamValue::F64(v)) => *v,
            other => panic!("{:?}: no f64 parameter {key:?} ({other:?})", self.name),
        }
    }

    /// UInt parameter (panics if absent — specs are registry-built).
    pub fn uint(&self, key: &str) -> u64 {
        match self.get(key) {
            Some(ParamValue::UInt(v)) => *v,
            other => panic!("{:?}: no uint parameter {key:?} ({other:?})", self.name),
        }
    }

    /// Bool parameter (panics if absent — specs are registry-built).
    pub fn flag(&self, key: &str) -> bool {
        match self.get(key) {
            Some(ParamValue::Bool(v)) => *v,
            other => panic!("{:?}: no bool parameter {key:?} ({other:?})", self.name),
        }
    }

    /// Stable identifier used in CSVs and config files.
    pub fn id(&self) -> String {
        let mut id = self.name.to_string();
        for (label, key) in self.entry().id_params {
            match self.get(key) {
                Some(ParamValue::UInt(v)) => id.push_str(&format!("-{label}{v}")),
                Some(ParamValue::F64(v)) => id.push_str(&format!("-{label}{v}")),
                Some(ParamValue::Bool(v)) => id.push_str(&format!("-{label}{v}")),
                None => unreachable!("id_params reference registered keys"),
            }
        }
        id
    }

    /// Parse a spec from a JSON object (`{"algo": "...", "<param>": ...}`).
    ///
    /// Strict on types: a parameter that is present but of the wrong kind
    /// is rejected with an error naming the field — absent parameters take
    /// their registered defaults; unrecognized keys are ignored so configs
    /// may carry annotations.
    pub fn from_json(j: &Json) -> Result<AlgoSpec, String> {
        let kind = j.get("algo").as_str().ok_or("missing algo")?;
        let entry = lookup(kind).ok_or_else(|| unknown_algo_error(kind))?;
        let mut overrides: Vec<(&str, ParamValue)> = Vec::new();
        for def in entry.params {
            let v = j.get(def.key);
            if matches!(v, Json::Null) {
                continue;
            }
            overrides.push((def.key, parse_json_param(entry.name, def, v)?));
        }
        AlgoSpec::of(entry.name, &overrides)
    }

    /// Parse a spec from CLI flags: `get(flag)` returns the raw value for
    /// a flag name, or `None` to take the registered default.
    pub fn from_flags(
        name: &str,
        get: &dyn Fn(&str) -> Option<String>,
    ) -> Result<AlgoSpec, String> {
        let entry = lookup(name).ok_or_else(|| unknown_algo_error(name))?;
        let mut overrides: Vec<(&str, ParamValue)> = Vec::new();
        for def in entry.params {
            let Some(flag) = def.flag else { continue };
            let Some(raw) = get(flag) else { continue };
            let value = match def.kind {
                ParamKind::F64 => raw.parse::<f64>().map(ParamValue::F64).map_err(|e| {
                    format!("--{flag} {raw:?}: {e}")
                })?,
                ParamKind::UInt => raw.parse::<u64>().map(ParamValue::UInt).map_err(|e| {
                    format!("--{flag} {raw:?}: {e}")
                })?,
                ParamKind::Bool => raw.parse::<bool>().map(ParamValue::Bool).map_err(|e| {
                    format!("--{flag} {raw:?}: {e}")
                })?,
            };
            overrides.push((def.key, value));
        }
        AlgoSpec::of(entry.name, &overrides)
    }

    /// Parse a spec from service-OPEN key/value tokens: `get(key)` returns
    /// the raw token for a wire key. Wire-pinned parameters take their pin
    /// instead of a token.
    pub fn from_wire(
        name: &str,
        get: &dyn Fn(&str) -> Option<String>,
    ) -> Result<AlgoSpec, String> {
        let entry = lookup(name).ok_or_else(|| unknown_algo_error(name))?;
        let mut overrides: Vec<(&str, ParamValue)> = Vec::new();
        for def in entry.params {
            if let Some(pin) = &def.wire_pin {
                overrides.push((def.key, pin.clone()));
                continue;
            }
            let Some(raw) = get(def.key) else { continue };
            let value = match def.kind {
                ParamKind::F64 => raw.parse::<f64>().map(ParamValue::F64).map_err(|_| {
                    format!("{}: expected a {}, got {raw:?}", def.key, def.kind.label())
                })?,
                ParamKind::UInt => raw.parse::<u64>().map(ParamValue::UInt).map_err(|_| {
                    format!("{}: expected a {}, got {raw:?}", def.key, def.kind.label())
                })?,
                ParamKind::Bool => raw.parse::<bool>().map(ParamValue::Bool).map_err(|_| {
                    format!("{}: expected a {}, got {raw:?}", def.key, def.kind.label())
                })?,
            };
            overrides.push((def.key, value));
        }
        AlgoSpec::of(entry.name, &overrides)
    }

    /// Serialize the wire-visible parameters as OPEN `key=value` tokens in
    /// definition order (wire pins omitted; [`from_wire`] re-pins them).
    ///
    /// [`from_wire`]: AlgoSpec::from_wire
    pub fn wire_tokens(&self) -> Vec<String> {
        let mut out = Vec::new();
        for def in self.entry().params {
            if def.wire_pin.is_some() {
                continue;
            }
            match self.get(def.key) {
                Some(ParamValue::F64(v)) => out.push(format!("{}={v}", def.key)),
                Some(ParamValue::UInt(v)) => out.push(format!("{}={v}", def.key)),
                Some(ParamValue::Bool(v)) => out.push(format!("{}={v}", def.key)),
                None => unreachable!("specs are fully populated"),
            }
        }
        out
    }

    /// Instantiate the algorithm with a fresh oracle.
    pub fn build(
        &self,
        oracle: Box<dyn SubmodularFunction>,
        k: usize,
        stream_len: Option<usize>,
    ) -> Box<dyn StreamingAlgorithm> {
        (self.entry().build)(self, oracle, k, stream_len)
    }

    // Convenience constructors — registry-backed replacements for the old
    // enum variants (parameter order matches the old struct fields).

    pub fn greedy() -> AlgoSpec {
        AlgoSpec::of("greedy", &[]).unwrap()
    }

    pub fn random(seed: u64) -> AlgoSpec {
        AlgoSpec::of("random", &[("seed", ParamValue::UInt(seed))]).unwrap()
    }

    pub fn stream_greedy(nu: f64) -> AlgoSpec {
        AlgoSpec::of("stream-greedy", &[("nu", ParamValue::F64(nu))]).unwrap()
    }

    pub fn preemption() -> AlgoSpec {
        AlgoSpec::of("preemption", &[]).unwrap()
    }

    pub fn isi() -> AlgoSpec {
        AlgoSpec::of("isi", &[]).unwrap()
    }

    pub fn sieve_streaming(epsilon: f64) -> AlgoSpec {
        AlgoSpec::of("sieve-streaming", &[("epsilon", ParamValue::F64(epsilon))]).unwrap()
    }

    pub fn sieve_streaming_pp(epsilon: f64) -> AlgoSpec {
        AlgoSpec::of("sieve-streaming-pp", &[("epsilon", ParamValue::F64(epsilon))]).unwrap()
    }

    pub fn salsa(epsilon: f64, use_length_hint: bool) -> AlgoSpec {
        AlgoSpec::of(
            "salsa",
            &[
                ("epsilon", ParamValue::F64(epsilon)),
                ("use_length_hint", ParamValue::Bool(use_length_hint)),
            ],
        )
        .unwrap()
    }

    pub fn quickstream(c: u64, epsilon: f64, seed: u64) -> AlgoSpec {
        AlgoSpec::of(
            "quickstream",
            &[
                ("c", ParamValue::UInt(c)),
                ("epsilon", ParamValue::F64(epsilon)),
                ("seed", ParamValue::UInt(seed)),
            ],
        )
        .unwrap()
    }

    pub fn three_sieves(epsilon: f64, t: u64) -> AlgoSpec {
        AlgoSpec::of(
            "three-sieves",
            &[("epsilon", ParamValue::F64(epsilon)), ("t", ParamValue::UInt(t))],
        )
        .unwrap()
    }

    pub fn sharded_three_sieves(epsilon: f64, t: u64, shards: u64) -> AlgoSpec {
        AlgoSpec::of(
            "sharded-three-sieves",
            &[
                ("epsilon", ParamValue::F64(epsilon)),
                ("t", ParamValue::UInt(t)),
                ("shards", ParamValue::UInt(shards)),
            ],
        )
        .unwrap()
    }

    pub fn stream_clipper(alpha: f64, beta: f64) -> AlgoSpec {
        AlgoSpec::of(
            "stream-clipper",
            &[
                ("clipper_alpha", ParamValue::F64(alpha)),
                ("clipper_beta", ParamValue::F64(beta)),
            ],
        )
        .unwrap()
    }

    pub fn subsampled_sieve_streaming(epsilon: f64, p: f64, seed: u64) -> AlgoSpec {
        AlgoSpec::of(
            "subsampled-sieve-streaming",
            &[
                ("epsilon", ParamValue::F64(epsilon)),
                ("subsample_p", ParamValue::F64(p)),
                ("seed", ParamValue::UInt(seed)),
            ],
        )
        .unwrap()
    }

    pub fn subsampled_three_sieves(epsilon: f64, t: u64, p: f64, seed: u64) -> AlgoSpec {
        AlgoSpec::of(
            "subsampled-three-sieves",
            &[
                ("epsilon", ParamValue::F64(epsilon)),
                ("t", ParamValue::UInt(t)),
                ("subsample_p", ParamValue::F64(p)),
                ("seed", ParamValue::UInt(seed)),
            ],
        )
        .unwrap()
    }
}

fn parse_json_param(algo: &str, def: &ParamDef, v: &Json) -> Result<ParamValue, String> {
    let fail = || {
        format!(
            "parameter {:?} of algo {algo:?} expects a {}, got {v:?}",
            def.key,
            def.kind.label()
        )
    };
    match def.kind {
        ParamKind::F64 => v.as_f64().map(ParamValue::F64).ok_or_else(fail),
        ParamKind::UInt => v
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| ParamValue::UInt(n as u64))
            .ok_or_else(fail),
        ParamKind::Bool => v.as_bool().map(ParamValue::Bool).ok_or_else(fail),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_resolves_names_and_aliases() {
        assert_eq!(lookup("three-sieves").unwrap().name, "three-sieves");
        assert_eq!(lookup("streamclipper").unwrap().name, "stream-clipper");
        assert_eq!(lookup("subsampled").unwrap().name, "subsampled-sieve-streaming");
        assert_eq!(lookup("independent-set-improvement").unwrap().name, "isi");
        assert!(lookup("magic").is_none());
    }

    #[test]
    fn ids_are_stable() {
        assert_eq!(AlgoSpec::greedy().id(), "greedy");
        assert_eq!(AlgoSpec::random(7).id(), "random");
        assert_eq!(AlgoSpec::stream_greedy(1e-4).id(), "stream-greedy");
        assert_eq!(AlgoSpec::preemption().id(), "preemption");
        assert_eq!(AlgoSpec::isi().id(), "isi");
        assert_eq!(AlgoSpec::sieve_streaming(0.1).id(), "sieve-streaming");
        assert_eq!(AlgoSpec::sieve_streaming_pp(0.1).id(), "sieve-streaming-pp");
        assert_eq!(AlgoSpec::salsa(0.1, true).id(), "salsa");
        assert_eq!(AlgoSpec::quickstream(4, 0.1, 1).id(), "quickstream-c4");
        assert_eq!(AlgoSpec::three_sieves(0.01, 2500).id(), "three-sieves-t2500");
        assert_eq!(
            AlgoSpec::sharded_three_sieves(0.01, 60, 3).id(),
            "sharded-three-sieves-t60-p3"
        );
        assert_eq!(AlgoSpec::stream_clipper(1.0, 0.5).id(), "stream-clipper");
        assert_eq!(
            AlgoSpec::subsampled_sieve_streaming(0.1, 0.5, 1).id(),
            "subsampled-sieve-streaming"
        );
        assert_eq!(
            AlgoSpec::subsampled_three_sieves(0.1, 500, 0.5, 1).id(),
            "subsampled-three-sieves-t500"
        );
    }

    #[test]
    fn from_json_defaults_and_overrides() {
        let j = Json::parse(r#"{"algo": "three-sieves", "t": 500}"#).unwrap();
        let spec = AlgoSpec::from_json(&j).unwrap();
        assert_eq!(spec, AlgoSpec::three_sieves(0.001, 500));

        let j = Json::parse(r#"{"algo": "quickstream", "c": 4}"#).unwrap();
        assert_eq!(AlgoSpec::from_json(&j).unwrap().id(), "quickstream-c4");
    }

    #[test]
    fn from_json_rejects_mistyped_params() {
        // The pre-registry parser silently unwrap_or-defaulted these.
        let j = Json::parse(r#"{"algo": "stream-greedy", "nu": "abc"}"#).unwrap();
        let err = AlgoSpec::from_json(&j).unwrap_err();
        assert!(err.contains("nu"), "error must name the field: {err}");

        let j = Json::parse(r#"{"algo": "three-sieves", "t": 12.5}"#).unwrap();
        let err = AlgoSpec::from_json(&j).unwrap_err();
        assert!(err.contains('t'), "error must name the field: {err}");

        let j = Json::parse(r#"{"algo": "salsa", "use_length_hint": 3}"#).unwrap();
        let err = AlgoSpec::from_json(&j).unwrap_err();
        assert!(err.contains("use_length_hint"), "error must name the field: {err}");
    }

    #[test]
    fn unknown_algo_errors_suggest_and_enumerate() {
        let err = AlgoSpec::of("tree-sieves", &[]).unwrap_err();
        assert!(err.contains("unknown algo"), "{err}");
        assert!(err.contains("did you mean \"three-sieves\""), "{err}");
        assert!(err.contains("stream-clipper"), "error lists registry names: {err}");
        // Nothing close: no suggestion, still enumerates.
        let err = AlgoSpec::of("magic", &[]).unwrap_err();
        assert!(err.contains("unknown algo"), "{err}");
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn of_rejects_unknown_keys_and_kind_mismatches() {
        let err = AlgoSpec::of("three-sieves", &[("shards", ParamValue::UInt(2))]).unwrap_err();
        assert!(err.contains("shards"), "{err}");
        let err = AlgoSpec::of("three-sieves", &[("t", ParamValue::F64(2.0))]).unwrap_err();
        assert!(err.contains('t'), "{err}");
    }

    #[test]
    fn shards_floor_at_one() {
        let spec = AlgoSpec::sharded_three_sieves(0.1, 10, 0);
        assert_eq!(spec.uint("shards"), 1);
    }

    #[test]
    fn wire_tokens_roundtrip_via_from_wire() {
        let specs = [
            AlgoSpec::three_sieves(0.02, 60),
            AlgoSpec::salsa(0.1, false),
            AlgoSpec::quickstream(2, 0.1, 7),
            AlgoSpec::stream_clipper(1.0, 0.25),
            AlgoSpec::subsampled_three_sieves(0.05, 40, 0.5, 9),
        ];
        for spec in &specs {
            let tokens = spec.wire_tokens();
            let get = |key: &str| -> Option<String> {
                tokens.iter().find_map(|t| {
                    t.strip_prefix(&format!("{key}=")).map(str::to_string)
                })
            };
            let back = AlgoSpec::from_wire(spec.name(), &get).unwrap();
            assert_eq!(&back, spec, "wire roundtrip for {}", spec.name());
        }
    }

    #[test]
    fn wire_pins_override_json_defaults() {
        // Over the wire, Salsa's length hint is pinned off even though the
        // JSON default is on.
        let spec = AlgoSpec::from_wire("salsa", &|_| None).unwrap();
        assert!(!spec.flag("use_length_hint"));
        let j = Json::parse(r#"{"algo": "salsa"}"#).unwrap();
        assert!(AlgoSpec::from_json(&j).unwrap().flag("use_length_hint"));
    }

    #[test]
    fn cli_and_wire_key_sets_cover_every_param() {
        let flags = cli_flags();
        for want in ["epsilon", "t", "shards", "nu", "c", "seed", "clipper-alpha", "subsample-p"]
        {
            assert!(flags.contains(&want), "missing CLI flag {want}");
        }
        let keys = wire_param_keys();
        assert!(keys.contains(&"clipper_beta"));
        assert!(!keys.contains(&"use_length_hint"), "wire-pinned keys stay off the wire");
    }

    #[test]
    fn markdown_table_lists_every_entry() {
        let table = markdown_table();
        for name in names() {
            assert!(table.contains(&format!("| `{name}` |")), "table missing {name}");
        }
    }

    #[test]
    fn did_you_mean_tolerates_typos() {
        assert_eq!(did_you_mean("salsa"), Some("salsa"));
        assert_eq!(did_you_mean("sallsa"), Some("salsa"));
        assert_eq!(did_you_mean("three-seives"), Some("three-sieves"));
        assert_eq!(did_you_mean("stream-cliper"), Some("stream-clipper"));
        assert_eq!(did_you_mean("zzzzzzzzzz"), None);
    }
}
