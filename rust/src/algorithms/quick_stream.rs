//! **QuickStream** (Kuhnle 2021), paper Alg. 10: buffer `c` elements and
//! evaluate `f` only once per buffer — built for settings where a single
//! oracle call is very expensive. Accepted buffers are appended wholesale;
//! when the working set exceeds `2·c·l·(K+1)·log₂K` elements the oldest
//! half is dropped; at stream end the last `c·K` elements are randomly
//! partitioned into ≤c candidate summaries of ≤K and the best one wins.
//! Guarantee `1/(4c) − ε`.

use crate::functions::SubmodularFunction;
use crate::metrics::AlgoStats;
use crate::util::rng::Rng;

use super::StreamingAlgorithm;

/// Buffered whole-chunk streaming.
pub struct QuickStream {
    proto: Box<dyn SubmodularFunction>,
    /// Working-set oracle over A (value queried once per buffer flush).
    work: Box<dyn SubmodularFunction>,
    /// Final chosen summary oracle (built in finalize()).
    chosen: Option<Box<dyn SubmodularFunction>>,
    k: usize,
    c: usize,
    /// l = ⌈log₂(1/(4ε))⌉ + 3 (paper line 1).
    l: usize,
    buffer: Vec<f32>,
    buffered: usize,
    rng: Rng,
    elements: u64,
    peak_stored: usize,
}

impl QuickStream {
    pub fn new(
        proto: Box<dyn SubmodularFunction>,
        k: usize,
        c: usize,
        epsilon: f64,
        seed: u64,
    ) -> Self {
        assert!(k >= 2, "QuickStream requires K >= 2");
        assert!(c >= 1);
        assert!(epsilon > 0.0);
        let l = ((1.0 / (4.0 * epsilon)).log2().ceil() as usize).max(1) + 3;
        let work = proto.clone_empty();
        QuickStream {
            proto,
            work,
            chosen: None,
            k,
            c,
            l,
            buffer: Vec::new(),
            buffered: 0,
            rng: Rng::seed_from(seed),
            elements: 0,
            peak_stored: 0,
        }
    }

    fn cap(&self) -> usize {
        self.c * self.l * (self.k + 1) * (usize::BITS as usize - self.k.leading_zeros() as usize)
    }

    fn flush_buffer(&mut self) {
        if self.buffered == 0 {
            return;
        }
        let d = self.proto.dim();
        // Evaluate f(A ∪ C) − f(A) with |C| oracle updates, then keep or
        // roll back. One "logical" query per buffer, as the paper counts.
        let before = self.work.current_value();
        let n_before = self.work.len();
        for i in 0..self.buffered {
            self.work.accept(&self.buffer[i * d..(i + 1) * d]);
        }
        let gain = self.work.current_value() - before;
        if gain < before / self.k as f64 {
            // Reject: roll back the appended chunk.
            for _ in 0..self.buffered {
                let idx = self.work.len() - 1;
                self.work.remove(idx);
            }
            debug_assert_eq!(self.work.len(), n_before);
        } else {
            // Keep; enforce the working-set cap by dropping the oldest.
            let cap = self.cap();
            while self.work.len() > cap {
                self.work.remove(0);
            }
        }
        self.buffer.clear();
        self.buffered = 0;
        if self.work.len() > self.peak_stored {
            self.peak_stored = self.work.len();
        }
    }
}

impl StreamingAlgorithm for QuickStream {
    fn name(&self) -> String {
        format!("QuickStream(c={})", self.c)
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        self.buffer.extend_from_slice(item);
        self.buffered += 1;
        if self.buffered == self.c {
            self.flush_buffer();
        }
    }

    fn finalize(&mut self) {
        self.flush_buffer();
        // Keep the cK most recent, randomly partition into ≤c summaries of
        // ≤K, return the best.
        let d = self.proto.dim();
        let n = self.work.len();
        let keep = (self.c * self.k).min(n);
        let feats: Vec<f32> = self.work.summary()[(n - keep) * d..].to_vec();
        let mut order: Vec<usize> = (0..keep).collect();
        self.rng.shuffle(&mut order);

        let mut best: Option<Box<dyn SubmodularFunction>> = None;
        for part in order.chunks(self.k.max(1)) {
            let mut cand = self.proto.clone_empty();
            for &i in part {
                cand.accept(&feats[i * d..(i + 1) * d]);
            }
            let better = match &best {
                None => true,
                Some(b) => cand.current_value() > b.current_value(),
            };
            if better {
                best = Some(cand);
            }
        }
        self.chosen = best;
    }

    fn value(&self) -> f64 {
        match &self.chosen {
            Some(c) => c.current_value(),
            None => self.work.current_value(),
        }
    }

    fn summary(&self) -> Vec<f32> {
        match &self.chosen {
            Some(c) => c.summary().to_vec(),
            None => self.work.summary().to_vec(),
        }
    }

    fn summary_len(&self) -> usize {
        match &self.chosen {
            Some(c) => c.len(),
            None => self.work.len(),
        }
    }

    fn dim(&self) -> usize {
        self.proto.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        let stored = self.work.len() + self.buffered;
        AlgoStats {
            queries: self.work.queries()
                + self.chosen.as_ref().map(|c| c.queries()).unwrap_or(0),
            kernel_evals: self.work.kernel_evals()
                + self.chosen.as_ref().map(|c| c.kernel_evals()).unwrap_or(0),
            elements: self.elements,
            stored,
            peak_stored: self.peak_stored.max(stored),
            instances: 1,
            wall_kernel_ns: self.work.wall_kernel_ns()
                + self.chosen.as_ref().map(|c| c.wall_kernel_ns()).unwrap_or(0),
            wall_solve_ns: self.work.wall_solve_ns()
                + self.chosen.as_ref().map(|c| c.wall_solve_ns()).unwrap_or(0),
            wall_scan_ns: 0,
            ..Default::default()
        }
    }

    fn reset(&mut self) {
        self.work = self.proto.clone_empty();
        self.chosen = None;
        self.buffer.clear();
        self.buffered = 0;
        self.elements = 0;
        self.peak_stored = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn final_summary_at_most_k() {
        let ds = testkit::clustered(600, 1);
        let k = 8;
        for c in [1usize, 4] {
            let mut algo = QuickStream::new(testkit::oracle(k), k, c, 0.05, 7);
            testkit::run(&mut algo, &ds);
            assert!(algo.summary_len() <= k, "c={c}: {} > {k}", algo.summary_len());
            assert!(algo.value() > 0.0);
        }
    }

    #[test]
    fn buffers_reduce_flushes() {
        let ds = testkit::clustered(400, 2);
        let k = 5;
        let mut c1 = QuickStream::new(testkit::oracle(k), k, 1, 0.05, 1);
        let mut c8 = QuickStream::new(testkit::oracle(k), k, 8, 0.05, 1);
        testkit::run(&mut c1, &ds);
        testkit::run(&mut c8, &ds);
        // Larger buffers => fewer oracle interactions overall.
        assert!(c8.stats().queries < c1.stats().queries);
    }

    #[test]
    fn working_set_capped() {
        let ds = testkit::clustered(2000, 3);
        let k = 4;
        let c = 2;
        let mut algo = QuickStream::new(testkit::oracle(k), k, c, 0.1, 3);
        let cap = algo.cap();
        testkit::run(&mut algo, &ds);
        assert!(algo.stats().peak_stored <= cap + c, "peak {} cap {cap}", algo.stats().peak_stored);
    }

    #[test]
    fn memory_exceeds_plain_k_algorithms() {
        // The paper notes QuickStream trades memory for fewer evaluations.
        let ds = testkit::clustered(1500, 4);
        let k = 5;
        let mut algo = QuickStream::new(testkit::oracle(k), k, 2, 0.05, 9);
        testkit::run(&mut algo, &ds);
        assert!(algo.stats().peak_stored > k);
    }

    #[test]
    fn reset_then_rerun() {
        let ds = testkit::clustered(300, 5);
        let k = 4;
        let mut algo = QuickStream::new(testkit::oracle(k), k, 2, 0.1, 11);
        testkit::run(&mut algo, &ds);
        algo.reset();
        assert_eq!(algo.summary_len(), 0);
        testkit::run(&mut algo, &ds);
        assert!(algo.summary_len() > 0);
    }
}
