//! **SieveStreaming** (Badanidiyuru et al. 2014), paper Alg. 7: maintain one
//! sieve per OPT guess from the geometric grid `O = {(1+ε)^i} ∩ [m, K·m]`;
//! each sieve applies the threshold rule. The best sieve is the output.
//! ½−ε approximation, O(K log K / ε) memory, O(log K / ε) queries/element.

use crate::exec::ExecContext;
use crate::functions::SubmodularFunction;
use crate::metrics::AlgoStats;
use crate::util::mathx::threshold_grid;

use super::{sieve_stats, Sieve, StreamingAlgorithm};

/// Multi-sieve thresholding with a known (or estimated) `m`.
pub struct SieveStreaming {
    proto: Box<dyn SubmodularFunction>,
    k: usize,
    epsilon: f64,
    sieves: Vec<Sieve>,
    /// Estimate-m-on-the-fly mode (Badanidiyuru et al. §"unknown m").
    estimate_m: bool,
    m: f64,
    elements: u64,
    extra_queries: u64,
    /// Speculative batch gains past a sieve's acceptance (see
    /// `Sieve::offer_batch`); excluded from reported query stats.
    speculative_queries: u64,
    peak_stored: usize,
    /// Parallel execution context: sieves fan out across its pool when
    /// one is attached (see [`StreamingAlgorithm::set_exec`]).
    exec: ExecContext,
}

impl SieveStreaming {
    /// With `m = max_e f({e})` known exactly (our log-det case).
    pub fn new(proto: Box<dyn SubmodularFunction>, k: usize, epsilon: f64) -> Self {
        assert!(k > 0 && epsilon > 0.0);
        let m = proto.max_singleton_value();
        let sieves = threshold_grid(epsilon, m, k as f64 * m)
            .into_iter()
            .map(|v| Sieve::new(v, proto.as_ref()))
            .collect();
        SieveStreaming {
            proto,
            k,
            epsilon,
            sieves,
            estimate_m: false,
            m,
            elements: 0,
            extra_queries: 0,
            speculative_queries: 0,
            peak_stored: 0,
            exec: ExecContext::sequential(),
        }
    }

    /// Estimating `m` on the fly: sieves are (re)built lazily as the
    /// maximum observed singleton value grows; sieves whose threshold falls
    /// outside `[m_new, K·m_new]` are dropped.
    pub fn with_m_estimation(proto: Box<dyn SubmodularFunction>, k: usize, epsilon: f64) -> Self {
        let mut s = Self::new(proto, k, epsilon);
        s.estimate_m = true;
        s.m = 0.0;
        s.sieves.clear();
        s
    }

    fn refresh_sieves_for_m(&mut self, m_new: f64) {
        self.m = m_new;
        let lo = m_new;
        let hi = self.k as f64 * m_new;
        // Drop sieves below the new lower bound.
        self.sieves.retain(|s| s.v >= lo && s.v <= hi * (1.0 + 1e-12));
        // Add missing grid points.
        for v in threshold_grid(self.epsilon, lo, hi) {
            let exists = self.sieves.iter().any(|s| (s.v / v - 1.0).abs() < 1e-9);
            if !exists {
                self.sieves.push(Sieve::new(v, self.proto.as_ref()));
            }
        }
        self.sieves.sort_by(|a, b| a.v.partial_cmp(&b.v).unwrap());
    }

    fn best_sieve(&self) -> Option<&Sieve> {
        self.sieves
            .iter()
            .max_by(|a, b| a.oracle.current_value().partial_cmp(&b.oracle.current_value()).unwrap())
    }

    /// Number of live sieves (tests / telemetry).
    pub fn sieve_count(&self) -> usize {
        self.sieves.len()
    }
}

impl StreamingAlgorithm for SieveStreaming {
    fn name(&self) -> String {
        "SieveStreaming".into()
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        if self.estimate_m {
            self.extra_queries += 1;
            let mut probe = self.proto.clone_empty();
            let singleton = probe.peek_gain(item);
            if singleton > self.m {
                self.refresh_sieves_for_m(singleton);
            }
        }
        for s in self.sieves.iter_mut() {
            s.offer(item, self.k);
        }
        let stored: usize = self.sieves.iter().map(|s| s.oracle.len()).sum();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }

    /// Batched ingestion: the sieves are fully independent (no cross-sieve
    /// coupling outside m estimation), so each sieve consumes the whole
    /// chunk through [`Sieve::offer_batch`] — one gain panel per rejection
    /// run instead of one oracle call per item — either sequentially or on
    /// the exec pool's worker threads when a context is attached. Each
    /// sieve runs the identical instruction sequence on state it owns and
    /// the speculative counts fold in sieve order, so results are
    /// bit-identical at every thread count. Stored elements only grow
    /// within a chunk, so the end-of-chunk peak equals the scalar per-item
    /// peak.
    fn process_batch(&mut self, chunk: &[f32]) {
        let d = self.proto.dim();
        debug_assert_eq!(chunk.len() % d, 0, "chunk not row-aligned");
        if self.estimate_m {
            // m estimation rebuilds the sieve set mid-stream; replay.
            for row in chunk.chunks_exact(d) {
                self.process(row);
            }
            return;
        }
        self.elements += (chunk.len() / d) as u64;
        let k = self.k;
        // Inline when sequential, worker threads when a pool is attached
        // (`set_exec` gated it on `parallel_safe()`); identical results
        // either way, speculative counts folded in sieve order.
        let wasted = self.exec.map_units(&mut self.sieves, |s| s.offer_batch(chunk, d, k));
        self.speculative_queries += wasted.iter().sum::<u64>();
        let stored: usize = self.sieves.iter().map(|s| s.oracle.len()).sum();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }

    fn set_exec(&mut self, exec: ExecContext) {
        self.exec = exec.gated(self.proto.as_ref());
    }

    fn value(&self) -> f64 {
        self.best_sieve().map(|s| s.oracle.current_value()).unwrap_or(0.0)
    }

    fn summary(&self) -> Vec<f32> {
        self.best_sieve().map(|s| s.oracle.summary().to_vec()).unwrap_or_default()
    }

    fn summary_len(&self) -> usize {
        self.best_sieve().map(|s| s.oracle.len()).unwrap_or(0)
    }

    fn dim(&self) -> usize {
        self.proto.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        let mut peak = self.peak_stored;
        let mut st = sieve_stats(&self.sieves, self.elements, self.extra_queries, &mut peak);
        st.queries = st.queries.saturating_sub(self.speculative_queries);
        st
    }

    fn reset(&mut self) {
        self.elements = 0;
        self.extra_queries = 0;
        // The sieve oracles (and their query counters) are rebuilt below,
        // so their speculative share resets with them.
        self.speculative_queries = 0;
        self.peak_stored = 0;
        if self.estimate_m {
            self.m = 0.0;
            self.sieves.clear();
        } else {
            let m = self.proto.max_singleton_value();
            self.sieves = threshold_grid(self.epsilon, m, self.k as f64 * m)
                .into_iter()
                .map(|v| Sieve::new(v, self.proto.as_ref()))
                .collect();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn sieve_count_scales_with_eps() {
        let coarse = SieveStreaming::new(testkit::oracle(20), 20, 0.5);
        let fine = SieveStreaming::new(testkit::oracle(20), 20, 0.01);
        assert!(fine.sieve_count() > 5 * coarse.sieve_count());
    }

    #[test]
    fn close_to_greedy_on_clustered_data() {
        let ds = testkit::clustered(3000, 1);
        let k = 10;
        let greedy = testkit::greedy_value(&ds, k);
        let mut algo = SieveStreaming::new(testkit::oracle(k), k, 0.01);
        testkit::run(&mut algo, &ds);
        let rel = algo.value() / greedy;
        assert!(rel > 0.7, "relative performance {rel:.3}");
    }

    #[test]
    fn queries_dominate_threesieves() {
        // The Table 1 claim, measured head-to-head: SieveStreaming pays
        // O(log K / ε) queries per element against ThreeSieves' O(1) —
        // with K large enough that sieves don't all fill instantly.
        use crate::algorithms::three_sieves::SieveTuning;
        let ds = testkit::clustered(400, 2);
        let k = 50;
        let mut ss = SieveStreaming::new(testkit::oracle(k), k, 0.05);
        let mut ts = super::super::ThreeSieves::new(
            testkit::oracle(k),
            k,
            0.05,
            SieveTuning::FixedT(100),
        );
        let sieves = ss.sieve_count() as f64;
        testkit::run(&mut ss, &ds);
        testkit::run(&mut ts, &ds);
        let ss_q = ss.stats().queries as f64;
        let ts_q = ts.stats().queries as f64;
        assert!(
            ss_q > 5.0 * ts_q,
            "SieveStreaming ({ss_q}) should pay ≫ ThreeSieves ({ts_q}) with {sieves} sieves"
        );
        assert!(ss.stats().queries_per_element() <= sieves + 1.0);
    }

    #[test]
    fn memory_exceeds_k_but_each_sieve_bounded() {
        let ds = testkit::clustered(2000, 3);
        let k = 8;
        let mut algo = SieveStreaming::new(testkit::oracle(k), k, 0.05);
        testkit::run(&mut algo, &ds);
        let st = algo.stats();
        assert!(st.peak_stored > k, "multi-sieve memory should exceed K");
        assert!(st.peak_stored <= algo.sieve_count() * k);
    }

    #[test]
    fn m_estimation_matches_known_m_for_logdet() {
        // Constant singleton values => identical behaviour after element 1.
        let ds = testkit::clustered(1500, 4);
        let k = 6;
        let mut known = SieveStreaming::new(testkit::oracle(k), k, 0.05);
        let mut est = SieveStreaming::with_m_estimation(testkit::oracle(k), k, 0.05);
        testkit::run(&mut known, &ds);
        testkit::run(&mut est, &ds);
        assert!((known.value() - est.value()).abs() < 1e-9);
    }

    #[test]
    fn reset_restores_sieves() {
        let ds = testkit::clustered(500, 5);
        let k = 5;
        let mut algo = SieveStreaming::new(testkit::oracle(k), k, 0.1);
        let n0 = algo.sieve_count();
        testkit::run(&mut algo, &ds);
        algo.reset();
        assert_eq!(algo.sieve_count(), n0);
        assert_eq!(algo.value(), 0.0);
    }
}
