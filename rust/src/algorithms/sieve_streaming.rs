//! **SieveStreaming** (Badanidiyuru et al. 2014), paper Alg. 7: maintain one
//! sieve per OPT guess from the geometric grid `O = {(1+ε)^i} ∩ [m, K·m]`;
//! each sieve applies the threshold rule. The best sieve is the output.
//! ½−ε approximation, O(K log K / ε) memory, O(log K / ε) queries/element.

use crate::exec::ExecContext;
use crate::functions::{ChunkPanel, PanelScratch, SharedRowStore, SubmodularFunction};
use crate::metrics::AlgoStats;
use crate::util::json::Json;
use crate::util::mathx::threshold_grid;

use super::{
    build_union_panel, offer_chunk_grid, sieve_first_hit, sieve_stats, tag_sieves, union_row_ids,
    Sieve, SolveGrid, StreamingAlgorithm,
};

/// Multi-sieve thresholding with a known (or estimated) `m`.
pub struct SieveStreaming {
    proto: Box<dyn SubmodularFunction>,
    k: usize,
    epsilon: f64,
    sieves: Vec<Sieve>,
    /// Estimate-m-on-the-fly mode (Badanidiyuru et al. §"unknown m").
    estimate_m: bool,
    m: f64,
    elements: u64,
    extra_queries: u64,
    /// Speculative batch gains past a sieve's acceptance (see
    /// `Sieve::offer_batch`); excluded from reported query stats.
    speculative_queries: u64,
    /// Kernel entries spent on shared chunk panels (charged once per
    /// chunk, not once per sieve — the broker's whole point).
    panel_evals: u64,
    /// Cross-sieve kernel-panel sharing (on whenever the oracle supports
    /// it; the bench/parity hook [`Self::set_panel_sharing`] can force the
    /// per-sieve path).
    share_panels: bool,
    /// Accounting carried over by [`StreamingAlgorithm::restore_state`]
    /// (the ThreeSieves resume pattern): the checkpointed totals, minus
    /// the replay's charges. Cleared by `reset` — this algorithm rebuilds
    /// its oracles (and their counters) wholesale there.
    restored_queries: u64,
    restored_kernel_evals: u64,
    discounted_kernel_evals: u64,
    /// Next decision-event roster tag (m-estimation spawns keep minting
    /// fresh ids so retired and live sieves stay distinguishable in the
    /// event log).
    next_tag: u32,
    /// Decision counters carried by sieves that m estimation retired, so
    /// `stats().accepts`/`rejects` stay monotone across refreshes.
    retired_accepts: u64,
    retired_rejects: u64,
    peak_stored: usize,
    /// Recycled chunk-panel storage (slot map, entries, candidate norms)
    /// — the broker path allocates nothing per chunk once warm.
    panel_scratch: PanelScratch,
    /// Scratch pool for the 2-D (sieve × candidate-range) solve grid.
    solve_pool: SolveGrid,
    /// Parallel execution context: sieves fan out across its pool when
    /// one is attached (see [`StreamingAlgorithm::set_exec`]).
    exec: ExecContext,
}

impl SieveStreaming {
    /// With `m = max_e f({e})` known exactly (our log-det case).
    pub fn new(mut proto: Box<dyn SubmodularFunction>, k: usize, epsilon: f64) -> Self {
        assert!(k > 0 && epsilon > 0.0);
        let dim = proto.dim();
        if let Some(ps) = proto.panel_sharing() {
            // The broker's row store: sieves spawned below (and on m
            // refreshes) share it through `clone_empty`.
            ps.attach_row_store(SharedRowStore::new(dim));
        }
        let m = proto.max_singleton_value();
        let mut sieves: Vec<Sieve> = threshold_grid(epsilon, m, k as f64 * m)
            .into_iter()
            .map(|v| Sieve::new(v, proto.as_ref()))
            .collect();
        let next_tag = tag_sieves(&mut sieves, 0);
        SieveStreaming {
            proto,
            k,
            epsilon,
            sieves,
            estimate_m: false,
            m,
            elements: 0,
            extra_queries: 0,
            speculative_queries: 0,
            panel_evals: 0,
            share_panels: true,
            restored_queries: 0,
            restored_kernel_evals: 0,
            discounted_kernel_evals: 0,
            next_tag,
            retired_accepts: 0,
            retired_rejects: 0,
            peak_stored: 0,
            panel_scratch: PanelScratch::default(),
            solve_pool: SolveGrid::default(),
            exec: ExecContext::sequential(),
        }
    }

    /// Estimating `m` on the fly: sieves are (re)built lazily as the
    /// maximum observed singleton value grows; sieves whose threshold falls
    /// outside `[m_new, K·m_new]` are dropped.
    pub fn with_m_estimation(proto: Box<dyn SubmodularFunction>, k: usize, epsilon: f64) -> Self {
        let mut s = Self::new(proto, k, epsilon);
        s.estimate_m = true;
        s.m = 0.0;
        s.sieves.clear();
        s
    }

    /// Force the per-sieve panel path (`false`) or restore the default
    /// shared-broker path (`true`). Bench/parity hook: both paths are
    /// bit-identical in summaries, values and reported queries — only
    /// [`AlgoStats::kernel_evals`] moves.
    pub fn set_panel_sharing(&mut self, on: bool) {
        self.share_panels = on;
    }

    fn refresh_sieves_for_m(&mut self, m_new: f64) {
        self.m = m_new;
        let lo = m_new;
        let hi = self.k as f64 * m_new;
        let keep = |s: &Sieve| s.v >= lo && s.v <= hi * (1.0 + 1e-12);
        // Drop sieves below the new lower bound, banking their decision
        // counters so the aggregate telemetry stays monotone.
        for s in self.sieves.iter().filter(|s| !keep(s)) {
            self.retired_accepts += s.accepts;
            self.retired_rejects += s.rejects;
            crate::obs::emit_event(crate::obs::Event::SieveRetire { sieve: s.tag, v: s.v });
        }
        self.sieves.retain(keep);
        // Add missing grid points.
        for v in threshold_grid(self.epsilon, lo, hi) {
            let exists = self.sieves.iter().any(|s| (s.v / v - 1.0).abs() < 1e-9);
            if !exists {
                let mut s = Sieve::new(v, self.proto.as_ref());
                s.tag = self.next_tag;
                self.next_tag += 1;
                crate::obs::emit_event(crate::obs::Event::SieveSpawn { sieve: s.tag, v });
                self.sieves.push(s);
            }
        }
        self.sieves.sort_by(|a, b| a.v.total_cmp(&b.v));
    }

    fn best_sieve(&self) -> Option<&Sieve> {
        // total_cmp, not partial_cmp().unwrap(): a NaN objective from a
        // pathological oracle must not panic the stream mid-serve. NaN
        // sorts above every real in the total order, so it surfaces as a
        // (visibly broken) best value instead of a crash.
        self.sieves
            .iter()
            .max_by(|a, b| a.oracle.current_value().total_cmp(&b.oracle.current_value()))
    }

    /// Number of live sieves (tests / telemetry).
    pub fn sieve_count(&self) -> usize {
        self.sieves.len()
    }

    /// One chunk panel across the union of the live sieves' interned
    /// summary rows — `None` when sharing is disabled, the oracle lacks
    /// the capability (no kernel/solve split), or the chunk is empty.
    fn build_shared_panel(&mut self, chunk: &[f32]) -> Option<ChunkPanel> {
        if !self.share_panels || chunk.is_empty() {
            return None;
        }
        let ids = union_row_ids(self.sieves.iter_mut().map(|s| &mut s.oracle), self.k)?;
        build_union_panel(&mut self.proto, &ids, chunk, &self.exec, &mut self.panel_scratch)
    }
}

impl StreamingAlgorithm for SieveStreaming {
    fn name(&self) -> String {
        "SieveStreaming".into()
    }

    fn process(&mut self, item: &[f32]) {
        self.elements += 1;
        if self.estimate_m {
            self.extra_queries += 1;
            let mut probe = self.proto.clone_empty();
            let singleton = probe.peek_gain(item);
            if singleton > self.m {
                self.refresh_sieves_for_m(singleton);
            }
        }
        for s in self.sieves.iter_mut() {
            s.offer(item, self.k);
        }
        let stored: usize = self.sieves.iter().map(|s| s.oracle.len()).sum();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }

    /// Batched ingestion: the sieves are fully independent (no cross-sieve
    /// coupling outside m estimation), so each sieve consumes the whole
    /// chunk — one gain panel per rejection run instead of one oracle call
    /// per item — either sequentially or on the exec pool's worker threads
    /// when a context is attached. Each sieve runs the identical
    /// instruction sequence on state it owns and the speculative counts
    /// fold in sieve order, so results are bit-identical at every thread
    /// count. Stored elements only grow within a chunk, so the
    /// end-of-chunk peak equals the scalar per-item peak.
    ///
    /// When the oracle exposes [`crate::functions::PanelSharing`], the
    /// chunk's kernel rows are computed **once** against the union of all
    /// distinct summary rows (the broker panel, built on the exec pool by
    /// row-range) and every sieve's rejection runs *gather* from it via
    /// [`Sieve::offer_batch_shared`] — same decisions, same queries,
    /// `kernel_evals` collapses from Σ-per-sieve to once-per-chunk.
    ///
    /// When the pool has more workers than live sieves can occupy, the
    /// per-sieve fan-out switches to the 2-D (sieve × candidate-range)
    /// solve grid ([`super::offer_chunk_grid`]): each rejection run's
    /// blocked solves split into candidate ranges that any worker can
    /// pick up, so a lone wide sieve no longer pins the chunk's critical
    /// path to a single thread. Bits, queries and kernel evals are
    /// unchanged — only where the solves run.
    fn process_batch(&mut self, chunk: &[f32]) {
        let d = self.proto.dim();
        debug_assert_eq!(chunk.len() % d, 0, "chunk not row-aligned");
        if self.estimate_m {
            // m estimation rebuilds the sieve set mid-stream; replay.
            for row in chunk.chunks_exact(d) {
                self.process(row);
            }
            return;
        }
        self.elements += (chunk.len() / d) as u64;
        let k = self.k;
        let shared = self.build_shared_panel(chunk);
        // Inline when sequential, worker threads when a pool is attached
        // (`set_exec` gated it on `parallel_safe()`); identical results
        // either way, speculative counts folded in sieve order. Under the
        // broker with live sieves too few to keep the workers busy, the
        // coarse one-chunk×sieve fan-out gives way to the 2-D
        // (sieve × candidate-range) solve grid — same gains, same scan,
        // same accounting (`offer_chunk_grid` documents the argument),
        // but solve work no longer serializes behind the widest sieve.
        let live = self.sieves.iter().filter(|s| s.oracle.len() < k).count();
        let use_grid = self.exec.is_parallel() && self.exec.threads() * 2 > live;
        let wasted: u64 = match &shared {
            Some(panel) => {
                let grid = if use_grid {
                    let mut refs: Vec<&mut Sieve> = self.sieves.iter_mut().collect();
                    offer_chunk_grid(
                        &mut refs,
                        panel,
                        chunk,
                        d,
                        k,
                        &self.exec,
                        &mut self.solve_pool,
                        |_, v, oracle, gains, _| sieve_first_hit(v, oracle, k, gains),
                    )
                } else {
                    None
                };
                match grid {
                    Some(w) => w,
                    None => self
                        .exec
                        .map_units(&mut self.sieves, |s| s.offer_batch_shared(panel, chunk, d, k))
                        .iter()
                        .sum(),
                }
            }
            None => {
                self.exec.map_units(&mut self.sieves, |s| s.offer_batch(chunk, d, k)).iter().sum()
            }
        };
        if let Some(panel) = shared {
            self.panel_evals += panel.evals();
            self.panel_scratch.recycle(panel);
        }
        self.speculative_queries += wasted;
        let stored: usize = self.sieves.iter().map(|s| s.oracle.len()).sum();
        if stored > self.peak_stored {
            self.peak_stored = stored;
        }
    }

    fn set_exec(&mut self, exec: ExecContext) {
        self.exec = exec.gated(self.proto.as_ref());
    }

    fn value(&self) -> f64 {
        self.best_sieve().map(|s| s.oracle.current_value()).unwrap_or(0.0)
    }

    fn summary(&self) -> Vec<f32> {
        self.best_sieve().map(|s| s.oracle.summary().to_vec()).unwrap_or_default()
    }

    fn summary_len(&self) -> usize {
        self.best_sieve().map(|s| s.oracle.len()).unwrap_or(0)
    }

    fn dim(&self) -> usize {
        self.proto.dim()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn stats(&self) -> AlgoStats {
        let mut peak = self.peak_stored;
        let mut st = sieve_stats(&self.sieves, self.elements, self.extra_queries, &mut peak);
        st.queries = (st.queries + self.restored_queries).saturating_sub(self.speculative_queries);
        st.kernel_evals = (st.kernel_evals + self.panel_evals + self.restored_kernel_evals)
            .saturating_sub(self.discounted_kernel_evals);
        st.accepts += self.retired_accepts;
        st.rejects += self.retired_rejects;
        st
    }

    fn reset(&mut self) {
        self.elements = 0;
        self.extra_queries = 0;
        // The sieve oracles (and their query/eval counters) are rebuilt
        // below, so the speculative, panel and restored shares reset with
        // them.
        self.speculative_queries = 0;
        self.panel_evals = 0;
        self.restored_queries = 0;
        self.restored_kernel_evals = 0;
        self.discounted_kernel_evals = 0;
        self.peak_stored = 0;
        // Fresh row store: the dropped sieves' interned rows would
        // otherwise pin memory across drift resets.
        let dim = self.proto.dim();
        if let Some(ps) = self.proto.panel_sharing() {
            ps.attach_row_store(SharedRowStore::new(dim));
        }
        self.retired_accepts = 0;
        self.retired_rejects = 0;
        if self.estimate_m {
            self.m = 0.0;
            self.sieves.clear();
        } else {
            let m = self.proto.max_singleton_value();
            self.sieves = threshold_grid(self.epsilon, m, self.k as f64 * m)
                .into_iter()
                .map(|v| Sieve::new(v, self.proto.as_ref()))
                .collect();
        }
        self.next_tag = tag_sieves(&mut self.sieves, 0);
    }

    /// Full resumable state: the grid is deterministic from `(ε, m, K)`,
    /// so per-sieve state is exactly each sieve's summary rows in
    /// acceptance order — replaying them through `accept` reproduces the
    /// incremental Cholesky (and the broker's interned row ids)
    /// bit-for-bit. The reported accounting rides along and is rebased on
    /// restore. `None` in m-estimation mode: there the sieve set depends
    /// on the stream prefix, not just the configuration.
    fn snapshot_state(&self) -> Option<Json> {
        if self.estimate_m {
            return None;
        }
        let st = self.stats();
        let sieves = Json::Arr(
            self.sieves
                .iter()
                .map(|s| {
                    Json::Arr(s.oracle.summary().iter().map(|&x| Json::num(x as f64)).collect())
                })
                .collect(),
        );
        Some(Json::obj(vec![
            ("algo", Json::str("sieve-streaming")),
            ("k", Json::num(self.k as f64)),
            ("dim", Json::num(self.proto.dim() as f64)),
            ("epsilon", Json::num(self.epsilon)),
            ("elements", Json::num(self.elements as f64)),
            ("queries", Json::num(st.queries as f64)),
            ("kernel_evals", Json::num(st.kernel_evals as f64)),
            ("peak_stored", Json::num(st.peak_stored as f64)),
            ("sieves", sieves),
        ]))
    }

    fn restore_state(&mut self, state: &Json, summary: &[f32]) -> Result<(), String> {
        if self.estimate_m {
            return Err("m-estimation SieveStreaming does not support checkpoint resume".into());
        }
        if state.get("algo").as_str() != Some("sieve-streaming") {
            return Err(format!(
                "checkpoint state is for {:?}, not sieve-streaming",
                state.get("algo").as_str().unwrap_or("?")
            ));
        }
        let field = |name: &str| {
            state.get(name).as_f64().ok_or_else(|| format!("checkpoint state missing {name:?}"))
        };
        let same = |name: &str, mine: f64| -> Result<(), String> {
            let theirs = field(name)?;
            if theirs.to_bits() != mine.to_bits() {
                return Err(format!("checkpoint {name} = {theirs} != configured {mine}"));
            }
            Ok(())
        };
        let d = self.proto.dim();
        same("k", self.k as f64)?;
        same("dim", d as f64)?;
        same("epsilon", self.epsilon)?;
        let elements = field("elements")? as u64;
        let queries = field("queries")? as u64;
        let kernel_evals = field("kernel_evals")? as u64;
        let peak_stored = field("peak_stored")? as usize;
        if summary.len() % d != 0 || summary.len() / d > self.k {
            return Err(format!(
                "checkpoint summary has {} floats, not <= {}x{d} rows",
                summary.len(),
                self.k
            ));
        }
        let sieves_json = state
            .get("sieves")
            .as_arr()
            .ok_or_else(|| "checkpoint state missing \"sieves\" array".to_string())?;
        let m = self.proto.max_singleton_value();
        let grid = threshold_grid(self.epsilon, m, self.k as f64 * m);
        if sieves_json.len() != grid.len() {
            return Err(format!(
                "checkpoint has {} sieves, the (ε, m, K) grid expects {}",
                sieves_json.len(),
                grid.len()
            ));
        }
        // Decode every sieve's rows before touching any state: a blob
        // that fails mid-way must leave this instance exactly as it was.
        let mut rows_per_sieve: Vec<Vec<f32>> = Vec::with_capacity(sieves_json.len());
        for (i, sj) in sieves_json.iter().enumerate() {
            let arr = sj.as_arr().ok_or_else(|| format!("checkpoint sieve {i}: not an array"))?;
            if arr.len() % d != 0 || arr.len() / d > self.k {
                return Err(format!(
                    "checkpoint sieve {i}: {} floats, not <= {}x{d} rows",
                    arr.len(),
                    self.k
                ));
            }
            let mut rows = Vec::with_capacity(arr.len());
            for v in arr {
                let x =
                    v.as_f64().ok_or_else(|| format!("checkpoint sieve {i}: non-numeric row"))?;
                rows.push(x as f32);
            }
            rows_per_sieve.push(rows);
        }
        // Rebuild off to the side — fresh prototype, fresh row store — and
        // only then commit, so a failed restore cannot half-apply.
        let mut proto = self.proto.clone_empty();
        if let Some(ps) = proto.panel_sharing() {
            ps.attach_row_store(SharedRowStore::new(d));
        }
        let mut sieves: Vec<Sieve> =
            grid.into_iter().map(|v| Sieve::new(v, proto.as_ref())).collect();
        let next_tag = tag_sieves(&mut sieves, 0);
        for (s, rows) in sieves.iter_mut().zip(&rows_per_sieve) {
            for row in rows.chunks_exact(d) {
                s.oracle.accept(row);
            }
        }
        let best = sieves
            .iter()
            .max_by(|a, b| a.oracle.current_value().total_cmp(&b.oracle.current_value()));
        let best_summary = best.map(|s| s.oracle.summary().to_vec()).unwrap_or_default();
        if best_summary != summary {
            return Err("checkpoint summary does not match the rebuilt sieves".into());
        }
        // Commit + rebase accounting: cancel the replay's oracle charges
        // and carry the checkpointed totals (the ThreeSieves pattern), so
        // stats() continues exactly where the paused run left off.
        let replayed_q: u64 = sieves.iter().map(|s| s.oracle.queries()).sum();
        let replayed_e: u64 = sieves.iter().map(|s| s.oracle.kernel_evals()).sum();
        let stored: usize = sieves.iter().map(|s| s.oracle.len()).sum();
        self.proto = proto;
        self.sieves = sieves;
        self.next_tag = next_tag;
        self.retired_accepts = 0;
        self.retired_rejects = 0;
        self.m = m;
        self.elements = elements;
        self.peak_stored = peak_stored.max(stored);
        self.extra_queries = 0;
        self.speculative_queries = replayed_q;
        self.restored_queries = queries;
        self.panel_evals = 0;
        self.discounted_kernel_evals = replayed_e;
        self.restored_kernel_evals = kernel_evals;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testkit;

    #[test]
    fn sieve_count_scales_with_eps() {
        let coarse = SieveStreaming::new(testkit::oracle(20), 20, 0.5);
        let fine = SieveStreaming::new(testkit::oracle(20), 20, 0.01);
        assert!(fine.sieve_count() > 5 * coarse.sieve_count());
    }

    #[test]
    fn close_to_greedy_on_clustered_data() {
        let ds = testkit::clustered(3000, 1);
        let k = 10;
        let greedy = testkit::greedy_value(&ds, k);
        let mut algo = SieveStreaming::new(testkit::oracle(k), k, 0.01);
        testkit::run(&mut algo, &ds);
        let rel = algo.value() / greedy;
        assert!(rel > 0.7, "relative performance {rel:.3}");
    }

    #[test]
    fn queries_dominate_threesieves() {
        // The Table 1 claim, measured head-to-head: SieveStreaming pays
        // O(log K / ε) queries per element against ThreeSieves' O(1) —
        // with K large enough that sieves don't all fill instantly.
        use crate::algorithms::three_sieves::SieveTuning;
        let ds = testkit::clustered(400, 2);
        let k = 50;
        let mut ss = SieveStreaming::new(testkit::oracle(k), k, 0.05);
        let mut ts = super::super::ThreeSieves::new(
            testkit::oracle(k),
            k,
            0.05,
            SieveTuning::FixedT(100),
        );
        let sieves = ss.sieve_count() as f64;
        testkit::run(&mut ss, &ds);
        testkit::run(&mut ts, &ds);
        let ss_q = ss.stats().queries as f64;
        let ts_q = ts.stats().queries as f64;
        assert!(
            ss_q > 5.0 * ts_q,
            "SieveStreaming ({ss_q}) should pay ≫ ThreeSieves ({ts_q}) with {sieves} sieves"
        );
        assert!(ss.stats().queries_per_element() <= sieves + 1.0);
    }

    #[test]
    fn memory_exceeds_k_but_each_sieve_bounded() {
        let ds = testkit::clustered(2000, 3);
        let k = 8;
        let mut algo = SieveStreaming::new(testkit::oracle(k), k, 0.05);
        testkit::run(&mut algo, &ds);
        let st = algo.stats();
        assert!(st.peak_stored > k, "multi-sieve memory should exceed K");
        assert!(st.peak_stored <= algo.sieve_count() * k);
    }

    #[test]
    fn m_estimation_matches_known_m_for_logdet() {
        // Constant singleton values => identical behaviour after element 1.
        let ds = testkit::clustered(1500, 4);
        let k = 6;
        let mut known = SieveStreaming::new(testkit::oracle(k), k, 0.05);
        let mut est = SieveStreaming::with_m_estimation(testkit::oracle(k), k, 0.05);
        testkit::run(&mut known, &ds);
        testkit::run(&mut est, &ds);
        assert!((known.value() - est.value()).abs() < 1e-9);
    }

    #[test]
    fn reset_restores_sieves() {
        let ds = testkit::clustered(500, 5);
        let k = 5;
        let mut algo = SieveStreaming::new(testkit::oracle(k), k, 0.1);
        let n0 = algo.sieve_count();
        testkit::run(&mut algo, &ds);
        algo.reset();
        assert_eq!(algo.sieve_count(), n0);
        assert_eq!(algo.value(), 0.0);
    }

    #[test]
    fn shared_panels_match_per_sieve_batches_bitwise() {
        // The broker acceptance point in miniature: same summaries, same
        // values, same reported queries; only kernel_evals may drop.
        let ds = testkit::clustered(1200, 6);
        let k = 6;
        let d = testkit::DIM;
        let mut shared = SieveStreaming::new(testkit::oracle(k), k, 0.05);
        let mut plain = SieveStreaming::new(testkit::oracle(k), k, 0.05);
        plain.set_panel_sharing(false);
        for chunk in ds.raw().chunks(64 * d) {
            shared.process_batch(chunk);
            plain.process_batch(chunk);
        }
        assert_eq!(shared.value().to_bits(), plain.value().to_bits());
        assert_eq!(shared.summary(), plain.summary());
        let (a, b) = (shared.stats(), plain.stats());
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.peak_stored, b.peak_stored);
        assert!(
            a.kernel_evals <= b.kernel_evals,
            "shared panels must never evaluate more kernel entries: {} vs {}",
            a.kernel_evals,
            b.kernel_evals
        );
        assert!(b.kernel_evals > 0, "workload must exercise the kernel");
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically_batched() {
        let ds = testkit::clustered(1600, 7);
        let k = 5;
        let d = testkit::DIM;
        let build = || SieveStreaming::new(testkit::oracle(k), k, 0.1);
        let half = ds.len() / 2 * d;
        let mut whole = build();
        let mut first = build();
        for chunk in ds.raw()[..half].chunks(41 * d) {
            whole.process_batch(chunk);
            first.process_batch(chunk);
        }
        // Snapshot → JSON text → parse → restore: the checkpoint-file
        // roundtrip, with the broker active on both timelines.
        let state = first.snapshot_state().expect("exact-m SieveStreaming is resumable");
        let text = state.to_string();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let mut resumed = build();
        resumed.restore_state(&parsed, &first.summary()).unwrap();
        assert_eq!(resumed.value().to_bits(), first.value().to_bits());
        assert_eq!(resumed.stats(), first.stats());
        for chunk in ds.raw()[half..].chunks(41 * d) {
            whole.process_batch(chunk);
            resumed.process_batch(chunk);
        }
        assert_eq!(resumed.value().to_bits(), whole.value().to_bits());
        assert_eq!(resumed.summary(), whole.summary());
        assert_eq!(resumed.stats(), whole.stats());
    }

    #[test]
    fn restore_rejects_mismatched_configuration() {
        let ds = testkit::clustered(300, 8);
        let k = 4;
        let mut donor = SieveStreaming::new(testkit::oracle(k), k, 0.1);
        testkit::run(&mut donor, &ds);
        let state = donor.snapshot_state().unwrap();
        let summary = donor.summary();
        // Different K.
        let mut other = SieveStreaming::new(testkit::oracle(5), 5, 0.1);
        assert!(other.restore_state(&state, &summary).is_err());
        // Different epsilon (different grid).
        let mut other = SieveStreaming::new(testkit::oracle(k), k, 0.2);
        assert!(other.restore_state(&state, &summary).is_err());
        // m-estimation mode cannot resume.
        let mut other = SieveStreaming::with_m_estimation(testkit::oracle(k), k, 0.1);
        assert!(other.restore_state(&state, &summary).is_err());
        // Tampered summary: must be rejected, donor state untouched.
        let mut other = SieveStreaming::new(testkit::oracle(k), k, 0.1);
        let before = other.stats();
        let mut bad = summary.clone();
        if let Some(x) = bad.first_mut() {
            *x += 1.0;
        }
        assert!(other.restore_state(&state, &bad).is_err());
        assert_eq!(other.stats(), before, "failed restore must leave state untouched");
        // Matching configuration restores.
        let mut ok = SieveStreaming::new(testkit::oracle(k), k, 0.1);
        ok.restore_state(&state, &summary).unwrap();
        assert_eq!(ok.value().to_bits(), donor.value().to_bits());
        assert_eq!(ok.stats(), donor.stats());
    }
}
