//! The streaming submodular maximization algorithm family (paper Table 1).
//!
//! Every algorithm implements [`StreamingAlgorithm`]: elements arrive one at
//! a time through [`StreamingAlgorithm::process`]; the algorithm owns one or
//! more [`SubmodularFunction`] oracles (sieves) and decides, per element,
//! whether to insert/swap/reject. Resource accounting matches the paper:
//! *memory* = peak stored elements across all sieves, *queries* = total
//! oracle evaluations.
//!
//! | Algorithm | Ratio | Memory | Queries/elem |
//! |---|---|---|---|
//! | [`Greedy`] (offline) | 1−1/e | O(K) | O(1) |
//! | [`StreamGreedy`] | ½−ε (multi-pass) | O(K) | O(K) |
//! | [`RandomReservoir`] | ¼ (expect.) | O(K) | O(1) |
//! | [`PreemptionStreaming`] | ¼ | O(K) | O(K) |
//! | [`IndependentSetImprovement`] | ¼ | O(K) | O(1) |
//! | [`SieveStreaming`] | ½−ε | O(K log K / ε) | O(log K / ε) |
//! | [`SieveStreamingPP`] | ½−ε | O(K/ε) | O(log K / ε) |
//! | [`Salsa`] | ½−ε | O(K log K / ε) | O(log K / ε) |
//! | [`QuickStream`] | 1/(4c)−ε | O(cK log K log 1/ε) | O(⌈1/c⌉+c) |
//! | [`ThreeSieves`] | (1−ε)(1−1/e) w.p. (1−α)^K | O(K) | O(1) |
//! | [`StreamClipper`] | ½ (buffered) | O(K) + 2K buffer | O(1) |
//! | [`Subsampled`] | inner's, on the sampled stream | inner's | p × inner's |
//!
//! Construction and dispatch are table-driven: [`registry`] holds one
//! [`registry::AlgoEntry`] per algorithm (name, parameters, docs, build
//! function), and config parsing, the CLI, the service OPEN grammar and
//! the experiment sweeps all route through it.

pub mod greedy;
pub mod independent_set;
pub mod preemption;
pub mod quick_stream;
pub mod random;
pub mod registry;
pub mod salsa;
pub mod sieve_streaming;
pub mod sieve_streaming_pp;
pub mod stream_clipper;
pub mod stream_greedy;
pub mod subsampled;
pub mod three_sieves;

pub use greedy::Greedy;
pub use independent_set::IndependentSetImprovement;
pub use preemption::PreemptionStreaming;
pub use quick_stream::QuickStream;
pub use random::RandomReservoir;
pub use salsa::Salsa;
pub use sieve_streaming::SieveStreaming;
pub use sieve_streaming_pp::SieveStreamingPP;
pub use stream_clipper::StreamClipper;
pub use stream_greedy::StreamGreedy;
pub use subsampled::Subsampled;
pub use three_sieves::ThreeSieves;

use crate::exec::ExecContext;
use crate::functions::{ChunkPanel, PanelScratch, PanelSharing, SolveScratch, SubmodularFunction};
use crate::metrics::AlgoStats;
use crate::util::json::Json;

/// A single-pass streaming summary-selection algorithm.
///
/// Not `Send` (see [`SubmodularFunction`]); the coordinator ships
/// constructor closures to worker threads instead of built algorithms.
pub trait StreamingAlgorithm {
    /// Display name (stable across runs; used in result CSVs).
    fn name(&self) -> String;

    /// Observe one stream element.
    fn process(&mut self, item: &[f32]);

    /// Observe a chunk of stream elements, flat row-major `count × dim()`.
    ///
    /// Contract: semantically identical to calling
    /// [`process`](Self::process) on each row in order — same summary, same
    /// value, same resource accounting (`rust/tests/batch_parity.rs` pins
    /// this). The default does exactly that; the threshold family overrides
    /// it to evaluate gains for the whole chunk against the current summary
    /// in one oracle call (`SubmodularFunction::peek_gain_batch`), which is
    /// where the batched-ingestion throughput comes from. Speculative gain
    /// evaluations past the point where the summary changes are tracked by
    /// the overrides and subtracted from the reported query stats, so
    /// `stats().queries` keeps the paper's per-element accounting.
    fn process_batch(&mut self, chunk: &[f32]) {
        let d = self.dim();
        debug_assert_eq!(chunk.len() % d, 0, "chunk not row-aligned");
        for row in chunk.chunks_exact(d) {
            self.process(row);
        }
    }

    /// Called once after the stream ends (QuickStream flushes its buffer,
    /// others are no-ops).
    fn finalize(&mut self) {}

    /// Install a parallel execution context (see [`crate::exec`]).
    ///
    /// Algorithms whose batched work decomposes into independent coarse
    /// units — ShardedThreeSieves shards, SieveStreaming/Salsa sieves —
    /// override this to fan [`process_batch`](Self::process_batch) out
    /// across the context's worker pool. Overrides must (a) keep results
    /// bit-identical to sequential execution at every thread count
    /// (`rust/tests/exec_parity.rs`) and (b) ignore the pool unless their
    /// oracle reports
    /// [`parallel_safe`](crate::functions::SubmodularFunction::parallel_safe).
    /// The default ignores the context (scalar algorithms have no units
    /// to fan out).
    fn set_exec(&mut self, _exec: ExecContext) {}

    /// Current best function value f(S).
    fn value(&self) -> f64;

    /// Current best summary, flat row-major `summary_len() × dim()`.
    fn summary(&self) -> Vec<f32>;

    /// Elements in the current best summary.
    fn summary_len(&self) -> usize;

    /// Feature dimensionality.
    fn dim(&self) -> usize;

    /// Target cardinality K.
    fn k(&self) -> usize;

    /// Resource statistics so far.
    fn stats(&self) -> AlgoStats;

    /// Clear all state (drift re-selection hook from the coordinator).
    fn reset(&mut self);

    /// True once the best summary holds K elements.
    fn is_full(&self) -> bool {
        self.summary_len() >= self.k()
    }

    /// Opaque, JSON-serializable snapshot of every piece of run state the
    /// summary itself does not capture (active threshold, rejection
    /// counter, element/query accounting, …), or `None` when the algorithm
    /// cannot be resumed from a checkpoint.
    ///
    /// Contract: feeding the snapshot and the matching summary back through
    /// [`restore_state`](Self::restore_state) on a freshly built instance
    /// of the same configuration must reproduce the exact pre-snapshot
    /// state — continuing the stream afterwards yields **bit-identical**
    /// summaries, values and [`stats`](Self::stats) to a run that never
    /// paused (`rust/tests/service_integration.rs` pins this for the
    /// session manager's evict → re-`OPEN` path). The default returns
    /// `None`: algorithms are summary-only checkpointable unless they opt
    /// in. All f64 fields survive the JSON text roundtrip bit-for-bit
    /// (shortest-roundtrip formatting), so implementations may store raw
    /// threshold values directly.
    fn snapshot_state(&self) -> Option<Json> {
        None
    }

    /// Restore from a [`snapshot_state`](Self::snapshot_state) blob plus
    /// the checkpointed summary rows (row-major, acceptance order). Must
    /// reject mismatched configurations (k, dim, hyperparameters) with a
    /// descriptive error rather than resuming into a different run.
    fn restore_state(&mut self, _state: &Json, _summary: &[f32]) -> Result<(), String> {
        Err(format!("{} does not support checkpoint resume", self.name()))
    }
}

/// The SieveStreaming insertion rule shared by the threshold family
/// (SieveStreaming, SieveStreaming++, Salsa's sieve rule, ThreeSieves):
///
/// accept e into S_v iff `Δf(e|S) ≥ (v/2 − f(S)) / (K − |S|)`.
#[inline]
pub(crate) fn sieve_threshold(v: f64, f_s: f64, k: usize, len: usize) -> f64 {
    debug_assert!(len < k);
    (v / 2.0 - f_s) / (k - len) as f64
}

/// First would-accept position in a rejection run's gains under the sieve
/// rule (the threshold is constant within a run — `v`, `f(S)` and `|S|`
/// only move on accept). The single scan definition shared by the
/// unit-serial batch paths and the 2-D grid's Phase B, so the two can
/// never drift.
#[inline]
pub(crate) fn sieve_first_hit(
    v: f64,
    oracle: &dyn SubmodularFunction,
    k: usize,
    gains: &[f64],
) -> Option<usize> {
    let thresh = sieve_threshold(v, oracle.current_value(), k, oracle.len());
    gains.iter().position(|&g| g >= thresh)
}

/// Gather one candidate's kv row for a sieve from the shared chunk panel
/// and the sieve's chunk-local rows — the single gather definition behind
/// [`Sieve::gains_shared`] and the 2-D grid's tasks.
#[inline]
pub(crate) fn gather_kv(
    panel: &ChunkPanel,
    kv_src: &[KvSrc],
    local: &[f64],
    b: usize,
    kv: &mut [f64],
) {
    let width = panel.width();
    for (i, src) in kv_src.iter().enumerate() {
        kv[i] = match *src {
            KvSrc::Shared(s) => panel.at(s, b),
            KvSrc::Local(l) => local[l as usize * width + b],
        };
    }
}

/// Where one summary row's kernel entries for the current chunk live:
/// a slot of the shared [`ChunkPanel`](crate::functions::ChunkPanel), or a
/// chunk-local row the sieve computed itself after a mid-chunk accept.
#[derive(Clone, Copy)]
pub(crate) enum KvSrc {
    Shared(u32),
    Local(u32),
}

/// One sieve: a candidate OPT estimate `v` plus its own oracle.
pub(crate) struct Sieve {
    pub v: f64,
    pub oracle: Box<dyn SubmodularFunction>,
    /// Gain-panel scratch for [`offer_batch`](Self::offer_batch) — owned
    /// per sieve so the exec pool's fan-out needs no shared buffers and
    /// the hot path allocates once, not once per chunk. The shared-panel
    /// path reuses it for its gathered gains.
    pub(crate) scratch: Vec<f64>,
    /// Chunk-scoped gather plan under the shared panel: one entry per
    /// summary row (in acceptance order).
    kv_src: Vec<KvSrc>,
    /// Chunk-local kernel rows (rows this sieve accepted mid-chunk whose
    /// entries the chunk-start panel cannot have), row-major with the
    /// chunk width.
    local: Vec<f64>,
    /// Interned id per chunk-local row — lets a post-refresh rebind (see
    /// SieveStreaming++) find a surviving row's entries again, and lets a
    /// duplicate acceptance reuse an already computed row.
    local_ids: Vec<u32>,
    /// Wall-ns spent scanning gains against the sieve rule. Advanced only
    /// while [`obs`](crate::obs) recording is on; surfaced through
    /// [`AlgoStats::wall_scan_ns`](crate::metrics::AlgoStats).
    pub(crate) scan_ns: u64,
    /// Decision-event identity: this sieve's roster position (see
    /// [`tag_sieves`]). Feeds the `sieve` field of Accept/Reject events;
    /// never read by the algorithms themselves.
    pub(crate) tag: u32,
    /// Sieve-rule accepts observed. Like `scan_ns`, advanced only while
    /// obs recording is on; surfaced through `AlgoStats::accepts`.
    pub(crate) accepts: u64,
    /// Sieve-rule rejects observed. Same gating as `accepts`.
    pub(crate) rejects: u64,
}

impl Sieve {
    pub fn new(v: f64, proto: &dyn SubmodularFunction) -> Self {
        Sieve {
            v,
            oracle: proto.clone_empty(),
            scratch: Vec::new(),
            kv_src: Vec::new(),
            local: Vec::new(),
            local_ids: Vec::new(),
            scan_ns: 0,
            tag: 0,
            accepts: 0,
            rejects: 0,
        }
    }

    /// Record one decision for the event log and the per-sieve counters.
    /// `tau` is the accept bar as the owning execution path computed it,
    /// *before* the accept mutated the oracle. One relaxed load when obs
    /// recording is off.
    #[inline]
    pub(crate) fn note_one(&mut self, accepted: bool, gain: f64, tau: f64) {
        if !crate::obs::enabled() {
            return;
        }
        let element = self.accepts + self.rejects;
        if accepted {
            self.accepts += 1;
            crate::obs::emit_event(crate::obs::Event::Accept {
                element,
                sieve: self.tag,
                gain,
                tau,
            });
        } else {
            self.rejects += 1;
            crate::obs::emit_event(crate::obs::Event::Reject {
                element,
                sieve: self.tag,
                gain,
                tau,
            });
        }
    }

    /// Record one scanned rejection run — the gains in
    /// `self.scratch[..len]`, with `hit` marking the first accept (if
    /// any): `hit` rejects, then one accept; or `len` rejects when the
    /// whole run failed the rule. Within a run the threshold is constant,
    /// so one `tau` covers every decision.
    pub(crate) fn note_run(&mut self, len: usize, hit: Option<usize>, tau: f64) {
        if !crate::obs::enabled() {
            return;
        }
        let upto = hit.unwrap_or(len);
        for j in 0..upto {
            let gain = self.scratch[j];
            self.note_one(false, gain, tau);
        }
        if let Some(j) = hit {
            let gain = self.scratch[j];
            self.note_one(true, gain, tau);
        }
    }

    /// Apply the sieve rule; returns true if the item was accepted.
    pub fn offer(&mut self, item: &[f32], k: usize) -> bool {
        let len = self.oracle.len();
        if len >= k {
            return false;
        }
        let thresh = sieve_threshold(self.v, self.oracle.current_value(), k, len);
        let gain = self.oracle.peek_gain(item);
        let accepted = gain >= thresh;
        self.note_one(accepted, gain, thresh);
        if accepted {
            self.oracle.accept(item);
        }
        accepted
    }

    /// Batched [`offer`](Self::offer) over a whole chunk (row-major
    /// `count × dim`): evaluate the remaining items' gains against the
    /// current summary in one oracle call, accept the first item that
    /// passes the sieve rule, then re-batch from the next item (gains
    /// computed before an accept are stale after it).
    ///
    /// Bit-identical to offering each row in order: within a rejection run
    /// the threshold is constant (`v`, `f(S)` and `|S|` only move on
    /// accept), so the first passing index is the same item the scalar
    /// loop would accept. Returns the number of *speculative* gain
    /// evaluations — gains the scalar path would not have computed because
    /// they lie past an acceptance — which the caller subtracts from its
    /// query stats to keep the paper's per-element accounting.
    pub fn offer_batch(&mut self, chunk: &[f32], dim: usize, k: usize) -> u64 {
        let total = chunk.len() / dim;
        let mut pos = 0usize;
        let mut wasted = 0u64;
        while pos < total {
            if self.oracle.len() >= k {
                return wasted; // full: the scalar path stops querying too
            }
            let remaining = total - pos;
            self.oracle.peek_gain_batch(&chunk[pos * dim..], remaining, &mut self.scratch);
            let t = crate::obs::clock();
            let hit = sieve_first_hit(self.v, self.oracle.as_ref(), k, &self.scratch[..remaining]);
            self.scan_ns += crate::obs::lap(t);
            if crate::obs::enabled() {
                let tau =
                    sieve_threshold(self.v, self.oracle.current_value(), k, self.oracle.len());
                self.note_run(remaining, hit, tau);
            }
            match hit {
                Some(j) => {
                    self.oracle.accept(&chunk[(pos + j) * dim..(pos + j + 1) * dim]);
                    wasted += (remaining - (j + 1)) as u64;
                    pos += j + 1;
                }
                None => return wasted,
            }
        }
        wasted
    }

    /// [`offer_batch`](Self::offer_batch) under the shared kernel-panel
    /// broker: identical decisions and query accounting, but every
    /// rejection run's gains are *gathered* from the chunk panel instead
    /// of paying a fresh B×n kernel panel per run. Falls back to
    /// `offer_batch` if this sieve cannot bind to the panel (defensive —
    /// the union covers every live sieve by construction).
    pub fn offer_batch_shared(
        &mut self,
        panel: &ChunkPanel,
        chunk: &[f32],
        dim: usize,
        k: usize,
    ) -> u64 {
        if self.oracle.len() >= k {
            return 0; // full: neither path queries
        }
        if !self.begin_shared_chunk(panel) {
            return self.offer_batch(chunk, dim, k);
        }
        let total = chunk.len() / dim;
        let mut pos = 0usize;
        let mut wasted = 0u64;
        while pos < total {
            if self.oracle.len() >= k {
                return wasted;
            }
            let remaining = total - pos;
            self.gains_shared(panel, pos, remaining);
            let t = crate::obs::clock();
            let hit = sieve_first_hit(self.v, self.oracle.as_ref(), k, &self.scratch[..remaining]);
            self.scan_ns += crate::obs::lap(t);
            if crate::obs::enabled() {
                let tau =
                    sieve_threshold(self.v, self.oracle.current_value(), k, self.oracle.len());
                self.note_run(remaining, hit, tau);
            }
            match hit {
                Some(j) => {
                    self.accept_shared(panel, chunk, dim, pos + j);
                    wasted += (remaining - (j + 1)) as u64;
                    pos += j + 1;
                }
                None => return wasted,
            }
        }
        wasted
    }

    /// Start a new chunk under the shared panel: drop the previous chunk's
    /// local rows and (re)build the gather plan. `false` means the sieve
    /// cannot use the panel (no capability, or a row the panel lacks) and
    /// the caller must keep the per-sieve path.
    pub fn begin_shared_chunk(&mut self, panel: &ChunkPanel) -> bool {
        self.local.clear();
        self.local_ids.clear();
        self.rebind_shared(panel)
    }

    /// Rebuild the gather plan mid-chunk (after SieveStreaming++'s
    /// prune/spawn/sort rebuilt the sieve set), keeping the chunk-local
    /// rows already computed this chunk.
    pub fn rebind_shared(&mut self, panel: &ChunkPanel) -> bool {
        let Sieve { oracle, kv_src, local_ids, .. } = self;
        kv_src.clear();
        let n = oracle.len();
        let Some(ps) = oracle.panel_sharing() else {
            return false;
        };
        let ids = ps.summary_row_ids();
        if ids.len() != n {
            return false; // rows predate the store — per-sieve path only
        }
        for &id in ids {
            if let Some(s) = panel.slot(id) {
                kv_src.push(KvSrc::Shared(s));
            } else if let Some(l) = local_ids.iter().position(|&x| x == id) {
                kv_src.push(KvSrc::Local(l as u32));
            } else {
                return false;
            }
        }
        true
    }

    /// Gains for chunk candidates `pos..pos+count`, gathered from the
    /// shared panel (and this sieve's local rows) into `self.scratch`.
    /// Charges exactly `count` queries — bitwise identical to
    /// `peek_gain_batch` over the same candidates.
    pub fn gains_shared(&mut self, panel: &ChunkPanel, pos: usize, count: usize) {
        let Sieve { oracle, scratch, kv_src, local, .. } = self;
        let ps = oracle.panel_sharing().expect("gains_shared: bound by begin_shared_chunk");
        ps.peek_gain_batch_gathered(
            count,
            &mut |t, kv| gather_kv(panel, kv_src, local, pos + t, kv),
            scratch,
        );
    }

    /// Accept chunk row `j` under the shared panel. The oracle accepts
    /// (and interns) the row; its kernel entries for the rest of the chunk
    /// are then bound — from the panel when the row's bits were already
    /// interned there (duplicate acceptance), from an existing local row,
    /// or as a freshly computed chunk-local row (the only kernel work the
    /// shared path adds, `B − j − 1` entries per accept).
    pub fn accept_shared(&mut self, panel: &ChunkPanel, chunk: &[f32], dim: usize, j: usize) {
        let item = &chunk[j * dim..(j + 1) * dim];
        self.oracle.accept(item);
        let width = panel.width();
        let Sieve { oracle, kv_src, local, local_ids, .. } = self;
        let ps = oracle.panel_sharing().expect("accept_shared: bound by begin_shared_chunk");
        let id = *ps.summary_row_ids().last().expect("accept interned a row");
        if let Some(s) = panel.slot(id) {
            kv_src.push(KvSrc::Shared(s));
            return;
        }
        if let Some(l) = local_ids.iter().position(|&x| x == id) {
            kv_src.push(KvSrc::Local(l as u32));
            return;
        }
        let start = local.len();
        local.resize(start + width, 0.0);
        ps.chunk_kernel_row(item, chunk, j + 1, &mut local[start..]);
        local_ids.push(id);
        kv_src.push(KvSrc::Local((start / width) as u32));
    }
}

/// Union of the interned summary-row ids across the sieve oracles that can
/// still query this chunk (non-full), ascending and deduped — the rows the
/// shared chunk panel must cover. `None` when any oracle lacks the
/// panel-sharing capability or holds rows the store never saw (the caller
/// keeps per-sieve panels).
pub(crate) fn union_row_ids<'a, I>(oracles: I, k: usize) -> Option<Vec<u32>>
where
    I: Iterator<Item = &'a mut Box<dyn SubmodularFunction>>,
{
    let mut ids: Vec<u32> = Vec::new();
    for oracle in oracles {
        let n = oracle.len();
        if n >= k {
            continue; // full sieves neither query nor accept
        }
        let ps = oracle.panel_sharing()?;
        let rid = ps.summary_row_ids();
        if rid.len() != n {
            return None;
        }
        ids.extend_from_slice(rid);
    }
    ids.sort_unstable();
    ids.dedup();
    Some(ids)
}

/// Build the shared chunk panel from an already collected id union:
/// `None` when the prototype lacks the [`PanelSharing`] capability or no
/// store is attached (callers then keep per-sieve panels). The one
/// definition behind every algorithm's `build_shared_panel`. `scratch`
/// recycles the previous chunk's panel storage (the algorithms hand each
/// spent panel back via [`PanelScratch::recycle`]).
pub(crate) fn build_union_panel(
    proto: &mut Box<dyn SubmodularFunction>,
    ids: &[u32],
    chunk: &[f32],
    exec: &ExecContext,
    scratch: &mut PanelScratch,
) -> Option<ChunkPanel> {
    let ps = proto.panel_sharing()?;
    ps.row_store()?;
    Some(ps.build_chunk_panel(ids, chunk, exec, scratch))
}

/// Where one solve task's kv rows come from. `Copy`: only shared
/// references and offsets, so the dispatch match can take it by value.
#[derive(Clone, Copy)]
pub(crate) enum SolveSrc<'a> {
    /// Gather from the shared chunk panel + the unit's chunk-local rows;
    /// `from` is the absolute chunk position of `out[0]`.
    Gather { panel: &'a ChunkPanel, kv_src: &'a [KvSrc], local: &'a [f64], from: usize },
    /// Compute kernel rows directly for `items` (`out.len() × dim`,
    /// already offset to the range) — the shard path without a broker.
    Kernel { items: &'a [f32] },
}

/// One (unit × candidate-range) task of the 2-D solve grid: a pure range
/// solve against one unit's factor, writing that range's gains. Disjoint
/// ranges of the same unit share `ps` by `&` — the range solves take
/// `&self` and all mutable state is the task-owned scratch — so the exec
/// pool can schedule them independently and solve work no longer
/// serializes behind the widest unit.
pub(crate) struct SolveTask<'a> {
    pub(crate) ps: &'a dyn PanelSharing,
    pub(crate) src: SolveSrc<'a>,
    pub(crate) out: &'a mut [f64],
    pub(crate) scratch: &'a mut SolveScratch,
}

/// Run a built task grid on the pool (inline when sequential). Gains are
/// range-split-invariant — every candidate's solve reads only shared
/// state — so the split policy moves wall time, never bits.
pub(crate) fn run_solve_tasks(exec: &ExecContext, tasks: &mut [SolveTask<'_>]) {
    exec.map_units(tasks, |t| {
        let count = t.out.len();
        match t.src {
            SolveSrc::Gather { panel, kv_src, local, from } => t.ps.solve_gathered_range(
                count,
                &mut |i, kv| gather_kv(panel, kv_src, local, from + i, kv),
                t.scratch,
                t.out,
            ),
            SolveSrc::Kernel { items } => t.ps.solve_batch_range(items, count, t.scratch, t.out),
        }
    });
}

/// Reusable per-algorithm scratch pool for the 2-D solve grid: one
/// [`SolveScratch`] per in-flight task, grown once and reused across
/// chunks so the grid allocates nothing per chunk beyond its task list.
#[derive(Default)]
pub(crate) struct SolveGrid {
    scratches: Vec<SolveScratch>,
}

impl SolveGrid {
    /// Grow the pool to at least `n` scratches and hand out an iterator.
    pub(crate) fn reserve(&mut self, n: usize) -> std::slice::IterMut<'_, SolveScratch> {
        if self.scratches.len() < n {
            self.scratches.resize_with(n, SolveScratch::default);
        }
        self.scratches.iter_mut()
    }
}

/// Candidate-range length for one unit's run in the 2-D grid: enough
/// ranges that `units` live units can keep `threads` workers busy (~2
/// tasks per worker), floored so per-task overhead stays negligible.
/// When units already outnumber the workers the grain degenerates to one
/// range per unit (the coarse fan-out). Results never depend on the
/// grain — only wall time does.
pub(crate) fn solve_grain(count: usize, units: usize, threads: usize) -> usize {
    if threads <= 1 || count == 0 {
        return count.max(1);
    }
    let ranges_per_unit = (threads * 2).div_ceil(units.max(1)).max(1);
    count.div_ceil(ranges_per_unit).max(16)
}

/// Number of candidate-range tasks one run of `count` candidates splits
/// into under [`solve_grain`] — the precount both grid drivers use to
/// size the scratch pool before building tasks.
pub(crate) fn count_range_tasks(count: usize, units: usize, threads: usize) -> usize {
    count.div_ceil(solve_grain(count, units, threads))
}

/// Split one run's gains buffer into candidate-range tasks and push them
/// onto the grid — the single task-building definition behind
/// [`gather_gains_grid`] and the sharded driver. `src(from, len)` builds
/// the range's kv source (gather or kernel) for the `len` candidates
/// starting at chunk-absolute `from`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn push_range_tasks<'a>(
    tasks: &mut Vec<SolveTask<'a>>,
    scratches: &mut std::slice::IterMut<'a, SolveScratch>,
    ps: &'a dyn PanelSharing,
    gains: &'a mut [f64],
    pos: usize,
    units: usize,
    threads: usize,
    src: impl Fn(usize, usize) -> SolveSrc<'a>,
) {
    let count = gains.len();
    let grain = solve_grain(count, units, threads);
    let mut from = pos;
    for out in gains.chunks_mut(grain) {
        let src = src(from, out.len());
        from += out.len();
        tasks.push(SolveTask { ps, src, out, scratch: scratches.next().expect("pool sized") });
    }
}

/// Phase A of the 2-D solve grid: compute each run's gathered gains
/// (chunk positions `pos..total`) into the run's sieve `scratch`, fanned
/// out as (sieve × candidate-range) tasks on `exec`, then charge each
/// oracle the run's `total − pos` queries — exactly what
/// [`Sieve::gains_shared`] charges, with the solves distributed instead
/// of unit-serial. Callers guarantee every listed sieve is bound to
/// `panel` (gather plan built) and its oracle exposes
/// [`SubmodularFunction::panel_sharing_ref`].
pub(crate) fn gather_gains_grid(
    runs: &mut [(usize, &mut Sieve)],
    panel: &ChunkPanel,
    total: usize,
    exec: &ExecContext,
    pool: &mut SolveGrid,
) {
    let threads = exec.threads();
    let units = runs.len();
    let mut n_tasks = 0usize;
    for (pos, _) in runs.iter() {
        n_tasks += count_range_tasks(total - *pos, units, threads);
    }
    let mut scratches = pool.reserve(n_tasks);
    let mut tasks: Vec<SolveTask<'_>> = Vec::with_capacity(n_tasks);
    for (pos, s) in runs.iter_mut() {
        let count = total - *pos;
        if s.scratch.len() < count {
            s.scratch.resize(count, 0.0);
        }
        let Sieve { oracle, scratch, kv_src, local, .. } = &mut **s;
        let ps = oracle.panel_sharing_ref().expect("grid runs over panel-sharing oracles");
        let (kv_src, local): (&[KvSrc], &[f64]) = (kv_src, local);
        push_range_tasks(
            &mut tasks,
            &mut scratches,
            ps,
            &mut scratch[..count],
            *pos,
            units,
            threads,
            |from, _| SolveSrc::Gather { panel, kv_src, local, from },
        );
    }
    run_solve_tasks(exec, &mut tasks);
    drop(tasks);
    for (pos, s) in runs.iter_mut() {
        let queries = (total - *pos) as u64;
        s.oracle.panel_sharing().expect("checked above").charge(queries, 0);
    }
}

/// The 2-D (sieve × candidate-range) chunk driver for independent-sieve
/// algorithms (SieveStreaming, Salsa): round-synchronized rejection runs
/// whose gains fan out through [`gather_gains_grid`], with each sieve's
/// sequence of runs — gains, first-hit scan, accept, speculative
/// accounting — identical to [`Sieve::offer_batch_shared`] by
/// construction (the gains are range-split-invariant and the scan is the
/// shared `first_hit` closure). Where the coarse fan-out hands one whole
/// chunk×sieve to a worker and serializes behind the widest sieve, the
/// grid keeps every worker busy even when live sieves ≪ threads.
///
/// `first_hit(si, v, oracle, gains, pos)` returns the first would-accept
/// index *relative* to `pos` (chunk-absolute position of `gains[0]`).
/// Returns the speculative query count, or `None` if a live sieve cannot
/// bind to the panel or lacks the shared-borrow capability — the caller
/// then keeps the unit-serial path (no oracle state has been touched:
/// binding only rebuilds chunk-scoped gather plans, exactly like
/// `offer_batch_shared`'s own bind).
#[allow(clippy::too_many_arguments)]
pub(crate) fn offer_chunk_grid(
    sieves: &mut [&mut Sieve],
    panel: &ChunkPanel,
    chunk: &[f32],
    dim: usize,
    k: usize,
    exec: &ExecContext,
    pool: &mut SolveGrid,
    first_hit: impl Fn(usize, f64, &dyn SubmodularFunction, &[f64], usize) -> Option<usize>,
) -> Option<u64> {
    let total = chunk.len() / dim;
    if total == 0 {
        return Some(0);
    }
    let mut need: Vec<bool> = Vec::with_capacity(sieves.len());
    for s in sieves.iter_mut() {
        let live = s.oracle.len() < k;
        if live && (s.oracle.panel_sharing_ref().is_none() || !s.begin_shared_chunk(panel)) {
            return None;
        }
        need.push(live);
    }
    let mut pos = vec![0usize; sieves.len()];
    let mut wasted = 0u64;
    loop {
        // Phase A: fan the invalidated runs out as one task grid.
        let mut runs: Vec<(usize, &mut Sieve)> = sieves
            .iter_mut()
            .enumerate()
            .filter(|(si, _)| need[*si])
            .map(|(si, s)| (pos[si], &mut **s))
            .collect();
        if runs.is_empty() {
            return Some(wasted);
        }
        gather_gains_grid(&mut runs, panel, total, exec, pool);
        drop(runs);
        // Phase B: scan + accept sequentially, in sieve order — the same
        // decisions and accounting as the unit-serial loop.
        for si in 0..sieves.len() {
            if !need[si] {
                continue;
            }
            let count = total - pos[si];
            let s: &mut Sieve = &mut *sieves[si];
            let t = crate::obs::clock();
            let hit = first_hit(si, s.v, s.oracle.as_ref(), &s.scratch[..count], pos[si]);
            s.scan_ns += crate::obs::lap(t);
            if crate::obs::enabled() {
                let tau = sieve_threshold(s.v, s.oracle.current_value(), k, s.oracle.len());
                s.note_run(count, hit, tau);
            }
            match hit {
                Some(j_rel) => {
                    let j = pos[si] + j_rel;
                    s.accept_shared(panel, chunk, dim, j);
                    wasted += (count - (j_rel + 1)) as u64;
                    pos[si] = j + 1;
                    need[si] = s.oracle.len() < k && pos[si] < total;
                }
                None => need[si] = false,
            }
        }
    }
}

/// Aggregate stats over a set of sieves (+ the element counter the caller
/// maintains). `extra_queries` covers bookkeeping queries the algorithm
/// makes outside its sieves (e.g. m-estimation singleton probes).
pub(crate) fn sieve_stats(
    sieves: &[Sieve],
    elements: u64,
    extra_queries: u64,
    peak: &mut usize,
) -> AlgoStats {
    let stored: usize = sieves.iter().map(|s| s.oracle.len()).sum();
    if stored > *peak {
        *peak = stored;
    }
    AlgoStats {
        queries: sieves.iter().map(|s| s.oracle.queries()).sum::<u64>() + extra_queries,
        // Per-sieve kernel work only; callers add their shared-panel and
        // retired-sieve contributions on top.
        kernel_evals: sieves.iter().map(|s| s.oracle.kernel_evals()).sum::<u64>(),
        elements,
        stored,
        peak_stored: *peak,
        instances: sieves.len(),
        wall_kernel_ns: sieves.iter().map(|s| s.oracle.wall_kernel_ns()).sum(),
        wall_solve_ns: sieves.iter().map(|s| s.oracle.wall_solve_ns()).sum(),
        wall_scan_ns: sieves.iter().map(|s| s.scan_ns).sum(),
        accepts: sieves.iter().map(|s| s.accepts).sum(),
        rejects: sieves.iter().map(|s| s.rejects).sum(),
        defers: 0,
        threshold_moves: 0,
    }
}

/// Assign roster tags `first, first+1, ..` to `sieves` in order and
/// return the next unused tag. Tags identify sieves in the decision-event
/// log ([`crate::obs::events`]); they carry no algorithmic meaning.
pub(crate) fn tag_sieves(sieves: &mut [Sieve], first: u32) -> u32 {
    let mut next = first;
    for s in sieves.iter_mut() {
        s.tag = next;
        next += 1;
    }
    next
}

#[cfg(test)]
pub(crate) mod testkit {
    //! Shared test fixtures for the algorithm suite.
    use crate::data::synthetic::{Mixture, MixtureSource};
    use crate::data::Dataset;
    use crate::data::StreamSource;
    use crate::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
    use crate::util::rng::Rng;

    pub const DIM: usize = 6;

    /// A small clustered dataset where diverse summaries clearly beat
    /// arbitrary ones.
    pub fn clustered(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::seed_from(seed);
        let mix = Mixture::random(DIM, 5, 6.0, 0.4, &mut rng);
        let mut ds = MixtureSource::new(mix, n, seed).materialize("clustered", n);
        ds.normalize();
        ds
    }

    pub fn oracle(k: usize) -> Box<dyn SubmodularFunction> {
        Box::new(NativeLogDet::new(LogDetConfig::with_gamma(DIM, k, 1.0, 1.0)))
    }

    /// Run a streaming algorithm over a dataset once.
    pub fn run(algo: &mut dyn super::StreamingAlgorithm, ds: &Dataset) {
        for row in ds.iter() {
            algo.process(row);
        }
        algo.finalize();
    }

    /// Greedy reference value for relative-performance assertions.
    pub fn greedy_value(ds: &Dataset, k: usize) -> f64 {
        let mut g = super::Greedy::new(oracle(k), k);
        g.fit(ds);
        use super::StreamingAlgorithm;
        g.value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sieve_threshold_formula() {
        // v = 2, f(S) = 0, K = 4, |S| = 0 -> (1 - 0)/4 = 0.25
        assert!((sieve_threshold(2.0, 0.0, 4, 0) - 0.25).abs() < 1e-12);
        // As f(S) approaches v/2 the threshold drops to 0.
        assert!(sieve_threshold(2.0, 1.0, 4, 2) == 0.0);
        // Past v/2 it goes negative (accept anything) — the sieve is "done".
        assert!(sieve_threshold(2.0, 1.5, 4, 2) < 0.0);
    }

    #[test]
    fn sieve_offer_respects_capacity() {
        let proto = testkit::oracle(1);
        let mut sieve = Sieve::new(0.1, proto.as_ref());
        let item = vec![0.0f32; testkit::DIM];
        assert!(sieve.offer(&item, 1));
        assert!(!sieve.offer(&item, 1), "full sieve must reject");
    }
}
