//! The streaming submodular maximization algorithm family (paper Table 1).
//!
//! Every algorithm implements [`StreamingAlgorithm`]: elements arrive one at
//! a time through [`StreamingAlgorithm::process`]; the algorithm owns one or
//! more [`SubmodularFunction`] oracles (sieves) and decides, per element,
//! whether to insert/swap/reject. Resource accounting matches the paper:
//! *memory* = peak stored elements across all sieves, *queries* = total
//! oracle evaluations.
//!
//! | Algorithm | Ratio | Memory | Queries/elem |
//! |---|---|---|---|
//! | [`Greedy`] (offline) | 1−1/e | O(K) | O(1) |
//! | [`StreamGreedy`] | ½−ε (multi-pass) | O(K) | O(K) |
//! | [`RandomReservoir`] | ¼ (expect.) | O(K) | O(1) |
//! | [`PreemptionStreaming`] | ¼ | O(K) | O(K) |
//! | [`IndependentSetImprovement`] | ¼ | O(K) | O(1) |
//! | [`SieveStreaming`] | ½−ε | O(K log K / ε) | O(log K / ε) |
//! | [`SieveStreamingPP`] | ½−ε | O(K/ε) | O(log K / ε) |
//! | [`Salsa`] | ½−ε | O(K log K / ε) | O(log K / ε) |
//! | [`QuickStream`] | 1/(4c)−ε | O(cK log K log 1/ε) | O(⌈1/c⌉+c) |
//! | [`ThreeSieves`] | (1−ε)(1−1/e) w.p. (1−α)^K | O(K) | O(1) |

pub mod greedy;
pub mod independent_set;
pub mod preemption;
pub mod quick_stream;
pub mod random;
pub mod salsa;
pub mod sieve_streaming;
pub mod sieve_streaming_pp;
pub mod stream_greedy;
pub mod three_sieves;

pub use greedy::Greedy;
pub use independent_set::IndependentSetImprovement;
pub use preemption::PreemptionStreaming;
pub use quick_stream::QuickStream;
pub use random::RandomReservoir;
pub use salsa::Salsa;
pub use sieve_streaming::SieveStreaming;
pub use sieve_streaming_pp::SieveStreamingPP;
pub use stream_greedy::StreamGreedy;
pub use three_sieves::ThreeSieves;

use crate::exec::ExecContext;
use crate::functions::SubmodularFunction;
use crate::metrics::AlgoStats;
use crate::util::json::Json;

/// A single-pass streaming summary-selection algorithm.
///
/// Not `Send` (see [`SubmodularFunction`]); the coordinator ships
/// constructor closures to worker threads instead of built algorithms.
pub trait StreamingAlgorithm {
    /// Display name (stable across runs; used in result CSVs).
    fn name(&self) -> String;

    /// Observe one stream element.
    fn process(&mut self, item: &[f32]);

    /// Observe a chunk of stream elements, flat row-major `count × dim()`.
    ///
    /// Contract: semantically identical to calling
    /// [`process`](Self::process) on each row in order — same summary, same
    /// value, same resource accounting (`rust/tests/batch_parity.rs` pins
    /// this). The default does exactly that; the threshold family overrides
    /// it to evaluate gains for the whole chunk against the current summary
    /// in one oracle call (`SubmodularFunction::peek_gain_batch`), which is
    /// where the batched-ingestion throughput comes from. Speculative gain
    /// evaluations past the point where the summary changes are tracked by
    /// the overrides and subtracted from the reported query stats, so
    /// `stats().queries` keeps the paper's per-element accounting.
    fn process_batch(&mut self, chunk: &[f32]) {
        let d = self.dim();
        debug_assert_eq!(chunk.len() % d, 0, "chunk not row-aligned");
        for row in chunk.chunks_exact(d) {
            self.process(row);
        }
    }

    /// Called once after the stream ends (QuickStream flushes its buffer,
    /// others are no-ops).
    fn finalize(&mut self) {}

    /// Install a parallel execution context (see [`crate::exec`]).
    ///
    /// Algorithms whose batched work decomposes into independent coarse
    /// units — ShardedThreeSieves shards, SieveStreaming/Salsa sieves —
    /// override this to fan [`process_batch`](Self::process_batch) out
    /// across the context's worker pool. Overrides must (a) keep results
    /// bit-identical to sequential execution at every thread count
    /// (`rust/tests/exec_parity.rs`) and (b) ignore the pool unless their
    /// oracle reports
    /// [`parallel_safe`](crate::functions::SubmodularFunction::parallel_safe).
    /// The default ignores the context (scalar algorithms have no units
    /// to fan out).
    fn set_exec(&mut self, _exec: ExecContext) {}

    /// Current best function value f(S).
    fn value(&self) -> f64;

    /// Current best summary, flat row-major `summary_len() × dim()`.
    fn summary(&self) -> Vec<f32>;

    /// Elements in the current best summary.
    fn summary_len(&self) -> usize;

    /// Feature dimensionality.
    fn dim(&self) -> usize;

    /// Target cardinality K.
    fn k(&self) -> usize;

    /// Resource statistics so far.
    fn stats(&self) -> AlgoStats;

    /// Clear all state (drift re-selection hook from the coordinator).
    fn reset(&mut self);

    /// True once the best summary holds K elements.
    fn is_full(&self) -> bool {
        self.summary_len() >= self.k()
    }

    /// Opaque, JSON-serializable snapshot of every piece of run state the
    /// summary itself does not capture (active threshold, rejection
    /// counter, element/query accounting, …), or `None` when the algorithm
    /// cannot be resumed from a checkpoint.
    ///
    /// Contract: feeding the snapshot and the matching summary back through
    /// [`restore_state`](Self::restore_state) on a freshly built instance
    /// of the same configuration must reproduce the exact pre-snapshot
    /// state — continuing the stream afterwards yields **bit-identical**
    /// summaries, values and [`stats`](Self::stats) to a run that never
    /// paused (`rust/tests/service_integration.rs` pins this for the
    /// session manager's evict → re-`OPEN` path). The default returns
    /// `None`: algorithms are summary-only checkpointable unless they opt
    /// in. All f64 fields survive the JSON text roundtrip bit-for-bit
    /// (shortest-roundtrip formatting), so implementations may store raw
    /// threshold values directly.
    fn snapshot_state(&self) -> Option<Json> {
        None
    }

    /// Restore from a [`snapshot_state`](Self::snapshot_state) blob plus
    /// the checkpointed summary rows (row-major, acceptance order). Must
    /// reject mismatched configurations (k, dim, hyperparameters) with a
    /// descriptive error rather than resuming into a different run.
    fn restore_state(&mut self, _state: &Json, _summary: &[f32]) -> Result<(), String> {
        Err(format!("{} does not support checkpoint resume", self.name()))
    }
}

/// The SieveStreaming insertion rule shared by the threshold family
/// (SieveStreaming, SieveStreaming++, Salsa's sieve rule, ThreeSieves):
///
/// accept e into S_v iff `Δf(e|S) ≥ (v/2 − f(S)) / (K − |S|)`.
#[inline]
pub(crate) fn sieve_threshold(v: f64, f_s: f64, k: usize, len: usize) -> f64 {
    debug_assert!(len < k);
    (v / 2.0 - f_s) / (k - len) as f64
}

/// One sieve: a candidate OPT estimate `v` plus its own oracle.
pub(crate) struct Sieve {
    pub v: f64,
    pub oracle: Box<dyn SubmodularFunction>,
    /// Gain-panel scratch for [`offer_batch`](Self::offer_batch) — owned
    /// per sieve so the exec pool's fan-out needs no shared buffers and
    /// the hot path allocates once, not once per chunk.
    scratch: Vec<f64>,
}

impl Sieve {
    pub fn new(v: f64, proto: &dyn SubmodularFunction) -> Self {
        Sieve { v, oracle: proto.clone_empty(), scratch: Vec::new() }
    }

    /// Apply the sieve rule; returns true if the item was accepted.
    pub fn offer(&mut self, item: &[f32], k: usize) -> bool {
        let len = self.oracle.len();
        if len >= k {
            return false;
        }
        let thresh = sieve_threshold(self.v, self.oracle.current_value(), k, len);
        let gain = self.oracle.peek_gain(item);
        if gain >= thresh {
            self.oracle.accept(item);
            true
        } else {
            false
        }
    }

    /// Batched [`offer`](Self::offer) over a whole chunk (row-major
    /// `count × dim`): evaluate the remaining items' gains against the
    /// current summary in one oracle call, accept the first item that
    /// passes the sieve rule, then re-batch from the next item (gains
    /// computed before an accept are stale after it).
    ///
    /// Bit-identical to offering each row in order: within a rejection run
    /// the threshold is constant (`v`, `f(S)` and `|S|` only move on
    /// accept), so the first passing index is the same item the scalar
    /// loop would accept. Returns the number of *speculative* gain
    /// evaluations — gains the scalar path would not have computed because
    /// they lie past an acceptance — which the caller subtracts from its
    /// query stats to keep the paper's per-element accounting.
    pub fn offer_batch(&mut self, chunk: &[f32], dim: usize, k: usize) -> u64 {
        let total = chunk.len() / dim;
        let mut pos = 0usize;
        let mut wasted = 0u64;
        while pos < total {
            if self.oracle.len() >= k {
                return wasted; // full: the scalar path stops querying too
            }
            let remaining = total - pos;
            self.oracle.peek_gain_batch(&chunk[pos * dim..], remaining, &mut self.scratch);
            let len = self.oracle.len();
            let thresh = sieve_threshold(self.v, self.oracle.current_value(), k, len);
            match self.scratch.iter().position(|&g| g >= thresh) {
                Some(j) => {
                    self.oracle.accept(&chunk[(pos + j) * dim..(pos + j + 1) * dim]);
                    wasted += (remaining - (j + 1)) as u64;
                    pos += j + 1;
                }
                None => return wasted,
            }
        }
        wasted
    }
}

/// Aggregate stats over a set of sieves (+ the element counter the caller
/// maintains). `extra_queries` covers bookkeeping queries the algorithm
/// makes outside its sieves (e.g. m-estimation singleton probes).
pub(crate) fn sieve_stats(
    sieves: &[Sieve],
    elements: u64,
    extra_queries: u64,
    peak: &mut usize,
) -> AlgoStats {
    let stored: usize = sieves.iter().map(|s| s.oracle.len()).sum();
    if stored > *peak {
        *peak = stored;
    }
    AlgoStats {
        queries: sieves.iter().map(|s| s.oracle.queries()).sum::<u64>() + extra_queries,
        elements,
        stored,
        peak_stored: *peak,
        instances: sieves.len(),
    }
}

#[cfg(test)]
pub(crate) mod testkit {
    //! Shared test fixtures for the algorithm suite.
    use crate::data::synthetic::{Mixture, MixtureSource};
    use crate::data::Dataset;
    use crate::data::StreamSource;
    use crate::functions::{LogDetConfig, NativeLogDet, SubmodularFunction};
    use crate::util::rng::Rng;

    pub const DIM: usize = 6;

    /// A small clustered dataset where diverse summaries clearly beat
    /// arbitrary ones.
    pub fn clustered(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::seed_from(seed);
        let mix = Mixture::random(DIM, 5, 6.0, 0.4, &mut rng);
        let mut ds = MixtureSource::new(mix, n, seed).materialize("clustered", n);
        ds.normalize();
        ds
    }

    pub fn oracle(k: usize) -> Box<dyn SubmodularFunction> {
        Box::new(NativeLogDet::new(LogDetConfig::with_gamma(DIM, k, 1.0, 1.0)))
    }

    /// Run a streaming algorithm over a dataset once.
    pub fn run(algo: &mut dyn super::StreamingAlgorithm, ds: &Dataset) {
        for row in ds.iter() {
            algo.process(row);
        }
        algo.finalize();
    }

    /// Greedy reference value for relative-performance assertions.
    pub fn greedy_value(ds: &Dataset, k: usize) -> f64 {
        let mut g = super::Greedy::new(oracle(k), k);
        g.fit(ds);
        use super::StreamingAlgorithm;
        g.value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sieve_threshold_formula() {
        // v = 2, f(S) = 0, K = 4, |S| = 0 -> (1 - 0)/4 = 0.25
        assert!((sieve_threshold(2.0, 0.0, 4, 0) - 0.25).abs() < 1e-12);
        // As f(S) approaches v/2 the threshold drops to 0.
        assert!(sieve_threshold(2.0, 1.0, 4, 2) == 0.0);
        // Past v/2 it goes negative (accept anything) — the sieve is "done".
        assert!(sieve_threshold(2.0, 1.5, 4, 2) < 0.0);
    }

    #[test]
    fn sieve_offer_respects_capacity() {
        let proto = testkit::oracle(1);
        let mut sieve = Sieve::new(0.1, proto.as_ref());
        let item = vec![0.0f32; testkit::DIM];
        assert!(sieve.offer(&item, 1));
        assert!(!sieve.offer(&item, 1), "full sieve must reject");
    }
}
