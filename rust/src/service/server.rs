//! The std-only TCP front end: an accept loop that dispatches connections
//! onto the exec worker pool, plus the in-process [`Client`] used by
//! tests, benches and the CLI smoke path.
//!
//! ## Concurrency model
//!
//! * The accept thread owns the listener (non-blocking, polled) and runs
//!   the idle-eviction sweep between accepts.
//! * Each connection becomes one [`WorkerPool::spawn`]ed job when the
//!   service is configured with a pool (`parallelism != off`) — so at most
//!   `threads` connections are served concurrently and the rest queue,
//!   which is the connection-level admission control. With `off`, each
//!   connection gets a dedicated thread instead.
//! * Handlers poll with a short read timeout and re-check the shutdown
//!   flag, so [`ServerHandle::shutdown`] quiesces in bounded time:
//!   flag → accept loop exits → pool drops → workers drain → remaining
//!   sessions checkpoint.
//!
//! [`WorkerPool::spawn`]: crate::exec::WorkerPool::spawn

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServiceConfig;
use crate::exec::ExecContext;

use super::protocol::{
    ErrorCode, MetricsSnapshot, PushBody, PushReply, Request, Response, SessionSpec, StatsReply,
    SummaryReply, WatchFrame, WatchMode, MAX_LINE_BYTES,
};
use super::sessions::SessionManager;

const READ_POLL: Duration = Duration::from_millis(100);
const SWEEP_EVERY: Duration = Duration::from_millis(250);

/// Entry point for the network service.
pub struct Server;

impl Server {
    /// Bind `listen` (e.g. `127.0.0.1:7777`, port 0 for ephemeral) and
    /// start accepting. Returns immediately; the accept loop runs on its
    /// own thread until [`ServerHandle::shutdown`].
    pub fn start(cfg: ServiceConfig, listen: &str) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(listen)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let manager = Arc::new(SessionManager::new(cfg.clone()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let exec = ExecContext::new(cfg.parallelism);
        let accept = {
            let manager = Arc::clone(&manager);
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("ts-accept".into())
                .spawn(move || accept_loop(listener, exec, manager, shutdown))?
        };
        Ok(ServerHandle { addr, manager, shutdown, accept: Some(accept) })
    }
}

/// A running service instance.
pub struct ServerHandle {
    addr: SocketAddr,
    manager: Arc<SessionManager>,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Direct handle to the session manager (in-process harnesses and the
    /// CLI's periodic metrics print).
    pub fn manager(&self) -> Arc<SessionManager> {
        Arc::clone(&self.manager)
    }

    /// Graceful shutdown: stop accepting, drain pool-dispatched handlers,
    /// checkpoint every remaining session, and return the final metrics
    /// snapshot (taken before the sessions close).
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(accept) = self.accept.take() {
            // Joining the accept thread also drops its ExecContext, which
            // (as the last pool reference) joins the workers and with them
            // every pool-dispatched connection handler.
            let _ = accept.join();
        }
        let snapshot = self.manager.metrics();
        self.manager.shutdown();
        snapshot
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    exec: ExecContext,
    manager: Arc<SessionManager>,
    shutdown: Arc<AtomicBool>,
) {
    let mut last_sweep = Instant::now();
    // Handlers running on dedicated threads (no pool) are tracked so the
    // shutdown path can join them — otherwise an in-flight PUSH could race
    // the final session checkpoints. Pool-dispatched handlers need no
    // tracking: dropping `exec` below joins the workers.
    let mut detached: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        if last_sweep.elapsed() >= SWEEP_EVERY {
            manager.evict_idle();
            detached.retain(|h| !h.is_finished());
            last_sweep = Instant::now();
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let manager = Arc::clone(&manager);
                let shutdown = Arc::clone(&shutdown);
                let job = move || handle_conn(stream, &manager, &shutdown);
                match exec.pool_handle() {
                    Some(pool) => pool.spawn(job),
                    None => {
                        if let Ok(handle) =
                            std::thread::Builder::new().name("ts-conn".into()).spawn(job)
                        {
                            detached.push(handle);
                        }
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    // Shutdown: handlers observe the flag within one read-timeout; joining
    // them (and, via `exec`'s drop, the pool workers) guarantees no PUSH
    // is still mutating a session when the manager checkpoints.
    for handle in detached {
        let _ = handle.join();
    }
    drop(exec);
}

enum LineStatus {
    /// A complete line is in the buffer.
    Line,
    /// Read timed out with no complete line yet — partial data stays in
    /// `buf`; call again to continue. This is the `WATCH` tick hook: the
    /// serve loop emits due frames between polls.
    Idle,
    /// Peer closed the connection cleanly.
    Eof,
    /// Shutdown flag observed while idle.
    ShutDown,
    /// Line exceeded [`MAX_LINE_BYTES`].
    TooLong,
}

/// Read one `\n`-terminated line into `buf` (delimiter stripped), bounded
/// by [`MAX_LINE_BYTES`] and interruptible by the shutdown flag. Partial
/// data survives read timeouts — unlike `read_line`, which discards
/// buffered bytes when the underlying read errors. Each read timeout
/// surfaces as [`LineStatus::Idle`] so the caller can interleave periodic
/// work (watch frames) with the poll.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    shutdown: &AtomicBool,
) -> std::io::Result<LineStatus> {
    loop {
        let consumed = {
            let available = match reader.fill_buf() {
                Ok(available) => available,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if shutdown.load(Ordering::SeqCst) {
                        return Ok(LineStatus::ShutDown);
                    }
                    return Ok(LineStatus::Idle);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            if available.is_empty() {
                // EOF: a final unterminated line still counts.
                return Ok(if buf.is_empty() { LineStatus::Eof } else { LineStatus::Line });
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    buf.extend_from_slice(&available[..pos]);
                    reader.consume(pos + 1);
                    return Ok(LineStatus::Line);
                }
                None => {
                    buf.extend_from_slice(available);
                    available.len()
                }
            }
        };
        reader.consume(consumed);
        if buf.len() > MAX_LINE_BYTES {
            return Ok(LineStatus::TooLong);
        }
    }
}

fn write_reply(stream: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    // Reply-side fault: the request was already dispatched, so a reset
    // here models the worst reconnect case — applied but unacknowledged.
    if matches!(
        crate::fault::check(crate::fault::site::CONN_WRITE),
        Some(crate::fault::FaultKind::ConnReset | crate::fault::FaultKind::IoError)
    ) {
        return Err(crate::fault::io_error(std::io::ErrorKind::BrokenPipe));
    }
    let mut line = resp.to_line();
    line.push('\n');
    stream.write_all(line.as_bytes())
}

/// Per-connection `WATCH` subscription. Frames are generated on the
/// connection's own thread between read polls, so a watcher never blocks
/// `PUSH` traffic on other connections. The pending-frame queue is
/// bounded at **one**: if the connection was busy (or the consumer slow)
/// past a frame boundary, the missed frames are coalesced into the next
/// one — totals are cumulative, so the survivor subsumes them — and
/// counted in the frame's `dropped=` field.
struct WatchState {
    interval: Duration,
    mode: WatchMode,
    seq: u64,
    dropped: u64,
    next_due: Instant,
}

impl WatchState {
    fn new(interval_ms: u64, mode: WatchMode) -> WatchState {
        // Clamp to the read-poll tick: finer intervals can't be honored.
        let interval = Duration::from_millis(interval_ms).max(READ_POLL);
        WatchState { interval, mode, seq: 0, dropped: 0, next_due: Instant::now() + interval }
    }

    /// Emit at most one frame if a boundary has passed, coalescing any
    /// further missed boundaries into `dropped`.
    fn emit_due(&mut self, writer: &mut TcpStream) -> std::io::Result<()> {
        let now = Instant::now();
        if now < self.next_due {
            return Ok(());
        }
        let missed = (now.duration_since(self.next_due).as_nanos()
            / self.interval.as_nanos().max(1)) as u64;
        self.dropped += missed;
        self.next_due = now + self.interval;
        let frame = WatchFrame {
            seq: self.seq,
            dropped: self.dropped,
            events: matches!(self.mode, WatchMode::Events | WatchMode::All)
                .then(crate::obs::events::totals),
            hists: matches!(self.mode, WatchMode::Hist | WatchMode::All)
                .then(crate::obs::histogram_snapshots),
        };
        self.seq += 1;
        let mut line = frame.to_line();
        line.push('\n');
        writer.write_all(line.as_bytes())
    }
}

/// Serve one connection to completion (EOF, `QUIT`, IO error or service
/// shutdown). Never panics on malformed input — every parse failure turns
/// into an `ERR` reply.
fn handle_conn(stream: TcpStream, manager: &Arc<SessionManager>, shutdown: &Arc<AtomicBool>) {
    let _ = serve_conn(stream, manager, shutdown);
}

fn serve_conn(
    stream: TcpStream,
    manager: &Arc<SessionManager>,
    shutdown: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(READ_POLL))?;
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    let mut watch: Option<WatchState> = None;
    loop {
        buf.clear();
        // Poll for a complete line; on idle ticks, push any due frame so
        // a silent subscriber still streams (requests on this connection
        // keep working — frames interleave between replies, never inside
        // them).
        let status = loop {
            match read_line_bounded(&mut reader, &mut buf, shutdown)? {
                LineStatus::Idle => {
                    if let Some(w) = watch.as_mut() {
                        w.emit_due(&mut writer)?;
                    }
                }
                other => break other,
            }
        };
        match status {
            LineStatus::Eof | LineStatus::ShutDown => return Ok(()),
            LineStatus::TooLong => {
                let resp = Response::error(
                    ErrorCode::BadRequest,
                    format!("line exceeds {MAX_LINE_BYTES} bytes"),
                );
                write_reply(&mut writer, &resp)?;
                return Ok(()); // framing is unrecoverable mid-line
            }
            LineStatus::Line | LineStatus::Idle => {}
        }
        let text = String::from_utf8_lossy(&buf);
        let line = text.trim();
        if line.is_empty() {
            continue;
        }
        // Read-side fault, checked once per complete request line (never
        // per poll tick, so a seeded schedule counts requests, not time).
        // A reset fires BEFORE dispatch: the request is dropped whole and
        // a client retry cannot double-apply it.
        match crate::fault::check(crate::fault::site::CONN_READ) {
            Some(crate::fault::FaultKind::SlowRead { ms }) => {
                std::thread::sleep(Duration::from_millis(ms));
            }
            Some(crate::fault::FaultKind::ConnReset | crate::fault::FaultKind::IoError) => {
                return Err(crate::fault::io_error(std::io::ErrorKind::ConnectionReset));
            }
            _ => {}
        }
        let resp = match Request::parse(line) {
            Ok(Request::Watch { interval_ms, mode }) => {
                // A second WATCH retunes the subscription in place.
                let w = WatchState::new(interval_ms, mode);
                let resp = Response::Watching {
                    interval_ms: w.interval.as_millis() as u64,
                    mode,
                };
                watch = Some(w);
                resp
            }
            Ok(req) => {
                let resp = manager.execute(&req);
                if matches!(req, Request::Quit) {
                    write_reply(&mut writer, &resp)?;
                    return Ok(());
                }
                resp
            }
            Err((code, msg)) => Response::error(code, msg),
        };
        write_reply(&mut writer, &resp)?;
    }
}

// ---------------------------------------------------------------------------
// Client

/// Client-side failure.
#[derive(Debug)]
pub enum ClientError {
    Io(std::io::Error),
    /// The reply line did not parse.
    Protocol(String),
    /// The server answered with an `ERR` reply.
    Server { code: ErrorCode, message: String },
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Protocol(msg) => write!(f, "protocol: {msg}"),
            ClientError::Server { code, message } => {
                write!(f, "server error [{}]: {message}", code.as_str())
            }
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

/// Deterministic client-side retry schedule: capped exponential backoff
/// with NO jitter (two runs of the same fault plan retry at the same
/// instants), bounded both by attempt count and a per-operation deadline.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Additional attempts after the first (0 = fail fast).
    pub max_retries: u32,
    /// Delay before the first retry; doubles each attempt.
    pub base_delay: Duration,
    /// Ceiling the exponential curve saturates at.
    pub max_delay: Duration,
    /// Wall-clock budget for one operation across all its attempts; also
    /// installed as the socket read timeout so a wedged server cannot
    /// stall an operation past it.
    pub op_deadline: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 4,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_millis(500),
            op_deadline: Duration::from_secs(10),
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (0-based):
    /// `min(base · 2^attempt, max)`.
    pub fn delay(&self, attempt: u32) -> Duration {
        self.base_delay.saturating_mul(1u32 << attempt.min(16)).min(self.max_delay)
    }
}

const CONN_CLOSED: &str = "connection closed by server";

/// The session id a request addresses, when resuming it could help.
fn resumable_id(req: &Request) -> Option<&str> {
    match req {
        Request::Push { id, .. } | Request::Summary { id } | Request::Stats { id } => Some(id),
        _ => None,
    }
}

/// Blocking line-protocol client — one TCP connection, synchronous
/// request/response. Used by the integration suite, the throughput bench
/// and the CI smoke job; doubles as the reference protocol implementation
/// for external clients.
///
/// With [`Client::with_retry`] the client survives connection loss and
/// server restarts: transport errors reconnect and re-send on the
/// deterministic [`RetryPolicy`] schedule, and an `ERR no-session` for a
/// session this client opened triggers one re-`OPEN` with the remembered
/// spec — the server restores the checkpoint bit-identically, so the
/// stream continues as if the fault never happened. Retries are
/// at-least-once: a reply lost *after* dispatch (reply-side reset) is
/// re-sent, which re-applies a non-idempotent `PUSH` — pair retries with
/// deduplication upstream if that matters, or accept the paper's
/// streaming semantics where re-processing a batch is detectable by the
/// element counters.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Peer address, remembered for reconnects.
    addr: Option<SocketAddr>,
    retry: Option<RetryPolicy>,
    /// Specs of sessions this client opened, for resume-on-reconnect.
    specs: std::collections::HashMap<String, SessionSpec>,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let addr = stream.peer_addr().ok();
        let writer = stream.try_clone()?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer,
            addr,
            retry: None,
            specs: std::collections::HashMap::new(),
        })
    }

    /// Enable retries. Installs `op_deadline` as the socket read timeout.
    pub fn with_retry(mut self, policy: RetryPolicy) -> Client {
        let _ = self.reader.get_ref().set_read_timeout(Some(policy.op_deadline));
        self.retry = Some(policy);
        self
    }

    /// Drop the current stream and dial the remembered address again.
    fn reconnect(&mut self) -> std::io::Result<()> {
        let addr = self.addr.ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::NotConnected, "peer address unknown")
        })?;
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        if let Some(policy) = &self.retry {
            let _ = stream.set_read_timeout(Some(policy.op_deadline));
        }
        self.writer = stream.try_clone()?;
        self.reader = BufReader::new(stream);
        Ok(())
    }

    /// Send one request and read its reply. `ERR` replies come back as
    /// `Ok(Response::Error { .. })`; use the typed helpers to get them as
    /// [`ClientError::Server`]. With a [`RetryPolicy`] set, transport
    /// failures reconnect and re-send within the policy's budget.
    pub fn request(&mut self, req: &Request) -> Result<Response, ClientError> {
        match self.retry.clone() {
            None => self.request_once(req),
            Some(policy) => self.request_with_retry(req, &policy),
        }
    }

    fn request_once(&mut self, req: &Request) -> Result<Response, ClientError> {
        let mut line = req.to_line();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut buf = Vec::new();
        self.reader.read_until(b'\n', &mut buf)?;
        if buf.is_empty() {
            return Err(ClientError::Protocol(CONN_CLOSED.into()));
        }
        let text = String::from_utf8_lossy(&buf);
        Response::parse(text.trim_end_matches(['\r', '\n'])).map_err(ClientError::Protocol)
    }

    fn request_with_retry(
        &mut self,
        req: &Request,
        policy: &RetryPolicy,
    ) -> Result<Response, ClientError> {
        let start = Instant::now();
        let mut attempt = 0u32;
        // One resume per operation: a second no-session after a successful
        // re-OPEN means the server truly lost the state — surface it.
        let mut resumed = false;
        loop {
            let err = match self.request_once(req) {
                Ok(Response::Error { code: ErrorCode::NoSession, message })
                    if !resumed
                        && resumable_id(req).is_some_and(|id| self.specs.contains_key(id)) =>
                {
                    // The server restarted (connection loss closed nothing:
                    // sessions only vanish with their process). Re-OPEN with
                    // the remembered spec: restore from checkpoint is
                    // bit-identical, so the stream just continues.
                    resumed = true;
                    let id = resumable_id(req).unwrap().to_string();
                    let spec = self.specs[&id].clone();
                    match self.request_once(&Request::Open { id, spec }) {
                        Ok(Response::Opened { .. }) => continue,
                        Ok(_) | Err(_) => {
                            return Ok(Response::Error { code: ErrorCode::NoSession, message })
                        }
                    }
                }
                Ok(resp) => return Ok(resp),
                // Transport loss (including our own clean-close sentinel)
                // is the retryable class; a reply that *parsed* wrong is
                // not — re-sending into a desynced stream compounds it.
                Err(ClientError::Io(e)) => ClientError::Io(e),
                Err(ClientError::Protocol(msg)) if msg == CONN_CLOSED => {
                    ClientError::Protocol(msg)
                }
                Err(other) => return Err(other),
            };
            if attempt >= policy.max_retries || start.elapsed() >= policy.op_deadline {
                return Err(err);
            }
            std::thread::sleep(policy.delay(attempt));
            attempt += 1;
            // A failed reconnect is not fatal here: the next request_once
            // fails fast on the dead stream and burns one more attempt.
            let _ = self.reconnect();
        }
    }

    fn expect<T>(
        &mut self,
        req: &Request,
        extract: impl FnOnce(Response) -> Result<T, Response>,
    ) -> Result<T, ClientError> {
        match self.request(req)? {
            Response::Error { code, message } => Err(ClientError::Server { code, message }),
            other => extract(other)
                .map_err(|resp| ClientError::Protocol(format!("unexpected reply {resp:?}"))),
        }
    }

    /// `OPEN`; returns whether the session resumed from a checkpoint. The
    /// spec is remembered so a retrying client can re-`OPEN` (resume) the
    /// session after a server restart.
    pub fn open(&mut self, id: &str, spec: &SessionSpec) -> Result<bool, ClientError> {
        let resumed =
            self.expect(&Request::Open { id: id.into(), spec: spec.clone() }, |r| match r {
                Response::Opened { resumed, .. } => Ok(resumed),
                other => Err(other),
            })?;
        self.specs.insert(id.to_string(), spec.clone());
        Ok(resumed)
    }

    /// `PUSH` in CSV form: `rows` is flat row-major `count × dim`.
    pub fn push_rows(
        &mut self,
        id: &str,
        rows: &[f32],
        dim: usize,
    ) -> Result<PushReply, ClientError> {
        let body = PushBody::Rows(rows.chunks(dim).map(<[f32]>::to_vec).collect());
        self.push(id, body)
    }

    /// `PUSH` in packed (base64) form: `rows` is flat row-major.
    pub fn push_packed(&mut self, id: &str, rows: &[f32]) -> Result<PushReply, ClientError> {
        self.push(id, PushBody::Packed(rows.to_vec()))
    }

    pub fn push(&mut self, id: &str, body: PushBody) -> Result<PushReply, ClientError> {
        self.expect(&Request::Push { id: id.into(), body }, |r| match r {
            Response::Pushed { reply, .. } => Ok(reply),
            other => Err(other),
        })
    }

    pub fn summary(&mut self, id: &str) -> Result<SummaryReply, ClientError> {
        self.expect(&Request::Summary { id: id.into() }, |r| match r {
            Response::SummaryData { reply, .. } => Ok(reply),
            other => Err(other),
        })
    }

    pub fn stats(&mut self, id: &str) -> Result<StatsReply, ClientError> {
        self.expect(&Request::Stats { id: id.into() }, |r| match r {
            Response::StatsData { reply, .. } => Ok(reply),
            other => Err(other),
        })
    }

    /// `CLOSE`; returns whether a checkpoint was written. Forgets the
    /// remembered spec — a closed session must not be auto-resurrected.
    pub fn close(&mut self, id: &str, discard: bool) -> Result<bool, ClientError> {
        let checkpointed =
            self.expect(&Request::Close { id: id.into(), discard }, |r| match r {
                Response::Closed { checkpointed, .. } => Ok(checkpointed),
                other => Err(other),
            })?;
        self.specs.remove(id);
        Ok(checkpointed)
    }

    pub fn metrics(&mut self) -> Result<MetricsSnapshot, ClientError> {
        self.expect(&Request::Metrics, |r| match r {
            Response::MetricsData(m) => Ok(m),
            other => Err(other),
        })
    }

    /// `METRICS HIST`: every registered latency histogram's summary.
    pub fn metrics_hist(&mut self) -> Result<Vec<crate::obs::HistSnapshot>, ClientError> {
        self.expect(&Request::MetricsHist, |r| match r {
            Response::MetricsHistData(h) => Ok(h),
            other => Err(other),
        })
    }

    /// `WATCH`: subscribe this connection to periodic `FRAME` pushes.
    /// Returns the interval the server will honor (it clamps very fine
    /// requests to its poll tick). After this call, read frames with
    /// [`Client::next_frame`]; this blocking client cannot interleave
    /// further requests on a watching connection (a frame could land
    /// between request and reply) — use a second connection for traffic.
    pub fn watch(&mut self, interval_ms: u64, mode: WatchMode) -> Result<u64, ClientError> {
        self.expect(&Request::Watch { interval_ms, mode }, |r| match r {
            Response::Watching { interval_ms, .. } => Ok(interval_ms),
            other => Err(other),
        })
    }

    /// Block for the next pushed `FRAME` line on a watching connection.
    pub fn next_frame(&mut self) -> Result<WatchFrame, ClientError> {
        let mut buf = Vec::new();
        self.reader.read_until(b'\n', &mut buf)?;
        if buf.is_empty() {
            return Err(ClientError::Protocol("connection closed by server".into()));
        }
        let text = String::from_utf8_lossy(&buf);
        WatchFrame::parse(text.trim_end_matches(['\r', '\n'])).map_err(ClientError::Protocol)
    }

    pub fn ping(&mut self) -> Result<(), ClientError> {
        self.expect(&Request::Ping, |r| match r {
            Response::Pong => Ok(()),
            other => Err(other),
        })
    }

    /// `QUIT`: ask the server to close this connection.
    pub fn quit(&mut self) -> Result<(), ClientError> {
        self.expect(&Request::Quit, |r| match r {
            Response::Bye => Ok(()),
            other => Err(other),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Parallelism;
    use std::time::Duration;

    fn test_cfg(par: Parallelism) -> ServiceConfig {
        ServiceConfig {
            idle_timeout: Duration::ZERO,
            parallelism: par,
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn start_ping_shutdown() {
        let handle = Server::start(test_cfg(Parallelism::Off), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.ping().unwrap();
        client.quit().unwrap();
        let m = handle.shutdown();
        assert_eq!(m.sessions, 0);
    }

    #[test]
    fn open_push_summary_over_tcp() {
        let handle = Server::start(test_cfg(Parallelism::Threads(2)), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        let spec = SessionSpec::three_sieves(4, 3, 0.05, 20);
        assert!(!client.open("t1", &spec).unwrap());
        let rows: Vec<f32> = (0..32).map(|i| (i as f32 * 0.17).sin()).collect();
        let reply = client.push_rows("t1", &rows, 4).unwrap();
        assert_eq!(reply.rows, 8);
        let got = client.summary("t1").unwrap();
        assert_eq!(got.dim, 4);
        assert_eq!(got.data.len(), got.dim * client.stats("t1").unwrap().len);
        let m = client.metrics().unwrap();
        assert_eq!(m.sessions, 1);
        assert_eq!(m.items, 8);
        client.close("t1", true).unwrap();
        handle.shutdown();
    }

    #[test]
    fn malformed_lines_get_err_replies_not_disconnects() {
        let handle = Server::start(test_cfg(Parallelism::Off), "127.0.0.1:0").unwrap();
        let mut stream = TcpStream::connect(handle.addr()).unwrap();
        stream.write_all(b"FROBNICATE now\nPUSH nosuch rows=1,2\n  \nPING\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut lines = Vec::new();
        for _ in 0..3 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            lines.push(line);
        }
        assert!(lines[0].starts_with("ERR unknown-command"), "{lines:?}");
        assert!(lines[1].starts_with("ERR no-session"), "{lines:?}");
        assert!(lines[2].starts_with("OK PONG"), "{lines:?}");
        handle.shutdown();
    }

    #[test]
    fn shutdown_with_connected_idle_client_completes() {
        let handle = Server::start(test_cfg(Parallelism::Threads(2)), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.ping().unwrap();
        // Client stays connected and idle; shutdown must still return
        // (handlers poll the flag on their read timeout).
        let start = std::time::Instant::now();
        handle.shutdown();
        assert!(start.elapsed() < Duration::from_secs(5), "shutdown wedged");
    }

    /// Minimal scripted peer: answers each incoming request line with the
    /// next canned reply, verbatim. Lets the [`Client`] parsers be
    /// exercised against wire forms a real server of this build would
    /// never produce (legacy peers, corrupt replies).
    fn canned_server(replies: Vec<&'static str>) -> (SocketAddr, JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            for reply in replies {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                stream.write_all(reply.as_bytes()).unwrap();
                stream.write_all(b"\n").unwrap();
            }
        });
        (addr, handle)
    }

    #[test]
    fn client_metrics_hist_parses_current_and_legacy_wire_forms() {
        let (addr, peer) = canned_server(vec![
            // Modern 8-cell entries (PR 8+): ...:max:min:mean.
            "OK METRICS HIST n=2 hist=service.request_ns:3:10:20:30:40:5:21.5;push_ns:0:0:0:0:0:0:0",
            // Legacy 6-cell entries (pre-PR-8 peer): min/mean absent.
            "OK METRICS HIST n=1 hist=service.request_ns:3:10:20:30:40",
            "OK METRICS HIST n=0",
        ]);
        let mut c = Client::connect(addr).unwrap();
        let modern = c.metrics_hist().unwrap();
        assert_eq!(modern.len(), 2);
        assert_eq!(modern[0].name, "service.request_ns");
        assert_eq!((modern[0].min, modern[0].mean), (5, 21.5));
        assert_eq!((modern[1].count, modern[1].mean), (0, 0.0));
        let legacy = c.metrics_hist().unwrap();
        assert_eq!(legacy.len(), 1);
        assert_eq!((legacy[0].count, legacy[0].max), (3, 40));
        assert_eq!((legacy[0].min, legacy[0].mean), (0, 0.0), "legacy entries default min/mean");
        assert!(c.metrics_hist().unwrap().is_empty());
        peer.join().unwrap();
    }

    #[test]
    fn client_metrics_hist_rejects_malformed_replies() {
        let (addr, peer) = canned_server(vec![
            "OK METRICS HIST n=1 hist=a:1:2:3:4:5:6",   // 7 cells: neither 6 nor 8
            "OK METRICS HIST n=2 hist=a:1:2:3:4:5",     // count disagrees with entries
            "OK METRICS HIST n=1 hist=a:x:2:3:4:5",     // non-numeric cell
        ]);
        let mut c = Client::connect(addr).unwrap();
        for _ in 0..3 {
            assert!(matches!(c.metrics_hist(), Err(ClientError::Protocol(_))));
        }
        peer.join().unwrap();
    }

    #[test]
    fn client_metrics_hist_roundtrips_against_live_server() {
        let _toggle = crate::obs::test_toggle_lock();
        crate::obs::set_enabled(true);
        let handle = Server::start(test_cfg(Parallelism::Off), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.ping().unwrap(); // records at least one service.request_ns sample
        let hists = client.metrics_hist().unwrap();
        let req = hists.iter().find(|h| h.name == "service.request_ns");
        let req = req.expect("request histogram must be registered");
        assert!(req.count >= 1);
        assert!(req.mean > 0.0, "mean must survive the wire");
        assert!(req.min > 0 && req.min <= req.max);
        handle.shutdown();
        crate::obs::set_enabled(false);
    }

    #[test]
    fn backoff_schedule_is_deterministic_and_capped() {
        let p = RetryPolicy {
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_millis(80),
            ..RetryPolicy::default()
        };
        let delays: Vec<u64> = (0..6).map(|a| p.delay(a).as_millis() as u64).collect();
        assert_eq!(delays, vec![10, 20, 40, 80, 80, 80]);
        // No overflow far past the cap's exponent.
        assert_eq!(p.delay(40), Duration::from_millis(80));
    }

    #[test]
    fn retrying_client_survives_injected_connection_reset() {
        let _serial = crate::fault::test_plan_lock();
        let handle = Server::start(test_cfg(Parallelism::Off), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(handle.addr()).unwrap().with_retry(RetryPolicy {
            base_delay: Duration::from_millis(1),
            ..RetryPolicy::default()
        });
        let spec = SessionSpec::three_sieves(4, 3, 0.05, 20);
        client.open("r1", &spec).unwrap();
        let rows: Vec<f32> = (0..48).map(|i| (i as f32 * 0.13).cos()).collect();
        // The reset fires BEFORE dispatch, so the dropped request was
        // never applied — the retry is exact, not a double-apply.
        let plan = crate::fault::FaultPlan::new()
            .once(crate::fault::site::CONN_READ, crate::fault::FaultKind::ConnReset);
        crate::fault::arm(plan);
        let reply = client.push_rows("r1", &rows, 4).unwrap();
        crate::fault::disarm();
        assert_eq!(reply.rows, 12);
        assert_eq!(client.metrics().unwrap().pushes, 1, "exactly one PUSH dispatched");
        client.close("r1", true).unwrap();
        handle.shutdown();
    }

    #[test]
    fn retrying_client_resumes_evicted_session_via_reopen() {
        let dir = std::env::temp_dir().join(format!("ts_retry_resume_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = ServiceConfig {
            idle_timeout: Duration::from_millis(5),
            checkpoint_dir: Some(dir.clone()),
            parallelism: Parallelism::Off,
            ..ServiceConfig::default()
        };
        let handle = Server::start(cfg, "127.0.0.1:0").unwrap();
        let mut client =
            Client::connect(handle.addr()).unwrap().with_retry(RetryPolicy::default());
        let spec = SessionSpec::three_sieves(3, 4, 0.05, 30);
        client.open("ev", &spec).unwrap();
        let rows: Vec<f32> = (0..300).map(|i| (i as f32 * 0.071).sin()).collect();
        client.push_rows("ev", &rows[..150], 3).unwrap();
        // Wait past the idle timeout so the accept loop's sweep evicts
        // (checkpointing) the session out from under this client.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while handle.manager().session_count() > 0 {
            assert!(std::time::Instant::now() < deadline, "eviction sweep never fired");
            std::thread::sleep(Duration::from_millis(20));
        }
        // The next push hits ERR no-session; the retry layer re-OPENs with
        // the remembered spec and the checkpoint resume continues the
        // stream bit-identically.
        client.push_rows("ev", &rows[150..], 3).unwrap();
        let got = client.summary("ev").unwrap();
        let mut solo = crate::experiments::build_algo(
            &spec.algo,
            3,
            spec.k,
            crate::experiments::GammaMode::Streaming,
            None,
        );
        solo.process_batch(&rows[..150]);
        solo.process_batch(&rows[150..]);
        assert_eq!(got.value.to_bits(), solo.value().to_bits());
        assert_eq!(got.data, solo.summary());
        client.close("ev", true).unwrap();
        handle.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn watch_streams_numbered_frames() {
        let handle = Server::start(test_cfg(Parallelism::Off), "127.0.0.1:0").unwrap();
        let mut watcher = Client::connect(handle.addr()).unwrap();
        // 1ms is clamped up to the server's poll tick; the granted value
        // comes back in the acknowledgment.
        let granted = watcher.watch(1, WatchMode::All).unwrap();
        assert!(granted >= 1);
        let f0 = watcher.next_frame().unwrap();
        let f1 = watcher.next_frame().unwrap();
        assert_eq!(f0.seq, 0);
        assert_eq!(f1.seq, 1);
        assert!(f0.events.is_some() && f0.hists.is_some(), "mode=all carries both sections");
        // Other connections keep getting served while the watcher streams.
        let mut second = Client::connect(handle.addr()).unwrap();
        second.ping().unwrap();
        handle.shutdown();
    }
}
