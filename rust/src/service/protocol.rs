//! The service's newline-delimited request/response protocol.
//!
//! Every request and every response is exactly one line of UTF-8 text —
//! `nc`-friendly, dependency-free, and trivially framed. The full grammar
//! lives in `docs/protocol.md`; the shape is:
//!
//! ```text
//! OPEN <id> k=<K> dim=<D> [algo=<name>] [<param>=<v>]... [drift=<W>:<TH>]
//! PUSH <id> rows=<f32,..>[;<f32,..>...]          (CSV form)
//! PUSH <id> raw=<base64 of little-endian f32s>   (packed form)
//! SUMMARY <id> | STATS <id> | CLOSE <id> [discard] | METRICS [HIST] | PING | QUIT
//! WATCH [interval_ms] [events|hist|all]          (periodic FRAME stream)
//! ```
//!
//! `algo=` accepts every name in [`crate::algorithms::registry`], and the
//! accepted `<param>` keys are exactly the registry's wire-visible
//! parameter keys — a newly registered algorithm is OPEN-able with no
//! change to this module.
//!
//! Replies start with `OK <VERB>` or `ERR <code> <message>`. All floats are
//! printed with Rust's shortest-roundtrip formatting, so a value crosses
//! the wire **bit-identically** — the integration suite compares summaries
//! fetched over TCP against in-process runs with exact equality.

use crate::config::AlgoSpec;
use crate::metrics::AlgoStats;
use crate::obs::HistSnapshot;

/// Hard cap on one protocol line (requests and responses). The server
/// closes connections that exceed it mid-line; at the default `dim`s this
/// allows pushes of tens of thousands of rows per line.
pub const MAX_LINE_BYTES: usize = 8 * 1024 * 1024;

/// Machine-readable error class carried by `ERR` replies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed request line (unknown key, bad number, missing field).
    BadRequest,
    /// First token is not a known verb.
    UnknownCommand,
    /// Session id is not open.
    NoSession,
    /// Session id is already open.
    Exists,
    /// Admission refused: the session-count cap is reached.
    SessionLimit,
    /// Admission refused: the stored-element reservation cap is reached.
    Capacity,
    /// Pushed rows do not match the session's feature dimensionality.
    DimMismatch,
    /// Row payload failed to decode (CSV/base64).
    BadRow,
    /// Row payload decoded but carries a non-finite f32 (NaN/±Inf); the
    /// whole batch is rejected before it reaches the oracle (PR 10).
    NonFinite,
    /// The session was fenced off after a fault (poisoned lock or
    /// handler panic); only `CLOSE <id> discard` is accepted (PR 10).
    Quarantined,
    /// Filesystem/network failure on the server side.
    Io,
    /// Server-side invariant failure.
    Internal,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad-request",
            ErrorCode::UnknownCommand => "unknown-command",
            ErrorCode::NoSession => "no-session",
            ErrorCode::Exists => "exists",
            ErrorCode::SessionLimit => "session-limit",
            ErrorCode::Capacity => "capacity",
            ErrorCode::DimMismatch => "dim-mismatch",
            ErrorCode::BadRow => "bad-row",
            ErrorCode::NonFinite => "nonfinite",
            ErrorCode::Quarantined => "quarantined",
            ErrorCode::Io => "io",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> ErrorCode {
        match s {
            "bad-request" => ErrorCode::BadRequest,
            "unknown-command" => ErrorCode::UnknownCommand,
            "no-session" => ErrorCode::NoSession,
            "exists" => ErrorCode::Exists,
            "session-limit" => ErrorCode::SessionLimit,
            "capacity" => ErrorCode::Capacity,
            "dim-mismatch" => ErrorCode::DimMismatch,
            "bad-row" => ErrorCode::BadRow,
            "nonfinite" => ErrorCode::NonFinite,
            "quarantined" => ErrorCode::Quarantined,
            "io" => ErrorCode::Io,
            _ => ErrorCode::Internal,
        }
    }
}

/// What a tenant asks for at `OPEN` time: the algorithm family plus its
/// per-session resource contract (`K` summary slots of `dim` f32s).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionSpec {
    pub algo: AlgoSpec,
    pub dim: usize,
    pub k: usize,
    /// Optional per-session mean-shift drift detection `(window, threshold)`
    /// — on a detected shift the session's summary is re-selected, exactly
    /// like the single-stream pipeline.
    pub drift: Option<(usize, f64)>,
}

impl SessionSpec {
    /// A `three-sieves` session — the paper's O(K)-memory flagship and the
    /// service default.
    pub fn three_sieves(dim: usize, k: usize, epsilon: f64, t: usize) -> Self {
        SessionSpec { algo: AlgoSpec::three_sieves(epsilon, t as u64), dim, k, drift: None }
    }
}

/// Row payload of a `PUSH`, preserving how the client framed it so
/// validation can distinguish "ragged CSV row" from "non-row-aligned blob".
#[derive(Clone, Debug, PartialEq)]
pub enum PushBody {
    /// CSV form: one `Vec<f32>` per row; every row must match the session
    /// `dim` exactly.
    Rows(Vec<Vec<f32>>),
    /// Packed form: a flat little-endian f32 blob; its length must be a
    /// multiple of the session `dim`.
    Packed(Vec<f32>),
}

impl PushBody {
    /// Total f32 count (before dim validation).
    pub fn floats(&self) -> usize {
        match self {
            PushBody::Rows(rows) => rows.iter().map(Vec::len).sum(),
            PushBody::Packed(flat) => flat.len(),
        }
    }
}

/// What a `WATCH` subscriber wants in each frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WatchMode {
    /// Cumulative decision-event totals only.
    Events,
    /// Latency-histogram summaries only.
    Hist,
    /// Both sections in every frame.
    All,
}

impl WatchMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            WatchMode::Events => "events",
            WatchMode::Hist => "hist",
            WatchMode::All => "all",
        }
    }

    pub fn parse(s: &str) -> Option<WatchMode> {
        match s {
            "events" => Some(WatchMode::Events),
            "hist" => Some(WatchMode::Hist),
            "all" => Some(WatchMode::All),
            _ => None,
        }
    }
}

/// One parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Open { id: String, spec: SessionSpec },
    Push { id: String, body: PushBody },
    Summary { id: String },
    Stats { id: String },
    Close { id: String, discard: bool },
    Metrics,
    /// `METRICS HIST`: latency-histogram summaries from the process-wide
    /// [`obs`](crate::obs) registry (p50/p90/p99/max/min/mean per named
    /// histogram).
    MetricsHist,
    /// `WATCH [interval_ms] [events|hist|all]`: subscribe this connection
    /// to periodic `FRAME` lines (see [`WatchFrame`]) until it closes.
    Watch { interval_ms: u64, mode: WatchMode },
    Ping,
    Quit,
}

/// `PUSH` acknowledgment.
#[derive(Clone, Debug, PartialEq)]
pub struct PushReply {
    pub rows: u64,
    pub len: usize,
    pub value: f64,
    pub drift_events: usize,
}

/// `SUMMARY` payload: the session's current summary, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct SummaryReply {
    pub dim: usize,
    pub value: f64,
    pub data: Vec<f32>,
}

/// `STATS` payload: the paper's per-run resource accounting for one tenant.
#[derive(Clone, Debug, PartialEq)]
pub struct StatsReply {
    pub stats: AlgoStats,
    pub value: f64,
    pub len: usize,
    pub drift_events: usize,
    /// Active kernel/solve dispatch table (`"scalar"`/`"avx2"`/`"neon"`,
    /// see [`crate::simd`]) — process-wide, reported per reply so clients
    /// can log which backend produced a run. Absent in pre-SIMD replies;
    /// the parser defaults to `"scalar"`, which is what those servers ran.
    pub backend: String,
    /// Rows this session has rejected under the non-finite input policy
    /// (`ERR nonfinite`). Absent in pre-PR-10 replies; defaults to 0.
    pub rejected_rows: u64,
}

/// `METRICS` payload: the service-wide snapshot. `items`/`queries`/`stored`
/// aggregate the *live* sessions' [`AlgoStats`] (the acceptance invariant:
/// they equal the sum of per-session `STATS`); the `*_total` counters are
/// lifetime counts that survive session close/eviction.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub sessions: usize,
    pub stored: usize,
    pub items: u64,
    pub queries: u64,
    /// Measured kernel-entry evaluations across live sessions — the
    /// shared-panel broker's saving, observable per service (see
    /// [`AlgoStats::kernel_evals`]).
    pub kernel_evals: u64,
    /// Wall-ns aggregates over live sessions' stats (kernel / solve /
    /// scan stages). Measured only while [`obs`](crate::obs) recording is
    /// on; 0 otherwise. Like the other live aggregates they obey
    /// `METRICS == Σ STATS` because the snapshot locks all sessions in
    /// one consistent pass.
    pub wall_kernel_ns: u64,
    pub wall_solve_ns: u64,
    pub wall_scan_ns: u64,
    /// Decision-telemetry aggregates over live sessions' stats (sieve-rule
    /// accepts / rejects / clip-zone defers / T-budget threshold moves).
    /// Counted only while [`obs`](crate::obs) recording is on; 0
    /// otherwise. Same snapshot consistency as the wall-ns fields.
    pub accepts: u64,
    pub rejects: u64,
    pub defers: u64,
    pub threshold_moves: u64,
    /// Active kernel/solve dispatch table (`"scalar"`/`"avx2"`/`"neon"`,
    /// see [`crate::simd`]). Absent in pre-SIMD replies; the parser
    /// defaults to `"scalar"`, which is what those servers ran.
    pub backend: String,
    pub opens: u64,
    pub resumes: u64,
    pub pushes: u64,
    pub items_total: u64,
    pub evictions: u64,
    pub closes: u64,
    pub checkpoints: u64,
    /// Lifetime rows rejected by the non-finite input policy across all
    /// sessions (`ERR nonfinite`). Absent pre-PR-10; defaults to 0.
    pub rejected_rows: u64,
    /// Lifetime sessions fenced off after a fault (poisoned lock or
    /// handler panic). Absent pre-PR-10; defaults to 0.
    pub quarantines: u64,
    /// Lifetime corrupt checkpoints moved to `.corrupt` (startup
    /// recovery sweep + resume path). Absent pre-PR-10; defaults to 0.
    pub ckpt_quarantines: u64,
    pub uptime_s: f64,
    pub items_per_s: f64,
}

/// One response line.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Opened { id: String, resumed: bool },
    Pushed { id: String, reply: PushReply },
    SummaryData { id: String, reply: SummaryReply },
    StatsData { id: String, reply: StatsReply },
    Closed { id: String, checkpointed: bool },
    MetricsData(MetricsSnapshot),
    MetricsHistData(Vec<HistSnapshot>),
    /// `WATCH` acknowledgment — `FRAME` lines follow on this connection.
    Watching { interval_ms: u64, mode: WatchMode },
    Pong,
    Bye,
    Error { code: ErrorCode, message: String },
}

/// One pushed `WATCH` frame: a single `FRAME` line carrying cumulative
/// decision-event totals and/or histogram summaries, depending on the
/// subscribed [`WatchMode`]. `seq` numbers the frames actually written to
/// this subscriber; `dropped` counts frames the server *coalesced away*
/// because the connection was busy or slow (the per-subscriber queue is
/// bounded at one pending frame, drop-oldest — totals are cumulative, so
/// the surviving frame subsumes the dropped ones).
#[derive(Clone, Debug, PartialEq)]
pub struct WatchFrame {
    pub seq: u64,
    pub dropped: u64,
    /// Cumulative event totals (present in `events`/`all` modes).
    pub events: Option<crate::obs::EventTotals>,
    /// Histogram summaries (present in `hist`/`all` modes).
    pub hists: Option<Vec<HistSnapshot>>,
}

impl WatchFrame {
    /// Serialize to one `FRAME` wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        use std::fmt::Write;
        let mut s = format!("FRAME seq={} dropped={}", self.seq, self.dropped);
        if let Some(ev) = &self.events {
            s.push_str(" events=");
            for (i, n) in ev.as_array().iter().enumerate() {
                if i > 0 {
                    s.push(':');
                }
                let _ = write!(s, "{n}");
            }
        }
        if let Some(hists) = &self.hists {
            let _ = write!(s, " hist_n={}", hists.len());
            if !hists.is_empty() {
                s.push_str(" hist=");
                s.push_str(&hist_cells(hists));
            }
        }
        s
    }

    /// Parse one `FRAME` line — the subscriber half of `WATCH`.
    pub fn parse(line: &str) -> Result<WatchFrame, String> {
        let line = line.trim_end_matches(['\r', '\n']);
        let rest = line.strip_prefix("FRAME ").ok_or_else(|| format!("bad frame {line:?}"))?;
        let fields: Vec<(&str, &str)> =
            rest.split(' ').filter(|t| !t.is_empty()).filter_map(|t| t.split_once('=')).collect();
        let field = |key: &str| -> Option<&str> {
            fields.iter().find(|&&(k, _)| k == key).map(|&(_, v)| v)
        };
        let num = |key: &str| -> Result<u64, String> {
            field(key)
                .ok_or_else(|| format!("frame missing {key}="))?
                .parse()
                .map_err(|e| format!("frame {key}: {e}"))
        };
        let events = match field("events") {
            None => None,
            Some(v) => {
                let mut cells = [0u64; crate::obs::events::KINDS];
                let parts: Vec<&str> = v.split(':').collect();
                // Lenient on *older* frames (fewer kinds existed — the
                // missing tail defaults to 0, like the six-cell hist
                // form); reject frames from a *newer* schema outright.
                if parts.is_empty() || parts.len() > cells.len() {
                    return Err(format!("frame events: {} cells, expected <= {}", parts.len(),
                        cells.len()));
                }
                for (slot, part) in cells.iter_mut().zip(&parts) {
                    *slot = part.parse().map_err(|e| format!("frame events {part:?}: {e}"))?;
                }
                Some(crate::obs::EventTotals::from_array(cells))
            }
        };
        let hists = match field("hist_n") {
            None => None,
            Some(v) => {
                let n: usize = v.parse().map_err(|e| format!("frame hist_n: {e}"))?;
                let hists = match field("hist") {
                    None if n == 0 => Vec::new(),
                    None => return Err(format!("frame hist_n={n} without hist=")),
                    Some(cells) => parse_hist_cells(cells)?,
                };
                if hists.len() != n {
                    return Err(format!("frame hist_n={n} but {} entries", hists.len()));
                }
                Some(hists)
            }
        };
        Ok(WatchFrame { seq: num("seq")?, dropped: num("dropped")?, events, hists })
    }
}

/// Shared `name:count:p50:p90:p99:max:min:mean` serialization for
/// `METRICS HIST` replies and `WATCH` frames.
fn hist_cells(hists: &[HistSnapshot]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    for (i, h) in hists.iter().enumerate() {
        if i > 0 {
            s.push(';');
        }
        let _ = write!(
            s,
            "{}:{}:{}:{}:{}:{}:{}:{}",
            h.name, h.count, h.p50, h.p90, h.p99, h.max, h.min, h.mean
        );
    }
    s
}

/// Parse `METRICS HIST` / `FRAME` histogram entries. Accepts the 6-cell
/// pre-PR-8 form (no `min`/`mean` — they default to zero) alongside the
/// current 8-cell form, so new clients read old servers.
fn parse_hist_cells(s: &str) -> Result<Vec<HistSnapshot>, String> {
    let mut hists = Vec::new();
    for part in s.split(';') {
        let cells: Vec<&str> = part.split(':').collect();
        if cells.len() != 6 && cells.len() != 8 {
            return Err(format!("bad histogram entry {part:?}"));
        }
        let pf = |i: usize| -> Result<f64, String> {
            cells[i].parse().map_err(|e| format!("histogram entry {part:?}: {e}"))
        };
        hists.push(HistSnapshot {
            name: cells[0].to_string(),
            count: pf(1)? as u64,
            p50: pf(2)?,
            p90: pf(3)?,
            p99: pf(4)?,
            max: pf(5)? as u64,
            min: if cells.len() == 8 { pf(6)? as u64 } else { 0 },
            mean: if cells.len() == 8 { pf(7)? } else { 0.0 },
        });
    }
    Ok(hists)
}

/// A session id: 1–64 chars of `[A-Za-z0-9._-]`. The charset keeps ids
/// token-safe on the wire *and* path-safe as `<id>.ckpt` file names (no
/// separators, no traversal).
pub fn valid_id(s: &str) -> bool {
    !s.is_empty()
        && s.len() <= 64
        && s.bytes().all(|b| b.is_ascii_alphanumeric() || matches!(b, b'.' | b'_' | b'-'))
}

type ParseErr = (ErrorCode, String);

fn bad(msg: impl Into<String>) -> ParseErr {
    (ErrorCode::BadRequest, msg.into())
}

fn parse_num<T: std::str::FromStr>(key: &str, v: &str) -> Result<T, ParseErr>
where
    T::Err: std::fmt::Display,
{
    v.parse().map_err(|e| bad(format!("{key}={v:?}: {e}")))
}

/// Key=value tail of an `OPEN` line.
struct Params<'a> {
    pairs: Vec<(&'a str, &'a str)>,
}

impl<'a> Params<'a> {
    fn parse(tokens: &[&'a str], allowed: &[&str]) -> Result<Params<'a>, ParseErr> {
        let mut pairs = Vec::with_capacity(tokens.len());
        for tok in tokens {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| bad(format!("expected key=value, got {tok:?}")))?;
            if !allowed.contains(&k) {
                return Err(bad(format!("unknown parameter {k:?} (allowed: {allowed:?})")));
            }
            if pairs.iter().any(|&(seen, _)| seen == k) {
                return Err(bad(format!("duplicate parameter {k:?}")));
            }
            pairs.push((k, v));
        }
        Ok(Params { pairs })
    }

    fn get(&self, key: &str) -> Option<&'a str> {
        self.pairs.iter().find(|&&(k, _)| k == key).map(|&(_, v)| v)
    }

    fn required<T: std::str::FromStr>(&self, key: &str) -> Result<T, ParseErr>
    where
        T::Err: std::fmt::Display,
    {
        let v = self.get(key).ok_or_else(|| bad(format!("missing required {key}=")))?;
        parse_num(key, v)
    }
}

/// The OPEN grammar's allowed keys: the fixed session keys plus every
/// wire-visible parameter key the registry declares.
fn open_keys() -> Vec<&'static str> {
    let mut keys = vec!["k", "dim", "algo", "drift"];
    keys.extend(crate::algorithms::registry::wire_param_keys());
    keys
}

fn parse_open_spec(params: &Params<'_>) -> Result<SessionSpec, ParseErr> {
    let dim: usize = params.required("dim")?;
    let k: usize = params.required("k")?;
    if dim == 0 || k == 0 {
        return Err(bad("k and dim must be positive"));
    }
    // The registry parses and type-checks the algorithm parameters; wire
    // pins (e.g. Salsa's length hint — a service stream is unbounded) are
    // applied inside from_wire.
    let name = params.get("algo").unwrap_or("three-sieves");
    let algo =
        AlgoSpec::from_wire(name, &|key| params.get(key).map(String::from)).map_err(bad)?;
    let drift = match params.get("drift") {
        None => None,
        Some(v) => {
            let (w, th) = v
                .split_once(':')
                .ok_or_else(|| bad(format!("drift={v:?}: expected <window>:<threshold>")))?;
            let w: usize = parse_num("drift window", w)?;
            let th: f64 = parse_num("drift threshold", th)?;
            let th_ok = th.is_finite() && th > 0.0;
            if w == 0 || !th_ok {
                return Err(bad("drift window and threshold must be positive"));
            }
            Some((w, th))
        }
    };
    Ok(SessionSpec { algo, dim, k, drift })
}

fn spec_params(spec: &SessionSpec) -> String {
    use std::fmt::Write;
    let mut s = format!("k={} dim={} algo={}", spec.k, spec.dim, spec.algo.name());
    for token in spec.algo.wire_tokens() {
        let _ = write!(s, " {token}");
    }
    if let Some((w, th)) = spec.drift {
        let _ = write!(s, " drift={w}:{th}");
    }
    s
}

fn parse_csv_rows(v: &str) -> Result<Vec<Vec<f32>>, ParseErr> {
    let mut rows = Vec::new();
    for (i, row) in v.split(';').enumerate() {
        let mut out = Vec::new();
        for cell in row.split(',') {
            let x: f32 = cell
                .parse()
                .map_err(|e| (ErrorCode::BadRow, format!("row {i}, cell {cell:?}: {e}")))?;
            out.push(x);
        }
        rows.push(out);
    }
    Ok(rows)
}

fn csv_rows(data: &[f32], dim: usize) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    for (r, row) in data.chunks_exact(dim).enumerate() {
        if r > 0 {
            s.push(';');
        }
        for (c, v) in row.iter().enumerate() {
            if c > 0 {
                s.push(',');
            }
            let _ = write!(s, "{v}");
        }
    }
    s
}

fn packed_to_floats(bytes: &[u8]) -> Result<Vec<f32>, ParseErr> {
    if bytes.len() % 4 != 0 {
        return Err((
            ErrorCode::BadRow,
            format!("packed payload is {} bytes, not a multiple of 4", bytes.len()),
        ));
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn floats_to_packed(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

impl Request {
    /// Parse one request line (no trailing newline). Errors come back as
    /// `(code, message)` ready to serialize as an `ERR` reply.
    pub fn parse(line: &str) -> Result<Request, ParseErr> {
        let tokens: Vec<&str> = line.split_whitespace().collect();
        let verb = *tokens.first().ok_or_else(|| bad("empty request"))?;
        let session_id = |idx: usize| -> Result<String, ParseErr> {
            let id = *tokens
                .get(idx)
                .ok_or_else(|| bad(format!("{verb} requires a session id")))?;
            if !valid_id(id) {
                return Err(bad(format!(
                    "invalid session id {id:?} (1-64 chars of [A-Za-z0-9._-])"
                )));
            }
            Ok(id.to_string())
        };
        match verb.to_ascii_uppercase().as_str() {
            "OPEN" => {
                let id = session_id(1)?;
                let params = Params::parse(&tokens[2..], &open_keys())?;
                Ok(Request::Open { id, spec: parse_open_spec(&params)? })
            }
            "PUSH" => {
                let id = session_id(1)?;
                let payload = *tokens
                    .get(2)
                    .ok_or_else(|| bad("PUSH requires rows=<csv> or raw=<base64>"))?;
                if tokens.len() > 3 {
                    return Err(bad("PUSH takes exactly one payload token"));
                }
                let body = if let Some(v) = payload.strip_prefix("rows=") {
                    PushBody::Rows(parse_csv_rows(v)?)
                } else if let Some(v) = payload.strip_prefix("raw=") {
                    let bytes =
                        b64_decode(v).map_err(|e| (ErrorCode::BadRow, format!("base64: {e}")))?;
                    PushBody::Packed(packed_to_floats(&bytes)?)
                } else {
                    return Err(bad("PUSH payload must start with rows= or raw="));
                };
                Ok(Request::Push { id, body })
            }
            "SUMMARY" => Ok(Request::Summary { id: session_id(1)? }),
            "STATS" => Ok(Request::Stats { id: session_id(1)? }),
            "CLOSE" => {
                let id = session_id(1)?;
                let discard = match tokens.get(2) {
                    None => false,
                    Some(&"discard") => true,
                    Some(other) => {
                        return Err(bad(format!("CLOSE: unexpected token {other:?}")))
                    }
                };
                Ok(Request::Close { id, discard })
            }
            "METRICS" => match tokens.get(1) {
                None => Ok(Request::Metrics),
                Some(&"HIST") => Ok(Request::MetricsHist),
                Some(other) => Err(bad(format!("METRICS: unexpected token {other:?}"))),
            },
            "WATCH" => {
                let mut rest = &tokens[1..];
                let mut interval_ms = 1000u64;
                if let Some(tok) = rest.first() {
                    if let Ok(ms) = tok.parse::<u64>() {
                        if ms == 0 {
                            return Err(bad("WATCH interval must be positive"));
                        }
                        interval_ms = ms;
                        rest = &rest[1..];
                    }
                }
                let mode = match rest {
                    [] => WatchMode::All,
                    [tok] => WatchMode::parse(tok).ok_or_else(|| {
                        bad(format!("WATCH: unknown mode {tok:?} (events|hist|all)"))
                    })?,
                    _ => return Err(bad("WATCH takes [interval_ms] [events|hist|all]")),
                };
                Ok(Request::Watch { interval_ms, mode })
            }
            "PING" => Ok(Request::Ping),
            "QUIT" => Ok(Request::Quit),
            other => Err((ErrorCode::UnknownCommand, format!("unknown command {other:?}"))),
        }
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Request::Open { id, spec } => format!("OPEN {id} {}", spec_params(spec)),
            Request::Push { id, body: PushBody::Rows(rows) } => {
                let flat: Vec<String> = rows
                    .iter()
                    .map(|r| {
                        r.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
                    })
                    .collect();
                format!("PUSH {id} rows={}", flat.join(";"))
            }
            Request::Push { id, body: PushBody::Packed(flat) } => {
                format!("PUSH {id} raw={}", b64_encode(&floats_to_packed(flat)))
            }
            Request::Summary { id } => format!("SUMMARY {id}"),
            Request::Stats { id } => format!("STATS {id}"),
            Request::Close { id, discard } => {
                if *discard {
                    format!("CLOSE {id} discard")
                } else {
                    format!("CLOSE {id}")
                }
            }
            Request::Metrics => "METRICS".into(),
            Request::MetricsHist => "METRICS HIST".into(),
            Request::Watch { interval_ms, mode } => {
                format!("WATCH {interval_ms} {}", mode.as_str())
            }
            Request::Ping => "PING".into(),
            Request::Quit => "QUIT".into(),
        }
    }
}

impl Response {
    pub fn error(code: ErrorCode, message: impl Into<String>) -> Response {
        // Responses are single-line by construction; scrub any newline an
        // inner error message might smuggle in.
        let message: String = message
            .into()
            .chars()
            .map(|c| if c == '\n' || c == '\r' { ' ' } else { c })
            .collect();
        Response::Error { code, message }
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Response::Opened { id, resumed } => {
                format!("OK OPEN id={id} resumed={}", u8::from(*resumed))
            }
            Response::Pushed { id, reply } => format!(
                "OK PUSH id={id} rows={} len={} value={} drift={}",
                reply.rows, reply.len, reply.value, reply.drift_events
            ),
            Response::SummaryData { id, reply } => {
                let rows = if reply.dim == 0 { 0 } else { reply.data.len() / reply.dim };
                let mut s = format!(
                    "OK SUMMARY id={id} dim={} rows={rows} value={}",
                    reply.dim, reply.value
                );
                if rows > 0 {
                    s.push_str(" data=");
                    s.push_str(&csv_rows(&reply.data, reply.dim));
                }
                s
            }
            Response::StatsData { id, reply } => format!(
                "OK STATS id={id} elements={} queries={} kernel_evals={} stored={} peak={} \
                 instances={} len={} value={} drift={} wall_kernel_ns={} wall_solve_ns={} \
                 wall_scan_ns={} accepts={} rejects={} defers={} threshold_moves={} \
                 backend={} rejected_rows={}",
                reply.stats.elements,
                reply.stats.queries,
                reply.stats.kernel_evals,
                reply.stats.stored,
                reply.stats.peak_stored,
                reply.stats.instances,
                reply.len,
                reply.value,
                reply.drift_events,
                reply.stats.wall_kernel_ns,
                reply.stats.wall_solve_ns,
                reply.stats.wall_scan_ns,
                reply.stats.accepts,
                reply.stats.rejects,
                reply.stats.defers,
                reply.stats.threshold_moves,
                reply.backend,
                reply.rejected_rows
            ),
            Response::Closed { id, checkpointed } => {
                format!("OK CLOSE id={id} checkpointed={}", u8::from(*checkpointed))
            }
            Response::MetricsData(m) => format!(
                "OK METRICS sessions={} stored={} items={} queries={} kernel_evals={} opens={} \
                 resumes={} pushes={} items_total={} evictions={} closes={} checkpoints={} \
                 uptime_s={} items_per_s={} wall_kernel_ns={} wall_solve_ns={} wall_scan_ns={} \
                 accepts={} rejects={} defers={} threshold_moves={} backend={} \
                 rejected_rows={} quarantines={} ckpt_quarantines={}",
                m.sessions,
                m.stored,
                m.items,
                m.queries,
                m.kernel_evals,
                m.opens,
                m.resumes,
                m.pushes,
                m.items_total,
                m.evictions,
                m.closes,
                m.checkpoints,
                m.uptime_s,
                m.items_per_s,
                m.wall_kernel_ns,
                m.wall_solve_ns,
                m.wall_scan_ns,
                m.accepts,
                m.rejects,
                m.defers,
                m.threshold_moves,
                m.backend,
                m.rejected_rows,
                m.quarantines,
                m.ckpt_quarantines
            ),
            Response::MetricsHistData(hists) => {
                let mut s = format!("OK METRICS HIST n={}", hists.len());
                if !hists.is_empty() {
                    s.push_str(" hist=");
                    s.push_str(&hist_cells(hists));
                }
                s
            }
            Response::Watching { interval_ms, mode } => {
                format!("OK WATCH interval_ms={interval_ms} mode={}", mode.as_str())
            }
            Response::Pong => "OK PONG".into(),
            Response::Bye => "OK BYE".into(),
            Response::Error { code, message } => format!("ERR {} {message}", code.as_str()),
        }
    }

    /// Parse one response line — the client half of the protocol.
    pub fn parse(line: &str) -> Result<Response, String> {
        let line = line.trim_end_matches(['\r', '\n']);
        if let Some(rest) = line.strip_prefix("ERR ") {
            let (code, message) = rest.split_once(' ').unwrap_or((rest, ""));
            return Ok(Response::Error {
                code: ErrorCode::parse(code),
                message: message.to_string(),
            });
        }
        let rest = line.strip_prefix("OK ").ok_or_else(|| format!("bad reply {line:?}"))?;
        let tokens: Vec<&str> = rest.split(' ').filter(|t| !t.is_empty()).collect();
        let verb = *tokens.first().ok_or("empty OK reply")?;
        let fields: Vec<(&str, &str)> =
            tokens[1..].iter().filter_map(|t| t.split_once('=')).collect();
        let field = |key: &str| -> Result<&str, String> {
            fields
                .iter()
                .find(|&&(k, _)| k == key)
                .map(|&(_, v)| v)
                .ok_or_else(|| format!("{verb} reply missing {key}="))
        };
        let num = |key: &str| -> Result<f64, String> {
            field(key)?.parse().map_err(|e| format!("{verb} reply {key}: {e}"))
        };
        match verb {
            "OPEN" => Ok(Response::Opened {
                id: field("id")?.to_string(),
                resumed: field("resumed")? == "1",
            }),
            "PUSH" => Ok(Response::Pushed {
                id: field("id")?.to_string(),
                reply: PushReply {
                    rows: num("rows")? as u64,
                    len: num("len")? as usize,
                    value: num("value")?,
                    drift_events: num("drift")? as usize,
                },
            }),
            "SUMMARY" => {
                let dim = num("dim")? as usize;
                let rows = num("rows")? as usize;
                let data = if rows == 0 {
                    Vec::new()
                } else {
                    let parsed = parse_csv_rows(field("data")?).map_err(|(_, m)| m)?;
                    let mut flat = Vec::with_capacity(rows * dim);
                    for row in &parsed {
                        flat.extend_from_slice(row);
                    }
                    if flat.len() != rows * dim {
                        return Err(format!(
                            "SUMMARY reply: {} floats, expected {rows}x{dim}",
                            flat.len()
                        ));
                    }
                    flat
                };
                Ok(Response::SummaryData {
                    id: field("id")?.to_string(),
                    reply: SummaryReply { dim, value: num("value")?, data },
                })
            }
            "STATS" => Ok(Response::StatsData {
                id: field("id")?.to_string(),
                reply: StatsReply {
                    stats: AlgoStats {
                        queries: num("queries")? as u64,
                        // Absent in pre-broker server replies; tolerate
                        // the skew like the checkpoint loader does.
                        kernel_evals: num("kernel_evals").unwrap_or(0.0) as u64,
                        elements: num("elements")? as u64,
                        stored: num("stored")? as usize,
                        peak_stored: num("peak")? as usize,
                        instances: num("instances")? as usize,
                        // Absent in pre-PR-7 server replies (same lenient
                        // default as kernel_evals above).
                        wall_kernel_ns: num("wall_kernel_ns").unwrap_or(0.0) as u64,
                        wall_solve_ns: num("wall_solve_ns").unwrap_or(0.0) as u64,
                        wall_scan_ns: num("wall_scan_ns").unwrap_or(0.0) as u64,
                        // Absent in pre-PR-8 server replies — the decision
                        // counters default to zero like the wall fields.
                        accepts: num("accepts").unwrap_or(0.0) as u64,
                        rejects: num("rejects").unwrap_or(0.0) as u64,
                        defers: num("defers").unwrap_or(0.0) as u64,
                        threshold_moves: num("threshold_moves").unwrap_or(0.0) as u64,
                    },
                    value: num("value")?,
                    len: num("len")? as usize,
                    drift_events: num("drift")? as usize,
                    // Absent in pre-SIMD server replies, which ran the
                    // scalar kernels unconditionally.
                    backend: field("backend").unwrap_or("scalar").to_string(),
                    // Absent in pre-PR-10 replies; same lenient default.
                    rejected_rows: num("rejected_rows").unwrap_or(0.0) as u64,
                },
            }),
            "CLOSE" => Ok(Response::Closed {
                id: field("id")?.to_string(),
                checkpointed: field("checkpointed")? == "1",
            }),
            "METRICS" => {
                if tokens.get(1) == Some(&"HIST") {
                    let n = num("n")? as usize;
                    let hists = if n > 0 {
                        parse_hist_cells(field("hist")?)
                            .map_err(|e| format!("METRICS HIST: {e}"))?
                    } else {
                        Vec::new()
                    };
                    if hists.len() != n {
                        return Err(format!(
                            "METRICS HIST: n={n} but {} entries",
                            hists.len()
                        ));
                    }
                    return Ok(Response::MetricsHistData(hists));
                }
                Ok(Response::MetricsData(MetricsSnapshot {
                    sessions: num("sessions")? as usize,
                    stored: num("stored")? as usize,
                    items: num("items")? as u64,
                    queries: num("queries")? as u64,
                    kernel_evals: num("kernel_evals").unwrap_or(0.0) as u64,
                    // Absent in pre-PR-7 replies; default like kernel_evals.
                    wall_kernel_ns: num("wall_kernel_ns").unwrap_or(0.0) as u64,
                    wall_solve_ns: num("wall_solve_ns").unwrap_or(0.0) as u64,
                    wall_scan_ns: num("wall_scan_ns").unwrap_or(0.0) as u64,
                    // Absent in pre-PR-8 replies; default like the wall
                    // fields above.
                    accepts: num("accepts").unwrap_or(0.0) as u64,
                    rejects: num("rejects").unwrap_or(0.0) as u64,
                    defers: num("defers").unwrap_or(0.0) as u64,
                    threshold_moves: num("threshold_moves").unwrap_or(0.0) as u64,
                    // Absent in pre-SIMD replies (scalar-only servers).
                    backend: field("backend").unwrap_or("scalar").to_string(),
                    opens: num("opens")? as u64,
                    resumes: num("resumes")? as u64,
                    pushes: num("pushes")? as u64,
                    items_total: num("items_total")? as u64,
                    evictions: num("evictions")? as u64,
                    closes: num("closes")? as u64,
                    checkpoints: num("checkpoints")? as u64,
                    // Absent in pre-PR-10 replies; lenient like the rest.
                    rejected_rows: num("rejected_rows").unwrap_or(0.0) as u64,
                    quarantines: num("quarantines").unwrap_or(0.0) as u64,
                    ckpt_quarantines: num("ckpt_quarantines").unwrap_or(0.0) as u64,
                    uptime_s: num("uptime_s")?,
                    items_per_s: num("items_per_s")?,
                }))
            }
            "WATCH" => {
                let mode = field("mode")?;
                Ok(Response::Watching {
                    interval_ms: num("interval_ms")? as u64,
                    mode: WatchMode::parse(mode)
                        .ok_or_else(|| format!("WATCH reply: unknown mode {mode:?}"))?,
                })
            }
            "PONG" => Ok(Response::Pong),
            "BYE" => Ok(Response::Bye),
            other => Err(format!("unknown reply verb {other:?}")),
        }
    }
}

// ---------------------------------------------------------------------------
// base64 (standard alphabet, padded) — hand-rolled, the crate has no deps.

const B64_ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes as standard padded base64.
pub fn b64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = (u32::from(b[0]) << 16) | (u32::from(b[1]) << 8) | u32::from(b[2]);
        out.push(B64_ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(B64_ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { B64_ALPHABET[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { B64_ALPHABET[n as usize & 63] as char } else { '=' });
    }
    out
}

/// Decode standard padded base64.
pub fn b64_decode(s: &str) -> Result<Vec<u8>, String> {
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(format!("length {} is not a multiple of 4", bytes.len()));
    }
    let val = |c: u8| -> Result<u32, String> {
        match c {
            b'A'..=b'Z' => Ok(u32::from(c - b'A')),
            b'a'..=b'z' => Ok(u32::from(c - b'a') + 26),
            b'0'..=b'9' => Ok(u32::from(c - b'0') + 52),
            b'+' => Ok(62),
            b'/' => Ok(63),
            _ => Err(format!("invalid base64 byte {:?}", c as char)),
        }
    };
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for (i, quad) in bytes.chunks_exact(4).enumerate() {
        let last = (i + 1) * 4 == bytes.len();
        let pad = if last { quad.iter().rev().take_while(|&&c| c == b'=').count() } else { 0 };
        if pad > 2 || (!last && quad.contains(&b'=')) {
            return Err("misplaced padding".into());
        }
        let mut n = 0u32;
        for &c in &quad[..4 - pad] {
            n = (n << 6) | val(c)?;
        }
        n <<= 6 * pad as u32;
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn b64_known_vectors() {
        assert_eq!(b64_encode(b""), "");
        assert_eq!(b64_encode(b"f"), "Zg==");
        assert_eq!(b64_encode(b"fo"), "Zm8=");
        assert_eq!(b64_encode(b"foo"), "Zm9v");
        assert_eq!(b64_encode(b"foobar"), "Zm9vYmFy");
        assert_eq!(b64_decode("Zm9vYmFy").unwrap(), b"foobar");
        assert_eq!(b64_decode("Zg==").unwrap(), b"f");
        assert_eq!(b64_decode("").unwrap(), b"");
        assert!(b64_decode("Zg=").is_err(), "bad length");
        assert!(b64_decode("Zg=a").is_err(), "misplaced padding");
        assert!(b64_decode("Z!==").is_err(), "bad alphabet");
    }

    #[test]
    fn b64_roundtrips_arbitrary_bytes() {
        let data: Vec<u8> = (0u16..=255).map(|b| b as u8).collect();
        for len in [0usize, 1, 2, 3, 4, 63, 255] {
            let slice = &data[..len];
            assert_eq!(b64_decode(&b64_encode(slice)).unwrap(), slice, "len {len}");
        }
    }

    #[test]
    fn open_roundtrip_all_algos() {
        let specs = [
            SessionSpec::three_sieves(16, 8, 0.001, 500),
            SessionSpec {
                algo: AlgoSpec::sharded_three_sieves(0.01, 100, 4),
                dim: 8,
                k: 5,
                drift: Some((200, 3.5)),
            },
            SessionSpec { algo: AlgoSpec::sieve_streaming_pp(0.05), dim: 4, k: 3, drift: None },
            SessionSpec { algo: AlgoSpec::salsa(0.1, false), dim: 4, k: 3, drift: None },
            SessionSpec { algo: AlgoSpec::quickstream(3, 0.1, 7), dim: 4, k: 3, drift: None },
            SessionSpec { algo: AlgoSpec::stream_clipper(1.5, 0.25), dim: 4, k: 3, drift: None },
            SessionSpec {
                algo: AlgoSpec::subsampled_sieve_streaming(0.1, 0.5, 9),
                dim: 4,
                k: 3,
                drift: None,
            },
            SessionSpec {
                algo: AlgoSpec::subsampled_three_sieves(0.05, 200, 0.25, 11),
                dim: 4,
                k: 3,
                drift: Some((100, 2.0)),
            },
        ];
        for spec in specs {
            let req = Request::Open { id: "tenant-1.a".into(), spec };
            let back = Request::parse(&req.to_line()).unwrap();
            assert_eq!(back, req, "line: {}", req.to_line());
        }
    }

    #[test]
    fn open_accepts_every_registry_name() {
        // The OPEN grammar is registry-driven: every registered name (and
        // its wire-roundtripped default spec) must parse. Offline entries
        // parse too — the session manager is what refuses them.
        for entry in crate::algorithms::registry::entries() {
            let line = format!("OPEN t k=3 dim=4 algo={}", entry.name);
            let req = Request::parse(&line).unwrap_or_else(|e| panic!("{line}: {e:?}"));
            let Request::Open { spec, .. } = req else { panic!("{line}") };
            assert_eq!(spec.algo.name(), entry.name);
            let reopened = Request::Open { id: "t".into(), spec: spec.clone() };
            let back = Request::parse(&reopened.to_line()).unwrap();
            assert_eq!(back, Request::Open { id: "t".into(), spec });
        }
    }

    #[test]
    fn open_unknown_algo_suggests_registry_name() {
        let err = Request::parse("OPEN t k=2 dim=2 algo=three-seives").unwrap_err();
        assert_eq!(err.0, ErrorCode::BadRequest);
        assert!(err.1.contains("did you mean \"three-sieves\""), "{}", err.1);
    }

    #[test]
    fn open_rejects_mistyped_registry_params() {
        let err = Request::parse("OPEN t k=2 dim=2 algo=stream-clipper clipper_alpha=abc")
            .unwrap_err();
        assert_eq!(err.0, ErrorCode::BadRequest);
        assert!(err.1.contains("clipper_alpha"), "{}", err.1);
    }

    #[test]
    fn push_csv_and_packed_roundtrip_exact_bits() {
        // Values chosen to stress shortest-roundtrip printing.
        let rows = vec![
            vec![0.1f32, -3.0, 1.5e-8],
            vec![f32::MIN_POSITIVE, 123456.78, -0.0],
        ];
        let req = Request::Push { id: "t".into(), body: PushBody::Rows(rows.clone()) };
        match Request::parse(&req.to_line()).unwrap() {
            Request::Push { body: PushBody::Rows(back), .. } => {
                for (a, b) in rows.iter().flatten().zip(back.iter().flatten()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
        let flat: Vec<f32> = rows.into_iter().flatten().collect();
        let req = Request::Push { id: "t".into(), body: PushBody::Packed(flat.clone()) };
        match Request::parse(&req.to_line()).unwrap() {
            Request::Push { body: PushBody::Packed(back), .. } => {
                assert_eq!(flat.len(), back.len());
                for (a, b) in flat.iter().zip(&back) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn simple_requests_roundtrip() {
        for req in [
            Request::Summary { id: "a".into() },
            Request::Stats { id: "b-2".into() },
            Request::Close { id: "c".into(), discard: false },
            Request::Close { id: "c".into(), discard: true },
            Request::Metrics,
            Request::MetricsHist,
            Request::Watch { interval_ms: 250, mode: WatchMode::Events },
            Request::Watch { interval_ms: 1000, mode: WatchMode::Hist },
            Request::Watch { interval_ms: 50, mode: WatchMode::All },
            Request::Ping,
            Request::Quit,
        ] {
            assert_eq!(Request::parse(&req.to_line()).unwrap(), req);
        }
    }

    #[test]
    fn watch_defaults_and_partial_forms() {
        assert_eq!(
            Request::parse("WATCH").unwrap(),
            Request::Watch { interval_ms: 1000, mode: WatchMode::All }
        );
        assert_eq!(
            Request::parse("WATCH 200").unwrap(),
            Request::Watch { interval_ms: 200, mode: WatchMode::All }
        );
        assert_eq!(
            Request::parse("WATCH events").unwrap(),
            Request::Watch { interval_ms: 1000, mode: WatchMode::Events }
        );
        assert_eq!(
            Request::parse("WATCH 75 hist").unwrap(),
            Request::Watch { interval_ms: 75, mode: WatchMode::Hist }
        );
    }

    #[test]
    fn parse_rejects_malformed_requests() {
        let cases = [
            ("", ErrorCode::BadRequest),
            ("FROB x", ErrorCode::UnknownCommand),
            ("OPEN", ErrorCode::BadRequest),
            ("OPEN bad/id k=2 dim=2", ErrorCode::BadRequest),
            ("OPEN t dim=2", ErrorCode::BadRequest),          // missing k
            ("OPEN t k=2 dim=2 bogus=1", ErrorCode::BadRequest), // unknown key
            ("OPEN t k=2 dim=2 k=3", ErrorCode::BadRequest),  // duplicate key
            ("OPEN t k=2 dim=2 algo=magic", ErrorCode::BadRequest),
            ("OPEN t k=0 dim=2", ErrorCode::BadRequest),
            ("OPEN t k=2 dim=2 drift=5", ErrorCode::BadRequest),
            ("PUSH t", ErrorCode::BadRequest),
            ("PUSH t rows=1,x", ErrorCode::BadRow),
            ("PUSH t raw=!!!!", ErrorCode::BadRow),
            ("PUSH t rows=1 rows=2", ErrorCode::BadRequest),
            ("CLOSE t keep", ErrorCode::BadRequest),
            ("METRICS BOGUS", ErrorCode::BadRequest),
            ("WATCH 0", ErrorCode::BadRequest),
            ("WATCH fast", ErrorCode::BadRequest),
            ("WATCH 100 events extra", ErrorCode::BadRequest),
        ];
        for (line, code) in cases {
            match Request::parse(line) {
                Err((got, _)) => assert_eq!(got, code, "line {line:?}"),
                Ok(req) => panic!("line {line:?} parsed as {req:?}"),
            }
        }
    }

    #[test]
    fn negative_and_exotic_floats_parse_in_push() {
        let req = Request::parse("PUSH t rows=-3.0,2.5e-4;-0.0,inf").unwrap();
        match req {
            Request::Push { body: PushBody::Rows(rows), .. } => {
                assert_eq!(rows[0][0], -3.0);
                assert!((rows[0][1] - 2.5e-4).abs() < 1e-12);
                assert!(rows[1][1].is_infinite());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn responses_roundtrip() {
        let cases = [
            Response::Opened { id: "t".into(), resumed: true },
            Response::Pushed {
                id: "t".into(),
                reply: PushReply { rows: 64, len: 9, value: 3.125678901234, drift_events: 2 },
            },
            Response::SummaryData {
                id: "t".into(),
                reply: SummaryReply {
                    dim: 2,
                    value: 1.75,
                    data: vec![0.25, -1.5, 3.0e-7, 42.0],
                },
            },
            Response::SummaryData {
                id: "t".into(),
                reply: SummaryReply { dim: 2, value: 0.0, data: vec![] },
            },
            Response::StatsData {
                id: "t".into(),
                reply: StatsReply {
                    stats: AlgoStats {
                        queries: 123,
                        kernel_evals: 4321,
                        elements: 456,
                        stored: 7,
                        peak_stored: 8,
                        instances: 1,
                        wall_kernel_ns: 1111,
                        wall_solve_ns: 2222,
                        wall_scan_ns: 3333,
                        accepts: 9,
                        rejects: 447,
                        defers: 3,
                        threshold_moves: 2,
                    },
                    value: 2.5,
                    len: 7,
                    drift_events: 0,
                    backend: "avx2".into(),
                    rejected_rows: 5,
                },
            },
            Response::Closed { id: "t".into(), checkpointed: true },
            Response::MetricsData(MetricsSnapshot {
                sessions: 3,
                stored: 21,
                items: 900,
                queries: 950,
                kernel_evals: 12345,
                wall_kernel_ns: 777,
                wall_solve_ns: 888,
                wall_scan_ns: 999,
                accepts: 12,
                rejects: 888,
                defers: 4,
                threshold_moves: 6,
                backend: "neon".into(),
                opens: 4,
                resumes: 1,
                pushes: 30,
                items_total: 1200,
                evictions: 1,
                closes: 1,
                checkpoints: 2,
                rejected_rows: 11,
                quarantines: 1,
                ckpt_quarantines: 2,
                uptime_s: 1.5,
                items_per_s: 800.0,
            }),
            Response::MetricsHistData(vec![
                HistSnapshot {
                    name: "service.request_ns".into(),
                    count: 42,
                    p50: 1536.0,
                    p90: 9000.5,
                    p99: 12000.0,
                    max: 15000,
                    min: 128,
                    mean: 2222.5,
                },
                HistSnapshot {
                    name: "empty.hist".into(),
                    count: 0,
                    p50: 0.0,
                    p90: 0.0,
                    p99: 0.0,
                    max: 0,
                    min: 0,
                    mean: 0.0,
                },
            ]),
            Response::MetricsHistData(Vec::new()),
            Response::Watching { interval_ms: 500, mode: WatchMode::All },
            Response::Pong,
            Response::Bye,
            Response::Error { code: ErrorCode::NoSession, message: "unknown session".into() },
        ];
        for resp in cases {
            let line = resp.to_line();
            assert_eq!(Response::parse(&line).unwrap(), resp, "line: {line}");
        }
    }

    /// Wall fields ride STATS and survive the roundtrip — and a pre-PR-7
    /// reply without them still parses with zero defaults (the
    /// `kernel_evals` compatibility pattern). Checked field-by-field
    /// because `AlgoStats::eq` deliberately ignores the timing fields.
    #[test]
    fn stats_wall_fields_roundtrip_and_default() {
        let resp = Response::StatsData {
            id: "t".into(),
            reply: StatsReply {
                stats: AlgoStats {
                    queries: 10,
                    kernel_evals: 20,
                    elements: 30,
                    stored: 2,
                    peak_stored: 2,
                    instances: 1,
                    wall_kernel_ns: 111,
                    wall_solve_ns: 222,
                    wall_scan_ns: 333,
                    accepts: 2,
                    rejects: 28,
                    defers: 5,
                    threshold_moves: 1,
                },
                value: 0.5,
                len: 2,
                drift_events: 0,
                backend: "scalar".into(),
                rejected_rows: 0,
            },
        };
        match Response::parse(&resp.to_line()).unwrap() {
            Response::StatsData { reply, .. } => {
                assert_eq!(reply.stats.wall_kernel_ns, 111);
                assert_eq!(reply.stats.wall_solve_ns, 222);
                assert_eq!(reply.stats.wall_scan_ns, 333);
                assert_eq!(reply.stats.accepts, 2);
                assert_eq!(reply.stats.rejects, 28);
                assert_eq!(reply.stats.defers, 5);
                assert_eq!(reply.stats.threshold_moves, 1);
            }
            other => panic!("{other:?}"),
        }
        let legacy = "OK STATS id=t elements=30 queries=10 kernel_evals=20 stored=2 peak=2 \
                      instances=1 len=2 value=0.5 drift=0";
        match Response::parse(legacy).unwrap() {
            Response::StatsData { reply, .. } => {
                assert_eq!(reply.stats.queries, 10);
                assert_eq!(reply.stats.wall_kernel_ns, 0);
                assert_eq!(reply.stats.wall_solve_ns, 0);
                assert_eq!(reply.stats.wall_scan_ns, 0);
                assert_eq!(reply.stats.accepts, 0);
                assert_eq!(reply.stats.rejects, 0);
                assert_eq!(reply.stats.defers, 0);
                assert_eq!(reply.stats.threshold_moves, 0);
                assert_eq!(reply.backend, "scalar", "pre-SIMD replies default to scalar");
                assert_eq!(reply.rejected_rows, 0, "pre-PR-10 replies default to 0");
            }
            other => panic!("{other:?}"),
        }
    }

    /// Old peers emit 6-cell `METRICS HIST` entries (no `min`/`mean`);
    /// the parser must accept both generations, mixed in one reply.
    #[test]
    fn hist_parse_accepts_legacy_six_cell_entries() {
        let legacy = "OK METRICS HIST n=2 hist=a.ns:5:10.5:20:30:40;b.ns:1:2:3:4:5";
        match Response::parse(legacy).unwrap() {
            Response::MetricsHistData(hists) => {
                assert_eq!(hists.len(), 2);
                assert_eq!(hists[0].name, "a.ns");
                assert_eq!(hists[0].count, 5);
                assert_eq!(hists[0].max, 40);
                assert_eq!(hists[0].min, 0, "legacy entries default min to 0");
                assert_eq!(hists[0].mean, 0.0, "legacy entries default mean to 0");
            }
            other => panic!("{other:?}"),
        }
        let mixed = "OK METRICS HIST n=2 hist=a.ns:5:10:20:30:40:1:15.5;b.ns:1:2:3:4:5";
        match Response::parse(mixed).unwrap() {
            Response::MetricsHistData(hists) => {
                assert_eq!(hists[0].min, 1);
                assert_eq!(hists[0].mean, 15.5);
                assert_eq!(hists[1].min, 0);
            }
            other => panic!("{other:?}"),
        }
        for bad in [
            "OK METRICS HIST n=1 hist=a:1:2:3:4",         // 5 cells
            "OK METRICS HIST n=1 hist=a:1:2:3:4:5:6",     // 7 cells
            "OK METRICS HIST n=1 hist=a:1:2:3:4:5:6:7:8", // 9 cells
            "OK METRICS HIST n=1 hist=a:x:2:3:4:5",       // non-numeric
            "OK METRICS HIST n=3 hist=a:1:2:3:4:5",       // count mismatch
        ] {
            assert!(Response::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn watch_frames_roundtrip() {
        let full = WatchFrame {
            seq: 7,
            dropped: 2,
            events: Some(crate::obs::EventTotals {
                accepts: 10,
                rejects: 990,
                defers: 12,
                threshold_moves: 3,
                confidence_resets: 1,
                sieve_spawns: 40,
                sieve_retires: 28,
                drift_resets: 2,
                checkpoint_saves: 5,
                checkpoint_restores: 1,
                session_quarantines: 1,
                checkpoint_quarantines: 2,
            }),
            hists: Some(vec![HistSnapshot {
                name: "service.request_ns".into(),
                count: 9,
                p50: 100.0,
                p90: 200.0,
                p99: 300.0,
                max: 400,
                min: 50,
                mean: 150.25,
            }]),
        };
        assert_eq!(WatchFrame::parse(&full.to_line()).unwrap(), full);
        let events_only =
            WatchFrame { seq: 0, dropped: 0, events: Some(Default::default()), hists: None };
        assert_eq!(WatchFrame::parse(&events_only.to_line()).unwrap(), events_only);
        let hist_only = WatchFrame { seq: 1, dropped: 0, events: None, hists: Some(vec![]) };
        assert_eq!(WatchFrame::parse(&hist_only.to_line()).unwrap(), hist_only);
        assert!(WatchFrame::parse("OK WATCH").is_err());
        assert!(WatchFrame::parse("FRAME seq=1").is_err(), "missing dropped=");
        // A frame from an older peer (fewer event kinds) parses with the
        // missing tail defaulting to 0 — same policy as 6-cell hists.
        let legacy = WatchFrame::parse("FRAME seq=1 dropped=0 events=1:2:3:4:5:6:7:8:9:10")
            .expect("pre-PR-10 ten-cell frames must still parse");
        let ev = legacy.events.expect("events present");
        assert_eq!(ev.accepts, 1);
        assert_eq!(ev.checkpoint_restores, 10);
        assert_eq!(ev.session_quarantines, 0, "missing tail defaults to 0");
        assert_eq!(ev.checkpoint_quarantines, 0);
        // A frame from a *newer* schema (more cells than we know) is an error.
        assert!(
            WatchFrame::parse("FRAME seq=1 dropped=0 events=1:2:3:4:5:6:7:8:9:10:11:12:13")
                .is_err(),
            "over-long cell lists must be rejected"
        );
        assert!(WatchFrame::parse("FRAME seq=1 dropped=0 events=1:x:3").is_err(), "bad cell");
    }

    #[test]
    fn error_messages_are_single_line() {
        let r = Response::error(ErrorCode::Io, "disk\nfull\r\n");
        assert!(!r.to_line().contains('\n'));
    }

    #[test]
    fn id_validation() {
        assert!(valid_id("tenant-1.a_B"));
        assert!(!valid_id(""));
        assert!(!valid_id("a b"));
        assert!(!valid_id("a/b"));
        assert!(!valid_id(&"x".repeat(65)));
    }
}
