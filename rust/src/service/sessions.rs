//! Multi-tenant session management: many independent `(algorithm, drift
//! detector, stats)` sessions in one process, each on the paper's fixed
//! per-stream memory budget (at most `K` stored elements = `K·d` f32s).
//!
//! The [`SessionManager`] owns the tenant map and enforces the service's
//! resource contract:
//!
//! * **Admission control** — `OPEN` is refused once `max_sessions` tenants
//!   are live or the stored-element reservation `Σ K` would exceed
//!   `max_total_stored`.
//! * **LRU idle eviction** — sessions untouched for `idle_timeout` are
//!   checkpointed to `<checkpoint_dir>/<id>.ckpt` (atomic save) and
//!   dropped, oldest first.
//! * **Resume** — a re-`OPEN` of an evicted/closed id with the same spec
//!   restores the algorithm from its checkpoint's state blob and continues
//!   **bit-identically** to a session that was never evicted
//!   (`rust/tests/service_integration.rs` pins this).
//!
//! ## Thread-safety
//!
//! Sessions are reached from whichever connection-handler thread carries
//! the tenant's TCP connection, so they must cross thread boundaries even
//! though [`StreamingAlgorithm`] is not `Send` (its oracle box is not —
//! see [`crate::functions::SubmodularFunction`]). The crate's second and
//! final audited `Send` erasure site lives here: [`SessionCell`] wraps
//! each session in a `Mutex` and asserts `Send + Sync`, which is sound
//! because (a) [`build_session_algo`] refuses any oracle family that does
//! not promise
//! [`parallel_safe`](crate::functions::SubmodularFunction::parallel_safe)
//! — the same contract the exec pool's `AssertThreadSafe` rests on — and
//! (b) the mutex guarantees no two threads ever touch a session
//! concurrently.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

use crate::algorithms::StreamingAlgorithm;
use crate::config::ServiceConfig;
use crate::coordinator::checkpoint::{self, Checkpoint, CheckpointError};
use crate::coordinator::drift::{DriftDetector, MeanShiftDetector, NoDrift};
use crate::experiments::runner::make_oracle;
use crate::experiments::{build_algo, GammaMode};
use crate::util::json::Json;

use super::protocol::{
    valid_id, ErrorCode, MetricsSnapshot, PushBody, PushReply, Request, Response, SessionSpec,
    StatsReply, SummaryReply,
};

/// Typed service failure, mapped 1:1 onto wire [`ErrorCode`]s.
#[derive(Clone, Debug, PartialEq)]
pub enum ServiceError {
    NoSession(String),
    Exists(String),
    SessionLimit { max: usize },
    Capacity { reserved: usize, requested: usize, max: usize },
    DimMismatch { expected: usize, got: usize },
    /// A pushed batch carries a non-finite f32 (NaN/±Inf) at the named
    /// position; the whole batch was rejected before touching the oracle.
    NonFinite { row: usize, col: usize },
    /// The session is fenced off after a fault (poisoned lock or handler
    /// panic); only `CLOSE <id> discard` releases it.
    Quarantined(String),
    Invalid(String),
    Io(String),
}

impl ServiceError {
    pub fn code(&self) -> ErrorCode {
        match self {
            ServiceError::NoSession(_) => ErrorCode::NoSession,
            ServiceError::Exists(_) => ErrorCode::Exists,
            ServiceError::SessionLimit { .. } => ErrorCode::SessionLimit,
            ServiceError::Capacity { .. } => ErrorCode::Capacity,
            ServiceError::DimMismatch { .. } => ErrorCode::DimMismatch,
            ServiceError::NonFinite { .. } => ErrorCode::NonFinite,
            ServiceError::Quarantined(_) => ErrorCode::Quarantined,
            ServiceError::Invalid(_) => ErrorCode::BadRequest,
            ServiceError::Io(_) => ErrorCode::Io,
        }
    }
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::NoSession(id) => write!(f, "unknown session {id:?}"),
            ServiceError::Exists(id) => write!(f, "session {id:?} is already open"),
            ServiceError::SessionLimit { max } => {
                write!(f, "session limit reached ({max} open)")
            }
            ServiceError::Capacity { reserved, requested, max } => write!(
                f,
                "stored-element capacity exceeded: {reserved} reserved + {requested} \
                 requested > {max}"
            ),
            ServiceError::DimMismatch { expected, got } => {
                write!(f, "row has {got} features, session dim is {expected}")
            }
            ServiceError::NonFinite { row, col } => write!(
                f,
                "non-finite value at row {row} column {col}; batch rejected"
            ),
            ServiceError::Quarantined(id) => write!(
                f,
                "session {id:?} is quarantined after a fault; CLOSE {id} discard releases it"
            ),
            ServiceError::Invalid(msg) => write!(f, "{msg}"),
            ServiceError::Io(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// One tenant's state: the streaming algorithm, its drift detector, and
/// the drift-event base carried over from a resumed checkpoint.
struct Session {
    spec: SessionSpec,
    algo: Box<dyn StreamingAlgorithm>,
    drift: Box<dyn DriftDetector>,
    /// Drift events recorded before the last resume (the detector itself
    /// restarts cold — its window is deliberately not persisted).
    drift_base: usize,
    /// Rows this session refused under the non-finite input policy.
    /// Deliberately not persisted: like the drift window, it describes
    /// what this *incarnation* saw, not the summary state.
    rejected_rows: u64,
}

impl Session {
    fn drift_events(&self) -> usize {
        self.drift_base + self.drift.events()
    }

    /// Ingest validated, row-aligned data. Without drift detection this is
    /// one `process_batch` call — exactly what a standalone run over the
    /// same chunks executes, so results stay bit-identical. With drift
    /// enabled the pipeline's ordering is reproduced: every row is
    /// observed *before* it reaches the algorithm, and a firing flushes
    /// the pending prefix, resets the summary, then lets the firing row
    /// start the next batch.
    fn push(&mut self, rows: &[f32]) -> PushReply {
        let d = self.spec.dim;
        let n = rows.len() / d;
        if self.spec.drift.is_none() {
            if n > 0 {
                self.algo.process_batch(rows);
            }
        } else {
            let mut start = 0usize;
            for i in 0..n {
                if self.drift.observe(&rows[i * d..(i + 1) * d]) {
                    if start < i {
                        self.algo.process_batch(&rows[start * d..i * d]);
                    }
                    {
                        let _g = crate::obs::span("drift-reset");
                        if crate::obs::enabled() {
                            crate::obs::emit_event(crate::obs::Event::DriftReset {
                                elements: self.algo.stats().elements,
                            });
                        }
                        self.algo.reset();
                    }
                    start = i;
                }
            }
            if start < n {
                self.algo.process_batch(&rows[start * d..]);
            }
        }
        PushReply {
            rows: n as u64,
            len: self.algo.summary_len(),
            value: self.algo.value(),
            drift_events: self.drift_events(),
        }
    }

    fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            algorithm: self.algo.name(),
            dim: self.spec.dim,
            k: self.spec.k,
            value: self.algo.value(),
            elements: self.algo.stats().elements,
            drift_events: self.drift_events(),
            state: self.algo.snapshot_state().unwrap_or(Json::Null),
            summary: self.algo.summary(),
        }
    }
}

/// Shared per-session slot: the LRU stamp lives outside the mutex so the
/// eviction sweep never blocks behind an in-flight push.
///
/// # Safety
///
/// `Session` is not `Send`/`Sync` only because its algorithm owns
/// `Box<dyn SubmodularFunction>` trait objects. Asserting both here is
/// sound because [`build_session_algo`] is the sole construction path and
/// it refuses oracle families whose
/// [`parallel_safe`](crate::functions::SubmodularFunction::parallel_safe)
/// is false — the per-implementation promise that instances are
/// self-contained owned data which may be *used* from any thread as long
/// as no two threads touch one concurrently. The `Mutex` provides exactly
/// that exclusion, and the manager never leaks `&Session` outside a
/// guard. This mirrors `exec::AssertThreadSafe`, the crate's other
/// audited erasure site.
struct SessionCell {
    /// The session's stored-element reservation (its `K`), readable
    /// without locking for admission accounting.
    k: usize,
    /// Milliseconds since manager start at last access.
    touched_ms: AtomicU64,
    /// Set by `close`/`shutdown` before the final checkpoint is written:
    /// new lookups are refused, and a straggler `push` that fetched the
    /// cell earlier re-checks this *after* acquiring the session lock —
    /// so no push is ever acknowledged without being covered by the
    /// closing checkpoint.
    closing: std::sync::atomic::AtomicBool,
    /// Set when a fault (handler panic, poisoned lock) fenced this tenant
    /// off. Quarantined sessions answer `ERR quarantined` to every verb
    /// except `CLOSE <id> discard`, hold their admission reservation, and
    /// are skipped by eviction/checkpoint sweeps — their in-memory state
    /// is suspect and must never be persisted over a good checkpoint.
    quarantined: std::sync::atomic::AtomicBool,
    session: Mutex<Session>,
}

unsafe impl Send for SessionCell {}
unsafe impl Sync for SessionCell {}

#[derive(Default)]
struct Counters {
    opens: AtomicU64,
    resumes: AtomicU64,
    pushes: AtomicU64,
    items: AtomicU64,
    evictions: AtomicU64,
    closes: AtomicU64,
    checkpoints: AtomicU64,
    checkpoint_failures: AtomicU64,
    /// Rows refused under the non-finite input policy (lifetime).
    rejected_rows: AtomicU64,
    /// Sessions fenced off after a fault (lifetime).
    quarantines: AtomicU64,
    /// Corrupt checkpoint files moved aside to `.corrupt` (lifetime),
    /// whether found by the startup sweep or by a resume attempt.
    ckpt_quarantines: AtomicU64,
}

/// Construct a session's algorithm, enforcing the service's two
/// constraints: streaming-capable specs only, and thread-safe oracle
/// families only (see [`SessionCell`] safety docs).
fn build_session_algo(spec: &SessionSpec) -> Result<Box<dyn StreamingAlgorithm>, ServiceError> {
    if spec.dim == 0 || spec.k == 0 {
        return Err(ServiceError::Invalid("k and dim must be positive".into()));
    }
    if spec.algo.entry().offline {
        return Err(ServiceError::Invalid(format!(
            "{} is an offline algorithm; pick a streaming one",
            spec.algo.name()
        )));
    }
    // Thread-safety gate: `build_algo` constructs every oracle through
    // `make_oracle`, so probing one instance vouches for the family the
    // session will hold. A non-parallel_safe oracle (e.g. PJRT) must never
    // enter a SessionCell.
    let probe = make_oracle(spec.dim, spec.k, GammaMode::Streaming);
    if !probe.parallel_safe() {
        return Err(ServiceError::Invalid(
            "session oracle family is not thread-safe; cannot host it multi-tenant".into(),
        ));
    }
    Ok(build_algo(&spec.algo, spec.dim, spec.k, GammaMode::Streaming, None))
}

/// The tenant map plus service-wide accounting. All methods take `&self`
/// and are safe to call from any number of threads.
pub struct SessionManager {
    cfg: ServiceConfig,
    started: Instant,
    sessions: Mutex<HashMap<String, Arc<SessionCell>>>,
    counters: Counters,
}

impl SessionManager {
    pub fn new(cfg: ServiceConfig) -> Self {
        let counters = Counters::default();
        // Startup recovery sweep: a crash mid-save can leave stale `.tmp`
        // staging files and (pre-v2 torn writes aside) corrupt `.ckpt`s.
        // Clean both BEFORE the first OPEN so every resume decision sees
        // only loadable checkpoints or quarantined `.corrupt` siblings.
        if let Some(dir) = &cfg.checkpoint_dir {
            let report = checkpoint::sweep_dir(dir);
            if report.quarantined > 0 || report.stale_tmp > 0 {
                eprintln!(
                    "checkpoint recovery in {}: {} good, {} quarantined, {} stale tmp removed",
                    dir.display(),
                    report.good,
                    report.quarantined,
                    report.stale_tmp
                );
            }
            counters.ckpt_quarantines.fetch_add(report.quarantined as u64, Ordering::Relaxed);
        }
        SessionManager {
            cfg,
            started: Instant::now(),
            sessions: Mutex::new(HashMap::new()),
            counters,
        }
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    fn now_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    fn map(&self) -> MutexGuard<'_, HashMap<String, Arc<SessionCell>>> {
        // The map mutex is only ever held for pointer-sized bookkeeping —
        // no user code runs under it, so poisoning here means a bug in
        // this module, not a tenant fault. Riding through is safe because
        // every critical section leaves the map structurally valid.
        self.sessions.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Fence one tenant off after a fault. Idempotent: only the first
    /// marking bumps the counter and emits the observability event, so a
    /// storm of requests against a broken session is counted once.
    #[cold]
    fn quarantine_cell(&self, id: &str, cell: &SessionCell, elements: u64) {
        if !cell.quarantined.swap(true, Ordering::SeqCst) {
            self.counters.quarantines.fetch_add(1, Ordering::Relaxed);
            if crate::obs::enabled() {
                crate::obs::emit_event(crate::obs::Event::SessionQuarantine { elements });
            }
            eprintln!("session {id:?} quarantined after fault");
        }
    }

    /// Acquire one session's lock unless the tenant is quarantined. A
    /// poisoned lock — some thread panicked while holding it — quarantines
    /// the tenant on the spot instead of riding through: the summary may
    /// be mid-mutation, and serving or persisting it would trade a loud
    /// typed error for silent corruption. Only this one tenant is lost;
    /// the manager and every other session keep running.
    fn lock_session<'a>(
        &self,
        id: &str,
        cell: &'a SessionCell,
    ) -> Result<MutexGuard<'a, Session>, ServiceError> {
        if cell.quarantined.load(Ordering::SeqCst) {
            return Err(ServiceError::Quarantined(id.to_string()));
        }
        match cell.session.lock() {
            Ok(guard) => Ok(guard),
            Err(_poisoned) => {
                self.quarantine_cell(id, cell, 0);
                Err(ServiceError::Quarantined(id.to_string()))
            }
        }
    }

    /// The admission rules, judged against one view of the map: id free,
    /// session count under the cap, Σ K reservation within budget.
    fn admit(
        &self,
        map: &HashMap<String, Arc<SessionCell>>,
        id: &str,
        k: usize,
    ) -> Result<(), ServiceError> {
        if map.contains_key(id) {
            return Err(ServiceError::Exists(id.to_string()));
        }
        if map.len() >= self.cfg.max_sessions {
            return Err(ServiceError::SessionLimit { max: self.cfg.max_sessions });
        }
        let reserved: usize = map.values().map(|c| c.k).sum();
        if reserved + k > self.cfg.max_total_stored {
            return Err(ServiceError::Capacity {
                reserved,
                requested: k,
                max: self.cfg.max_total_stored,
            });
        }
        Ok(())
    }

    /// Open (or resume) a session. Returns whether it resumed from a
    /// checkpoint.
    pub fn open(&self, id: &str, spec: &SessionSpec) -> Result<bool, ServiceError> {
        if !valid_id(id) {
            return Err(ServiceError::Invalid(format!("invalid session id {id:?}")));
        }
        // Expired tenants release their slots before admission is judged.
        self.evict_idle();
        // Cheap pre-flight admission BEFORE paying for oracle construction
        // or checkpoint replay — a retry loop hammering a full service must
        // cost O(map) per refusal, not a Cholesky build plus disk I/O. The
        // authoritative re-check happens under the lock again right before
        // the insert.
        self.admit(&self.map(), id, spec.k)?;
        let mut algo = build_session_algo(spec)?;
        // Resume path, done WITHOUT holding the map lock (checkpoint load
        // is disk I/O and restore replays the summary through the oracle —
        // no reason to stall every other tenant behind it): a matching
        // checkpoint with a state blob restores the algorithm exactly;
        // anything else (absent, summary-only, mismatched spec, corrupt)
        // starts fresh with resumed=0. A concurrent OPEN of the same id
        // only wastes this work — the insert below still decides the
        // winner and the loser gets `Exists`.
        let mut resumed = false;
        let mut drift_base = 0usize;
        if let Some(dir) = &self.cfg.checkpoint_dir {
            let path = dir.join(format!("{id}.ckpt"));
            match Checkpoint::load(&path) {
                Ok(ck) => {
                    if ck.state != Json::Null
                        && ck.dim == spec.dim
                        && ck.k == spec.k
                        && algo.restore_state(&ck.state, &ck.summary).is_ok()
                    {
                        resumed = true;
                        drift_base = ck.drift_events;
                    }
                }
                // A corrupt checkpoint must not block the tenant: move the
                // bytes aside for forensics and let this OPEN start fresh.
                Err(CheckpointError::Corrupt(c)) => {
                    if checkpoint::quarantine(&path).is_ok() {
                        self.counters.ckpt_quarantines.fetch_add(1, Ordering::Relaxed);
                        eprintln!("checkpoint for {id:?} quarantined on open: {c}");
                    }
                }
                // Absent or unreadable: plain fresh start, as before.
                Err(CheckpointError::Io(_)) => {}
            }
        }
        let mut map = self.map();
        self.admit(&map, id, spec.k)?;
        let drift: Box<dyn DriftDetector> = match spec.drift {
            Some((w, th)) => Box::new(MeanShiftDetector::new(spec.dim, w, th)),
            None => Box::new(NoDrift::default()),
        };
        let session = Session { spec: spec.clone(), algo, drift, drift_base, rejected_rows: 0 };
        map.insert(
            id.to_string(),
            Arc::new(SessionCell {
                k: spec.k,
                touched_ms: AtomicU64::new(self.now_ms()),
                closing: std::sync::atomic::AtomicBool::new(false),
                quarantined: std::sync::atomic::AtomicBool::new(false),
                session: Mutex::new(session),
            }),
        );
        self.counters.opens.fetch_add(1, Ordering::Relaxed);
        if resumed {
            self.counters.resumes.fetch_add(1, Ordering::Relaxed);
        }
        Ok(resumed)
    }

    /// Fetch a live cell, refreshing its LRU stamp.
    fn cell(&self, id: &str) -> Result<Arc<SessionCell>, ServiceError> {
        let map = self.map();
        let cell = map.get(id).ok_or_else(|| ServiceError::NoSession(id.to_string()))?;
        if cell.closing.load(Ordering::SeqCst) {
            return Err(ServiceError::NoSession(id.to_string()));
        }
        cell.touched_ms.store(self.now_ms(), Ordering::Relaxed);
        Ok(Arc::clone(cell))
    }

    pub fn push(&self, id: &str, body: &PushBody) -> Result<PushReply, ServiceError> {
        let cell = self.cell(id)?;
        let mut session = self.lock_session(id, &cell)?;
        // Straggler guard: if a close/shutdown marked the cell after we
        // fetched it, its final checkpoint is (or is about to be) on disk
        // without these rows — refuse rather than acknowledge data that
        // would silently miss the persisted state.
        if cell.closing.load(Ordering::SeqCst) {
            return Err(ServiceError::NoSession(id.to_string()));
        }
        let d = session.spec.dim;
        // Oracle-poisoning fault: flips one value to NaN *before* the
        // non-finite gate below, proving the gate (not luck) keeps
        // injected garbage away from the oracle.
        let injected_nan = matches!(
            crate::fault::check(crate::fault::site::PUSH_ROWS),
            Some(crate::fault::FaultKind::PoisonNan)
        );
        // CSV rows must be flattened (they arrive as separate Vecs); the
        // packed form is already row-major and feeds the algorithm
        // directly — no copy on the high-throughput path unless a fault
        // forces a mutable staging copy.
        let mut staged: Option<Vec<f32>> = match body {
            PushBody::Rows(rows) => {
                let mut flat = Vec::with_capacity(rows.iter().map(Vec::len).sum());
                for row in rows {
                    if row.len() != d {
                        return Err(ServiceError::DimMismatch { expected: d, got: row.len() });
                    }
                    flat.extend_from_slice(row);
                }
                Some(flat)
            }
            PushBody::Packed(flat) => {
                if flat.len() % d != 0 {
                    return Err(ServiceError::DimMismatch { expected: d, got: flat.len() % d });
                }
                if injected_nan {
                    Some(flat.clone())
                } else {
                    None
                }
            }
        };
        if injected_nan {
            if let Some(first) = staged.as_mut().and_then(|buf| buf.first_mut()) {
                *first = f32::NAN;
            }
        }
        let flat: &[f32] = match &staged {
            Some(buf) => buf,
            None => match body {
                PushBody::Packed(flat) => flat,
                PushBody::Rows(_) => unreachable!("CSV rows are always staged"),
            },
        };
        // Non-finite input policy: NaN/±Inf would flow through kernel
        // evaluations into every downstream marginal-gain comparison
        // (NaN makes them all false), silently corrupting the summary.
        // Reject the whole batch atomically — either every row reaches
        // the algorithm or none does, so a retried clean batch continues
        // bit-identically.
        if let Some(idx) = flat.iter().position(|v| !v.is_finite()) {
            let rows_rejected = (flat.len() / d) as u64;
            session.rejected_rows += rows_rejected;
            self.counters.rejected_rows.fetch_add(rows_rejected, Ordering::Relaxed);
            if crate::obs::enabled() {
                crate::obs::counter("service.rejected_rows").add(rows_rejected);
            }
            return Err(ServiceError::NonFinite { row: idx / d, col: idx % d });
        }
        // Panic containment: a handler panic (real bug or injected fault)
        // unwinds only to here. The guard lives OUTSIDE the closure, so
        // the mutex is NOT poisoned by the catch — the session is fenced
        // off explicitly instead, and the manager keeps serving every
        // other tenant.
        let elements_before = session.algo.stats().elements;
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if matches!(
                crate::fault::check(crate::fault::site::SESSION_HANDLER),
                Some(crate::fault::FaultKind::Panic)
            ) {
                panic!("{}", crate::fault::INJECTED_MSG);
            }
            session.push(flat)
        }));
        match outcome {
            Ok(reply) => {
                self.counters.pushes.fetch_add(1, Ordering::Relaxed);
                self.counters.items.fetch_add(reply.rows, Ordering::Relaxed);
                Ok(reply)
            }
            Err(_panic) => {
                self.quarantine_cell(id, &cell, elements_before);
                Err(ServiceError::Quarantined(id.to_string()))
            }
        }
    }

    pub fn summary(&self, id: &str) -> Result<SummaryReply, ServiceError> {
        let cell = self.cell(id)?;
        let session = self.lock_session(id, &cell)?;
        Ok(SummaryReply {
            dim: session.spec.dim,
            value: session.algo.value(),
            data: session.algo.summary(),
        })
    }

    pub fn stats(&self, id: &str) -> Result<StatsReply, ServiceError> {
        let cell = self.cell(id)?;
        let session = self.lock_session(id, &cell)?;
        Ok(StatsReply {
            stats: session.algo.stats(),
            value: session.algo.value(),
            len: session.algo.summary_len(),
            drift_events: session.drift_events(),
            backend: crate::simd::active_name().to_string(),
            rejected_rows: session.rejected_rows,
        })
    }

    /// Close a session, checkpointing it first unless `discard` is set (or
    /// no checkpoint dir is configured). Returns whether a checkpoint was
    /// written.
    ///
    /// The session leaves the map only *after* its checkpoint is safely on
    /// disk — a failed write returns the error with the session still
    /// live, and there is no remove-then-reinsert window during which a
    /// concurrent re-`OPEN` could silently displace the original state.
    /// `discard` also deletes any on-disk `<id>.ckpt`, so a later
    /// re-`OPEN` really does start fresh instead of resuming stale state.
    pub fn close(&self, id: &str, discard: bool) -> Result<bool, ServiceError> {
        let cell = {
            let map = self.map();
            map.get(id).cloned().ok_or_else(|| ServiceError::NoSession(id.to_string()))?
        };
        // Mark closing first: new lookups are refused and any push that
        // already fetched the cell re-checks the flag under the session
        // lock, so the checkpoint below cannot miss an acknowledged row.
        if cell.closing.swap(true, Ordering::SeqCst) {
            return Err(ServiceError::NoSession(id.to_string())); // concurrent close won
        }
        let checkpointed = if discard {
            if let Some(dir) = &self.cfg.checkpoint_dir {
                std::fs::remove_file(dir.join(format!("{id}.ckpt"))).ok();
            }
            false
        } else {
            match self.persist(id, &cell) {
                Ok(written) => written,
                Err(e) => {
                    self.counters.checkpoint_failures.fetch_add(1, Ordering::Relaxed);
                    cell.closing.store(false, Ordering::SeqCst); // keep the session live
                    return Err(e);
                }
            }
        };
        if self.map().remove(id).is_some() {
            self.counters.closes.fetch_add(1, Ordering::Relaxed);
        }
        Ok(checkpointed)
    }

    /// Write `<id>.ckpt` into the checkpoint dir (atomic tmp+rename).
    /// `Ok(false)` means persistence is disabled.
    fn persist(&self, id: &str, cell: &SessionCell) -> Result<bool, ServiceError> {
        let Some(dir) = &self.cfg.checkpoint_dir else {
            return Ok(false);
        };
        let ck = self.lock_session(id, cell)?.checkpoint();
        ck.save(&dir.join(format!("{id}.ckpt")))
            .map_err(|e| ServiceError::Io(format!("checkpoint {id}: {e}")))?;
        self.counters.checkpoints.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    /// Checkpoint-evict every session idle longer than the configured
    /// timeout, oldest (LRU) first. Returns the number evicted. A session
    /// whose checkpoint fails to write is kept alive instead of dropped.
    pub fn evict_idle(&self) -> usize {
        let timeout = self.cfg.idle_timeout;
        if timeout.is_zero() {
            return 0;
        }
        let Some(cutoff) = self.now_ms().checked_sub(timeout.as_millis() as u64) else {
            return 0;
        };
        let mut expired: Vec<(String, Arc<SessionCell>)> = {
            let map = self.map();
            map.iter()
                .filter(|(_, c)| c.touched_ms.load(Ordering::Relaxed) <= cutoff)
                .map(|(id, c)| (id.clone(), Arc::clone(c)))
                .collect()
        };
        expired.sort_by_key(|(_, c)| c.touched_ms.load(Ordering::Relaxed));
        let mut evicted = 0usize;
        for (id, cell) in expired {
            if cell.touched_ms.load(Ordering::Relaxed) > cutoff {
                continue; // touched since the scan
            }
            if cell.quarantined.load(Ordering::SeqCst) {
                // A quarantined session's state must never be persisted,
                // and dropping it would discard the evidence the operator
                // needs — it waits for an explicit `CLOSE <id> discard`.
                continue;
            }
            // Checkpoint FIRST, remove second: a failed write keeps the
            // tenant live (no state loss, no remove-then-reinsert window),
            // and a touch that lands between the write and the re-check
            // below simply cancels the eviction — the extra checkpoint
            // file is harmless because resume only consults it once the
            // session is gone from the map.
            if self.persist(&id, &cell).is_err() {
                self.counters.checkpoint_failures.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let mut map = self.map();
            let still_expired = match map.get(&id) {
                Some(c) => {
                    Arc::ptr_eq(c, &cell) && c.touched_ms.load(Ordering::Relaxed) <= cutoff
                }
                None => false,
            };
            if still_expired {
                map.remove(&id);
                evicted += 1;
                self.counters.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        evicted
    }

    /// Checkpoint every live session in place without evicting it —
    /// crash insurance for deployments that can only be stopped with a
    /// hard kill. Returns the number of checkpoints written; 0 when
    /// persistence is disabled.
    pub fn checkpoint_all(&self) -> usize {
        if self.cfg.checkpoint_dir.is_none() {
            return 0;
        }
        let cells: Vec<(String, Arc<SessionCell>)> =
            self.map().iter().map(|(id, c)| (id.clone(), Arc::clone(c))).collect();
        let mut written = 0usize;
        for (id, cell) in cells {
            if cell.quarantined.load(Ordering::SeqCst) {
                continue; // suspect state is never persisted
            }
            match self.persist(&id, &cell) {
                Ok(true) => written += 1,
                Ok(false) => {}
                Err(_) => {
                    self.counters.checkpoint_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        written
    }

    /// Checkpoint and drop every live session (service shutdown). Returns
    /// the number of checkpoints written.
    pub fn shutdown(&self) -> usize {
        let cells: Vec<(String, Arc<SessionCell>)> = self.map().drain().collect();
        // Refuse straggler pushes that fetched a cell before the drain —
        // they must not be acknowledged after the final checkpoint.
        for (_, cell) in &cells {
            cell.closing.store(true, Ordering::SeqCst);
        }
        let mut written = 0usize;
        for (id, cell) in cells {
            if cell.quarantined.load(Ordering::SeqCst) {
                continue; // suspect state is never persisted
            }
            match self.persist(&id, &cell) {
                Ok(true) => written += 1,
                Ok(false) => {}
                Err(_) => {
                    self.counters.checkpoint_failures.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        written
    }

    pub fn session_count(&self) -> usize {
        self.map().len()
    }

    /// Service-wide snapshot. `items`/`queries`/`stored` aggregate the
    /// live sessions' [`crate::metrics::AlgoStats`] — by construction they
    /// equal the sum of per-session `STATS` replies taken at the same
    /// moment.
    pub fn metrics(&self) -> MetricsSnapshot {
        // Snapshot the cell handles first, then aggregate without the map
        // lock — METRICS behind one busy tenant must not freeze session
        // lookup for everyone else. Every session guard is then held at
        // once while the sums are taken: a cell-at-a-time sweep would let
        // a push land between two locks, so `METRICS == Σ STATS` would
        // only hold for monotone counters and not for the wall-clock
        // fields. Guards are acquired in sorted-id order so two
        // concurrent METRICS calls cannot deadlock against each other.
        let mut cells: Vec<(String, Arc<SessionCell>)> =
            self.map().iter().map(|(id, c)| (id.clone(), Arc::clone(c))).collect();
        cells.sort_by(|a, b| a.0.cmp(&b.0));
        let sessions = cells.len();
        // Quarantined sessions still occupy a slot (counted above) but
        // cannot answer STATS, so they are excluded from the aggregates —
        // the `METRICS == Σ STATS` invariant ranges over the sessions
        // that can actually reply.
        let guards: Vec<_> =
            cells.iter().filter_map(|(id, c)| self.lock_session(id, c).ok()).collect();
        let mut stored = 0usize;
        let mut items = 0u64;
        let mut queries = 0u64;
        let mut kernel_evals = 0u64;
        let mut wall_kernel_ns = 0u64;
        let mut wall_solve_ns = 0u64;
        let mut wall_scan_ns = 0u64;
        let mut accepts = 0u64;
        let mut rejects = 0u64;
        let mut defers = 0u64;
        let mut threshold_moves = 0u64;
        for s in &guards {
            let st = s.algo.stats();
            stored += st.stored;
            items += st.elements;
            queries += st.queries;
            kernel_evals += st.kernel_evals;
            wall_kernel_ns += st.wall_kernel_ns;
            wall_solve_ns += st.wall_solve_ns;
            wall_scan_ns += st.wall_scan_ns;
            accepts += st.accepts;
            rejects += st.rejects;
            defers += st.defers;
            threshold_moves += st.threshold_moves;
        }
        drop(guards);
        let uptime_s = self.started.elapsed().as_secs_f64();
        let items_total = self.counters.items.load(Ordering::Relaxed);
        MetricsSnapshot {
            sessions,
            stored,
            items,
            queries,
            kernel_evals,
            wall_kernel_ns,
            wall_solve_ns,
            wall_scan_ns,
            accepts,
            rejects,
            defers,
            threshold_moves,
            backend: crate::simd::active_name().to_string(),
            opens: self.counters.opens.load(Ordering::Relaxed),
            resumes: self.counters.resumes.load(Ordering::Relaxed),
            pushes: self.counters.pushes.load(Ordering::Relaxed),
            items_total,
            evictions: self.counters.evictions.load(Ordering::Relaxed),
            closes: self.counters.closes.load(Ordering::Relaxed),
            checkpoints: self.counters.checkpoints.load(Ordering::Relaxed),
            rejected_rows: self.counters.rejected_rows.load(Ordering::Relaxed),
            quarantines: self.counters.quarantines.load(Ordering::Relaxed),
            ckpt_quarantines: self.counters.ckpt_quarantines.load(Ordering::Relaxed),
            uptime_s,
            items_per_s: if uptime_s > 0.0 { items_total as f64 / uptime_s } else { 0.0 },
        }
    }

    /// Execute one parsed request — the single dispatch point shared by
    /// the TCP server and in-process harnesses. When observability is on
    /// each call records a `service-request` span and a sample in the
    /// `service.request_ns` histogram.
    pub fn execute(&self, req: &Request) -> Response {
        let _g = crate::obs::span("service-request");
        let t = crate::obs::clock();
        let resp = self.execute_inner(req);
        if let Some(t) = t {
            static REQUEST_NS: OnceLock<Arc<crate::obs::Histogram>> = OnceLock::new();
            REQUEST_NS
                .get_or_init(|| crate::obs::histogram("service.request_ns"))
                .observe(t.elapsed().as_nanos() as u64);
        }
        resp
    }

    fn execute_inner(&self, req: &Request) -> Response {
        let err = |e: ServiceError| Response::error(e.code(), e.to_string());
        match req {
            Request::Open { id, spec } => match self.open(id, spec) {
                Ok(resumed) => Response::Opened { id: id.clone(), resumed },
                Err(e) => err(e),
            },
            Request::Push { id, body } => match self.push(id, body) {
                Ok(reply) => Response::Pushed { id: id.clone(), reply },
                Err(e) => err(e),
            },
            Request::Summary { id } => match self.summary(id) {
                Ok(reply) => Response::SummaryData { id: id.clone(), reply },
                Err(e) => err(e),
            },
            Request::Stats { id } => match self.stats(id) {
                Ok(reply) => Response::StatsData { id: id.clone(), reply },
                Err(e) => err(e),
            },
            Request::Close { id, discard } => match self.close(id, *discard) {
                Ok(checkpointed) => Response::Closed { id: id.clone(), checkpointed },
                Err(e) => err(e),
            },
            Request::Metrics => Response::MetricsData(self.metrics()),
            Request::MetricsHist => Response::MetricsHistData(crate::obs::histogram_snapshots()),
            // WATCH is a connection-level subscription: the TCP server
            // intercepts it before dispatch (it owns the write half the
            // frames go out on), so it can never reach the shared executor.
            Request::Watch { .. } => Response::error(
                ErrorCode::BadRequest,
                "WATCH binds to a connection; unavailable via in-process dispatch".into(),
            ),
            Request::Ping => Response::Pong,
            Request::Quit => Response::Bye,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgoSpec;
    use crate::data::registry;
    use std::time::Duration;

    fn cfg() -> ServiceConfig {
        ServiceConfig { idle_timeout: Duration::ZERO, ..ServiceConfig::default() }
    }

    fn spec(dim: usize, k: usize) -> SessionSpec {
        SessionSpec::three_sieves(dim, k, 0.01, 50)
    }

    fn stream(n: usize, seed: u64) -> crate::data::Dataset {
        registry::get("fact-highlevel-like", n, seed).unwrap()
    }

    #[test]
    fn push_matches_standalone_run() {
        let mgr = SessionManager::new(cfg());
        let ds = stream(400, 3);
        let sp = spec(ds.dim(), 6);
        assert!(!mgr.open("t1", &sp).unwrap());
        let d = ds.dim();
        let mut standalone = build_algo(&sp.algo, d, sp.k, GammaMode::Streaming, None);
        for chunk in ds.raw().chunks(64 * d) {
            let reply =
                mgr.push("t1", &PushBody::Packed(chunk.to_vec())).unwrap();
            standalone.process_batch(chunk);
            assert_eq!(reply.value.to_bits(), standalone.value().to_bits());
        }
        let summary = mgr.summary("t1").unwrap();
        assert_eq!(summary.data, standalone.summary());
        let stats = mgr.stats("t1").unwrap();
        assert_eq!(stats.stats, standalone.stats());
    }

    #[test]
    fn admission_control_refuses_over_caps() {
        let mut c = cfg();
        c.max_sessions = 2;
        c.max_total_stored = 10;
        let mgr = SessionManager::new(c);
        mgr.open("a", &spec(4, 4)).unwrap();
        mgr.open("b", &spec(4, 4)).unwrap();
        // Session cap first.
        match mgr.open("c", &spec(4, 1)) {
            Err(ServiceError::SessionLimit { max }) => assert_eq!(max, 2),
            other => panic!("{other:?}"),
        }
        mgr.close("b", true).unwrap();
        // Now the Σ K reservation cap: 4 + 7 > 10.
        match mgr.open("c", &spec(4, 7)) {
            Err(ServiceError::Capacity { reserved, requested, max }) => {
                assert_eq!((reserved, requested, max), (4, 7, 10));
            }
            other => panic!("{other:?}"),
        }
        // Within budget is fine: 4 + 6 = 10.
        mgr.open("c", &spec(4, 6)).unwrap();
    }

    #[test]
    fn session_errors_are_typed() {
        let mgr = SessionManager::new(cfg());
        let missing = mgr.push("nope", &PushBody::Packed(vec![]));
        assert!(matches!(missing, Err(ServiceError::NoSession(_))));
        mgr.open("t", &spec(4, 3)).unwrap();
        assert!(matches!(mgr.open("t", &spec(4, 3)), Err(ServiceError::Exists(_))));
        assert!(matches!(
            mgr.push("t", &PushBody::Rows(vec![vec![1.0; 3]])),
            Err(ServiceError::DimMismatch { expected: 4, got: 3 })
        ));
        assert!(matches!(
            mgr.push("t", &PushBody::Packed(vec![0.0; 7])),
            Err(ServiceError::DimMismatch { .. })
        ));
        assert!(matches!(
            mgr.open("u", &SessionSpec { algo: AlgoSpec::greedy(), dim: 4, k: 3, drift: None }),
            Err(ServiceError::Invalid(_))
        ));
        assert!(matches!(mgr.open("bad id", &spec(4, 3)), Err(ServiceError::Invalid(_))));
    }

    #[test]
    fn idle_eviction_checkpoints_and_reopen_resumes() {
        let dir = std::env::temp_dir().join(format!("ts_svc_evict_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let c = ServiceConfig {
            idle_timeout: Duration::from_millis(5),
            checkpoint_dir: Some(dir.clone()),
            ..ServiceConfig::default()
        };
        let mgr = SessionManager::new(c);
        let ds = stream(600, 9);
        let sp = spec(ds.dim(), 5);
        let d = ds.dim();
        let half = ds.len() / 2 * d;
        mgr.open("ev", &sp).unwrap();
        mgr.push("ev", &PushBody::Packed(ds.raw()[..half].to_vec())).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(mgr.evict_idle(), 1);
        assert_eq!(mgr.session_count(), 0);
        let ck = Checkpoint::load(&dir.join("ev.ckpt")).unwrap();
        assert_eq!(ck.dim, d);
        assert_ne!(ck.state, Json::Null, "ThreeSieves checkpoints must carry state");
        // Re-open resumes and finishes bit-identically to an uninterrupted run.
        assert!(mgr.open("ev", &sp).unwrap(), "must resume from the eviction checkpoint");
        mgr.push("ev", &PushBody::Packed(ds.raw()[half..].to_vec())).unwrap();
        let mut whole = build_algo(&sp.algo, d, sp.k, GammaMode::Streaming, None);
        whole.process_batch(&ds.raw()[..half]);
        whole.process_batch(&ds.raw()[half..]);
        let got = mgr.summary("ev").unwrap();
        assert_eq!(got.value.to_bits(), whole.value().to_bits());
        assert_eq!(got.data, whole.summary());
        assert_eq!(mgr.stats("ev").unwrap().stats, whole.stats());
        let m = mgr.metrics();
        assert_eq!(m.evictions, 1);
        assert_eq!(m.resumes, 1);
        // Discarding close also forgets the on-disk state.
        mgr.close("ev", true).unwrap();
        assert!(!dir.join("ev.ckpt").exists(), "discard close must delete the checkpoint");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_aggregate_live_session_stats() {
        let mgr = SessionManager::new(cfg());
        let mut want_items = 0u64;
        let mut want_queries = 0u64;
        let mut want_stored = 0usize;
        for (i, n) in [200usize, 300, 250].iter().enumerate() {
            let ds = stream(*n, i as u64 + 1);
            let id = format!("m{i}");
            mgr.open(&id, &spec(ds.dim(), 4)).unwrap();
            mgr.push(&id, &PushBody::Packed(ds.raw().to_vec())).unwrap();
            let st = mgr.stats(&id).unwrap().stats;
            want_items += st.elements;
            want_queries += st.queries;
            want_stored += st.stored;
        }
        let m = mgr.metrics();
        assert_eq!(m.sessions, 3);
        assert_eq!(m.items, want_items);
        assert_eq!(m.queries, want_queries);
        assert_eq!(m.stored, want_stored);
        assert_eq!(m.items_total, want_items, "no closes yet, totals match live");
        assert_eq!(m.opens, 3);
        assert_eq!(m.pushes, 3);
    }

    #[test]
    fn drift_session_reselects_like_the_pipeline() {
        let mgr = SessionManager::new(cfg());
        let ds = registry::get("stream51-like", 2000, 8).unwrap();
        let d = ds.dim();
        let sp = SessionSpec { drift: Some((100, 3.0)), ..spec(d, 6) };
        mgr.open("dr", &sp).unwrap();
        for chunk in ds.raw().chunks(64 * d) {
            mgr.push("dr", &PushBody::Packed(chunk.to_vec())).unwrap();
        }
        let st = mgr.stats("dr").unwrap();
        assert!(st.drift_events > 0, "stream51-like must drift");
        // Mirror of the pipeline's flush-before-reset ordering.
        let mut algo = build_algo(&sp.algo, d, sp.k, GammaMode::Streaming, None);
        let mut det = MeanShiftDetector::new(d, 100, 3.0);
        let mut pending: Vec<f32> = Vec::new();
        for row in ds.iter() {
            if det.observe(row) {
                if !pending.is_empty() {
                    algo.process_batch(&pending);
                    pending.clear();
                }
                algo.reset();
            }
            pending.extend_from_slice(row);
            if pending.len() >= 64 * d {
                algo.process_batch(&pending);
                pending.clear();
            }
        }
        if !pending.is_empty() {
            algo.process_batch(&pending);
        }
        assert_eq!(st.drift_events, det.events());
        let got = mgr.summary("dr").unwrap();
        assert_eq!(got.value.to_bits(), algo.value().to_bits());
        assert_eq!(got.data, algo.summary());
    }

    #[test]
    fn concurrent_pushes_from_threads_match_sequential_replay() {
        let mgr = Arc::new(SessionManager::new(cfg()));
        let n_sessions = 6;
        let handles: Vec<_> = (0..n_sessions)
            .map(|i| {
                let mgr = Arc::clone(&mgr);
                std::thread::spawn(move || {
                    let ds = stream(300 + 40 * i, 100 + i as u64);
                    let d = ds.dim();
                    let sp = spec(d, 5);
                    let id = format!("c{i}");
                    mgr.open(&id, &sp).unwrap();
                    for chunk in ds.raw().chunks(48 * d) {
                        mgr.push(&id, &PushBody::Packed(chunk.to_vec())).unwrap();
                    }
                    let got = mgr.summary(&id).unwrap();
                    let stats = mgr.stats(&id).unwrap().stats;
                    (ds, sp, got, stats)
                })
            })
            .collect();
        for h in handles {
            let (ds, sp, got, stats) = h.join().unwrap();
            let d = ds.dim();
            let mut solo = build_algo(&sp.algo, d, sp.k, GammaMode::Streaming, None);
            for chunk in ds.raw().chunks(48 * d) {
                solo.process_batch(chunk);
            }
            assert_eq!(got.value.to_bits(), solo.value().to_bits());
            assert_eq!(got.data, solo.summary());
            assert_eq!(stats, solo.stats());
        }
        assert_eq!(mgr.metrics().sessions, n_sessions);
    }

    #[test]
    fn nonfinite_rows_rejected_atomically_in_both_encodings() {
        let mgr = SessionManager::new(cfg());
        let ds = stream(300, 21);
        let d = ds.dim();
        let sp = spec(d, 5);
        mgr.open("nf", &sp).unwrap();
        // Packed encoding: NaN in the middle of the second row.
        let mut bad = ds.raw()[..3 * d].to_vec();
        bad[d + 1] = f32::NAN;
        match mgr.push("nf", &PushBody::Packed(bad)) {
            Err(ServiceError::NonFinite { row: 1, col: 1 }) => {}
            other => panic!("{other:?}"),
        }
        // CSV encoding: +Inf in the first row.
        let mut row0 = ds.raw()[..d].to_vec();
        row0[0] = f32::INFINITY;
        let rows = vec![row0, ds.raw()[d..2 * d].to_vec()];
        match mgr.push("nf", &PushBody::Rows(rows)) {
            Err(ServiceError::NonFinite { row: 0, col: 0 }) => {}
            other => panic!("{other:?}"),
        }
        // Rejection is atomic: after both refusals the session continues
        // bit-identically to an algorithm that never saw the bad batches.
        mgr.push("nf", &PushBody::Packed(ds.raw().to_vec())).unwrap();
        let mut solo = build_algo(&sp.algo, d, sp.k, GammaMode::Streaming, None);
        solo.process_batch(ds.raw());
        let got = mgr.summary("nf").unwrap();
        assert_eq!(got.value.to_bits(), solo.value().to_bits());
        assert_eq!(got.data, solo.summary());
        let st = mgr.stats("nf").unwrap();
        assert_eq!(st.rejected_rows, 3 + 2, "both refused batches counted in full");
        assert_eq!(mgr.metrics().rejected_rows, 5);
    }

    #[test]
    fn handler_panic_quarantines_one_session_not_the_manager() {
        let _serial = crate::fault::test_plan_lock();
        let mgr = SessionManager::new(cfg());
        let ds = stream(200, 33);
        let d = ds.dim();
        mgr.open("bad", &spec(d, 4)).unwrap();
        mgr.open("good", &spec(d, 4)).unwrap();
        let plan = crate::fault::FaultPlan::new()
            .once(crate::fault::site::SESSION_HANDLER, crate::fault::FaultKind::Panic);
        crate::fault::arm(plan);
        let hit = mgr.push("bad", &PushBody::Packed(ds.raw()[..4 * d].to_vec()));
        crate::fault::disarm();
        assert!(matches!(hit, Err(ServiceError::Quarantined(_))), "{hit:?}");
        // Every verb except discard-close now refuses this tenant...
        assert!(matches!(mgr.stats("bad"), Err(ServiceError::Quarantined(_))));
        assert!(matches!(mgr.summary("bad"), Err(ServiceError::Quarantined(_))));
        assert!(matches!(
            mgr.push("bad", &PushBody::Packed(ds.raw()[..d].to_vec())),
            Err(ServiceError::Quarantined(_))
        ));
        assert!(matches!(mgr.close("bad", false), Err(ServiceError::Quarantined(_))));
        // ...while the neighbour tenant is untouched.
        mgr.push("good", &PushBody::Packed(ds.raw().to_vec())).unwrap();
        assert!(mgr.stats("good").is_ok());
        let m = mgr.metrics();
        assert_eq!(m.quarantines, 1);
        assert_eq!(m.sessions, 2, "quarantined session still occupies its slot");
        // Discard-close releases the slot; the id is reusable.
        mgr.close("bad", true).unwrap();
        assert_eq!(mgr.session_count(), 1);
        mgr.open("bad", &spec(d, 4)).unwrap();
        mgr.push("bad", &PushBody::Packed(ds.raw()[..2 * d].to_vec())).unwrap();
    }

    #[test]
    fn poisoned_lock_quarantines_instead_of_riding_through() {
        let mgr = SessionManager::new(cfg());
        let ds = stream(100, 7);
        let d = ds.dim();
        mgr.open("p", &spec(d, 3)).unwrap();
        mgr.push("p", &PushBody::Packed(ds.raw()[..4 * d].to_vec())).unwrap();
        // Poison the session mutex the only way possible: panic while
        // holding the raw guard (production code can't — push catches).
        let cell = mgr.cell("p").unwrap();
        let _ = std::thread::spawn(move || {
            let _g = cell.session.lock().unwrap();
            panic!("poison the session lock");
        })
        .join();
        assert!(matches!(mgr.stats("p"), Err(ServiceError::Quarantined(_))));
        assert_eq!(mgr.metrics().quarantines, 1);
        mgr.close("p", true).unwrap();
        assert_eq!(mgr.session_count(), 0);
    }

    #[test]
    fn open_after_corrupt_checkpoint_quarantines_and_starts_fresh() {
        let dir = std::env::temp_dir().join(format!("ts_svc_corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Written AFTER construction so the startup sweep can't clean it:
        // this exercises the resume path's own quarantine arm.
        let mgr = SessionManager::new(ServiceConfig {
            checkpoint_dir: Some(dir.clone()),
            idle_timeout: Duration::ZERO,
            ..ServiceConfig::default()
        });
        std::fs::write(dir.join("cx.ckpt"), b"definitely not a checkpoint").unwrap();
        assert!(!mgr.open("cx", &spec(4, 3)).unwrap(), "fresh open, not a resume");
        assert!(!dir.join("cx.ckpt").exists(), "corrupt file moved aside");
        assert!(dir.join("cx.ckpt.corrupt").exists(), "quarantined sibling kept");
        assert_eq!(mgr.metrics().ckpt_quarantines, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn startup_sweep_quarantines_corrupt_and_counts_it() {
        let dir = std::env::temp_dir().join(format!("ts_svc_sweep_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("old.ckpt"), b"garbage header").unwrap();
        std::fs::write(dir.join("stale.ckpt.tmp"), b"torn staging file").unwrap();
        let mgr = SessionManager::new(ServiceConfig {
            checkpoint_dir: Some(dir.clone()),
            idle_timeout: Duration::ZERO,
            ..ServiceConfig::default()
        });
        assert!(!dir.join("stale.ckpt.tmp").exists(), "stale tmp cleaned at startup");
        assert!(dir.join("old.ckpt.corrupt").exists(), "corrupt checkpoint fenced off");
        assert_eq!(mgr.metrics().ckpt_quarantines, 1);
        assert!(!mgr.open("old", &spec(4, 3)).unwrap(), "fresh OPEN proceeds after sweep");
        std::fs::remove_dir_all(&dir).ok();
    }
}
