//! The multi-tenant streaming service: thousands of independent
//! summarization sessions in one process, each on the paper's fixed
//! per-stream memory budget (at most `K` stored elements — `K·d` f32s —
//! regardless of stream length), multiplexed behind a dependency-free
//! newline-delimited TCP protocol.
//!
//! * [`sessions::SessionManager`] — tenant map, admission control, LRU
//!   idle eviction with atomic checkpoint persistence, bit-identical
//!   resume on re-`OPEN`, service-wide metrics.
//! * [`protocol`] — the typed line protocol (`OPEN` / `PUSH` / `SUMMARY` /
//!   `STATS` / `CLOSE` / `METRICS`), CSV or base64-packed f32 rows, `ERR`
//!   replies with machine-readable codes. Grammar: `docs/protocol.md`.
//! * [`server`] — std-only `TcpListener` accept loop dispatching
//!   connections onto the [`exec`](crate::exec) worker pool, graceful
//!   shutdown, plus the in-process [`server::Client`].
//!
//! Wire-level floats use shortest-roundtrip formatting, so summaries and
//! values cross the network **bit-identically** — the integration suite
//! (`rust/tests/service_integration.rs`) compares TCP tenants against
//! standalone runs with exact equality.

pub mod protocol;
pub mod server;
pub mod sessions;

pub use protocol::{
    ErrorCode, MetricsSnapshot, PushBody, PushReply, Request, Response, SessionSpec, StatsReply,
    SummaryReply, WatchFrame, WatchMode,
};
pub use server::{Client, ClientError, RetryPolicy, Server, ServerHandle};
pub use sessions::{ServiceError, SessionManager};
