//! `threesieves` CLI — the leader entrypoint.
//!
//! ```text
//! threesieves summarize --dataset <name> --n <N> --k <K> [--algo three-sieves] [--t 1000]
//! threesieves experiment <table1|table2|fig1|fig2|fig3> [--n N] [--out DIR] [--quick]
//! threesieves serve --dataset <name> --n <N> --k <K> [--drift-window W] [--checkpoint PATH]
//! threesieves pjrt-info [--artifacts DIR]
//! ```
//!
//! Argument parsing is hand-rolled (`clap` is not vendored in this image);
//! see `cli::Args` for the tiny flag grammar.

use std::path::PathBuf;
use std::process::ExitCode;

use threesieves::config::AlgoSpec;
use threesieves::coordinator::{MeanShiftDetector, NoDrift, PipelineConfig, StreamPipeline};
use threesieves::data::registry;
use threesieves::exec::{ExecContext, Parallelism};
use threesieves::experiments::figures::{self, SweepScale};
use threesieves::experiments::runner::{run_batch_protocol_chunked, run_stream_protocol_chunked};
use threesieves::experiments::GammaMode;
use threesieves::experiments::{table1, table2};

mod cli {
    //! Minimal `--flag value` argument parser.
    use std::collections::BTreeMap;

    pub struct Args {
        pub positional: Vec<String>,
        flags: BTreeMap<String, String>,
    }

    impl Args {
        pub fn parse(argv: &[String]) -> Result<Self, String> {
            let mut positional = Vec::new();
            let mut flags = BTreeMap::new();
            let mut i = 0;
            while i < argv.len() {
                let a = &argv[i];
                if let Some(name) = a.strip_prefix("--") {
                    if let Some((k, v)) = name.split_once('=') {
                        flags.insert(k.to_string(), v.to_string());
                    } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                        flags.insert(name.to_string(), argv[i + 1].clone());
                        i += 1;
                    } else {
                        flags.insert(name.to_string(), "true".to_string());
                    }
                } else {
                    positional.push(a.clone());
                }
                i += 1;
            }
            Ok(Args { positional, flags })
        }

        pub fn get(&self, name: &str) -> Option<&str> {
            self.flags.get(name).map(|s| s.as_str())
        }

        pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
            match self.get(name) {
                None => Ok(default),
                Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
            }
        }

        pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
            match self.get(name) {
                None => Ok(default),
                Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
            }
        }

        pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
            match self.get(name) {
                None => Ok(default),
                Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
            }
        }

        pub fn has(&self, name: &str) -> bool {
            self.flags.contains_key(name)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        fn parse(s: &str) -> Args {
            let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
            Args::parse(&argv).unwrap()
        }

        #[test]
        fn positional_and_flags() {
            let a = parse("experiment fig1 --n 500 --out results --quick");
            assert_eq!(a.positional, vec!["experiment", "fig1"]);
            assert_eq!(a.get("n"), Some("500"));
            assert_eq!(a.get("out"), Some("results"));
            assert!(a.has("quick"));
            assert!(!a.has("nope"));
        }

        #[test]
        fn equals_syntax() {
            let a = parse("run --k=20 --epsilon=0.01");
            assert_eq!(a.get_usize("k", 0).unwrap(), 20);
            assert!((a.get_f64("epsilon", 0.0).unwrap() - 0.01).abs() < 1e-12);
        }

        #[test]
        fn defaults_apply() {
            let a = parse("run");
            assert_eq!(a.get_usize("n", 77).unwrap(), 77);
            assert_eq!(a.get_u64("seed", 9).unwrap(), 9);
        }

        #[test]
        fn bad_numbers_error() {
            let a = parse("run --n abc");
            assert!(a.get_usize("n", 0).is_err());
        }

        #[test]
        fn boolean_flag_before_flag() {
            // --quick followed by another flag must not eat it as a value.
            let a = parse("x --quick --n 5");
            assert!(a.has("quick"));
            assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        }
    }
}

const USAGE: &str = "\
threesieves — streaming submodular function maximization (ThreeSieves)

USAGE:
  threesieves summarize --dataset <name> --n <N> --k <K>
                        [--algo <id>] [--epsilon E] [--t T] [--seed S] [--batch]
                        [--batch-size B] [--threads off|auto|N]
  threesieves experiment <table1|table2|fig1|fig2|fig3|ablations> [--n N] [--out DIR] [--quick]
  threesieves experiment custom --config <file.json> [--stream]
  threesieves serve     --dataset <name> --n <N> --k <K>
                        [--drift-window W] [--drift-threshold X] [--checkpoint PATH]
                        [--batch-size B] [--threads off|auto|N]
  threesieves pjrt-info [--artifacts DIR] [--config NAME]
  threesieves datasets

Algorithms (--algo): greedy | random | isi | stream-greedy | preemption |
  sieve-streaming | sieve-streaming-pp | salsa | quickstream |
  sharded-three-sieves [--shards P] | three-sieves (default)

--threads fans shard/sieve work out across a worker pool (pair with
--batch-size); summaries, values and query counts are identical at every
thread count.
";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: &[String]) -> Result<(), String> {
    let args = cli::Args::parse(argv)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "summarize" => cmd_summarize(&args),
        "experiment" => cmd_experiment(&args),
        "serve" => cmd_serve(&args),
        "pjrt-info" => cmd_pjrt_info(&args),
        "datasets" => {
            for row in table2::rows() {
                println!("{row}");
            }
            if args.has("stats") {
                println!("\nkernel diagnostics (streaming gamma, 2000 rows, 4000 pairs):");
                for info in registry::REGISTRY {
                    let ds = registry::get(info.name, 2_000, 7).unwrap();
                    let diag = threesieves::data::stats::diagnose(
                        &ds,
                        info.dim as f64 / 2.0,
                        4_000,
                        1,
                    );
                    println!("{}", diag.to_row(info.name));
                }
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}")),
    }
}

fn algo_spec(args: &cli::Args) -> Result<AlgoSpec, String> {
    let eps = args.get_f64("epsilon", 0.001)?;
    let t = args.get_usize("t", 1000)?;
    let seed = args.get_u64("seed", 42)?;
    Ok(match args.get("algo").unwrap_or("three-sieves") {
        "greedy" => AlgoSpec::Greedy,
        "random" => AlgoSpec::Random { seed },
        "isi" => AlgoSpec::IndependentSetImprovement,
        "stream-greedy" => AlgoSpec::StreamGreedy { nu: args.get_f64("nu", 1e-4)? },
        "preemption" => AlgoSpec::Preemption,
        "sieve-streaming" => AlgoSpec::SieveStreaming { epsilon: eps },
        "sieve-streaming-pp" => AlgoSpec::SieveStreamingPP { epsilon: eps },
        "salsa" => AlgoSpec::Salsa { epsilon: eps, use_length_hint: true },
        "quickstream" => {
            AlgoSpec::QuickStream { c: args.get_usize("c", 2)?, epsilon: eps, seed }
        }
        "three-sieves" => AlgoSpec::ThreeSieves { epsilon: eps, t },
        "sharded-three-sieves" => AlgoSpec::ShardedThreeSieves {
            epsilon: eps,
            t,
            shards: args.get_usize("shards", 4)?.max(1),
        },
        other => return Err(format!("unknown algorithm {other:?}")),
    })
}

/// Parse `--threads off|auto|N` (default off).
fn parallelism_arg(args: &cli::Args) -> Result<Parallelism, String> {
    match args.get("threads") {
        None => Ok(Parallelism::Off),
        Some(v) => Parallelism::parse(v),
    }
}

fn cmd_summarize(args: &cli::Args) -> Result<(), String> {
    let dataset = args.get("dataset").ok_or("--dataset required")?.to_string();
    let n = args.get_usize("n", 10_000)?;
    let k = args.get_usize("k", 20)?;
    let seed = args.get_u64("seed", 42)?;
    let spec = algo_spec(args)?;
    let mode = if args.has("batch") { GammaMode::Batch } else { GammaMode::Streaming };
    // Chunked ingestion width (1 = per-item). Semantics-preserving; larger
    // chunks amortize the oracle's kernel work (see process_batch).
    let batch_size = args.get_usize("batch-size", 1)?.max(1);
    // Shard/sieve fan-out pool; results are identical at every setting.
    let exec = ExecContext::new(parallelism_arg(args)?);

    let rec = if args.has("batch") {
        let ds = registry::get(&dataset, n, seed)
            .ok_or_else(|| format!("unknown dataset {dataset:?}"))?;
        run_batch_protocol_chunked(&spec, &ds, k, mode, 1.0, batch_size, &exec)
    } else {
        let mut src = registry::source(&dataset, n, seed)
            .ok_or_else(|| format!("unknown dataset {dataset:?}"))?;
        run_stream_protocol_chunked(&spec, src.as_mut(), &dataset, k, mode, 1.0, batch_size, &exec)
    };
    println!("algorithm      : {}", rec.algorithm);
    println!(
        "dataset        : {} (n={n}, dim={})",
        rec.dataset,
        registry::info(&dataset).map(|i| i.dim).unwrap_or(0)
    );
    println!("f(S)           : {:.6}", rec.value);
    println!("summary size   : {}/{}", rec.summary_size, k);
    println!("runtime        : {:.3}s", rec.runtime.as_secs_f64());
    println!(
        "oracle queries : {} ({:.2}/element)",
        rec.stats.queries,
        rec.stats.queries_per_element()
    );
    println!("peak memory    : {} stored elements", rec.stats.peak_stored);
    Ok(())
}

fn cmd_experiment(args: &cli::Args) -> Result<(), String> {
    let which = args.positional.get(1).map(|s| s.as_str()).ok_or("experiment name required")?;
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    let quick = args.has("quick");
    let n = args.get_usize("n", if quick { 1_000 } else { 5_000 })?;
    let seed = args.get_u64("seed", 42)?;
    let scale = SweepScale { n, seed };
    let ks: Vec<usize> =
        if quick { vec![5, 10, 20] } else { vec![5, 10, 20, 30, 40, 50, 75, 100] };
    match which {
        "table1" => {
            table1::run(&out, n, args.get_usize("k", 20)?, seed).map_err(|e| e.to_string())?;
        }
        "table2" | "datasets" => {
            for row in table2::rows() {
                println!("{row}");
            }
        }
        "fig1" => {
            figures::fig1(&out, scale).map_err(|e| e.to_string())?;
        }
        "fig2" => {
            figures::fig2(&out, scale, &ks).map_err(|e| e.to_string())?;
        }
        "fig3" => {
            figures::fig3(&out, scale, &ks).map_err(|e| e.to_string())?;
        }
        "ablations" => {
            threesieves::experiments::ablations::run_all(&out, n, seed)
                .map_err(|e| e.to_string())?;
        }
        "custom" => {
            let path = args.get("config").ok_or("--config <file.json> required")?;
            let cfg = threesieves::config::ExperimentConfig::load(std::path::Path::new(path))?;
            threesieves::experiments::custom::run(&cfg, args.has("stream"))
                .map_err(|e| e.to_string())?;
        }
        other => return Err(format!("unknown experiment {other:?}")),
    }
    println!("results written under {}", out.display());
    Ok(())
}

fn cmd_serve(args: &cli::Args) -> Result<(), String> {
    let dataset = args.get("dataset").ok_or("--dataset required")?.to_string();
    let n = args.get_usize("n", 50_000)?;
    let k = args.get_usize("k", 20)?;
    let seed = args.get_u64("seed", 42)?;
    let window = args.get_usize("drift-window", 500)?;
    let threshold = args.get_f64("drift-threshold", 3.0)?;
    let info = registry::info(&dataset).ok_or_else(|| format!("unknown dataset {dataset:?}"))?;
    let src = registry::source(&dataset, n, seed).unwrap();

    let spec = algo_spec(args)?;
    let mut algo =
        threesieves::experiments::build_algo(&spec, info.dim, k, GammaMode::Streaming, Some(n));

    let cfg = PipelineConfig {
        channel_capacity: args.get_usize("channel", 1024)?,
        // Serving defaults to chunked ingestion: 64-item chunks amortize
        // the oracle's kernel work with identical selection semantics.
        batch_size: args.get_usize("batch-size", 64)?.max(1),
        checkpoint_every: args.get_u64("checkpoint-every", 0)?,
        checkpoint_path: args.get("checkpoint").map(PathBuf::from),
        reselect_on_drift: !args.has("no-reselect"),
        parallelism: parallelism_arg(args)?,
    };
    let pipeline = StreamPipeline::new(cfg);
    let report = if args.has("no-drift") {
        let mut det = NoDrift::default();
        pipeline.run(src, algo.as_mut(), &mut det)
    } else {
        let mut det = MeanShiftDetector::new(info.dim, window, threshold);
        pipeline.run(src, algo.as_mut(), &mut det)
    }
    .map_err(|e| e.to_string())?;

    println!("items          : {}", report.items);
    println!("throughput     : {:.0} items/s", report.throughput);
    println!("drift events   : {}", report.drift_events);
    println!("re-selections  : {}", report.reselections);
    println!("checkpoints    : {}", report.checkpoints_written);
    println!("backpressure   : {} blocked sends", report.backpressure_hits);
    println!("final f(S)     : {:.6} ({} elements)", report.final_value, report.final_summary_len);
    Ok(())
}

fn cmd_pjrt_info(args: &cli::Args) -> Result<(), String> {
    use threesieves::functions::SubmodularFunction;
    use threesieves::runtime::{Engine, Manifest, PjrtLogDet};
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    // The manifest parser is dependency-free, so artifact listing works
    // even when the PJRT engine is stubbed out (default build).
    match Engine::cpu() {
        Ok(engine) => println!("PJRT platform: {}", engine.platform()),
        Err(e) => println!("PJRT engine unavailable ({e}); listing artifacts only"),
    }
    let manifest = Manifest::load(&dir).map_err(|e| e.to_string())?;
    println!("artifact configs in {}:", dir.display());
    for c in &manifest.configs {
        println!(
            "  {:<18} d={:<4} K={:<4} B={:<4} gamma={:<8} files={}",
            c.name,
            c.d,
            c.k,
            c.b,
            c.gamma,
            c.files.len()
        );
    }
    if let Some(name) = args.get("config") {
        let mut oracle = PjrtLogDet::from_artifacts(&dir, name).map_err(|e| e.to_string())?;
        let d = oracle.dim();
        let probe = vec![0.25f32; d];
        let g = oracle.peek_gain(&probe);
        println!("smoke: gain(0.25·1; ∅) = {g:.6} (expect ½·ln 2 = {:.6})", 0.5f64 * 2f64.ln());
    }
    Ok(())
}
